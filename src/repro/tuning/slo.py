"""The SLO contract and the offline planner.

:class:`SLOTarget` is the tenant's service-level objective — a frozen,
hashable config validated at construction exactly like ``SolveConfig``
(it pins sessions and keys nothing silently).  :func:`plan_for_slo`
interpolates a :class:`~repro.tuning.profile.TuningProfile`'s measured
curves and picks the cheapest ``SolveConfig`` whose predicted quality
loss and step latency meet the SLO.  Candidate k values are powers of
two, so a fleet of tuned tenants grows the jit cache O(log k_max), and —
per the granular-POP follow-up (arXiv 2110.11927) — a deadline that the
quality-feasible k cannot meet escalates **replication of hot entities**
at a larger k before it surrenders quality by shrinking the partition.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ..core.config import ExecConfig, SolveConfig, validate_cache_key
from .profile import DomainCurves, TuningProfile

__all__ = ["SLOTarget", "TunedPlan", "plan_for_slo", "quality_loss_at",
           "latency_at", "launch_defaults"]


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """A tenant's service-level objective.

    ``max_quality_loss`` bounds the relative quality loss vs the k=1 full
    solve (0.02 = "within 2% of optimal"); ``step_deadline_s``, when set,
    bounds a step's wall time (the online refiner shares the degradation
    ladder's measured rate model to enforce it).  Frozen + hashable so a
    session can pin it like its configs."""

    max_quality_loss: float = 0.02
    step_deadline_s: Optional[float] = None

    def __post_init__(self):
        mql = self.max_quality_loss
        if not isinstance(mql, (int, float)) or not 0.0 <= mql < 1.0:
            raise ValueError("max_quality_loss must be in [0, 1), got "
                             f"{mql!r}")
        if self.step_deadline_s is not None and self.step_deadline_s <= 0:
            raise ValueError("step_deadline_s must be positive or None, "
                             f"got {self.step_deadline_s!r}")
        validate_cache_key(self)


@dataclasses.dataclass(frozen=True)
class TunedPlan:
    """What the planner chose and why: the configs plus the predictions
    the choice was made on (``source``: ``"curves"`` — quality-feasible
    pick, ``"replicated"`` — deadline met by escalating replication,
    ``"deadline-limited"`` — deadline forced a quality-infeasible k,
    ``"no-curves"`` — profile has no curves for the domain)."""

    solve: SolveConfig
    exec: ExecConfig
    predicted_quality_loss: float = 0.0
    predicted_step_s: Optional[float] = None
    source: str = "curves"


def _interp_log2(rows, k: float, col: int) -> Optional[float]:
    """Piecewise-linear interpolation in log2(k) over curve rows sorted by
    k; extrapolates from the last segment's slope beyond the support."""
    pts = sorted((float(r[0]), float(r[col])) for r in rows)
    if not pts:
        return None
    xs = [math.log2(x) for x, _ in pts]
    ys = [y for _, y in pts]
    x = math.log2(max(k, 1.0))
    if len(pts) == 1 or x <= xs[0]:
        return ys[0]
    for i in range(1, len(xs)):
        if x <= xs[i] or i == len(xs) - 1:
            x0, x1, y0, y1 = xs[i - 1], xs[i], ys[i - 1], ys[i]
            if x1 == x0:
                return y1
            return y0 + (y1 - y0) * (x - x0) / (x1 - x0)
    return ys[-1]


def quality_loss_at(curves: DomainCurves, k: int) -> float:
    """Predicted relative quality loss at k (0 = lossless), clamped to
    [0, 1]."""
    if k <= 1:
        return 0.0
    rel = _interp_log2(curves.quality_vs_k, k, 1)
    if rel is None:
        return 0.0
    return float(min(max(1.0 - rel, 0.0), 1.0))


def latency_at(curves: DomainCurves, k: int,
               n_entities: Optional[int] = None) -> Optional[float]:
    """Predicted steady solve seconds at k, scaled from the probe size by
    the fitted exponent (``None`` when the curve has no latency rows)."""
    t = _interp_log2(curves.latency_vs_k, max(k, 1), 1)
    if t is None:
        return None
    if n_entities and curves.probe_n > 0:
        t *= (n_entities / curves.probe_n) ** curves.n_exponent
    return float(max(t, 0.0))


def _pow2_candidates(curves: DomainCurves, n_entities: int) -> list:
    """Power-of-two ks inside the measured support, clamped to the
    instance size (every sub-problem keeps >= 2 entities)."""
    max_k = max((int(r[0]) for r in curves.quality_vs_k), default=1)
    ks, k = [1], 2
    while k <= max_k and k * 2 <= max(n_entities, 2):
        ks.append(k)
        k *= 2
    return ks


def plan_for_slo(profile: TuningProfile, domain: str, n_entities: int,
                 slo: SLOTarget, base_solve: Optional[SolveConfig] = None,
                 base_exec: Optional[ExecConfig] = None) -> TunedPlan:
    """The cheapest config whose interpolated curves meet ``slo``.

    Among quality-feasible ks (predicted loss <= ``max_quality_loss``;
    k=1 is always feasible) the planner takes the lowest predicted
    latency.  If a ``step_deadline_s`` is set and that pick misses it, it
    first tries the profile's replication rows at larger k (recover
    quality by replicating hot entities — granular-POP — instead of
    giving it up), then falls back to the deadline-meeting k with the
    least quality loss."""
    base_solve = base_solve or SolveConfig()
    base_exec = base_exec or ExecConfig()
    curves = profile.domains.get(domain)
    if curves is None or not curves.quality_vs_k:
        return TunedPlan(solve=base_solve, exec=base_exec,
                         source="no-curves")

    def mk(k: int, thr: Optional[float] = None) -> SolveConfig:
        # min_per_sub dropped: the planner already clamps k to the size
        return SolveConfig(k=k, strategy=base_solve.strategy,
                           seed=base_solve.seed, replicate_threshold=thr)

    ks = _pow2_candidates(curves, n_entities)
    pred = {k: (quality_loss_at(curves, k),
                latency_at(curves, k, n_entities)) for k in ks}
    feasible = [k for k in ks if pred[k][0] <= slo.max_quality_loss + 1e-12]
    best = min(feasible,
               key=lambda k: (pred[k][1] if pred[k][1] is not None
                              else float("inf"), -k))
    loss, lat = pred[best]
    deadline = slo.step_deadline_s
    if deadline is None or lat is None or lat <= deadline:
        return TunedPlan(solve=mk(best), exec=base_exec,
                         predicted_quality_loss=loss, predicted_step_s=lat)

    # quality-feasible pick misses the deadline: escalate replication at
    # larger k before shrinking quality
    rep_rows = []
    for k, thr, rel, solve_s in curves.replication:
        t = solve_s
        if n_entities and curves.probe_n > 0:
            t *= (n_entities / curves.probe_n) ** curves.n_exponent
        rep_rows.append((int(k), float(thr), 1.0 - float(rel), float(t)))
    rep_ok = [r for r in rep_rows
              if r[2] <= slo.max_quality_loss + 1e-12 and r[3] <= deadline]
    if rep_ok:
        k, thr, rloss, rt = min(rep_ok, key=lambda r: r[3])
        return TunedPlan(solve=mk(k, thr), exec=base_exec,
                         predicted_quality_loss=rloss, predicted_step_s=rt,
                         source="replicated")

    in_deadline = [k for k in ks
                   if pred[k][1] is not None and pred[k][1] <= deadline]
    pool = in_deadline or [max(ks)]
    k = min(pool, key=lambda k: (pred[k][0], pred[k][1] or 0.0))
    return TunedPlan(solve=mk(k), exec=base_exec,
                     predicted_quality_loss=pred[k][0],
                     predicted_step_s=pred[k][1], source="deadline-limited")


def launch_defaults(profile: TuningProfile) -> Optional[dict]:
    """``DispatchConfig`` defaults from the measured launch-cost line:
    the batching window is worth ~2 launch overheads of added latency,
    and a coalesced launch stops paying once its lane time dwarfs the
    overhead it amortizes.  Returns ``{"max_wait_ms", "max_lanes"}`` or
    ``None`` when the profile has no launch measurement."""
    lc = profile.launch_cost
    overhead = float(lc.get("overhead_s", 0.0) or 0.0)
    per_lane = float(lc.get("per_lane_s", 0.0) or 0.0)
    if overhead <= 0.0:
        return None
    max_wait_ms = float(min(max(2.0 * overhead * 1e3, 0.5), 20.0))
    if per_lane > 0.0:
        lanes = int(overhead / per_lane) * 4
    else:
        lanes = 64
    lanes = max(8, min(lanes, 256))
    max_lanes = 1 << (lanes.bit_length() - 1)        # floor to a pow2
    return {"max_wait_ms": max_wait_ms, "max_lanes": max_lanes}
