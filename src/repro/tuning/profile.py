"""The measured substrate of the auto-tuner: the :class:`TuningProfile`
artifact and the offline profiler that builds it.

BENCH_pop.json shows the quality-vs-k tradeoff is sharply domain-dependent
(cluster scheduling holds 0.998 rel-quality at k=32 while traffic falls
0.95 @k=4 -> 0.53 @k=64), so no static ``SolveConfig`` default can serve
every tenant.  A profile records, per domain, the measured quality-vs-k
and latency-vs-k curves on a scaled-down probe instance (plus replication
recovery rows, the granular-POP follow-up's quality lever), the measured
launch-cost line of the micro-batch dispatcher, and the per-platform
vmap-vs-chunked crossover behind ``backend="auto"``.  The planner in
:mod:`repro.tuning.slo` interpolates these curves to pick the cheapest
config that meets an :class:`~repro.tuning.slo.SLOTarget`.

The artifact is **versioned and digest-sealed**: every consumer must pass
a loaded profile through :func:`check_profile` before reading curves from
it — the ``profile-staleness`` popcheck rule (docs/LINTS.md) flags scopes
that call :func:`load_profile` without a matching :func:`check_profile`.
``scripts/tune.py`` regenerates the committed ``TUNING_profile.json``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time as _time
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "PROFILE_VERSION", "ProfileError", "DomainCurves", "TuningProfile",
    "profile_digest", "save_profile", "load_profile", "check_profile",
    "build_profile",
]

PROFILE_VERSION = 1

# pow2 k sweep of the offline profiler (clamped per probe size)
_SWEEP_KS = (2, 4, 8, 16, 32)


class ProfileError(ValueError):
    """A TuningProfile failed validation (version, digest, platform)."""


@dataclasses.dataclass
class DomainCurves:
    """One domain's measured tradeoff curves, at ``probe_n`` entities.

    ``quality_vs_k`` rows are ``(k, rel_quality)`` with rel_quality the
    domain quality scalar at k over the k=1 full solve (1.0 = lossless);
    ``latency_vs_k`` rows are ``(k, solve_s, iters)`` at the probe size;
    ``n_exponent`` scales latency to other instance sizes
    (``t(n) ~ t(probe_n) * (n/probe_n)**n_exponent``); ``replication``
    rows are ``(k, threshold, rel_quality, solve_s)`` — quality recovery
    from §4.3 hot-entity replication at the same k."""

    probe_n: int
    quality_vs_k: Tuple[Tuple[float, float], ...] = ()
    latency_vs_k: Tuple[Tuple[float, float, float], ...] = ()
    n_exponent: float = 1.0
    replication: Tuple[Tuple[float, float, float, float], ...] = ()


@dataclasses.dataclass
class TuningProfile:
    """The versioned, digest-sealed measurement artifact.

    ``backend_thresholds`` maps a JAX platform name (``"cpu"`` / ``"gpu"``
    / ``"tpu"``) to measured ``backend="auto"`` selection thresholds
    (``{"vmap_max_k": ..., "vmap_max_elems": ...}``) —
    ``repro.core.backends`` consults them when a profile is installed and
    falls back to its constants otherwise.  ``launch_cost`` is the fitted
    dispatcher launch-cost line ``{"overhead_s": ..., "per_lane_s": ...}``
    that sizes ``DispatchConfig`` defaults."""

    version: int
    platform: str
    device_count: int
    jax_version: str
    created: str
    domains: Dict[str, DomainCurves] = dataclasses.field(default_factory=dict)
    backend_thresholds: Dict[str, dict] = dataclasses.field(
        default_factory=dict)
    launch_cost: Dict[str, float] = dataclasses.field(default_factory=dict)
    digest: str = ""


# --------------------------------------------------------------------------
# (de)serialization + the digest seal
# --------------------------------------------------------------------------

def _to_json(profile: TuningProfile) -> dict:
    obj = dataclasses.asdict(profile)
    obj["domains"] = {name: dataclasses.asdict(c) if
                      isinstance(c, DomainCurves) else dict(c)
                      for name, c in profile.domains.items()}
    return obj


def _from_json(obj: dict) -> TuningProfile:
    """Parse WITHOUT validating — :func:`check_profile` is the gate."""
    domains = {}
    for name, c in (obj.get("domains") or {}).items():
        domains[name] = DomainCurves(
            probe_n=int(c["probe_n"]),
            quality_vs_k=tuple(tuple(r) for r in c.get("quality_vs_k", ())),
            latency_vs_k=tuple(tuple(r) for r in c.get("latency_vs_k", ())),
            n_exponent=float(c.get("n_exponent", 1.0)),
            replication=tuple(tuple(r) for r in c.get("replication", ())))
    return TuningProfile(
        version=int(obj.get("version", -1)),
        platform=str(obj.get("platform", "")),
        device_count=int(obj.get("device_count", 1)),
        jax_version=str(obj.get("jax_version", "")),
        created=str(obj.get("created", "")),
        domains=domains,
        backend_thresholds=dict(obj.get("backend_thresholds") or {}),
        launch_cost=dict(obj.get("launch_cost") or {}),
        digest=str(obj.get("digest", "")))


def profile_digest(profile: TuningProfile) -> str:
    """sha256 over the canonical JSON rendering, digest field excluded —
    the seal :func:`check_profile` verifies."""
    obj = _to_json(profile)
    obj.pop("digest", None)
    blob = json.dumps(obj, sort_keys=True, separators=(",", ":"))
    return "sha256:" + hashlib.sha256(blob.encode("utf-8")).hexdigest()


def save_profile(profile: TuningProfile, path: Union[str, Path]) -> Path:
    """Seal (stamp the digest) and write the artifact as JSON."""
    profile.digest = profile_digest(profile)
    p = Path(path)
    p.write_text(json.dumps(_to_json(profile), indent=2, sort_keys=True)
                 + "\n")
    return p


def load_profile(path: Union[str, Path]) -> TuningProfile:
    """Read + parse a profile.  Does NOT validate: pass the result through
    :func:`check_profile` before reading curves (the ``profile-staleness``
    popcheck rule enforces the pairing)."""
    try:
        obj = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ProfileError(f"cannot read tuning profile {path}: "
                           f"{type(e).__name__}: {e}") from e
    if not isinstance(obj, dict):
        raise ProfileError(f"tuning profile {path} is not a JSON object")
    return _from_json(obj)


def check_profile(profile: TuningProfile,
                  platform: Optional[str] = None) -> TuningProfile:
    """Validate a profile's version and digest seal (and, when
    ``platform`` is given, that it was measured on that platform).
    Returns the profile unchanged so it chains:
    ``check_profile(load_profile(p))``.  Raises :class:`ProfileError`."""
    if profile.version != PROFILE_VERSION:
        raise ProfileError(
            f"tuning profile version {profile.version} != supported "
            f"{PROFILE_VERSION} — regenerate with scripts/tune.py")
    want = profile_digest(profile)
    if not profile.digest:
        raise ProfileError("tuning profile carries no digest seal — "
                           "regenerate with scripts/tune.py")
    if profile.digest != want:
        raise ProfileError(
            f"tuning profile digest mismatch ({profile.digest[:23]}... != "
            f"{want[:23]}...) — the artifact was edited after sealing")
    if platform is not None and profile.platform != platform:
        raise ProfileError(
            f"tuning profile was measured on {profile.platform!r}, "
            f"running on {platform!r} — latency curves do not transfer; "
            "regenerate with scripts/tune.py")
    return profile


# --------------------------------------------------------------------------
# the offline profiler
# --------------------------------------------------------------------------

def _probe_instances(fast: bool, seed: int) -> Dict[str, tuple]:
    """Per-domain (full-size probe, half-size probe) instance pairs.
    Imports stay local so ``import repro.tuning`` is light."""
    from ..domains.gavel import GavelInstance
    from ..domains.moe_placement import make_placement_instance
    from ..problems.cluster_scheduling import make_cluster_workload
    from ..problems.traffic_engineering import (k_shortest_paths,
                                                make_demands, make_topology)

    def traffic(n):
        topo = make_topology(20, 40, seed=seed)
        pairs, dem = make_demands(topo, n, seed=seed)
        pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
        from ..problems.traffic_engineering import TrafficProblem
        return TrafficProblem(topo, pairs, dem, pe)

    n_gavel = 64 if fast else 192
    n_traffic = 48 if fast else 160
    n_moe = 48 if fast else 128
    return {
        "gavel": (
            GavelInstance(make_cluster_workload(n_gavel, seed=seed)),
            GavelInstance(make_cluster_workload(n_gavel // 2, seed=seed))),
        "traffic": (traffic(n_traffic), traffic(n_traffic // 2)),
        "moe_placement": (
            make_placement_instance(n_moe, 6, seed=seed),
            make_placement_instance(n_moe // 2, 6, seed=seed)),
    }


def _alloc_quality(spec, inst, problem, alloc) -> Optional[float]:
    """The domain quality scalar for a raw solver allocation (through the
    domain's rounding hook first, like a session step would)."""
    a = alloc
    if spec.round is not None:
        a = spec.round(inst, alloc)
    return spec.quality_of(spec.metrics_of(inst, problem, a))


def _solve_timed(problem, solve_cfg, exec_cfg):
    """(result, steady seconds): warm up once (compilation), then report
    the better of two WALL-clock solves.  Wall — plan + build + solve —
    is what an SLO ``step_deadline_s`` is spent on; the solver-internal
    time alone hides the k-proportional host-side build cost that makes
    large k a net loss on small instances."""
    from ..core import pop as pop_mod

    def call():
        if solve_cfg is None or solve_cfg.k <= 1:
            return pop_mod.solve_full_ex(problem, exec_cfg=exec_cfg)
        return pop_mod.solve_instance(problem, solve_cfg, exec_cfg)

    call()                                    # compile warmup
    best, res = float("inf"), None
    for _ in range(2):
        t0 = _time.perf_counter()
        res = call()
        best = min(best, _time.perf_counter() - t0)
    return res, float(best)


def _profile_domain(name: str, inst, half_inst, *, fast: bool,
                    log=None) -> Optional[DomainCurves]:
    from ..core.config import ExecConfig, SolveConfig
    from ..domains import registry as registry_mod

    spec = registry_mod.get(name)
    if spec.step_override is not None:
        return None         # domain runs its own pipeline: no generic curves
    problem = spec.make_problem(inst)
    n = problem.n_entities
    kw = dict(spec.default_exec.solver_dict())
    kw["max_iters"] = min(int(kw.get("max_iters", 20_000)),
                          600 if fast else 4_000)
    exec_cfg = ExecConfig(backend=spec.default_exec.backend,
                          engine=spec.default_exec.engine, solver_kw=kw)
    # the k=1 reference must be CONVERGED (it anchors rel_quality=1.0);
    # give it the domain's full budget, capped well above the sweep's
    ref_kw = dict(kw)
    ref_kw["max_iters"] = min(
        int(spec.default_exec.solver_dict().get("max_iters", 20_000)),
        4_000 if fast else 20_000)
    ref_cfg = ExecConfig(backend=spec.default_exec.backend,
                         engine=spec.default_exec.engine, solver_kw=ref_kw)
    base = spec.default_solve

    full, _ = _solve_timed(problem, None, ref_cfg)
    q_full = _alloc_quality(spec, inst, problem, full.alloc)
    if q_full is None or q_full <= 0:
        return None         # no usable quality scalar: cannot build curves
    # the k=1 LATENCY row runs the same capped serving budget as the
    # sweep (apples-to-apples for the planner); only the quality
    # reference above needs the converged budget
    capped, full_s = _solve_timed(problem, None, exec_cfg)
    quality = [(1.0, 1.0)]
    latency = [(1.0, full_s,
                float(np.asarray(capped.res.iterations).max(initial=0)))]

    ks = [k for k in _SWEEP_KS if k * 2 <= n]
    for k in ks:
        scfg = SolveConfig(k=k, strategy=base.strategy, seed=base.seed)
        res, solve_s = _solve_timed(problem, scfg, exec_cfg)
        q = _alloc_quality(spec, inst, problem, res.alloc)
        rel = max(q / q_full, 0.0) if q is not None else 0.0
        quality.append((float(k), float(rel)))
        latency.append((float(k), solve_s,
                        float(np.asarray(res.iterations).max(initial=0))))
        if log:
            log(f"  {name}: k={k} rel_quality={rel:.4f} "
                f"solve_s={solve_s:.3f}")

    # replication recovery at the largest measured ks (granular-POP's
    # quality lever: replicate hot entities instead of shrinking k)
    replication = []
    for k in ks[-2:]:
        for thr in (0.5, 0.2):
            scfg = SolveConfig(k=k, strategy=base.strategy, seed=base.seed,
                               replicate_threshold=thr)
            try:
                res, solve_s = _solve_timed(problem, scfg, exec_cfg)
            except Exception:
                continue     # domain/shape rejects replication: no row
            q = _alloc_quality(spec, inst, problem, res.alloc)
            if q is None:
                continue
            replication.append((float(k), float(thr),
                                float(max(q / q_full, 0.0)), solve_s))

    # size scaling: same k on the half-size probe fits the latency exponent
    n_exponent = 1.0
    if ks:
        k_ref = ks[min(1, len(ks) - 1)]
        half_problem = spec.make_problem(half_inst)
        if half_problem.n_entities >= 2 * k_ref:
            scfg = SolveConfig(k=k_ref, strategy=base.strategy,
                               seed=base.seed)
            _, t_half = _solve_timed(half_problem, scfg, exec_cfg)
            t_ref = next(t for kk, t, _ in latency if kk == float(k_ref))
            if t_half > 0 and t_ref > 0:
                ratio = n / max(half_problem.n_entities, 1)
                n_exponent = float(np.clip(
                    np.log(t_ref / t_half) / np.log(ratio), 0.5, 2.5))

    return DomainCurves(probe_n=int(n), quality_vs_k=tuple(quality),
                        latency_vs_k=tuple(latency),
                        n_exponent=n_exponent,
                        replication=tuple(replication))


def _measure_launch_cost(fast: bool, seed: int) -> Dict[str, float]:
    """Fit wall = overhead + per_lane * lanes on tiny stacked dense
    solves — what sizes the dispatcher's batching window."""
    import jax
    import jax.numpy as jnp
    from ..core import backends as backends_mod, pdhg

    rng = np.random.default_rng(seed)
    n, mi = 24, 12
    kw = dict(max_iters=64, tol_primal=1e-3, tol_gap=1e-3)

    def stack(k):
        from ..core.pdhg import LinearProgram
        lps = []
        for _ in range(k):
            c = rng.normal(size=n)
            G = rng.normal(size=(mi, n))
            h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)
            lps.append(LinearProgram.build(c=c, G=G, h=h,
                                           l=np.zeros(n), u=np.ones(n)))
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[pdhg.dense_ops(lp) for lp in lps])

    solver = backends_mod.make_map_solver(pdhg.dense_K_mv, pdhg.dense_KT_mv,
                                          kw, "matvec")
    lanes = (1, 2, 4, 8) if fast else (1, 2, 4, 8, 16)
    rows = []
    for k in lanes:
        ops = stack(k)
        batch = (ops, *backends_mod.cold_start(ops))
        jax.block_until_ready(solver(batch).x)          # compile warmup
        best = float("inf")
        for _ in range(3):
            t0 = _time.perf_counter()
            jax.block_until_ready(solver(batch).x)
            best = min(best, _time.perf_counter() - t0)
        rows.append((float(k), best))
    xs = np.array([r[0] for r in rows])
    ys = np.array([r[1] for r in rows])
    per_lane, overhead = np.polyfit(xs, ys, 1)
    return {"overhead_s": float(max(overhead, 0.0)),
            "per_lane_s": float(max(per_lane, 0.0)),
            "rows": [[k, t] for k, t in rows]}


def _measure_backend_thresholds(fast: bool, seed: int) -> Dict[str, dict]:
    """Measured vmap-vs-chunked_vmap crossover on this platform: the
    largest lane count where plain vmap still wins.  The element ceiling
    is not probed (it guards peak memory, not speed) and keeps the
    constant."""
    import jax
    import jax.numpy as jnp
    from ..core import backends as backends_mod, pdhg

    rng = np.random.default_rng(seed)
    n, mi = 20, 10
    kw = dict(max_iters=48, tol_primal=1e-3, tol_gap=1e-3)

    def stack(k):
        from ..core.pdhg import LinearProgram
        lps = []
        for _ in range(k):
            c = rng.normal(size=n)
            G = rng.normal(size=(mi, n))
            h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)
            lps.append(LinearProgram.build(c=c, G=G, h=h,
                                           l=np.zeros(n), u=np.ones(n)))
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[pdhg.dense_ops(lp) for lp in lps])

    def timed(backend, batch):
        fn = backends_mod.get_backend(backend)
        jax.block_until_ready(
            fn(batch, pdhg.dense_K_mv, pdhg.dense_KT_mv, kw).x)
        best = float("inf")
        for _ in range(2):
            t0 = _time.perf_counter()
            jax.block_until_ready(
                fn(batch, pdhg.dense_K_mv, pdhg.dense_KT_mv, kw).x)
            best = min(best, _time.perf_counter() - t0)
        return best

    vmap_max_k = backends_mod.AUTO_VMAP_MAX_K
    kks = (16, 32, 64) if fast else (16, 32, 64, 128)
    winning = []
    for k in kks:
        ops = stack(k)
        batch = (ops, *backends_mod.cold_start(ops))
        t_v = timed("vmap", batch)
        t_c = timed("chunked_vmap", batch)
        if t_v <= t_c * 1.1:
            winning.append(k)
    if winning:
        vmap_max_k = max(winning)
    return {jax.default_backend(): {
        "vmap_max_k": int(vmap_max_k),
        "vmap_max_elems": int(backends_mod.AUTO_VMAP_MAX_ELEMS),
        "measured": True}}


def build_profile(domains: Sequence[str] = ("gavel", "traffic",
                                            "moe_placement"),
                  *, fast: bool = True, seed: int = 0,
                  measure_launch: bool = True,
                  measure_backends: bool = True,
                  log=None) -> TuningProfile:
    """Sweep (k, replication) per domain on scaled-down probes and return
    an unsealed profile (:func:`save_profile` stamps the digest).

    ``fast=True`` is the seconds-scale smoke build (``make tune-smoke``);
    ``fast=False`` grows probes ~3x for a steadier committed artifact."""
    import jax

    probes = _probe_instances(fast, seed)
    curves: Dict[str, DomainCurves] = {}
    for name in domains:
        pair = probes.get(name)
        if pair is None:
            continue
        if log:
            log(f"profiling domain {name} ...")
        c = _profile_domain(name, *pair, fast=fast, log=log)
        if c is not None:
            curves[name] = c
    profile = TuningProfile(
        version=PROFILE_VERSION,
        platform=jax.default_backend(),
        device_count=int(jax.device_count()),
        jax_version=jax.__version__,
        created=_time.strftime("%Y-%m-%dT%H:%M:%S", _time.gmtime()),
        domains=curves)
    if measure_launch:
        profile.launch_cost = _measure_launch_cost(fast, seed)
    if measure_backends:
        profile.backend_thresholds = _measure_backend_thresholds(fast, seed)
    return profile
