"""The online refiner: per-session curve estimates + SLO-driven retuning.

A tuned session (``PopService.session(tenant, instance, slo=...)``) owns
one :class:`OnlineTuner`.  Every fault-free step feeds the tuner the
:class:`~repro.service.Allocation`'s reported solve time and domain
quality scalar; the tuner EMA-updates its per-k estimates and **re-plans
only when the SLO is violated or newly slack** — never on noise:

* violations must persist ``patience`` consecutive steps before a move,
* every move is one power-of-two notch of k (jit-cache growth stays
  O(log) like the degradation ladder's budget quantization),
* after a move the tuner holds still for ``cooldown`` steps so the new
  operating point gets measured before it is judged,
* a quality violation first escalates replication at the current k (the
  granular-POP recovery) when the profile has rows for it, and only then
  shrinks k.

The session routes a retuned ``SolveConfig`` through the normal
``prepare_instance`` path, so the existing ``repair_plan``/``remap_warm``
machinery carries warm state across the k change — retuning never costs
a cold start.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.config import ExecConfig, SolveConfig
from .profile import TuningProfile
from .slo import SLOTarget, TunedPlan, latency_at, plan_for_slo, \
    quality_loss_at

__all__ = ["OnlineTuner", "TuneEvent"]

_EMA = 0.5


@dataclasses.dataclass
class TuneEvent:
    """What one observation decided: the violation recorded this step (if
    any) and the new config to apply from the next step (if retuned)."""

    violation: Optional[str] = None      # "latency" | "quality" | None
    new_solve: Optional[SolveConfig] = None


class OnlineTuner:
    """Per-session curve refinement + re-planning against one SLO."""

    def __init__(self, profile: Optional[TuningProfile], domain: str,
                 slo: SLOTarget, base_solve: SolveConfig,
                 base_exec: ExecConfig, *, patience: int = 2,
                 cooldown: int = 3):
        self.profile = profile
        self.domain = domain
        self.slo = slo
        self.base_solve = base_solve
        self.base_exec = base_exec
        self.patience = max(int(patience), 1)
        self.cooldown = max(int(cooldown), 0)
        self.plan: Optional[TunedPlan] = None
        self.solve_cfg: Optional[SolveConfig] = None
        self.n_entities: Optional[int] = None
        # online estimates, keyed by the k that actually ran
        self.lat_ema: dict = {}
        self.qual_ema: dict = {}
        self._hot = 0            # consecutive violated steps
        self._slack = 0          # consecutive clearly-slack steps
        self._cool = 0           # steps left before the next move may fire

    # ---------------------------------------------------------- planning --
    def plan_initial(self, n_entities: int) -> SolveConfig:
        """The offline pick for this instance size (identity when the
        profile carries no curves for the domain)."""
        self.n_entities = int(n_entities)
        if self.profile is not None:
            self.plan = plan_for_slo(self.profile, self.domain, n_entities,
                                     self.slo, self.base_solve,
                                     self.base_exec)
            self.solve_cfg = self.plan.solve
        else:
            self.solve_cfg = self.base_solve
        return self.solve_cfg

    def ensure_planned(self, n_entities: int,
                       current: SolveConfig) -> Optional[SolveConfig]:
        """First-step hook for sessions created without an instance:
        returns the planned config once, None after."""
        if self.solve_cfg is not None:
            return None
        cfg = self.plan_initial(n_entities)
        return cfg if cfg != current else None

    # ------------------------------------------------------- observation --
    def observe(self, k: int, solve_time_s: float,
                quality: Optional[float]) -> TuneEvent:
        """Fold one fault-free step's measurements in; decide whether to
        move.  Returns the step's :class:`TuneEvent`."""
        k = max(int(k), 1)
        if solve_time_s > 0.0:
            old = self.lat_ema.get(k)
            self.lat_ema[k] = (solve_time_s if old is None
                               else (1 - _EMA) * old + _EMA * solve_time_s)
        if quality is not None and quality > 0.0:
            old = self.qual_ema.get(k)
            self.qual_ema[k] = (quality if old is None
                                else (1 - _EMA) * old + _EMA * quality)
        if self._cool > 0:
            self._cool -= 1

        violation = self._violation(k)
        ev = TuneEvent(violation=violation)
        if violation is not None:
            self._hot += 1
            self._slack = 0
            if self._hot >= self.patience and self._cool == 0:
                ev.new_solve = self._move(k, violation)
        else:
            self._hot = 0
            if self._newly_slack(k):
                self._slack += 1
                if self._slack >= self.patience and self._cool == 0:
                    ev.new_solve = self._move(k, "slack")
            else:
                self._slack = 0
        if ev.new_solve is not None:
            self._hot = self._slack = 0
            self._cool = self.cooldown
            self.solve_cfg = ev.new_solve
        return ev

    # ---------------------------------------------------------- decisions --
    def _violation(self, k: int) -> Optional[str]:
        dl = self.slo.step_deadline_s
        lat = self.lat_ema.get(k)
        if dl is not None and lat is not None and lat > dl:
            return "latency"
        loss = self._observed_loss(k)
        if loss is not None and loss > self.slo.max_quality_loss + 1e-9:
            return "quality"
        return None

    def _observed_loss(self, k: int) -> Optional[float]:
        """Estimated relative quality loss at k vs the best quality this
        session has observed at any SMALLER k (smaller k = closer to the
        full solve; comparing against larger k would read improvement as
        loss)."""
        q = self.qual_ema.get(k)
        if q is None:
            return None
        ref = max((v for kk, v in self.qual_ema.items() if kk < k),
                  default=None)
        if ref is None or ref <= 0.0:
            return None
        return max(1.0 - q / ref, 0.0)

    def _newly_slack(self, k: int) -> bool:
        """A deadline-limited pick can step back toward quality once the
        measured latency shows the next-smaller k would comfortably fit:
        the curves' k->k/2 latency ratio applied to the measured EMA must
        stay under 80% of the deadline."""
        dl = self.slo.step_deadline_s
        if dl is None or k <= 1 or self.profile is None:
            return False
        if self.plan is None or self.plan.source not in ("deadline-limited",
                                                         "replicated"):
            return False
        if quality_loss_at_or_zero(self.profile, self.domain, k) <= \
                self.slo.max_quality_loss:
            return False                   # current k already loses nothing
        lat = self.lat_ema.get(k)
        curves = self.profile.domains.get(self.domain)
        if lat is None or curves is None:
            return False
        t_k = latency_at(curves, k, self.n_entities)
        t_half = latency_at(curves, k // 2, self.n_entities)
        if not t_k or t_half is None:
            return False
        return lat * (t_half / t_k) <= 0.8 * dl

    def _move(self, k: int, why: str) -> Optional[SolveConfig]:
        """One pow2 notch in the direction ``why`` demands; None when the
        move is impossible (already at the edge)."""
        cur = self.solve_cfg or self.base_solve
        if why == "latency":
            new_k = k * 2
            if self.n_entities is not None:
                if new_k * 2 > max(self.n_entities, 2):
                    return None
                cand = dataclasses.replace(cur, k=new_k)
                # min_per_sub clamping can void the move: don't churn the
                # config (and the retune counter) for an unchanged split
                if cand.k_for(self.n_entities) == \
                        cur.k_for(self.n_entities):
                    return None
                return cand
            return dataclasses.replace(cur, k=new_k)
        # quality violated (or slack): first try replication at this k,
        # then halve
        if why == "quality" and self.profile is not None \
                and cur.replicate_threshold is None:
            curves = self.profile.domains.get(self.domain)
            rows = [r for r in (curves.replication if curves else ())
                    if int(r[0]) == k
                    and 1.0 - r[2] <= self.slo.max_quality_loss + 1e-12]
            if rows:
                thr = min(rows, key=lambda r: 1.0 - r[2])[1]
                return dataclasses.replace(cur, replicate_threshold=thr)
        if k <= 1:
            return None
        return dataclasses.replace(cur, k=k // 2, replicate_threshold=None)


def quality_loss_at_or_zero(profile: TuningProfile, domain: str,
                            k: int) -> float:
    curves = profile.domains.get(domain)
    return 0.0 if curves is None else quality_loss_at(curves, k)
