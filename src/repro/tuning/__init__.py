"""SLO-driven auto-tuning: measured curves choose the POP configuration.

Three layers (docs/TUNING.md):

* :mod:`repro.tuning.profile` — the **offline profiler**: sweep
  (k, replication, backend, lanes) per domain on scaled-down probes and
  seal the measurements into a versioned :class:`TuningProfile` artifact
  (``scripts/tune.py`` writes the committed ``TUNING_profile.json``).
  Every consumer validates with :func:`check_profile` — the
  ``profile-staleness`` popcheck rule flags unchecked reads.
* :mod:`repro.tuning.slo` — the **SLO contract**: frozen, hashable
  :class:`SLOTarget` plus :func:`plan_for_slo`, the planner that picks
  the cheapest config whose interpolated curves meet the SLO (escalating
  hot-entity replication before shrinking k, per granular-POP).
* :mod:`repro.tuning.online` — the **online refiner**
  (:class:`OnlineTuner`): per-session EMA curve estimates from each
  step's reported solve time/quality, re-planning only on violated or
  newly-slack SLOs, in power-of-two k moves routed through the plan
  repair path so warm state survives.

Entry point: ``PopService(profile=...).session(tenant, instance,
slo=SLOTarget(max_quality_loss=0.02))``.
"""

from __future__ import annotations

from .online import OnlineTuner, TuneEvent  # noqa: F401
from .profile import (  # noqa: F401
    PROFILE_VERSION,
    DomainCurves,
    ProfileError,
    TuningProfile,
    build_profile,
    check_profile,
    load_profile,
    profile_digest,
    save_profile,
)
from .slo import (  # noqa: F401
    SLOTarget,
    TunedPlan,
    latency_at,
    launch_defaults,
    plan_for_slo,
    quality_loss_at,
)

__all__ = [
    "PROFILE_VERSION",
    "TuningProfile",
    "DomainCurves",
    "ProfileError",
    "build_profile",
    "save_profile",
    "load_profile",
    "check_profile",
    "profile_digest",
    "SLOTarget",
    "TunedPlan",
    "plan_for_slo",
    "quality_loss_at",
    "latency_at",
    "launch_defaults",
    "OnlineTuner",
    "TuneEvent",
]
