"""Sharded checkpointing with atomic commits, async writes, and restart.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, extras
        arrays.npz           # one entry per leaf, path-keyed

Commit protocol: write into ``step_N.tmp``, fsync, rename to ``step_N`` —
a crashed writer never corrupts the latest checkpoint; ``latest()`` only
ever sees fully-committed directories.  ``save_async`` runs the gather +
serialisation off-thread so the train loop keeps stepping (fault-tolerance
requirement: checkpoint cadence must not gate step time).

Restores are sharding-aware: leaves are ``device_put`` against the target
mesh's NamedShardings, so a checkpoint taken on one mesh restores onto
another (elastic resize path).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                     for e in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


class Checkpointer:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, extras: Optional[dict] = None):
        keys, leaves, _ = _flatten(tree)
        arrays = {k: np.asarray(l) for k, l in zip(keys, leaves)}
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": keys,
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
            "extras": extras or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    def save_async(self, step: int, tree, extras: Optional[dict] = None):
        """Gather to host synchronously (cheap vs serialisation), write in
        the background.  Joins any in-flight write first (ordering)."""
        self.wait()
        keys, leaves, _ = _flatten(tree)
        host = {k: np.asarray(l) for k, l in zip(keys, leaves)}

        # snapshot gathered above; the thread only does serialisation + I/O
        def work():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step, "keys": keys,
                "shapes": {k: list(a.shape) for k, a in host.items()},
                "dtypes": {k: str(a.dtype) for k, a in host.items()},
                "extras": extras or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # --------------------------------------------------------------- restore
    def latest(self) -> Optional[int]:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_") and not d.endswith(".tmp")]
        return max(steps) if steps else None

    def restore(self, step: int, like_tree, mesh: Optional[Mesh] = None,
                shardings=None):
        """Restore into the structure of ``like_tree`` (shapes validated).
        With mesh+shardings, leaves are placed sharded (elastic restore)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(path, "arrays.npz"))
        keys, leaves, treedef = _flatten(like_tree)
        assert keys == manifest["keys"], "checkpoint/model structure mismatch"
        out = []
        flat_sh = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(keys))
        for k, proto, shd in zip(keys, leaves, flat_sh):
            a = arrays[k]
            assert tuple(a.shape) == tuple(proto.shape), (k, a.shape, proto.shape)
            out.append(jax.device_put(a, shd) if shd is not None
                       else jax.numpy.asarray(a))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extras"]
