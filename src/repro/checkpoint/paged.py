"""Host-memory page store for evicted tenant session state.

The serving layer (``repro.service``) keeps every resident tenant's warm
state — PopPlan + solver iterates — as live device arrays.  At fleet
scale that cannot hold: cold tenants must page out.  This store holds
each evicted tenant's state as ONE packed blob in host memory, encoded
with the same self-checking byte codec the rolling-restart checkpoints
use (:mod:`repro.checkpoint.session_state` — magic + manifest + sha256'd
npz payload), so a paged-out tenant is byte-for-byte a single-tenant
checkpoint: page-in reuses the restore path, corruption degrades to a
cold start, and :meth:`PopService.checkpoint` can fold paged tenants into
a full-service blob without touching device memory.

The store is thread-safe (its own lock) but deliberately policy-free:
WHO pages out and when (LRU over resident sessions, capacity caps) is the
service's call; this is just the byte shelf.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from . import session_state

__all__ = ["PagedSessionStore"]


class PagedSessionStore:
    """Packed per-tenant blobs, insertion-ordered (oldest page-out first).

    ``put`` packs (meta, arrays) through :func:`session_state.pack_state`
    — device arrays are materialised to host numpy by the codec itself —
    and replaces any previous blob for the tenant.  ``take`` pops AND
    unpacks (a page-in consumes the blob); ``peek_packed`` reads the raw
    bytes without consuming (the service checkpoint path).  All methods
    are safe under concurrent callers."""

    def __init__(self):
        self._lock = threading.Lock()
        self._blobs: "OrderedDict[str, bytes]" = OrderedDict()

    def put(self, tenant: str, meta: dict,
            arrays: Dict[str, np.ndarray]) -> int:
        """Pack and shelve ``tenant``'s state; returns the blob size in
        bytes.  Raises whatever the codec raises (non-JSON meta, ...) —
        the caller decides whether a failed page-out drops state."""
        blob = session_state.pack_state(meta, arrays)
        with self._lock:
            self._blobs.pop(tenant, None)
            self._blobs[tenant] = blob
        return len(blob)

    def take(self, tenant: str) -> Optional[Tuple[dict, Dict[str, np.ndarray]]]:
        """Pop + unpack ``tenant``'s blob; ``None`` when not paged.
        Raises :class:`session_state.CheckpointError` on a corrupt blob
        (the blob is already consumed — a corrupt page never resurrects)."""
        with self._lock:
            blob = self._blobs.pop(tenant, None)
        if blob is None:
            return None
        return session_state.unpack_state(blob)

    def peek_packed(self, tenant: str) -> Optional[bytes]:
        with self._lock:
            return self._blobs.get(tenant)

    def discard(self, tenant: str) -> bool:
        """Drop a tenant's blob (end_session / explicit purge)."""
        with self._lock:
            return self._blobs.pop(tenant, None) is not None

    def tenants(self) -> tuple:
        with self._lock:
            return tuple(self._blobs)

    def nbytes(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._blobs.values())

    def __contains__(self, tenant: str) -> bool:
        with self._lock:
            return tenant in self._blobs

    def __len__(self) -> int:
        with self._lock:
            return len(self._blobs)
