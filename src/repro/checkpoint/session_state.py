"""Self-describing byte format for serialized PopService session state.

`PopService.checkpoint()` / `PopService.restore()` (the rolling-restart
path in docs/ROBUSTNESS.md) serialize every tenant session's warm state —
PopPlan arrays + solver iterates + entity ids + a config digest — into one
`bytes` blob through this module.  The format is deliberately dumb and
fully self-checking, so a torn write, a truncated copy, or a blob from a
different build degrades to a COLD START at restore time instead of a
crash or (worse) silently wrong warm state:

    MAGIC (8 bytes)  b"POPSES1\\n"
    LEN   (8 bytes)  little-endian manifest byte length
    MANIFEST         UTF-8 JSON: {"version", "payload_sha256",
                     "payload_len", "meta": <caller meta>}
    PAYLOAD          an .npz archive of the named arrays

Integrity = sha256 over the payload, pinned in the manifest; alignment
(array shapes vs. plan shapes, entity-id counts, config digests) is the
caller's job — :meth:`repro.service.PopService.restore` checks those per
tenant.  Every parse failure raises :class:`CheckpointError` (a
``ValueError``), never anything rawer.
"""

from __future__ import annotations

import hashlib
import io
import json
import struct
import zipfile
from typing import Dict, Tuple

import numpy as np

__all__ = ["MAGIC", "VERSION", "CheckpointError", "pack_state",
           "unpack_state", "config_digest"]

MAGIC = b"POPSES1\n"
VERSION = 1

_LEN = struct.Struct("<Q")


class CheckpointError(ValueError):
    """Raised for any malformed / corrupt / incompatible checkpoint blob."""


def config_digest(*cfgs) -> str:
    """Stable digest of (frozen, repr-deterministic) config dataclasses.
    A restored session must reconstruct configs with the SAME digest, or
    the warm state belongs to a different solver setup and is stale."""
    h = hashlib.sha256()
    for c in cfgs:
        h.update(repr(c).encode("utf-8"))
    return h.hexdigest()[:16]


def pack_state(meta: dict, arrays: Dict[str, np.ndarray]) -> bytes:
    """Serialize ``meta`` (JSON-able) + named numpy arrays to bytes."""
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
    payload = buf.getvalue()
    manifest = json.dumps({
        "version": VERSION,
        "payload_sha256": hashlib.sha256(payload).hexdigest(),
        "payload_len": len(payload),
        "meta": meta,
    }, sort_keys=True).encode("utf-8")
    return MAGIC + _LEN.pack(len(manifest)) + manifest + payload


def unpack_state(data: bytes) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Parse a :func:`pack_state` blob -> (meta, arrays).

    Raises :class:`CheckpointError` on bad magic, truncation, version
    mismatch, hash mismatch, or undecodable manifest/payload.
    """
    if not isinstance(data, (bytes, bytearray, memoryview)):
        raise CheckpointError(
            f"checkpoint must be bytes, got {type(data).__name__}")
    data = bytes(data)
    hdr = len(MAGIC) + _LEN.size
    if len(data) < hdr:
        raise CheckpointError(
            f"checkpoint truncated: {len(data)} bytes < {hdr}-byte header")
    if data[:len(MAGIC)] != MAGIC:
        raise CheckpointError("bad checkpoint magic (not a PopService "
                              "session checkpoint)")
    (mlen,) = _LEN.unpack(data[len(MAGIC):hdr])
    if len(data) < hdr + mlen:
        raise CheckpointError("checkpoint truncated inside manifest")
    try:
        manifest = json.loads(data[hdr:hdr + mlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CheckpointError(f"undecodable checkpoint manifest: {e}")
    version = manifest.get("version")
    if version != VERSION:
        raise CheckpointError(
            f"unsupported checkpoint version {version!r} (this build "
            f"reads version {VERSION})")
    payload = data[hdr + mlen:]
    want_len = manifest.get("payload_len")
    if want_len != len(payload):
        raise CheckpointError(
            f"checkpoint truncated: payload is {len(payload)} bytes, "
            f"manifest promises {want_len}")
    digest = hashlib.sha256(payload).hexdigest()
    if digest != manifest.get("payload_sha256"):
        raise CheckpointError("checkpoint payload hash mismatch "
                              "(corrupt or tampered blob)")
    try:
        with np.load(io.BytesIO(payload), allow_pickle=False) as npz:
            arrays = {k: npz[k] for k in npz.files}
    except (zipfile.BadZipFile, ValueError, OSError, KeyError) as e:
        raise CheckpointError(f"undecodable checkpoint payload: {e}")
    return manifest.get("meta", {}), arrays
