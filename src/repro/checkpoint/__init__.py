"""Sharded, atomic, async checkpointing."""
from .checkpointer import Checkpointer
