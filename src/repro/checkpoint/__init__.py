"""Sharded, atomic, async checkpointing + session-state byte format +
the host-memory page store for evicted serving tenants."""
from .checkpointer import Checkpointer
from .paged import PagedSessionStore
from .session_state import (CheckpointError, config_digest, pack_state,
                            unpack_state)
