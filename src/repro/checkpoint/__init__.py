"""Sharded, atomic, async checkpointing + session-state byte format."""
from .checkpointer import Checkpointer
from .session_state import (CheckpointError, config_digest, pack_state,
                            unpack_state)
