"""Entity partitioners + distributional-similarity diagnostics (paper §2.3/§4.2).

The paper's central requirement: sub-problems must be *distributionally
similar* to the full problem — the mean and covariance of entity attribute
vectors inside each sub-problem should match the global ones.  Random
assignment achieves this at scale (law of large numbers); stratified
assignment enforces it under skew; the deliberately *skewed* partitioner
reproduces the paper's Fig. 6 failure mode.

All partitioners return a dense assignment
    idx : int32 [k, n_per]   (entity ids per sub-problem, -1 = padding)
so downstream sub-problem construction is a fixed-shape gather — this is
what lets POP's map step be a single batched (vmap/shard_map) solve.
"""

from __future__ import annotations

import numpy as np


def _to_dense(order: np.ndarray, k: int) -> np.ndarray:
    """Deal `order` round-robin into k bins; pad with -1 to equal length."""
    n = order.shape[0]
    n_per = (n + k - 1) // k
    out = np.full((k, n_per), -1, np.int64)
    for i in range(k):
        chunk = order[i::k]
        out[i, : chunk.shape[0]] = chunk
    return out


def random_partition(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Uniform random balanced split — the paper's default (LLN-similar)."""
    rng = np.random.default_rng(seed)
    return _to_dense(rng.permutation(n), k)


def stratified_partition(scores: np.ndarray, k: int) -> np.ndarray:
    """Sort by score, deal round-robin — each sub-problem samples every
    stratum evenly (paper §4.2: stratified sampling on per-dim strata)."""
    return _to_dense(np.argsort(scores, kind="stable"), k)


def stratified_partition_multidim(attrs: np.ndarray, k: int,
                                  seed: int = 0) -> np.ndarray:
    """Multi-dimensional stratification: project attributes onto their first
    principal component, then stratify along it.  Used when no single
    dimension dominates (paper §4.2 'inputs with continuous distribution
    across all dimensions')."""
    a = attrs - attrs.mean(axis=0, keepdims=True)
    std = a.std(axis=0); std[std == 0] = 1.0
    a = a / std
    # power iteration for the top PC (cheap, deterministic)
    rng = np.random.default_rng(seed)
    v = rng.normal(size=a.shape[1]); v /= np.linalg.norm(v)
    for _ in range(50):
        v = a.T @ (a @ v)
        v /= np.linalg.norm(v) + 1e-30
    return stratified_partition(a @ v, k)


def clustered_partition(labels: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """Deal each cluster/type evenly across sub-problems (paper §4.2:
    'inputs can also be clustered by key properties such as job type')."""
    rng = np.random.default_rng(seed)
    order_parts = []
    for lab in np.unique(labels):
        members = np.flatnonzero(labels == lab)
        order_parts.append(rng.permutation(members))
    order = np.concatenate(order_parts)
    return _to_dense(order, k)


def skewed_partition(group_of: np.ndarray, k: int) -> np.ndarray:
    """Adversarial split for Fig. 6: entities sharing a group (e.g. all
    commodities originating at one node) land in the SAME sub-problem."""
    groups = np.unique(group_of)
    gk = {g: i % k for i, g in enumerate(groups)}
    bins = [[] for _ in range(k)]
    for e, g in enumerate(group_of):
        bins[gk[g]].append(e)
    n_per = max(len(b) for b in bins)
    out = np.full((k, n_per), -1, np.int64)
    for i, b in enumerate(bins):
        out[i, : len(b)] = b
    return out


# --------------------------------------------------------------------------
# diagnostics — "is this split self-similar?" (paper §2.3)
# --------------------------------------------------------------------------

def similarity_report(attrs: np.ndarray, idx: np.ndarray) -> dict:
    """Mean/covariance distance of each sub-problem's attribute distribution
    from the global one, normalised by global scales.  Small values (≲0.1)
    indicate a self-similar split."""
    mu = attrs.mean(axis=0)
    sd = attrs.std(axis=0) + 1e-12
    cov = np.cov(((attrs - mu) / sd).T) if attrs.shape[1] > 1 else np.ones((1, 1))
    mean_d, cov_d = [], []
    for i in range(idx.shape[0]):
        ids = idx[i][idx[i] >= 0]
        if ids.size < 2:
            continue
        sub = attrs[ids]
        mean_d.append(np.linalg.norm((sub.mean(axis=0) - mu) / sd) /
                      np.sqrt(attrs.shape[1]))
        sub_cov = (np.cov(((sub - mu) / sd).T) if attrs.shape[1] > 1
                   else np.ones((1, 1)))
        cov_d.append(np.linalg.norm(sub_cov - cov) /
                     (np.linalg.norm(cov) + 1e-12))
    if not mean_d:
        # every lane holds < 2 entities (tiny or departure-gutted plans):
        # no within-lane statistics exist, report a trivially-similar split
        return {"max_mean_dist": 0.0, "avg_mean_dist": 0.0,
                "max_cov_dist": 0.0, "avg_cov_dist": 0.0}
    return {
        "max_mean_dist": float(np.max(mean_d)),
        "avg_mean_dist": float(np.mean(mean_d)),
        "max_cov_dist": float(np.max(cov_d)),
        "avg_cov_dist": float(np.mean(cov_d)),
    }


# the strategy names make_partition dispatches — what SolveConfig validates
STRATEGIES = ("random", "stratified", "stratified_multidim")


def make_partition(strategy: str, attrs: np.ndarray, scores: np.ndarray,
                   n: int, k: int, seed: int = 0) -> np.ndarray:
    """Strategy-name dispatch for the planning stage (``core/plan.py``).

    The returned idx rows ARE the partition's entity provenance: slot
    ``(i, s)`` holds the original entity id placed there (-1 = padding),
    which is what churn-aware warm-start remapping matches on.
    """
    if strategy == "random":
        return random_partition(n, k, seed)
    if strategy == "stratified":
        return stratified_partition(scores, k)
    if strategy == "stratified_multidim":
        return stratified_partition_multidim(attrs, k, seed)
    raise ValueError(f"unknown strategy {strategy!r}; expected one of "
                     "'random', 'stratified', 'stratified_multidim' "
                     "(or pass an explicit partition_idx)")
