"""Hot-entity replication (paper §4.3).

Skewed workloads have heavy tails ("Taylor Swift shards"): a single entity
can demand more than one sub-problem's 1/k resource slice, so no
entity-to-sub-problem assignment is self-similar.  The paper's fix:
*replicate* such entities into several sub-problems, splitting their demand
evenly; the reduce step then SUMS the replica sub-allocations.

This module decides which entities to replicate and produces the expanded
entity table + a mapping used by ``reduce.coalesce_replicated``.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ReplicationPlan:
    # expanded entity table: replica r of entity e carries demand[e]/n_rep[e]
    replica_entity: np.ndarray   # [n_expanded] original entity id per replica
    replica_scale: np.ndarray    # [n_expanded] demand scale (1/n_rep)
    n_original: int

    @property
    def n_expanded(self) -> int:
        return self.replica_entity.shape[0]

    def entity_of(self, replica_ids: np.ndarray) -> np.ndarray:
        """Original entity id per replica id, preserving -1 padding — the
        provenance map plans carry so warm-start remapping can follow an
        entity across partition changes."""
        replica_ids = np.asarray(replica_ids)
        return np.where(replica_ids >= 0,
                        self.replica_entity[np.maximum(replica_ids, 0)], -1)


def plan_replication(demands: np.ndarray, k: int,
                     threshold: float = 0.5) -> ReplicationPlan:
    """Replicate entity e into ceil(demand_e / (threshold * slice)) replicas,
    where slice = total_demand / k is one sub-problem's fair share.  Entities
    below the threshold keep a single replica (the common case)."""
    total = float(demands.sum())
    slice_cap = max(total / k, 1e-12)
    n_rep = np.maximum(1, np.ceil(demands / (threshold * slice_cap)).astype(np.int64))
    n_rep = np.minimum(n_rep, k)   # at most one replica per sub-problem
    replica_entity = np.repeat(np.arange(demands.shape[0]), n_rep)
    replica_scale = np.repeat(1.0 / n_rep, n_rep)
    return ReplicationPlan(replica_entity=replica_entity,
                           replica_scale=replica_scale,
                           n_original=demands.shape[0])


def replicated_partition(plan: ReplicationPlan, scores: np.ndarray, k: int,
                         seed: int = 0) -> np.ndarray:
    """Partition the *expanded* replica table so that

      * replicas of one entity land on DISTINCT sub-problems, and
      * bins stay balanced and stratified by ``scores`` (per original entity).

    Strategy: visit entities in stratified order (sort by score, so heavy
    and light entities interleave across bins), placing each entity's r
    replicas on the r currently least-loaded bins.  Returns idx [k, n_per]
    over replica ids, -1 padded."""
    rng = np.random.default_rng(seed)
    n = plan.n_original
    # replica ids grouped per entity
    replicas_of = [[] for _ in range(n)]
    for r, e in enumerate(plan.replica_entity):
        replicas_of[e].append(r)
    # stratified entity order with random tie-break
    order = np.argsort(scores + 1e-9 * rng.standard_normal(n), kind="stable")[::-1]
    bins = [[] for _ in range(k)]
    load = np.zeros(k)
    for e in order:
        reps = replicas_of[e]
        # r least-loaded bins (stable) — guarantees distinctness since r <= k
        target_bins = np.argsort(load, kind="stable")[: len(reps)]
        for r_id, b in zip(reps, target_bins):
            bins[b].append(r_id)
            load[b] += scores[e] * plan.replica_scale[r_id]
    n_per = max(len(b) for b in bins)
    out = np.full((k, n_per), -1, np.int64)
    for i, b in enumerate(bins):
        out[i, : len(b)] = b
    return out
