"""JAX version-compat layer.

The public JAX surface this repo leans on has moved across releases:

* ``shard_map`` lives at ``jax.shard_map`` on new JAX but at
  ``jax.experimental.shard_map.shard_map`` on 0.4.x;
* its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.

Everything that needs ``shard_map`` (the POP map-step backend, gradient
compression under data parallelism, tests) goes through :func:`shard_map`
here, so a JAX upgrade is a one-file change instead of a grep-the-repo
event.  ``scripts/check_imports.py`` catches the next rename at smoke
speed.
"""

from __future__ import annotations

import inspect
from typing import Any, Callable, Optional

import jax

try:  # JAX >= 0.6: top-level export
    from jax import shard_map as _raw_shard_map
except ImportError:  # JAX 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _raw_shard_map

# the replication-safety check kwarg: check_rep (<= 0.5) vs check_vma (>= 0.6)
_SHARD_MAP_PARAMS = inspect.signature(_raw_shard_map).parameters
if "check_vma" in _SHARD_MAP_PARAMS:
    _CHECK_KW: Optional[str] = "check_vma"
elif "check_rep" in _SHARD_MAP_PARAMS:
    _CHECK_KW = "check_rep"
else:
    _CHECK_KW = None


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check: Optional[bool] = None, **kw: Any) -> Callable:
    """Version-portable ``shard_map``.

    ``check`` maps onto whichever of ``check_vma``/``check_rep`` this JAX
    understands (dropped silently if neither exists — newest JAX infers
    it).  POP map steps pass ``check=False``: solver constants (e.g.
    power-iteration seed vectors) are intentionally unvarying while the
    problem data varies over the POP axis.
    """
    if check is not None and _CHECK_KW is not None:
        kw[_CHECK_KW] = check
    return _raw_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)


def device_count() -> int:
    return jax.device_count()
