"""Max-min fairness as an epigraph LP (Gavel-style policies).

    maximize   min_m  s_m . x                    (s_m = scaled throughput row)
    subject to domain constraints

is rewritten with an epigraph variable t appended to x:

    minimize   -t
    subject to t - s_m . x <= 0   for all m      (epigraph rows)
               (domain constraints unchanged)

The helper below just assembles the epigraph inequality block; domain
problems append it to their own constraint operators.  Exact (no bisection
needed): PDHG solves the joint (x, t) LP directly — this is the TPU-native
replacement for Gavel's water-filling + solver loop.
"""

from __future__ import annotations

import numpy as np


def epigraph_rows(S: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Dense epigraph block for  t <= S x  (row per entity).

    S : [n_entities, n_vars] scaled-throughput rows.
    Returns (G_block [n, n_vars+1], h_block [n]) where the last column is t.
    """
    n, v = S.shape
    G = np.zeros((n, v + 1))
    G[:, :v] = -S
    G[:, v] = 1.0
    return G, np.zeros(n)


def maxmin_objective(n_vars: int) -> np.ndarray:
    """c for min -t with t as the last of n_vars+1 variables."""
    c = np.zeros(n_vars + 1)
    c[-1] = -1.0
    return c
