"""PopPlan: the POP planning artifact + churn-aware warm-start remapping.

Planning (partition + replicate + layout) is separable from solving: a
:class:`PopPlan` is a cached, reusable description of HOW a problem is
split — the partition, the replication plan, per-entity -> (lane, slot)
placement provenance, and (after ``pop.build``) the stacked sub-LP shapes.
Online callers re-plan only when they must (entity churn, k change,
re-stratification) and re-use the plan otherwise.

The plan is also what makes warm starts survive *partition changes*.
PR-2-style warm starts required the previous partition verbatim; with two
plans in hand, :func:`remap_warm` scatters the previous solver iterates
onto the new plan's lanes:

* **primal**: each entity's per-slot variable block (``SubLayout.x_slot``)
  is copied from wherever the entity lived in the old plan to wherever it
  lives in the new one (averaged over replicas, clipped into the new
  bounds).  Lane-global variables (e.g. Gavel's epigraph ``t``) are
  averaged across old lanes and broadcast.
* **dual**: per-entity constraint rows move with their entity; lane-global
  rows (worker caps, edge caps) follow their lane's closest ancestor (the
  old lane contributing most matched entities), falling back to the
  cross-lane average.  Freshly *arrived* entities have no previous iterate
  of their own, so they get a dual-only warm start from the population:
  their constraint rows take the mean over all old entities' rows of the
  same block (truncation to the feasible cone is inherited — means of
  projected duals stay projected), plus the peer-average primal block as a
  prior (measured on Gavel: the prior cuts another ~25% of warm iterations
  at 20% churn vs leaving arrivals' primal cold).
* **mask**: lanes that matched no entity at all start cold.  The mask is
  per-lane data (``WarmStart.mask``), applied by ``backends._resolve_warm``
  / ``pdhg.solve_stacked(warm_mask=)`` with a ``jnp.where`` — no
  Python-level branch, so every lane flows through the same jitted solve.

Problems opt in by implementing ``POPProblem.sub_layout`` (a
:class:`SubLayout` describing which variables/rows belong to which slot);
problems without a layout degrade gracefully to cold starts instead of
raising — ``pop_solve(warm=prev)`` is total across entity arrival,
departure, k changes and re-stratification.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import numpy as np

from .replicate import ReplicationPlan


# frozen for immutability only — layouts are never cache keys
# popcheck: disable=config-hashability
@dataclasses.dataclass(frozen=True)
class SubLayout:
    """Variable/constraint layout of one sub-LP, for warm-start remapping.

    All indices are into a single sub-problem's flat solution vector ``x``
    (length N) / dual vector ``y`` (length M).  ``-1`` entries are ignored.

    x_slot   : [n_slots, v_per] variable ids owned by slot ``s``
    y_slot   : [n_slots, c_per] constraint row ids owned by slot ``s``
    x_global : [g] lane-global variable ids (matched positionally old->new)
    y_global : [h] lane-global constraint row ids (resource/capacity rows)
    """

    x_slot: np.ndarray
    y_slot: np.ndarray
    x_global: np.ndarray
    y_global: np.ndarray


@dataclasses.dataclass
class PopPlan:
    """A reusable POP split: partition + replication + placement provenance.

    ``idx`` holds *build ids* per (lane, slot): entity ids for plain splits,
    replica ids under §4.3 replication.  ``entity_of_slot`` always holds
    ORIGINAL entity ids (the provenance the warm-start remap matches on);
    ``entity_ids`` optionally carries stable *external* ids (job ids,
    demand ids) so entities can be matched across instances whose
    positional indexing churned.  ``shapes`` is filled by ``pop.build``.
    """

    k: int
    n_entities: int
    idx: np.ndarray                  # [k, n_per] build ids, -1 padded
    entity_of_slot: np.ndarray       # [k, n_per] original entity ids, -1 padded
    strategy: str = "random"
    seed: int = 0
    replication: Optional[ReplicationPlan] = None
    entity_ids: Optional[np.ndarray] = None   # [n_entities] stable external ids
    similarity: Optional[dict] = None
    layout: Optional[SubLayout] = None
    # filled by pop.build: {"x": (k, N), "y": (k, M)} stacked iterate shapes
    # (what remap_warm sizes cold bases from), plus
    # "ell": (Wr, Ww, Dr, Wc, Wv, Dc) when the problem attaches
    # StructuredOperator metadata — every data-dependent ELL dim (narrow
    # widths, wide-bucket widths, wide-bucket counts), so plan consumers
    # can tell when a rebuild changed the kernel shapes (any of them
    # moving retraces the jitted solve; iterate shapes do not move)
    shapes: Optional[dict] = None

    @property
    def n_per(self) -> int:
        return self.idx.shape[1]

    def external_ids(self) -> np.ndarray:
        """Stable per-entity ids (positional indices when none were given)."""
        if self.entity_ids is not None:
            return np.asarray(self.entity_ids)
        return np.arange(self.n_entities)

    def row_scale(self, lane: int) -> Optional[np.ndarray]:
        """Per-slot demand scale for ``lane`` (replication), or None."""
        if self.replication is None:
            return None
        row = self.idx[lane]
        return np.where(row >= 0,
                        self.replication.replica_scale[np.maximum(row, 0)], 0.0)


def repair_plan(old_plan: PopPlan, problem, *,
                entity_ids: Optional[np.ndarray] = None) -> PopPlan:
    """Incrementally re-plan after entity churn, disturbing the old plan as
    little as possible: surviving entities KEEP their (lane, slot), departed
    entities vacate theirs, and arrivals fill vacancies score-balanced
    (heaviest arrival to the lightest lane), growing the slot axis only when
    the arrivals outnumber the vacancies.

    Slot stability is what makes warm starts transfer: a surviving entity's
    sub-problem keeps (statistically) the same peers and the same 1/k
    resource slice, so its previous iterates stay near-optimal.  A fresh
    stratified partition of the churned entity set is still self-similar,
    but it reshuffles every entity's lane context and throws that locality
    away — measurably worse than cold at >10% churn, while the repaired
    plan keeps warm re-solves well under the cold iteration count.

    Replicated plans are not repaired (replica counts depend on the global
    demand profile); callers fall back to a fresh plan + remap.
    """
    if old_plan.replication is not None:
        raise ValueError("repair_plan does not support replicated plans; "
                         "re-plan from scratch and remap instead")
    n = problem.n_entities
    new_ids = (np.arange(n) if entity_ids is None else np.asarray(entity_ids))
    if new_ids.shape[0] != n:
        raise ValueError(f"entity_ids has {new_ids.shape[0]} entries for "
                         f"{n} entities")
    old_ids = old_plan.external_ids()
    pos_of = {}
    for lane in range(old_plan.k):
        for slot in range(old_plan.n_per):
            e = int(old_plan.entity_of_slot[lane, slot])
            if e >= 0:
                pos_of.setdefault(old_ids[e], (lane, slot))

    scores = np.asarray(problem.entity_scores(), np.float64)
    k = old_plan.k
    slots = [[-1] * old_plan.n_per for _ in range(k)]
    lane_load = np.zeros(k)
    arrivals = []
    for e in range(n):
        hit = pos_of.get(new_ids[e])
        if hit is not None:
            lane, slot = hit
            slots[lane][slot] = e
            lane_load[lane] += scores[e]
        else:
            arrivals.append(e)

    # heaviest arrivals first, each to the lightest lane with a vacancy
    # (append a fresh slot everywhere once vacancies run out)
    arrivals.sort(key=lambda e: -scores[e])
    free = [[s for s, v in enumerate(row) if v < 0] for row in slots]
    for e in arrivals:
        open_lanes = [i for i in range(k) if free[i]]
        if not open_lanes:
            for row in slots:
                row.append(-1)
            free = [[len(slots[i]) - 1] for i in range(k)]
            open_lanes = list(range(k))
        lane = min(open_lanes, key=lambda i: lane_load[i])
        slots[lane][free[lane].pop(0)] = e
        lane_load[lane] += scores[e]

    idx = np.asarray(slots, np.int64)
    # drop trailing all-padding slot columns (departure-heavy churn)
    live = np.flatnonzero((idx >= 0).any(axis=0))
    n_per = max(int(live.max()) + 1, 1) if live.size else 1
    idx = idx[:, :n_per]

    attrs = np.asarray(problem.entity_attrs(), np.float64)
    if attrs.ndim == 1:
        attrs = attrs[:, None]
    from .partition import similarity_report
    return PopPlan(k=k, n_entities=n, idx=idx, entity_of_slot=idx,
                   strategy=old_plan.strategy, seed=old_plan.seed,
                   replication=None,
                   entity_ids=None if entity_ids is None else new_ids,
                   similarity=similarity_report(attrs, idx),
                   layout=problem.sub_layout(n_per))


class WarmStart(NamedTuple):
    """Remapped starting iterates for a stacked solve.

    ``mask`` is per-lane: False lanes are started cold by the solver (the
    blend happens inside ``backends._resolve_warm`` with a ``jnp.where``).
    ``stats`` carries ``warm_fraction`` (matched slots / live slots) and
    match counts for logging.
    """

    x: Any
    y: Any
    mask: Any
    stats: dict


def _cold_base(ops) -> tuple:
    """Cold starting iterates in numpy (mirrors ``backends.cold_start``)."""
    l = np.asarray(ops.l)
    u = np.asarray(ops.u)
    return np.clip(np.zeros_like(l), l, u), np.zeros(np.asarray(ops.q).shape,
                                                     np.asarray(ops.q).dtype)


def _new_shapes(new_plan: PopPlan, ops) -> Optional[tuple]:
    if ops is not None:
        return tuple(np.asarray(ops.c).shape), tuple(np.asarray(ops.q).shape)
    if new_plan.shapes is not None:
        return tuple(new_plan.shapes["x"]), tuple(new_plan.shapes["y"])
    return None


def _cold(new_plan: PopPlan, ops, reason: str) -> WarmStart:
    shp = _new_shapes(new_plan, ops)
    if shp is None:
        raise ValueError("remap_warm needs the new stacked ops (or a plan "
                         "that has been through pop.build) to size the "
                         "starting iterates")
    (kx, n_var), (ky, n_con) = shp
    if ops is not None:
        x0, y0 = _cold_base(ops)
    else:
        x0 = np.zeros((kx, n_var), np.float32)
        y0 = np.zeros((ky, n_con), np.float32)
    return WarmStart(x0, y0, np.zeros(kx, bool),
                     dict(warm_fraction=0.0, matched=0, fresh=0, dropped=0,
                          lanes_cold=int(kx), identity=False, reason=reason))


def remap_warm(old_plan: PopPlan, new_plan: PopPlan, old_result,
               *, ops=None) -> WarmStart:
    """Map a previous solve's iterates onto a (possibly different) plan.

    ``old_result`` is anything with stacked ``.x``/``.y`` (a ``POPResult``
    or ``SolveResult``) or an ``(x, y)`` pair shaped for ``old_plan``.
    ``ops`` is the NEW plan's stacked :class:`~repro.core.pdhg.OperatorLP`
    (used for cold bases and bound clipping); when omitted the new plan
    must have been through ``pop.build`` so its shapes are known.

    Handles entity arrival (dual-only warm start), departure (iterates
    dropped), k changes and re-stratification.  Identity churn (same
    entities, same slots, same shapes) returns the old iterates verbatim —
    bit-for-bit the PR-2 warm path.
    """
    if hasattr(old_result, "x") and hasattr(old_result, "y"):
        ox, oy = old_result.x, old_result.y
    else:
        ox, oy = old_result
    if ox is None or oy is None:
        raise ValueError("warm result lacks solver state (x/y)")
    ox = np.asarray(ox)
    oy = np.asarray(oy)

    shp = _new_shapes(new_plan, ops)
    if shp is None:
        raise ValueError("remap_warm needs ops= or a built new_plan")
    (k_new, n_var), (_, n_con) = shp

    old_ids = old_plan.external_ids()
    new_ids = new_plan.external_ids()

    # ---- identity fast path: the PR-2 warm start, bit-for-bit -------------
    if (ox.shape == (k_new, n_var) and oy.shape == (k_new, n_con)
            and old_plan.entity_of_slot.shape == new_plan.entity_of_slot.shape
            and np.array_equal(old_plan.entity_of_slot,
                               new_plan.entity_of_slot)
            and np.array_equal(old_ids, new_ids)):
        n_live = int((new_plan.entity_of_slot >= 0).sum())
        return WarmStart(ox, oy, np.ones(k_new, bool),
                         dict(warm_fraction=1.0, matched=n_live, fresh=0,
                              dropped=0, lanes_cold=0, identity=True))

    lo, ln = old_plan.layout, new_plan.layout
    if lo is None or ln is None:
        return _cold(new_plan, ops, "no sub_layout")
    if (lo.x_slot.shape[1] != ln.x_slot.shape[1]
            or lo.y_slot.shape[1] != ln.y_slot.shape[1]):
        return _cold(new_plan, ops, "per-entity block widths differ")

    # ---- accumulate old per-entity blocks (averaged over replicas) --------
    k_old = old_plan.k
    sum_x: dict = {}
    sum_y: dict = {}
    count: dict = {}
    lane_of: dict = {}               # first old lane an entity appeared in
    v_per = lo.x_slot.shape[1]
    c_per = lo.y_slot.shape[1]
    xs_mask = lo.x_slot >= 0
    ys_mask = lo.y_slot >= 0
    primal_rows = []                 # per-block means: priors for arrivals
    dual_rows = []
    for lane in range(k_old):
        row = old_plan.entity_of_slot[lane]
        for slot in range(row.shape[0]):
            e = int(row[slot])
            if e < 0:
                continue
            xv = np.zeros(v_per, ox.dtype)
            xv[xs_mask[slot]] = ox[lane, lo.x_slot[slot][xs_mask[slot]]]
            yv = np.zeros(c_per, oy.dtype)
            yv[ys_mask[slot]] = oy[lane, lo.y_slot[slot][ys_mask[slot]]]
            key = old_ids[e]
            if key in count:
                sum_x[key] += xv
                sum_y[key] += yv
                count[key] += 1
            else:
                sum_x[key] = xv.copy()
                sum_y[key] = yv.copy()
                count[key] = 1
                lane_of[key] = lane
            primal_rows.append(xv)
            dual_rows.append(yv)
    avg_primal = (np.mean(primal_rows, axis=0) if primal_rows
                  else np.zeros(v_per, ox.dtype))
    avg_dual = (np.mean(dual_rows, axis=0) if dual_rows
                else np.zeros(c_per, oy.dtype))

    # ---- scatter onto the new plan ----------------------------------------
    if ops is not None:
        x_w, y_w = _cold_base(ops)
        x_w = x_w.astype(ox.dtype, copy=True)
        y_w = y_w.astype(oy.dtype, copy=True)
    else:
        x_w = np.zeros((k_new, n_var), ox.dtype)
        y_w = np.zeros((k_new, n_con), oy.dtype)

    nxs_mask = ln.x_slot >= 0
    nys_mask = ln.y_slot >= 0
    matched = 0
    fresh = 0
    lane_hit = np.zeros(k_new, bool)
    overlap = np.zeros((k_new, k_old), np.int64)   # matched entities per pair
    for lane in range(k_new):
        row = new_plan.entity_of_slot[lane]
        for slot in range(row.shape[0]):
            e = int(row[slot])
            if e < 0:
                continue
            key = new_ids[e]
            ys_idx = ln.y_slot[slot][nys_mask[slot]]
            if key in count:
                c = count[key]
                x_w[lane, ln.x_slot[slot][nxs_mask[slot]]] = \
                    (sum_x[key] / c)[nxs_mask[slot]]
                y_w[lane, ys_idx] = (sum_y[key] / c)[nys_mask[slot]]
                matched += 1
                lane_hit[lane] = True
                overlap[lane, lane_of[key]] += 1
            else:
                # arrived entity: no previous iterate of its own, so it
                # starts from the population means — the peer-average
                # primal block as a prior (clipped into its own bounds
                # below) and the mean dual row of its constraint block
                x_w[lane, ln.x_slot[slot][nxs_mask[slot]]] = \
                    avg_primal[nxs_mask[slot]]
                y_w[lane, ys_idx] = avg_dual[nys_mask[slot]]
                fresh += 1

    # ---- lane-global blocks (epigraph vars, resource-cap duals) -----------
    # each new lane inherits them from its closest ancestor — the old lane
    # contributing most of its matched entities (under an incremental
    # repair_plan that IS the same lane, so per-lane state survives
    # verbatim); lanes with no ancestor get the cross-lane average
    x_gavg = ox[:, lo.x_global].mean(axis=0) if lo.x_global.size else None
    y_gavg = oy[:, lo.y_global].mean(axis=0) if lo.y_global.size else None
    for lane in range(k_new):
        parent = int(np.argmax(overlap[lane])) if lane_hit[lane] else None
        if lo.x_global.size and lo.x_global.size == ln.x_global.size:
            x_w[lane, ln.x_global] = (ox[parent, lo.x_global]
                                      if parent is not None else x_gavg)
        if lo.y_global.size and lo.y_global.size == ln.y_global.size:
            y_w[lane, ln.y_global] = (oy[parent, lo.y_global]
                                      if parent is not None else y_gavg)

    if ops is not None:              # new bounds may be tighter than old ones
        x_w = np.clip(x_w, np.asarray(ops.l), np.asarray(ops.u))

    new_id_set = set(new_ids.tolist())
    dropped = sum(1 for key in count if key not in new_id_set)
    live = matched + fresh
    return WarmStart(
        x_w, y_w, lane_hit,
        dict(warm_fraction=matched / max(live, 1), matched=matched,
             fresh=fresh, dropped=dropped,
             lanes_cold=int((~lane_hit).sum()), identity=False))
