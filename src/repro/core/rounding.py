"""MILP via LP relaxation + randomized rounding + greedy repair.

Branch-and-bound does not map to TPUs (data-dependent tree search), so the
framework solves mixed-integer allocation problems the TPU-idiomatic way:

  1. solve the LP relaxation with PDHG (binary vars relaxed to [0, 1]),
  2. round the relaxation — deterministically (threshold) and with R
     randomized draws, keeping the best feasible candidate,
  3. hand near-feasible candidates to a domain-specific ``repair`` hook
     (e.g. load balancing greedily shifts fractional load between servers).

Empirically (benchmarks/bench_load_balancing.py) this lands within a few
percent of the exact MILP objective at a tiny fraction of the runtime —
the same quality/runtime trade POP itself makes, one level down.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np


def round_relaxation(
    x_relaxed: np.ndarray,
    binary_mask: np.ndarray,
    *,
    feasible: Callable[[np.ndarray], bool],
    objective: Callable[[np.ndarray], float],
    repair: Optional[Callable[[np.ndarray], np.ndarray]] = None,
    n_draws: int = 16,
    seed: int = 0,
) -> tuple[np.ndarray, float, bool]:
    """Return (x_int, objective, was_feasible)."""
    rng = np.random.default_rng(seed)
    frac = np.clip(x_relaxed[binary_mask], 0.0, 1.0)

    candidates = []
    det = x_relaxed.copy()
    det[binary_mask] = (frac >= 0.5).astype(x_relaxed.dtype)
    candidates.append(det)
    for _ in range(n_draws):
        draw = x_relaxed.copy()
        draw[binary_mask] = (rng.random(frac.shape) < frac).astype(x_relaxed.dtype)
        candidates.append(draw)

    best, best_obj, best_feas = None, np.inf, False
    for cand in candidates:
        if repair is not None:
            cand = repair(cand)
        feas = feasible(cand)
        obj = objective(cand)
        # prefer feasible; among feasible (or among infeasible), lower objective
        key = (not feas, obj)
        if best is None or key < (not best_feas, best_obj):
            best, best_obj, best_feas = cand, obj, feas
    return best, float(best_obj), bool(best_feas)
