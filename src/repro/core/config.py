"""Frozen, hashable configuration for the POP pipeline.

The public surface used to thread a dozen loose kwargs (``strategy=``,
``k=``, ``backend=``, ``engine=``, ``solver_kw=``, ``backend_opts=``, ...)
through every entry point.  These two dataclasses collapse that soup:

:class:`SolveConfig`
    WHAT split to solve — k, partition strategy, replication — the inputs
    of the planning stage (``pop.plan``).

:class:`ExecConfig`
    HOW to execute it — map-step backend, PDHG step engine, solver
    keywords, backend options — the inputs of the solve stage
    (``backends.solve_map``).

Both are validated eagerly at construction (an unknown backend name or a
misspelled solver keyword fails where the config is *written*, not three
layers down inside a jitted solve) and are hashable, so they can key the
jit/plan caches directly: two sessions sharing an :class:`ExecConfig`
share compiled solvers.  Dict-valued inputs (``solver_kw``,
``backend_opts``) are frozen into sorted item tuples automatically —
``ExecConfig(solver_kw={"max_iters": 100})`` works and hashes.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional, Tuple, Union

__all__ = ["SolveConfig", "ExecConfig", "validate_cache_key"]


def _check_cache_key(cfg) -> None:
    """Construction-time ``__hash__``/``__eq__`` consistency check.

    These configs key the jit/plan caches directly, so an unhashable field
    value (or a hash that disagrees with equality) must fail where the
    config is WRITTEN, not as a silent per-call cache miss three layers
    down.  An equal reconstruction (``dataclasses.replace`` with no
    changes — which re-runs validation and field freezing) must compare
    equal and hash identically; this also covers subclasses that add
    fields (``tests/test_config_keys.py``)."""
    if getattr(_CHECKING, "active", False):
        return   # the reconstruction below re-enters __post_init__
    _CHECKING.active = True
    try:
        try:
            h = hash(cfg)
        except TypeError as e:
            raise TypeError(
                f"{type(cfg).__name__} must stay hashable — it keys the "
                f"jit/plan caches ({e}); pass hashable field values "
                "(dicts are frozen automatically)") from e
        twin = dataclasses.replace(cfg)
        if twin != cfg or hash(twin) != h:
            raise ValueError(
                f"{type(cfg).__name__} hash/eq are inconsistent: an equal "
                "reconstruction produced a different cache key — field "
                "freezing in __post_init__ must be idempotent")
    finally:
        _CHECKING.active = False


_CHECKING = threading.local()

# public alias: config-like frozen dataclasses OUTSIDE this module (e.g.
# repro.tuning.SLOTarget) get the same construction-time hash/eq gate
validate_cache_key = _check_cache_key


def _freeze_items(value: Any, field: str) -> Tuple:
    """dict -> sorted item tuple; tuples pass through; reject the rest."""
    if value is None:
        return ()
    if isinstance(value, dict):
        return tuple(sorted(value.items()))
    if isinstance(value, tuple):
        return value
    raise TypeError(f"{field} must be a dict or an item tuple, "
                    f"got {type(value).__name__}")


@dataclasses.dataclass(frozen=True)
class SolveConfig:
    """The planning-stage config: how the problem is split.

    ``k`` is the requested sub-problem count; ``min_per_sub``, when set,
    clamps it so every sub-problem keeps at least that many entities
    (``k_for(n)`` — small instances then degrade toward the k=1 full
    solve instead of over-splitting).  ``strategy`` names a partition
    strategy from ``core/partition.py``; ``replicate_threshold`` enables
    §4.3 hot-entity replication.
    """

    k: int = 4
    strategy: str = "stratified"
    seed: int = 0
    replicate_threshold: Optional[float] = None
    min_per_sub: Optional[int] = None

    def __post_init__(self):
        from .partition import STRATEGIES
        if not isinstance(self.k, int) or self.k < 1:
            raise ValueError(f"k must be an int >= 1, got {self.k!r}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; expected "
                             f"one of {STRATEGIES}")
        if self.replicate_threshold is not None and self.replicate_threshold <= 0:
            raise ValueError("replicate_threshold must be positive or None, "
                             f"got {self.replicate_threshold!r}")
        if self.min_per_sub is not None and self.min_per_sub < 1:
            raise ValueError(f"min_per_sub must be >= 1 or None, "
                             f"got {self.min_per_sub!r}")
        _check_cache_key(self)

    def k_for(self, n_entities: int) -> int:
        """Effective k for an instance of ``n_entities`` (1 = full solve)."""
        if self.min_per_sub is None:
            return max(1, min(self.k, n_entities))
        return max(1, min(self.k, n_entities // self.min_per_sub))


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """The execution-stage config: how the stacked solve runs.

    ``backend`` names a map-step backend (``core/backends.py`` registry,
    ``"auto"`` selects by k/devices/size); ``engine`` a PDHG step engine
    (``core/pdhg.py``: ``"auto"``/``"matvec"``/``"fused"``/
    ``"fused_structured"`` or a :class:`~repro.core.pdhg.StepEngine`).
    ``solver_kw`` keys are validated against the solver signature
    (``pdhg.SOLVER_KW_NAMES``).  The *resolved* backend/engine that
    actually ran are reported on every :class:`~repro.core.pop.POPResult`
    / :class:`~repro.service.Allocation` — ``"auto"`` is a request, not
    an answer.
    """

    backend: str = "auto"
    engine: Any = "auto"
    solver_kw: Union[dict, tuple] = ()
    backend_opts: Union[dict, tuple] = ()

    def __post_init__(self):
        from . import backends as backends_mod
        from . import pdhg
        object.__setattr__(self, "solver_kw",
                           _freeze_items(self.solver_kw, "solver_kw"))
        object.__setattr__(self, "backend_opts",
                           _freeze_items(self.backend_opts, "backend_opts"))
        if self.backend != "auto" and self.backend not in backends_mod.MAP_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected 'auto' or one "
                f"of {sorted(backends_mod.MAP_BACKENDS)}")
        if not isinstance(self.engine, pdhg.StepEngine) and \
                self.engine not in pdhg.ENGINE_NAMES:
            raise ValueError(
                f"unknown engine {self.engine!r}; expected one of "
                f"{pdhg.ENGINE_NAMES} or a StepEngine")
        bad = [k for k, _ in self.solver_kw if k not in pdhg.SOLVER_KW_NAMES]
        if bad:
            raise ValueError(
                f"unknown solver_kw key(s) {bad}; the solver accepts "
                f"{sorted(pdhg.SOLVER_KW_NAMES)}")
        _check_cache_key(self)

    def solver_dict(self) -> dict:
        return dict(self.solver_kw)

    def opts_dict(self) -> dict:
        return dict(self.backend_opts)
