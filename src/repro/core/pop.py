"""POP orchestrator: a staged pipeline around the :class:`PopPlan` artifact.

The paper's technique as a composable module.  A domain problem (cluster
scheduling, traffic engineering, load balancing, MoE expert placement, ...)
subclasses :class:`POPProblem`; the pipeline then runs in four separable
stages:

  ``plan()``    partition entities into k self-similar subsets
                (``core/partition.py``), optionally replicating hot entities
                (``core/replicate.py``), and record the per-entity ->
                (lane, slot) provenance plus the problem's sub-LP layout in
                a reusable :class:`~repro.core.plan.PopPlan`.
  ``build()``   materialise k identically-shaped sub-LPs from the plan and
                STACK them on a leading axis (fills ``plan.shapes``).
  ``solve()``   one batched PDHG solve through a pluggable execution
                backend (``core/backends.py``: serial / vmap / chunked_vmap
                / shard_map / pmap — sub-problems are independent, so the
                map step needs ZERO collectives; this is the whole point of
                POP).
  ``reduce()``  coalesce sub-allocations back to global entity order
                (``core/reduce.py``).

:func:`solve_instance` is the one-call wrapper chaining all four,
configured by the frozen dataclasses in ``core/config.py``
(:class:`SolveConfig` / :class:`ExecConfig`); the legacy kwarg surface
:func:`pop_solve` forwards onto it with a DeprecationWarning.  These
stages are the DOCUMENTED INTERNALS that the public surface drives: the
domain registry (``repro.domains``) describes each scenario
declaratively, and :class:`repro.service.PopService` sessions call
:func:`solve_instance` per online step.  Online callers hold onto the
:class:`PopPlan` (every :class:`POPResult` carries its plan) and re-plan
only when they must — planning is pure numpy and cheap, but *re-using* a
plan is what keeps warm starts exact and the jit caches hot.

Warm starts across churn
------------------------

``pop_solve(warm=prev)`` re-solves an updated instance from a previous
:class:`POPResult`:

* **identity churn** (same entities, same k): the previous plan is reused
  verbatim and every lane continues from its previous (x, y) iterates —
  bit-for-bit the PR-2 warm path.
* **anything else** (entity arrivals/departures, k changes,
  re-stratification via ``replan=True`` or an explicit ``plan=``):
  :func:`~repro.core.plan.remap_warm` scatters the old per-entity iterates
  onto the new plan's lanes, gives freshly arrived entities a dual-only
  warm start, and marks lanes with no matched entity to start cold via a
  per-lane mask (``backends._resolve_warm`` applies it with a ``jnp.where``
  — no Python-level branch).  Pass ``entity_ids=`` stable external ids when
  positional indexing churns (a scheduler's job ids, a balancer's group
  ids); without them entities are matched by position.

``benchmarks/bench_churn.py`` measures the warm/cold iteration ratio under
5/20/50% entity churn for all three paper domains.

``solve_full`` runs the unpartitioned baseline (k=1 path) for quality
comparison — the paper's "original problem formulation" — through the same
backend/engine substrate as the POP path.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import backends as backends_mod
from . import partition as part_mod
from . import pdhg
from .config import ExecConfig, SolveConfig
from .pdhg import OperatorLP, SolveResult
from .plan import PopPlan, SubLayout, WarmStart, remap_warm, repair_plan
from .replicate import ReplicationPlan, plan_replication, replicated_partition
from .reduce import coalesce_concat, coalesce_replicated


class POPProblem:
    """Interface a domain problem implements to be POP-able.

    Subclasses define how to build the full LP and any entity-subset sub-LP
    (in operator form), how to pull the per-entity allocation out of the LP
    solution vector, and how to score an allocation.
    """

    n_entities: int

    # --- partitioning inputs -------------------------------------------------
    def entity_attrs(self) -> np.ndarray:
        """[n, d] attribute vectors (for similarity + stratification)."""
        raise NotImplementedError

    def entity_scores(self) -> np.ndarray:
        """[n] scalar load/demand (stratification + replication)."""
        attrs = self.entity_attrs()
        return attrs[:, 0] if attrs.ndim == 2 else attrs

    # --- LP construction ------------------------------------------------------
    def build_sub(self, idx_row: np.ndarray, frac: float,
                  scale: Optional[np.ndarray] = None) -> OperatorLP:
        """Sub-LP over entities ``idx_row`` (-1 = padded slot) with ``frac``
        of every resource.  ``scale`` (replication) multiplies per-entity
        demand.  MUST return identical array shapes for identical row
        lengths, so sub-problems stack."""
        raise NotImplementedError

    def build_full(self) -> OperatorLP:
        return self.build_sub(np.arange(self.n_entities), 1.0)

    def sub_layout(self, n_slots: int) -> Optional[SubLayout]:
        """Describe the sub-LP variable/row layout for warm-start remapping
        (see ``core/plan.py``).  ``None`` (the default) disables cross-plan
        warm starts — ``pop_solve(warm=)`` then degrades to cold instead of
        raising when the partition changed."""
        return None

    # operator matvecs — override for structured (non-dense) constraints
    K_mv = staticmethod(pdhg.dense_K_mv)
    KT_mv = staticmethod(pdhg.dense_KT_mv)

    # --- solution handling -----------------------------------------------------
    def extract(self, op: OperatorLP, x: np.ndarray,
                idx_row: np.ndarray) -> np.ndarray:
        """Per-slot allocation rows [n_per, ...] from an LP solution."""
        raise NotImplementedError

    def evaluate(self, alloc: np.ndarray) -> dict:
        raise NotImplementedError


@dataclasses.dataclass
class POPResult:
    alloc: np.ndarray
    idx: np.ndarray
    solve_time_s: float
    build_time_s: float
    iterations: np.ndarray
    converged: np.ndarray
    similarity: dict
    sub_objectives: np.ndarray
    replication: Optional[ReplicationPlan] = None
    # raw stacked solver iterates [k, n_var]/[k, n_con] — the warm-start
    # state for online re-solves (``pop_solve(..., warm=prev_result)``)
    x: Optional[np.ndarray] = None
    y: Optional[np.ndarray] = None
    # the plan this result was computed under (reused/remapped by warm
    # re-solves) and, for warm solves, the remap statistics
    plan: Optional[PopPlan] = None
    warm_stats: Optional[dict] = None
    # observability: the backend/engine that ACTUALLY ran ("auto" resolved
    # — callers and benchmarks otherwise can't see what won), and where
    # the plan came from: "reused" (cache hit), "repaired" (incremental
    # re-plan under churn), "fresh" (new partition), "provided" (explicit
    # plan=)
    backend: Optional[str] = None
    engine: Optional[str] = None
    plan_source: Optional[str] = None
    # [k] per-lane divergence-quarantine flags from the solver (lanes whose
    # KKT score went non-finite or blew up; see pdhg.solve_stacked) — what
    # the service layer reads to cold-restart only the poisoned lanes
    diverged: Optional[np.ndarray] = None


# --------------------------------------------------------------------------
# map-step backends — the execution substrate lives in ``core/backends.py``;
# this alias keeps the historical ``pop.MAP_BACKENDS`` surface working
# --------------------------------------------------------------------------

MAP_BACKENDS = backends_mod.MAP_BACKENDS


# --------------------------------------------------------------------------
# stage 1: plan
# --------------------------------------------------------------------------

def plan(
    problem: POPProblem,
    k: int,
    *,
    strategy: str = "random",
    seed: int = 0,
    replicate_threshold: Optional[float] = None,
    partition_idx: Optional[np.ndarray] = None,
    entity_ids: Optional[np.ndarray] = None,
) -> PopPlan:
    """Partition (+ optionally replicate) ``problem`` into k subsets and
    return the reusable :class:`PopPlan`.  ``strategy`` ∈ {random,
    stratified, stratified_multidim}; an explicit ``partition_idx``
    overrides it (custom or adversarial splits).  ``replicate_threshold``
    enables §4.3 hot-entity replication.  ``entity_ids`` attaches stable
    external ids used to match entities across instances when warm-starting
    through churn."""
    n = problem.n_entities
    scores = np.asarray(problem.entity_scores(), np.float64)
    attrs = np.asarray(problem.entity_attrs(), np.float64)
    if attrs.ndim == 1:
        attrs = attrs[:, None]

    rep = None
    if partition_idx is not None:
        idx = np.asarray(partition_idx)
    elif replicate_threshold is not None:
        rep = plan_replication(scores, k, replicate_threshold)
        idx = replicated_partition(rep, scores, k, seed)
    else:
        idx = part_mod.make_partition(strategy, attrs, scores, n, k, seed)

    entity_of_slot = idx if rep is None else rep.entity_of(idx)
    # similarity diagnostics run on ORIGINAL entity ids
    sim = part_mod.similarity_report(attrs, entity_of_slot)
    layout = problem.sub_layout(idx.shape[1])
    if entity_ids is not None:
        entity_ids = np.asarray(entity_ids)
        if entity_ids.shape[0] != n:
            raise ValueError(f"entity_ids has {entity_ids.shape[0]} entries "
                             f"for {n} entities")
    return PopPlan(k=k, n_entities=n, idx=idx,
                   entity_of_slot=entity_of_slot, strategy=strategy,
                   seed=seed, replication=rep, entity_ids=entity_ids,
                   similarity=sim, layout=layout)


make_plan = plan     # alias: lets ``pop_solve(plan=...)`` shadow the name


# --------------------------------------------------------------------------
# stage 2: build
# --------------------------------------------------------------------------

def build(problem: POPProblem, pop_plan: PopPlan) -> OperatorLP:
    """Materialise the plan's k identically-shaped sub-LPs and stack them
    (``pdhg.stack_ops`` pads per-lane ELL widths to the stack maximum when
    the problem attaches :class:`~repro.core.pdhg.StructuredOperator`
    metadata).  Records the stacked shapes on the plan (what sizes warm
    remaps; ``"ell"`` carries the structured row/col widths so plan
    consumers can see when a rebuild changed the stacked kernel shapes)."""
    subs = []
    for i in range(pop_plan.k):
        subs.append(problem.build_sub(pop_plan.entity_of_slot[i],
                                      1.0 / pop_plan.k,
                                      scale=pop_plan.row_scale(i)))
    ops = pdhg.stack_ops(subs)
    pop_plan.shapes = {"x": tuple(ops.c.shape), "y": tuple(ops.q.shape)}
    if ops.structured is not None:
        s = ops.structured
        # every data-dependent ELL dim: narrow widths, wide-bucket widths
        # AND wide-bucket counts — any of them moving retraces the solve
        pop_plan.shapes["ell"] = (
            int(s.row_idx.shape[-2]), int(s.wrow_idx.shape[-2]),
            int(s.wrow_ids.shape[-1]),
            int(s.col_idx.shape[-2]), int(s.wcol_idx.shape[-2]),
            int(s.wcol_ids.shape[-1]))
    return ops


# --------------------------------------------------------------------------
# stage 3: solve (the map step)
# --------------------------------------------------------------------------

def solve(
    problem: POPProblem,
    pop_plan: PopPlan,
    ops: OperatorLP,
    *,
    backend: str = "auto",
    engine: str = "auto",
    solver_kw: Optional[dict] = None,
    backend_opts: Optional[dict] = None,
    warm=None,
) -> SolveResult:
    """Batched solve of the stacked sub-LPs through ``backends.solve_map``.
    ``warm`` is a :class:`~repro.core.plan.WarmStart` (masked, from
    ``remap_warm``), an (x, y) pair, or a SolveResult-like object."""
    res = backends_mod.solve_map(ops, problem.K_mv, problem.KT_mv,
                                 dict(solver_kw or {}), backend=backend,
                                 engine=engine, warm=warm,
                                 **(backend_opts or {}))
    jax.block_until_ready(res.x)
    return res


# --------------------------------------------------------------------------
# stage 4: reduce
# --------------------------------------------------------------------------

def reduce(problem: POPProblem, pop_plan: PopPlan, ops: OperatorLP,
           res: SolveResult) -> np.ndarray:
    """Coalesce per-lane allocations into the global one (scatter by entity
    id; replicated entities SUM their replica sub-allocations)."""
    allocs = np.stack([
        np.asarray(problem.extract(jax.tree.map(lambda a: a[i], ops),
                                   np.asarray(res.x[i]), pop_plan.idx[i]))
        for i in range(pop_plan.k)
    ])
    if pop_plan.replication is None:
        return coalesce_concat(allocs, pop_plan.idx, pop_plan.n_entities)
    return coalesce_replicated(allocs, pop_plan.idx, pop_plan.replication)


# --------------------------------------------------------------------------
# the one-call wrapper
# --------------------------------------------------------------------------

def _require_finite_ops(ops: OperatorLP, where: str) -> None:
    """Reject NaN/inf instance data before it reaches the solver.

    BIG-sentinel bounds are finite by construction (``core/problem.py``),
    so any genuine non-finite value in the built operator means the
    *instance* carried NaN/inf (bad rates, corrupted demands).  Raising
    here with the field name beats the alternative — a silently garbage
    allocation, or a divergence quarantine blamed on the warm start.
    Host-side scalar reads are fine at this boundary: it runs before the
    map-step backends (the steady-state host-sync tripwire arms around
    those only), and it is not reachable from ``solve_stacked``.
    """
    def _nonfinite(a) -> bool:
        a = jnp.asarray(a)
        if not jnp.issubdtype(a.dtype, jnp.floating):
            return False
        return not bool(jnp.all(jnp.isfinite(a)))

    for name in ("c", "q", "l", "u"):
        if _nonfinite(getattr(ops, name)):
            raise ValueError(
                f"non-finite instance data reached {where}: field {name!r} "
                "contains NaN/inf — fix the instance (rates/demands/bounds) "
                "before solving")
    for group_name, group in (("data", ops.data),
                              ("structured", ops.structured)):
        if group is None:
            continue
        leaves = jax.tree_util.tree_flatten_with_path(group)[0]
        for path, leaf in leaves:
            if _nonfinite(leaf):
                key = jax.tree_util.keystr(path)
                raise ValueError(
                    f"non-finite instance data reached {where}: operator "
                    f"field {group_name}{key} contains NaN/inf — fix the "
                    "instance (constraint matrices) before solving")


def _ids_or_positional(ids, n: int) -> np.ndarray:
    return np.arange(n) if ids is None else np.asarray(ids)


def _plan_fits(prev: PopPlan, problem: POPProblem, k: int,
               entity_ids: Optional[np.ndarray]) -> bool:
    """Can ``prev`` be reused verbatim for this instance?"""
    return (prev.k == k and prev.n_entities == problem.n_entities
            and np.array_equal(_ids_or_positional(entity_ids,
                                                  problem.n_entities),
                               prev.external_ids()))


def _plan_of(warm) -> Optional[PopPlan]:
    """The plan a warm result was computed under; reconstructed from the
    pre-plan (idx, replication) fields for results that predate PopPlan."""
    p = getattr(warm, "plan", None)
    if p is not None:
        return p
    idx = getattr(warm, "idx", None)
    if idx is None:
        return None
    rep = getattr(warm, "replication", None)
    ent = idx if rep is None else rep.entity_of(idx)
    n = int(ent.max()) + 1 if ent.size else 0
    return PopPlan(k=idx.shape[0], n_entities=n, idx=idx,
                   entity_of_slot=ent, replication=rep)


@dataclasses.dataclass
class PreparedSolve:
    """Stages plan+build of the pipeline, stopped at the map-step boundary.

    :func:`prepare_instance` produces one (plan resolved, sub-LPs built
    and stacked, warm start remapped, ``"auto"`` backend/engine resolved);
    the map-step launch itself — ``backends.get_backend(backend)(batch,
    ...)`` on ``backends.make_batch(ops, warm)`` — can then run anywhere
    (inline, or coalesced with other tenants' prepared solves by the
    serving dispatcher), and :func:`finish_prepared` turns the launch's
    :class:`SolveResult` back into a :class:`POPResult`.
    ``solve_instance`` is exactly ``prepare -> launch -> finish``."""

    problem: POPProblem
    plan: Optional[PopPlan]
    ops: OperatorLP
    warm: object                 # None | (x, y) | WarmStart
    warm_stats: Optional[dict]
    plan_source: str
    backend: str
    engine: object               # "matvec" | StepEngine
    opts: dict
    solver_kw: dict
    build_time_s: float


def prepare_instance(
    problem: POPProblem,
    solve_cfg: SolveConfig = SolveConfig(),
    exec_cfg: ExecConfig = ExecConfig(),
    *,
    warm: Optional[POPResult] = None,
    plan: Optional[PopPlan] = None,
    replan: bool = False,
    partition_idx: Optional[np.ndarray] = None,
    entity_ids: Optional[np.ndarray] = None,
    cold_lanes: Optional[np.ndarray] = None,
) -> PreparedSolve:
    """Everything :func:`solve_instance` does BEFORE the map-step launch:
    plan resolution (reuse / repair / fresh), sub-LP build + stack, warm
    start resolution (remap, quarantine masking), and ``"auto"``
    backend/engine resolution — returning a :class:`PreparedSolve` whose
    launch the caller owns.  See :func:`solve_instance` for the parameter
    semantics."""
    # honour the SolveConfig.min_per_sub promise HERE (the canonical
    # entry), not in each caller; without min_per_sub the requested k is
    # used verbatim (the historical pop_solve semantics)
    k = (solve_cfg.k if solve_cfg.min_per_sub is None
         else solve_cfg.k_for(problem.n_entities))
    solver_kw = exec_cfg.solver_dict()
    if warm is not None and getattr(warm, "x", None) is None:
        raise ValueError("warm result lacks solver state (x/y)")

    t0 = time.perf_counter()
    prev_plan = _plan_of(warm) if warm is not None else None
    # one side naming entities externally while the other matches by
    # position would pair arbitrary entities — refuse to match, start cold
    ids_agree = (prev_plan is None
                 or (prev_plan.entity_ids is None) == (entity_ids is None))
    source = "fresh"
    if plan is not None:
        p = plan
        source = "provided"
    elif (warm is not None and prev_plan is not None and not replan
          and partition_idx is None
          and solve_cfg.replicate_threshold is None and ids_agree):
        if _plan_fits(prev_plan, problem, k, entity_ids):
            p = prev_plan
            source = "reused"
        elif prev_plan.k == k and prev_plan.replication is None:
            # entity churn at the same k: repair the old plan in place —
            # survivors keep their (lane, slot), so the remapped warm start
            # lands in an unchanged lane context (see plan.repair_plan)
            p = repair_plan(prev_plan, problem, entity_ids=entity_ids)
            source = "repaired"
        else:
            p = make_plan(problem, k, strategy=solve_cfg.strategy,
                          seed=solve_cfg.seed, entity_ids=entity_ids)
    else:
        p = make_plan(problem, k, strategy=solve_cfg.strategy,
                      seed=solve_cfg.seed,
                      replicate_threshold=solve_cfg.replicate_threshold,
                      partition_idx=partition_idx, entity_ids=entity_ids)
    ops = build(problem, p)
    _require_finite_ops(ops, "solve_instance")
    build_time = time.perf_counter() - t0

    warm_in = None
    warm_stats = None
    if warm is not None:
        if source == "reused":
            # identity churn: the PR-2 path, previous iterates verbatim
            warm_in = (warm.x, warm.y)
            n_live = int((p.entity_of_slot >= 0).sum())
            warm_stats = dict(warm_fraction=1.0, matched=n_live, fresh=0,
                              dropped=0, lanes_cold=0, identity=True)
        elif not ids_agree:
            warm_stats = dict(warm_fraction=0.0, matched=0, fresh=0,
                              dropped=0, lanes_cold=k, identity=False,
                              reason="entity id spaces differ (one side has "
                                     "entity_ids, the other is positional)")
        elif prev_plan is not None:
            ws = remap_warm(prev_plan, p, warm, ops=ops)
            warm_in = ws
            warm_stats = ws.stats

    if cold_lanes is not None and warm_in is not None:
        # divergence quarantine: poisoned lanes restart cold, survivors
        # keep their iterates (a data-level mask — same jit cache key)
        cl = np.asarray(cold_lanes, bool).reshape(-1)
        if cl.shape[0] != p.k:
            raise ValueError(f"cold_lanes has {cl.shape[0]} entries for "
                             f"k={p.k} lanes")
        if isinstance(warm_in, WarmStart):
            wx, wy = warm_in.x, warm_in.y
            mask = np.asarray(warm_in.mask, bool) & ~cl
            stats = dict(warm_in.stats or {})
        else:
            wx, wy = warm_in
            mask = ~cl
            stats = dict(warm_stats or {})
        stats["quarantined_lanes"] = int(cl.sum())
        stats["lanes_cold"] = int((~mask).sum())
        stats["warm_fraction"] = float(
            stats.get("warm_fraction", 1.0) * mask.mean()) if p.k else 0.0
        stats["identity"] = False
        warm_in = WarmStart(x=wx, y=wy, mask=mask, stats=stats)
        warm_stats = stats

    # resolve "auto" specs HERE so the result can report what actually ran
    backend_name, engine_run, opts = backends_mod.resolve_exec(
        ops, problem.K_mv, problem.KT_mv, exec_cfg.backend, exec_cfg.engine,
        exec_cfg.opts_dict())
    return PreparedSolve(
        problem=problem, plan=p, ops=ops, warm=warm_in,
        warm_stats=warm_stats, plan_source=source, backend=backend_name,
        engine=engine_run, opts=opts, solver_kw=solver_kw,
        build_time_s=build_time)


def finish_prepared(prep: PreparedSolve, res: SolveResult,
                    solve_time_s: float) -> POPResult:
    """Stage 4 for a :class:`PreparedSolve` whose launch already ran:
    reduce per-lane allocations and assemble the :class:`POPResult`."""
    p = prep.plan
    alloc = reduce(prep.problem, p, prep.ops, res)
    return POPResult(
        alloc=alloc, idx=p.idx,
        solve_time_s=solve_time_s, build_time_s=prep.build_time_s,
        iterations=np.asarray(res.iterations),
        converged=np.asarray(res.converged),
        similarity=p.similarity or {},
        sub_objectives=np.asarray(res.primal_obj),
        replication=p.replication,
        x=np.asarray(res.x), y=np.asarray(res.y),
        plan=p, warm_stats=prep.warm_stats,
        backend=prep.backend, engine=pdhg.engine_name(prep.engine),
        plan_source=prep.plan_source,
        diverged=(None if res.diverged is None
                  else np.asarray(res.diverged)),
    )


def solve_instance(
    problem: POPProblem,
    solve_cfg: SolveConfig = SolveConfig(),
    exec_cfg: ExecConfig = ExecConfig(),
    *,
    warm: Optional[POPResult] = None,
    plan: Optional[PopPlan] = None,
    replan: bool = False,
    partition_idx: Optional[np.ndarray] = None,
    entity_ids: Optional[np.ndarray] = None,
    cold_lanes: Optional[np.ndarray] = None,
) -> POPResult:
    """Run POP on ``problem``: :func:`plan` -> :func:`build` ->
    :func:`solve` -> :func:`reduce` in one call, configured by the two
    frozen config dataclasses (``core/config.py``): :class:`SolveConfig`
    says how to split (k, strategy, replication), :class:`ExecConfig` how
    to execute (backend, engine, solver keywords).  This is the canonical
    pipeline entry — :class:`~repro.service.PopService` sessions call it
    per step, and the legacy :func:`pop_solve` kwarg surface forwards
    here.  (Internally it is :func:`prepare_instance` -> the map-step
    launch -> :func:`finish_prepared`; the serving dispatcher drives those
    stages separately to coalesce concurrent tenants into one launch.)

    ``warm`` re-solves an UPDATED instance from a previous
    :class:`POPResult`.  While the instance shape is unchanged the previous
    plan is reused and every lane continues from its previous (x, y)
    iterates; across entity arrivals/departures, k changes or forced
    re-planning (``replan=True`` / explicit ``plan=``) the old iterates
    are remapped onto the new plan (see module docstring).  ``entity_ids``
    names entities stably across instances for that matching;
    ``partition_idx`` overrides the strategy with an explicit split.

    The result reports the backend/engine that ACTUALLY ran (``"auto"``
    resolved) and where its plan came from (``plan_source``: "reused" /
    "repaired" / "fresh" / "provided") — the observability the service
    plan cache and the benchmarks aggregate.

    ``cold_lanes`` ([k] bool) forces those lanes to start cold even when a
    warm start is supplied — the divergence-quarantine retry path:
    ``PopSession.step`` re-solves with ``plan=prev.plan`` and
    ``cold_lanes=prev.diverged`` so only the poisoned lanes restart while
    healthy lanes keep their iterates."""
    prep = prepare_instance(
        problem, solve_cfg, exec_cfg, warm=warm, plan=plan, replan=replan,
        partition_idx=partition_idx, entity_ids=entity_ids,
        cold_lanes=cold_lanes)
    t1 = time.perf_counter()
    res = solve(problem, prep.plan, prep.ops, backend=prep.backend,
                engine=prep.engine, solver_kw=prep.solver_kw,
                backend_opts=prep.opts, warm=prep.warm)
    return finish_prepared(prep, res, time.perf_counter() - t1)


def pop_solve(
    problem: POPProblem,
    k: int,
    *,
    strategy: str = "random",
    backend: str = "auto",
    engine: str = "auto",
    seed: int = 0,
    replicate_threshold: Optional[float] = None,
    partition_idx: Optional[np.ndarray] = None,
    solver_kw: Optional[dict] = None,
    backend_opts: Optional[dict] = None,
    warm: Optional[POPResult] = None,
    plan: Optional[PopPlan] = None,
    replan: bool = False,
    entity_ids: Optional[np.ndarray] = None,
) -> POPResult:
    """DEPRECATED kwarg surface over :func:`solve_instance` — collapse the
    loose kwargs into a :class:`SolveConfig` + :class:`ExecConfig` (or use
    a :class:`~repro.service.PopService` session for online re-solves) and
    call :func:`solve_instance`; results are bit-identical.  Kept as a
    thin forwarder so existing callers keep working."""
    warnings.warn(
        "pop_solve(problem, k, ...) is deprecated: use "
        "pop.solve_instance(problem, SolveConfig(k=..., strategy=...), "
        "ExecConfig(...)) or a repro.service.PopService session — results "
        "are identical when the configs mirror these kwargs (NOTE: "
        "SolveConfig defaults strategy='stratified'; pop_solve's default "
        "was 'random')",
        DeprecationWarning, stacklevel=2)
    return solve_instance(
        problem,
        SolveConfig(k=k, strategy=strategy, seed=seed,
                    replicate_threshold=replicate_threshold),
        ExecConfig(backend=backend, engine=engine,
                   solver_kw=dict(solver_kw or {}),
                   backend_opts=dict(backend_opts or {})),
        warm=warm, plan=plan, replan=replan, partition_idx=partition_idx,
        entity_ids=entity_ids)


@dataclasses.dataclass
class FullResult:
    """Unpartitioned (k=1) solve outcome, with the same observability as
    :class:`POPResult` (resolved backend/engine)."""

    alloc: np.ndarray
    res: SolveResult
    solve_time_s: float
    build_time_s: float
    backend: Optional[str] = None
    engine: Optional[str] = None


def prepare_full(problem: POPProblem, *,
                 warm: Optional[SolveResult] = None,
                 exec_cfg: Optional[ExecConfig] = None) -> PreparedSolve:
    """The pre-launch half of :func:`solve_full_ex`: build the full LP as
    a k=1 stack, resolve ``"auto"`` backend/engine on it, and batch the
    warm iterates — returning a :class:`PreparedSolve` (``plan=None``,
    ``plan_source="full"``) whose single-lane launch the caller owns (the
    serving dispatcher coalesces compatible k=1 stacks from concurrent
    tenants into one multi-lane launch)."""
    exec_cfg = exec_cfg or ExecConfig()
    solver_kw = exec_cfg.solver_dict()
    t0 = time.perf_counter()
    op = problem.build_full()
    _require_finite_ops(op, "solve_full_ex")
    build_time = time.perf_counter() - t0
    opb = jax.tree.map(lambda a: jnp.asarray(a)[None], op)
    backend_name, engine_run, opts = backends_mod.resolve_exec(
        opb, problem.K_mv, problem.KT_mv, exec_cfg.backend, exec_cfg.engine,
        exec_cfg.opts_dict())
    if warm is not None:
        if hasattr(warm, "x") and hasattr(warm, "y"):
            warm = (warm.x, warm.y)
        warm = tuple(jnp.asarray(w)[None] for w in warm)
    return PreparedSolve(
        problem=problem, plan=None, ops=opb, warm=warm, warm_stats=None,
        plan_source="full", backend=backend_name, engine=engine_run,
        opts=opts, solver_kw=solver_kw, build_time_s=build_time)


def finish_full(prep: PreparedSolve, res: SolveResult,
                solve_time_s: float) -> FullResult:
    """Unbatch a :func:`prepare_full` launch's k=1 result and extract the
    allocation — the post-launch half of :func:`solve_full_ex`."""
    res1 = jax.tree.map(lambda a: a[0], res)
    op = jax.tree.map(lambda a: a[0], prep.ops)
    idx = np.arange(prep.problem.n_entities)
    alloc = np.asarray(prep.problem.extract(op, np.asarray(res1.x), idx))
    return FullResult(alloc=alloc, res=res1, solve_time_s=solve_time_s,
                      build_time_s=prep.build_time_s, backend=prep.backend,
                      engine=pdhg.engine_name(prep.engine))


def solve_full_ex(problem: POPProblem, *,
                  warm: Optional[SolveResult] = None,
                  exec_cfg: Optional[ExecConfig] = None) -> FullResult:
    """Unpartitioned baseline (the paper's 'original problem') as a k=1
    stack through the SAME execution substrate as the POP path — so
    full-problem baselines get the fused step engine, explicit backend
    selection and the jit-cached map solver too.  Everything about the
    execution (including ``solver_kw``) comes from ``exec_cfg``; ``warm``
    re-solves from a previous full-problem :class:`SolveResult`.  Returns
    a :class:`FullResult` reporting the resolved backend/engine."""
    prep = prepare_full(problem, warm=warm, exec_cfg=exec_cfg)
    t1 = time.perf_counter()
    res = backends_mod.solve_map(
        prep.ops, problem.K_mv, problem.KT_mv, prep.solver_kw,
        backend=prep.backend, engine=prep.engine, warm=prep.warm,
        **prep.opts)
    jax.block_until_ready(res.x)
    return finish_full(prep, res, time.perf_counter() - t1)


def solve_full(problem: POPProblem, solver_kw: Optional[dict] = None,
               warm: Optional[SolveResult] = None, *,
               backend: str = "auto", engine: str = "auto",
               backend_opts: Optional[dict] = None):
    """Tuple-returning wrapper over :func:`solve_full_ex` (the historical
    surface: ``(alloc, res, solve_time, build_time)``)."""
    r = solve_full_ex(
        problem, warm=warm,
        exec_cfg=ExecConfig(backend=backend, engine=engine,
                            solver_kw=dict(solver_kw or {}),
                            backend_opts=dict(backend_opts or {})))
    return r.alloc, r.res, r.solve_time_s, r.build_time_s
