"""POP orchestrator: split -> map (batched solve) -> reduce.

This is the paper's technique as a composable module.  A domain problem
(cluster scheduling, traffic engineering, load balancing, MoE expert
placement, ...) subclasses :class:`POPProblem`; ``pop_solve`` then

  1. partitions entities into k self-similar subsets (``core/partition.py``),
     optionally replicating hot entities (``core/replicate.py``),
  2. builds k identically-shaped sub-LPs and STACKS them on a leading axis,
  3. solves them as ONE batched PDHG solve through a pluggable execution
     backend (``core/backends.py``: serial / vmap / chunked_vmap /
     shard_map / pmap — sub-problems are independent, so the map step
     needs ZERO collectives; this is the whole point of POP), and
  4. coalesces sub-allocations (``core/reduce.py``).

``solve_full`` runs the unpartitioned baseline (k=1 path) for quality
comparison — the paper's "original problem formulation".
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import backends as backends_mod
from . import partition as part_mod
from . import pdhg
from .pdhg import OperatorLP, SolveResult
from .replicate import ReplicationPlan, plan_replication, replicated_partition
from .reduce import coalesce_concat, coalesce_replicated


class POPProblem:
    """Interface a domain problem implements to be POP-able.

    Subclasses define how to build the full LP and any entity-subset sub-LP
    (in operator form), how to pull the per-entity allocation out of the LP
    solution vector, and how to score an allocation.
    """

    n_entities: int

    # --- partitioning inputs -------------------------------------------------
    def entity_attrs(self) -> np.ndarray:
        """[n, d] attribute vectors (for similarity + stratification)."""
        raise NotImplementedError

    def entity_scores(self) -> np.ndarray:
        """[n] scalar load/demand (stratification + replication)."""
        attrs = self.entity_attrs()
        return attrs[:, 0] if attrs.ndim == 2 else attrs

    # --- LP construction ------------------------------------------------------
    def build_sub(self, idx_row: np.ndarray, frac: float,
                  scale: Optional[np.ndarray] = None) -> OperatorLP:
        """Sub-LP over entities ``idx_row`` (-1 = padded slot) with ``frac``
        of every resource.  ``scale`` (replication) multiplies per-entity
        demand.  MUST return identical array shapes for identical row
        lengths, so sub-problems stack."""
        raise NotImplementedError

    def build_full(self) -> OperatorLP:
        return self.build_sub(np.arange(self.n_entities), 1.0)

    # operator matvecs — override for structured (non-dense) constraints
    K_mv = staticmethod(pdhg.dense_K_mv)
    KT_mv = staticmethod(pdhg.dense_KT_mv)

    # --- solution handling -----------------------------------------------------
    def extract(self, op: OperatorLP, x: np.ndarray,
                idx_row: np.ndarray) -> np.ndarray:
        """Per-slot allocation rows [n_per, ...] from an LP solution."""
        raise NotImplementedError

    def evaluate(self, alloc: np.ndarray) -> dict:
        raise NotImplementedError


@dataclasses.dataclass
class POPResult:
    alloc: np.ndarray
    idx: np.ndarray
    solve_time_s: float
    build_time_s: float
    iterations: np.ndarray
    converged: np.ndarray
    similarity: dict
    sub_objectives: np.ndarray
    replication: Optional[ReplicationPlan] = None
    # raw stacked solver iterates [k, n_var]/[k, n_con] — the warm-start
    # state for online re-solves (``pop_solve(..., warm=prev_result)``)
    x: Optional[np.ndarray] = None
    y: Optional[np.ndarray] = None


# --------------------------------------------------------------------------
# map-step backends — the execution substrate lives in ``core/backends.py``;
# this alias keeps the historical ``pop.MAP_BACKENDS`` surface working
# --------------------------------------------------------------------------

MAP_BACKENDS = backends_mod.MAP_BACKENDS


# --------------------------------------------------------------------------
# the POP pipeline
# --------------------------------------------------------------------------

def pop_solve(
    problem: POPProblem,
    k: int,
    *,
    strategy: str = "random",
    backend: str = "auto",
    engine: str = "auto",
    seed: int = 0,
    replicate_threshold: Optional[float] = None,
    partition_idx: Optional[np.ndarray] = None,
    solver_kw: Optional[dict] = None,
    backend_opts: Optional[dict] = None,
    warm: Optional[POPResult] = None,
) -> POPResult:
    """Run POP-k on ``problem``.  ``strategy`` ∈ {random, stratified, skewed-*}
    (domain problems may pass an explicit ``partition_idx`` for custom or
    adversarial splits).  ``replicate_threshold`` enables §4.3 hot-entity
    replication.  ``backend`` names a map-step backend from
    ``core/backends.py`` (``"auto"`` picks by k, device count and problem
    size); ``engine`` a PDHG step engine from ``core/pdhg.py`` (``"auto"``:
    fused kernels for dense data on TPU, operator matvecs otherwise);
    ``backend_opts`` are forwarded to the backend (e.g. ``chunk=``,
    ``mesh=``).

    ``warm`` re-solves an UPDATED instance from a previous :class:`POPResult`
    (online path: perturbed throughputs/loads, same entities): the previous
    partition is reused so sub-problem shapes line up, and every lane starts
    from its previous (x, y) iterates instead of cold."""
    solver_kw = dict(solver_kw or {})
    n = problem.n_entities
    scores = np.asarray(problem.entity_scores(), np.float64)
    attrs = np.asarray(problem.entity_attrs(), np.float64)
    if attrs.ndim == 1:
        attrs = attrs[:, None]

    t0 = time.perf_counter()
    plan = None
    rep_scale = None
    if warm is not None:
        if warm.x is None or warm.idx.shape[0] != k:
            raise ValueError("warm result lacks solver state or was computed "
                             f"with k={warm.idx.shape[0]} != {k}")
        idx = warm.idx
        plan = warm.replication
        rep_scale = plan.replica_scale if plan is not None else None
    elif partition_idx is not None:
        idx = partition_idx
    elif replicate_threshold is not None:
        plan = plan_replication(scores, k, replicate_threshold)
        idx = replicated_partition(plan, scores, k, seed)
        rep_scale = plan.replica_scale
    elif strategy == "random":
        idx = part_mod.random_partition(n, k, seed)
    elif strategy == "stratified":
        idx = part_mod.stratified_partition(scores, k)
    elif strategy == "stratified_multidim":
        idx = part_mod.stratified_partition_multidim(attrs, k, seed)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    # similarity diagnostics run on ORIGINAL entity ids
    if plan is None:
        sim = part_mod.similarity_report(attrs, idx)
    else:
        orig_idx = np.where(idx >= 0, plan.replica_entity[np.maximum(idx, 0)], -1)
        sim = part_mod.similarity_report(attrs, orig_idx)

    # build k identically-shaped sub-LPs and stack them
    subs = []
    for i in range(k):
        row = idx[i]
        row_scale = None
        if rep_scale is not None:
            row_scale = np.where(row >= 0, rep_scale[np.maximum(row, 0)], 0.0)
        if plan is not None:
            row = np.where(row >= 0, plan.replica_entity[np.maximum(row, 0)], -1)
        subs.append(problem.build_sub(row, 1.0 / k, scale=row_scale))
    ops = jax.tree.map(lambda *xs: jnp.stack(xs), *subs)
    build_time = time.perf_counter() - t0

    t1 = time.perf_counter()
    warm_xy = None if warm is None else (warm.x, warm.y)
    res = backends_mod.solve_map(ops, problem.K_mv, problem.KT_mv, solver_kw,
                                 backend=backend, engine=engine, warm=warm_xy,
                                 **(backend_opts or {}))
    jax.block_until_ready(res.x)
    solve_time = time.perf_counter() - t1

    # reduce
    allocs = np.stack([
        np.asarray(problem.extract(jax.tree.map(lambda a: a[i], ops),
                                   np.asarray(res.x[i]), idx[i]))
        for i in range(k)
    ])
    if plan is None:
        alloc = coalesce_concat(allocs, idx, n)
    else:
        alloc = coalesce_replicated(allocs, idx, plan)

    return POPResult(
        alloc=alloc, idx=idx,
        solve_time_s=solve_time, build_time_s=build_time,
        iterations=np.asarray(res.iterations),
        converged=np.asarray(res.converged),
        similarity=sim,
        sub_objectives=np.asarray(res.primal_obj),
        replication=plan,
        x=np.asarray(res.x), y=np.asarray(res.y),
    )


def solve_full(problem: POPProblem, solver_kw: Optional[dict] = None,
               warm: Optional[SolveResult] = None):
    """Unpartitioned baseline (the paper's 'original problem').  ``warm``
    re-solves from a previous full-problem :class:`SolveResult`."""
    solver_kw = dict(solver_kw or {})
    t0 = time.perf_counter()
    op = problem.build_full()
    build_time = time.perf_counter() - t0
    t1 = time.perf_counter()
    fn = jax.jit(functools.partial(pdhg.solve, K_mv=problem.K_mv,
                                   KT_mv=problem.KT_mv, **solver_kw))
    res = (fn(op) if warm is None
           else fn(op, warm_x=jnp.asarray(warm.x), warm_y=jnp.asarray(warm.y)))
    jax.block_until_ready(res.x)
    solve_time = time.perf_counter() - t1
    idx = np.arange(problem.n_entities)
    alloc = np.asarray(problem.extract(op, np.asarray(res.x), idx))
    return alloc, res, solve_time, build_time
