"""POP reduce step: coalesce sub-problem allocations into a global one.

Because the straightforward POP split assigns disjoint entity subsets and
disjoint resource slices, the reduce step is a *concatenation* (scatter by
entity id).  With hot-entity replication (paper §4.3) an entity owns several
replicas across sub-problems and its final allocation is the SUM of replica
sub-allocations.
"""

from __future__ import annotations

import numpy as np

from .replicate import ReplicationPlan


def coalesce_concat(sub_alloc: np.ndarray, idx: np.ndarray, n: int) -> np.ndarray:
    """Scatter per-sub allocations back to global entity order.

    sub_alloc : [k, n_per, ...] allocation rows per sub-problem slot
    idx       : [k, n_per] entity id per slot (-1 = padding)
    returns   : [n, ...]
    """
    out = np.zeros((n,) + sub_alloc.shape[2:], sub_alloc.dtype)
    valid = idx >= 0
    out[idx[valid]] = sub_alloc[valid]
    return out


def coalesce_replicated(sub_alloc: np.ndarray, idx: np.ndarray,
                        plan: ReplicationPlan) -> np.ndarray:
    """Sum replica allocations into original-entity allocations."""
    out = np.zeros((plan.n_original,) + sub_alloc.shape[2:], sub_alloc.dtype)
    valid = idx >= 0
    replica_ids = idx[valid]
    np.add.at(out, plan.replica_entity[replica_ids], sub_alloc[valid])
    return out
