"""POP map-step execution substrate: pluggable backends for the batched solve.

POP's whole speedup lives in the map step — k independent sub-LPs solved
with ZERO collectives (they share no variables by construction).  How those
k solves are *executed* is an orthogonal choice, so it lives here as a
registry of interchangeable backends, all with the same contract:

    backend(ops, K_mv, KT_mv, solver_kw, **opts) -> SolveResult

where ``ops`` is an :class:`~repro.core.pdhg.OperatorLP` pytree stacked on
a leading axis of length k, and the result carries the same leading axis.
Backends differ only in scheduling, never in math — every backend must
match ``vmap`` to float tolerance (enforced by ``tests/test_backends.py``).

Registered backends:

``serial``
    Python loop over the k sub-problems, one jitted solve each.  The
    reference/debugging backend: what the other four must reproduce.
``vmap``
    One batched solve on one device.  Best below the device-memory knee.
``chunked_vmap``
    ``lax.map`` over fixed-size vmapped chunks: peak memory is bounded by
    the chunk size, not k, so huge k fits on one device at the cost of a
    sequential walk over chunks.
``shard_map``
    Sub-problems spread over a mesh axis, vmapped within each shard.  k is
    padded up to a multiple of the device count with dummy sub-problems
    (replicas of sub-problem 0) and the padding is sliced off afterwards —
    no device idles, and results are bit-identical to the unpadded solve
    (each lane is independent, so extra lanes cannot perturb real ones).
``pmap``
    Same layout via ``jax.pmap`` — the fallback for JAX versions or
    platforms where shard_map misbehaves.

``backend="auto"`` picks by device count, k, and per-sub-problem size
(:func:`select_backend`).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import compat
from . import pdhg
from .pdhg import OperatorLP, SolveResult

MapBackend = Callable[..., SolveResult]

MAP_BACKENDS: Dict[str, MapBackend] = {}

# chunked_vmap default chunk; auto-selection switches off plain vmap above
# this many sub-problems (CPU-sized default — meshes usually decide first)
DEFAULT_CHUNK = 16
AUTO_VMAP_MAX_K = 64
# ... or above this many floats of stacked problem data (~256 MB fp32)
AUTO_VMAP_MAX_ELEMS = 64_000_000


def register_backend(name: str) -> Callable[[MapBackend], MapBackend]:
    def deco(fn: MapBackend) -> MapBackend:
        MAP_BACKENDS[name] = fn
        return fn
    return deco


def available_backends() -> tuple:
    return tuple(MAP_BACKENDS)


def get_backend(name: str) -> MapBackend:
    if name not in MAP_BACKENDS:
        raise ValueError(
            f"unknown map backend {name!r}; registered: {sorted(MAP_BACKENDS)}")
    return MAP_BACKENDS[name]


# --------------------------------------------------------------------------
# padding: k -> multiple of the device axis
# --------------------------------------------------------------------------

def batch_size(ops: OperatorLP) -> int:
    return jax.tree.leaves(ops)[0].shape[0]


def pad_to_multiple(ops: OperatorLP, m: int):
    """Pad the stacked sub-problem axis to a multiple of ``m`` by repeating
    sub-problem 0.  Returns ``(padded_ops, k)`` with the ORIGINAL k, so the
    caller slices ``[:k]`` off every result leaf.  Dummy lanes solve a real
    (already-solved-elsewhere) LP and are discarded; lanes are independent,
    so the real lanes' trajectories are unchanged."""
    k = batch_size(ops)
    pad = (-k) % m
    if pad == 0:
        return ops, k
    padded = jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])]),
        ops)
    return padded, k


def _slice_result(res: SolveResult, k: int) -> SolveResult:
    return jax.tree.map(lambda a: a[:k], res)


def _vmapped_solve(K_mv, KT_mv, solver_kw):
    return jax.vmap(lambda o: pdhg.solve(o, K_mv, KT_mv, **solver_kw))


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------

@register_backend("serial")
def solve_serial(ops: OperatorLP, K_mv, KT_mv, solver_kw) -> SolveResult:
    """One jitted solve per sub-problem, in a Python loop.  Slowest and
    simplest — the numerical reference the parallel backends must match."""
    fn = jax.jit(lambda o: pdhg.solve(o, K_mv, KT_mv, **solver_kw))
    outs = [fn(jax.tree.map(lambda a: a[i], ops))
            for i in range(batch_size(ops))]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


@register_backend("vmap")
def solve_vmap(ops: OperatorLP, K_mv, KT_mv, solver_kw) -> SolveResult:
    return jax.jit(_vmapped_solve(K_mv, KT_mv, solver_kw))(ops)


@register_backend("chunked_vmap")
def solve_chunked_vmap(ops: OperatorLP, K_mv, KT_mv, solver_kw,
                       chunk: int = DEFAULT_CHUNK) -> SolveResult:
    """``lax.map`` over vmapped chunks: peak memory ~ one chunk of
    sub-problems instead of all k.  k pads up to a chunk multiple."""
    k = batch_size(ops)
    chunk = max(1, min(chunk, k))
    padded, _ = pad_to_multiple(ops, chunk)
    k_pad = batch_size(padded)
    chunked = jax.tree.map(
        lambda a: a.reshape((k_pad // chunk, chunk) + a.shape[1:]), padded)
    inner = _vmapped_solve(K_mv, KT_mv, solver_kw)
    res = jax.jit(lambda c: jax.lax.map(inner, c))(chunked)
    res = jax.tree.map(lambda a: a.reshape((k_pad,) + a.shape[2:]), res)
    return _slice_result(res, k)


@register_backend("shard_map")
def solve_shard_map(ops: OperatorLP, K_mv, KT_mv, solver_kw,
                    mesh: Optional[Mesh] = None,
                    axis: str = "pop",
                    chunk: Optional[int] = None) -> SolveResult:
    """Shard the k sub-problems over a mesh axis; vmap within each shard.
    No collectives in the mapped body — POP sub-problems are independent
    by construction.  Goes through :mod:`repro.core.compat` so it runs on
    any JAX that has shard_map under either name/kwarg spelling.

    ``chunk`` bounds per-device memory the same way chunked_vmap does on
    one device: each shard walks its lanes in vmapped chunks of that size
    (``None`` = decide from the per-device share: chunk only when it
    exceeds the single-device vmap ceiling; ``0`` = never chunk)."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (axis,))
    n_dev = mesh.shape[axis]
    if chunk is None:
        per_dev = -(-batch_size(ops) // n_dev)
        heavy = (per_dev > AUTO_VMAP_MAX_K
                 or per_dev * max(_n_elems_per_sub(ops), 1)
                 > AUTO_VMAP_MAX_ELEMS)
        chunk = DEFAULT_CHUNK if heavy else 0
    padded, k = pad_to_multiple(ops, n_dev * chunk if chunk else n_dev)

    inner = _vmapped_solve(K_mv, KT_mv, solver_kw)
    if chunk:
        def local_solve(local_ops):
            chunked = jax.tree.map(
                lambda a: a.reshape((a.shape[0] // chunk, chunk)
                                    + a.shape[1:]), local_ops)
            res = jax.lax.map(inner, chunked)
            return jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), res)
    else:
        local_solve = inner
    spec = jax.tree.map(lambda _: P(axis), padded)
    out_spec = jax.tree.map(lambda _: P(axis),
                            jax.eval_shape(local_solve, padded))
    fn = compat.shard_map(local_solve, mesh=mesh, in_specs=(spec,),
                          out_specs=out_spec,
                          # solver constants (power-iteration seed vectors)
                          # are unvarying while problem data varies over the
                          # POP axis — exactly the intent; skip the check
                          check=False)
    return _slice_result(jax.jit(fn)(padded), k)


@register_backend("pmap")
def solve_pmap(ops: OperatorLP, K_mv, KT_mv, solver_kw,
               devices: Optional[list] = None) -> SolveResult:
    """Per-device vmapped shards via ``jax.pmap`` — fallback when shard_map
    is unusable on the installed JAX/platform."""
    devices = list(devices if devices is not None else jax.devices())
    n_dev = len(devices)
    padded, k = pad_to_multiple(ops, n_dev)
    k_pad = batch_size(padded)
    sharded = jax.tree.map(
        lambda a: a.reshape((n_dev, k_pad // n_dev) + a.shape[1:]), padded)
    fn = jax.pmap(_vmapped_solve(K_mv, KT_mv, solver_kw), devices=devices)
    res = fn(sharded)
    res = jax.tree.map(lambda a: a.reshape((k_pad,) + a.shape[2:]), res)
    return _slice_result(res, k)


# --------------------------------------------------------------------------
# auto-selection + entry point
# --------------------------------------------------------------------------

def select_backend(k: int, n_elems_per_sub: int = 0,
                   n_dev: Optional[int] = None) -> str:
    """Pick a backend from (k, per-sub-problem element count, devices).

    Multi-device and enough sub-problems to fill the mesh -> ``shard_map``
    (each device solves its own lanes, zero communication).  Single device
    -> ``vmap`` until the stacked batch gets big (many lanes or a large
    stacked footprint), then ``chunked_vmap`` to bound peak memory.
    """
    n_dev = compat.device_count() if n_dev is None else n_dev
    if n_dev > 1 and k >= n_dev:
        # memory-safe at any k: solve_shard_map self-chunks each shard when
        # the per-device share exceeds the single-device vmap ceiling
        return "shard_map"
    if k > AUTO_VMAP_MAX_K or k * max(n_elems_per_sub, 1) > AUTO_VMAP_MAX_ELEMS:
        return "chunked_vmap"
    return "vmap"


def _n_elems_per_sub(ops: OperatorLP) -> int:
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(ops))


def solve_map(ops: OperatorLP, K_mv, KT_mv, solver_kw: Optional[dict] = None,
              backend: str = "auto", **opts: Any) -> SolveResult:
    """Run the POP map step on stacked ``ops`` with the named backend
    (``"auto"`` resolves via :func:`select_backend`).

    Under ``"auto"``, opts the chosen backend doesn't take (e.g. ``chunk=``
    when it resolves to vmap) are dropped — they are hints for *whichever*
    backend wins, not requirements.  An explicitly named backend still
    rejects unknown opts."""
    solver_kw = dict(solver_kw or {})
    if backend == "auto":
        backend = select_backend(batch_size(ops), _n_elems_per_sub(ops))
        if opts:
            import inspect
            accepted = inspect.signature(get_backend(backend)).parameters
            opts = {k: v for k, v in opts.items() if k in accepted}
    return get_backend(backend)(ops, K_mv, KT_mv, solver_kw, **opts)
