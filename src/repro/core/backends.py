"""POP map-step execution substrate: pluggable backends for the batched solve.

POP's whole speedup lives in the map step — k independent sub-LPs solved
with ZERO collectives (they share no variables by construction).  How those
k solves are *executed* is an orthogonal choice, so it lives here as a
registry of interchangeable backends, all with the same contract:

    backend(batch, K_mv, KT_mv, solver_kw, engine=..., **opts) -> SolveResult

where ``batch = (ops, warm_x, warm_y)``: an :class:`~repro.core.pdhg.
OperatorLP` pytree stacked on a leading axis of length k plus the starting
iterates for every lane (cold starts are materialised up front by
:func:`solve_map`, so warm-started online re-solves flow through exactly
the same code path as cold ones).  The result carries the same leading
axis.  Backends differ only in scheduling, never in math — every backend
must match ``vmap`` to float tolerance (``tests/test_backends.py``).

Four *step engines* (see ``core/pdhg.py``) plug into every backend:
``engine="matvec"`` vmaps the per-problem operator matvecs (any structured
LP), ``engine="fused"`` hands the whole stacked batch to the fused Pallas
matmul kernels in one launch per half-step (dense LPs; compiled on TPU,
XLA-fused reference elsewhere), ``engine="fused_structured"`` does the
same through batched gather/segment-reduce kernels for operators carrying
:class:`~repro.core.pdhg.StructuredOperator` index metadata (the
segment-sum matvecs of the structured paper domains), and
``engine="fused_structured_full"`` is the M-blocked streaming variant for
the single-lane unpartitioned problem (the ``solve_full`` baseline at
paper scale).  ``engine="auto"`` picks per
:func:`repro.core.pdhg.select_engine` — structured-fused whenever index
metadata is present, the streaming full engine when additionally
single-lane with large wide buckets.  :func:`resolve_exec` resolves specs
*outside* jit with concrete operators, which is what lets the full
engine's static ragged wide-block plan be computed from values.

Registered backends:

``serial``
    Python loop over the k sub-problems, one jitted k=1 solve each.  The
    reference/debugging backend: what the other four must reproduce.
``vmap``
    One batched solve on one device.  Best below the device-memory knee.
``chunked_vmap``
    ``lax.map`` over fixed-size batched chunks: peak memory is bounded by
    the chunk size, not k, so huge k fits on one device at the cost of a
    sequential walk over chunks.
``shard_map``
    Sub-problems spread over a mesh axis, solved batched within each
    shard.  k is padded up to a multiple of the device count with dummy
    sub-problems (replicas of sub-problem 0) and the padding is sliced off
    afterwards — no device idles, and results are bit-identical to the
    unpadded solve (each lane is independent, so extra lanes cannot
    perturb real ones).
``pmap``
    Same layout via ``jax.pmap`` — the fallback for JAX versions or
    platforms where shard_map misbehaves.

``backend="auto"`` picks by device count, k, and per-sub-problem size
(:func:`select_backend`).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from . import compat
from . import pdhg
from .pdhg import OperatorLP, SolveResult, StepEngine

MapBackend = Callable[..., SolveResult]

MAP_BACKENDS: Dict[str, MapBackend] = {}

# chunked_vmap default chunk; auto-selection switches off plain vmap above
# this many sub-problems (CPU-sized default — meshes usually decide first)
DEFAULT_CHUNK = 16
AUTO_VMAP_MAX_K = 64
# ... or above this many floats of stacked problem data (~256 MB fp32)
AUTO_VMAP_MAX_ELEMS = 64_000_000

# per-platform MEASURED overrides of the auto-selection constants above,
# installed from a TuningProfile (``PopService(profile=...)`` /
# install_tuned_thresholds); empty = the hand-set constants decide.
# Process-wide by design — like the jit caches these thresholds describe
# the hardware, not one service instance.
_TUNED_THRESHOLDS: Dict[str, dict] = {}


def install_tuned_thresholds(per_platform: Optional[dict]) -> None:
    """Install measured ``backend="auto"`` thresholds keyed by JAX
    platform name (``{"cpu": {"vmap_max_k": ..., "vmap_max_elems": ...}}``
    — the ``backend_thresholds`` table of a validated
    :class:`repro.tuning.TuningProfile`).  ``None``/empty clears back to
    the constants."""
    _TUNED_THRESHOLDS.clear()
    for platform, t in (per_platform or {}).items():
        if isinstance(t, dict):
            _TUNED_THRESHOLDS[str(platform)] = dict(t)


def _auto_thresholds() -> Tuple[int, int]:
    """(vmap_max_k, vmap_max_elems) for the current platform: the
    installed measured values when a profile provided them, else the
    constants."""
    t = _TUNED_THRESHOLDS.get(jax.default_backend())
    if not t:
        return AUTO_VMAP_MAX_K, AUTO_VMAP_MAX_ELEMS
    return (int(t.get("vmap_max_k", AUTO_VMAP_MAX_K)),
            int(t.get("vmap_max_elems", AUTO_VMAP_MAX_ELEMS)))


EngineSpec = Union[str, StepEngine]


def register_backend(name: str) -> Callable[[MapBackend], MapBackend]:
    def deco(fn: MapBackend) -> MapBackend:
        MAP_BACKENDS[name] = fn
        return fn
    return deco


def available_backends() -> tuple:
    return tuple(MAP_BACKENDS)


def get_backend(name: str) -> MapBackend:
    if name not in MAP_BACKENDS:
        raise ValueError(
            f"unknown map backend {name!r}; registered: {sorted(MAP_BACKENDS)}")
    return MAP_BACKENDS[name]


# --------------------------------------------------------------------------
# padding: k -> multiple of the device axis
# --------------------------------------------------------------------------

def batch_size(tree) -> int:
    """Leading-axis length of any stacked pytree (ops or (ops, wx, wy))."""
    return jax.tree.leaves(tree)[0].shape[0]


def pad_to_multiple(tree, m: int):
    """Pad the stacked sub-problem axis of any pytree to a multiple of ``m``
    by repeating lane 0.  Returns ``(padded, k)`` with the ORIGINAL k, so
    the caller slices ``[:k]`` off every result leaf.  Dummy lanes solve a
    real (already-solved-elsewhere) LP and are discarded; lanes are
    independent, so the real lanes' trajectories are unchanged."""
    k = batch_size(tree)
    pad = (-k) % m
    if pad == 0:
        return tree, k
    padded = jax.tree.map(
        lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])]),
        tree)
    return padded, k


def _slice_result(res: SolveResult, k: int) -> SolveResult:
    return jax.tree.map(lambda a: a[:k], res)


# --------------------------------------------------------------------------
# the per-batch solver (shared by every backend)
# --------------------------------------------------------------------------

def cold_start(ops: OperatorLP) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The solver's default starting iterates, materialised eagerly so warm
    and cold solves share one code path (bit-identical to passing no warm
    start: x0 = clip(0, l, u), y0 = 0)."""
    return (jnp.clip(jnp.zeros_like(ops.c), ops.l, ops.u),
            jnp.zeros_like(ops.q))


def _freeze_kw(solver_kw: dict):
    try:
        return tuple(sorted(solver_kw.items())), True
    except TypeError:
        return tuple(solver_kw.items()), False


@functools.lru_cache(maxsize=64)
def _cached_solver(K_mv, KT_mv, kw_items, engine):
    return jax.jit(_build_solver(K_mv, KT_mv, dict(kw_items), engine))


def _build_solver(K_mv, KT_mv, solver_kw: dict, engine: EngineSpec):
    if engine == "matvec":
        # vmap over per-lane k=1 solves (pdhg.solve IS solve_stacked at
        # k=1 — same loop) rather than one native k-stack: per-lane XLA
        # numerics are then independent of the batch size, which is what
        # lets serial/chunked/shard_map/pmap match vmap bit-for-bit.
        sol = functools.partial(pdhg.solve, K_mv=K_mv, KT_mv=KT_mv, **solver_kw)
        return lambda batch: jax.vmap(
            lambda o, wx, wy: sol(o, warm_x=wx, warm_y=wy))(*batch)
    if not isinstance(engine, StepEngine):
        raise ValueError(f"unresolved engine {engine!r} reached a backend; "
                         "go through solve_map or pass a StepEngine")
    return lambda batch: pdhg.solve_stacked(
        batch[0], engine=engine, warm_x=batch[1], warm_y=batch[2], **solver_kw)


def make_map_solver(K_mv, KT_mv, solver_kw: Optional[dict] = None,
                    engine: EngineSpec = "matvec"):
    """Jitted ``fn(batch) -> SolveResult`` for one stacked batch, where
    ``batch = (ops, warm_x, warm_y)``.  The jitted function is cached on
    (matvecs, solver_kw, engine) when hashable, so online re-solves reuse
    the compilation instead of retracing every round (engine objects from
    :func:`pdhg.fused_dense_engine` are themselves cached, so the default
    fused engine hits this cache too).  Nesting the returned function
    inside lax.map/shard_map/pmap just inlines its jaxpr."""
    solver_kw = dict(solver_kw or {})
    kw_items, hashable = _freeze_kw(solver_kw)
    if hashable:
        try:
            return _cached_solver(K_mv, KT_mv, kw_items, engine)
        except TypeError:
            pass
    # unhashable solver_kw / matvecs: a fresh jit per call is the documented
    # degradation (callers wanting cache hits pass hashable configs)
    return jax.jit(_build_solver(K_mv, KT_mv, solver_kw, engine))


# --------------------------------------------------------------------------
# memoized outer runners: the jit/pmap wrapper around a map solver must be
# built ONCE per (inner solver, layout) — jax.jit keys its own cache on the
# wrapped callable's identity, so re-wrapping per call recompiles the whole
# solver every invocation (the retrace popcheck's `retrace-hazard` rule and
# tests/test_retrace_guard.py pin this)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _chunked_runner(inner):
    """jit(lax.map(inner)) over [n_chunks, chunk, ...] stacked chunks."""
    return jax.jit(lambda chunks: jax.lax.map(inner, chunks))


@functools.lru_cache(maxsize=64)
def _result_treedef(inner, in_treedef, shapes_dtypes):
    """Output tree structure of ``inner`` for a given input layout —
    abstract eval only, memoized so steady-state re-solves skip even the
    trace."""
    leaves = [jax.ShapeDtypeStruct(s, d) for s, d in shapes_dtypes]
    batch = jax.tree.unflatten(in_treedef, leaves)
    return jax.tree.structure(jax.eval_shape(inner, batch))


def _tree_key(tree):
    """Hashable (treedef, shapes/dtypes) layout key for a stacked batch."""
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, tuple((l.shape, jnp.asarray(l).dtype.name)
                          for l in leaves)


@functools.lru_cache(maxsize=64)
def _shard_runner(inner, mesh, axis, chunk, in_treedef, shapes_dtypes):
    """jit(shard_map(...)) for one (solver, mesh, chunking, layout)."""
    if chunk:
        def local_solve(local_batch):
            chunked = jax.tree.map(
                lambda a: a.reshape((a.shape[0] // chunk, chunk)
                                    + a.shape[1:]), local_batch)
            res = jax.lax.map(inner, chunked)
            return jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                                res)
    else:
        local_solve = inner
    spec = jax.tree.unflatten(in_treedef,
                              [P(axis)] * in_treedef.num_leaves)
    out_treedef = _result_treedef(inner, in_treedef, shapes_dtypes)
    out_spec = jax.tree.unflatten(out_treedef,
                                  [P(axis)] * out_treedef.num_leaves)
    fn = compat.shard_map(local_solve, mesh=mesh, in_specs=(spec,),
                          out_specs=out_spec,
                          # solver constants (power-iteration seed vectors)
                          # are unvarying while problem data varies over the
                          # POP axis — exactly the intent; skip the check
                          check=False)
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _pmap_runner(inner, devices: tuple):
    return jax.pmap(inner, devices=list(devices))


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------

@register_backend("serial")
def solve_serial(batch, K_mv, KT_mv, solver_kw,
                 engine: EngineSpec = "matvec") -> SolveResult:
    """One jitted k=1 solve per sub-problem, in a Python loop.  Slowest and
    simplest — the numerical reference the parallel backends must match."""
    fn = make_map_solver(K_mv, KT_mv, solver_kw, engine)
    outs = [fn(jax.tree.map(lambda a: a[i:i + 1], batch))
            for i in range(batch_size(batch))]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs)


@register_backend("vmap")
def solve_vmap(batch, K_mv, KT_mv, solver_kw,
               engine: EngineSpec = "matvec") -> SolveResult:
    return make_map_solver(K_mv, KT_mv, solver_kw, engine)(batch)


@register_backend("chunked_vmap")
def solve_chunked_vmap(batch, K_mv, KT_mv, solver_kw,
                       engine: EngineSpec = "matvec",
                       chunk: int = DEFAULT_CHUNK) -> SolveResult:
    """``lax.map`` over batched chunks: peak memory ~ one chunk of
    sub-problems instead of all k.  k pads up to a chunk multiple."""
    k = batch_size(batch)
    chunk = max(1, min(chunk, k))
    padded, _ = pad_to_multiple(batch, chunk)
    k_pad = batch_size(padded)
    chunked = jax.tree.map(
        lambda a: a.reshape((k_pad // chunk, chunk) + a.shape[1:]), padded)
    inner = make_map_solver(K_mv, KT_mv, solver_kw, engine)
    res = _chunked_runner(inner)(chunked)
    res = jax.tree.map(lambda a: a.reshape((k_pad,) + a.shape[2:]), res)
    return _slice_result(res, k)


@register_backend("shard_map")
def solve_shard_map(batch, K_mv, KT_mv, solver_kw,
                    engine: EngineSpec = "matvec",
                    mesh: Optional[Mesh] = None,
                    axis: str = "pop",
                    chunk: Optional[int] = None) -> SolveResult:
    """Shard the k sub-problems over a mesh axis; solve batched within each
    shard.  No collectives in the mapped body — POP sub-problems are
    independent by construction.  Goes through :mod:`repro.core.compat` so
    it runs on any JAX that has shard_map under either name/kwarg spelling.

    ``chunk`` bounds per-device memory the same way chunked_vmap does on
    one device: each shard walks its lanes in batched chunks of that size
    (``None`` = decide from the per-device share: chunk only when it
    exceeds the single-device vmap ceiling; ``0`` = never chunk)."""
    if mesh is None:
        mesh = Mesh(np.array(jax.devices()), (axis,))
    n_dev = mesh.shape[axis]
    if chunk is None:
        per_dev = -(-batch_size(batch) // n_dev)
        max_k, max_elems = _auto_thresholds()
        heavy = (per_dev > max_k
                 or per_dev * max(_n_elems_per_sub(batch[0]), 1)
                 > max_elems)
        chunk = DEFAULT_CHUNK if heavy else 0
    padded, k = pad_to_multiple(batch, n_dev * chunk if chunk else n_dev)

    inner = make_map_solver(K_mv, KT_mv, solver_kw, engine)
    in_treedef, shapes_dtypes = _tree_key(padded)
    fn = _shard_runner(inner, mesh, axis, chunk, in_treedef, shapes_dtypes)
    return _slice_result(fn(padded), k)


@register_backend("pmap")
def solve_pmap(batch, K_mv, KT_mv, solver_kw,
               engine: EngineSpec = "matvec",
               devices: Optional[list] = None) -> SolveResult:
    """Per-device batched shards via ``jax.pmap`` — fallback when shard_map
    is unusable on the installed JAX/platform."""
    devices = list(devices if devices is not None else jax.devices())
    n_dev = len(devices)
    padded, k = pad_to_multiple(batch, n_dev)
    k_pad = batch_size(padded)
    sharded = jax.tree.map(
        lambda a: a.reshape((n_dev, k_pad // n_dev) + a.shape[1:]), padded)
    fn = _pmap_runner(make_map_solver(K_mv, KT_mv, solver_kw, engine),
                      tuple(devices))
    res = fn(sharded)
    res = jax.tree.map(lambda a: a.reshape((k_pad,) + a.shape[2:]), res)
    return _slice_result(res, k)


# --------------------------------------------------------------------------
# auto-selection + entry point
# --------------------------------------------------------------------------

def select_backend(k: int, n_elems_per_sub: int = 0,
                   n_dev: Optional[int] = None) -> str:
    """Pick a backend from (k, per-sub-problem element count, devices).

    Multi-device and enough sub-problems to fill the mesh -> ``shard_map``
    (each device solves its own lanes, zero communication).  Single device
    -> ``vmap`` until the stacked batch gets big (many lanes or a large
    stacked footprint), then ``chunked_vmap`` to bound peak memory.
    The crossover thresholds are the hand-set constants unless a
    :class:`repro.tuning.TuningProfile` installed measured per-platform
    values (:func:`install_tuned_thresholds`).
    """
    n_dev = compat.device_count() if n_dev is None else n_dev
    if n_dev > 1 and k >= n_dev:
        # memory-safe at any k: solve_shard_map self-chunks each shard when
        # the per-device share exceeds the single-device vmap ceiling
        return "shard_map"
    max_k, max_elems = _auto_thresholds()
    if k > max_k or k * max(n_elems_per_sub, 1) > max_elems:
        return "chunked_vmap"
    return "vmap"


def _n_elems_per_sub(ops: OperatorLP) -> int:
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(ops))


def _resolve_warm(ops: OperatorLP, warm) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Starting iterates from ``warm``: None (cold), a SolveResult-like
    object with .x/.y, an (x, y) pair, or a masked
    :class:`~repro.core.plan.WarmStart` — each stacked [k, ...].

    A WarmStart's per-lane ``mask`` is applied HERE, as data: masked-out
    lanes get the cold iterates via ``jnp.where``, so remapped warm starts
    with cold lanes flow through the same jitted solve as everything else
    (no Python-level branch, no retrace)."""
    if warm is None:
        return cold_start(ops)
    mask = getattr(warm, "mask", None)
    if hasattr(warm, "x") and hasattr(warm, "y"):
        wx, wy = warm.x, warm.y
    else:
        wx, wy = warm
    wx = jnp.asarray(wx, ops.c.dtype)
    wy = jnp.asarray(wy, ops.q.dtype)
    if wx.shape != ops.c.shape or wy.shape != ops.q.shape:
        raise ValueError(
            f"warm-start shapes {wx.shape}/{wy.shape} do not match the "
            f"stacked problem {ops.c.shape}/{ops.q.shape} — for warm "
            "re-solves across partition changes go through pop_solve(warm=) "
            "or core.plan.remap_warm, which rebuild matching iterates")
    if mask is not None:
        m = jnp.asarray(mask, bool)[:, None]
        cx, cy = cold_start(ops)
        wx = jnp.where(m, wx, cx)
        wy = jnp.where(m, wy, cy)
    return wx, wy


def make_batch(ops: OperatorLP, warm=None):
    """The ``(ops, warm_x, warm_y)`` batch a map backend consumes, with
    cold lanes materialised (see :func:`_resolve_warm`).  This is the
    exact batch :func:`solve_map` builds — exposed so the serving
    dispatcher can assemble per-tenant batches on the caller thread and
    hand the map-step launch to a shared worker."""
    return (ops, *_resolve_warm(ops, warm))


# --------------------------------------------------------------------------
# cross-tenant coalescing: shared launches over concatenated batches
# --------------------------------------------------------------------------

def coalesce_key(ops: OperatorLP, K_mv, KT_mv, backend: str,
                 engine: EngineSpec, solver_kw: dict, opts: dict):
    """Hashable compatibility key for sharing one map-step launch across
    prepared batches — the jit-cache key contract under shared launches:
    two batches with EQUAL keys run the same compiled solver
    (``_cached_solver`` keys on the same matvecs / solver_kw / engine) and
    may be lane-concatenated into one call without changing any lane's
    trajectory.

    Per-lane array layouts must match exactly EXCEPT structured ELL
    widths and wide-bucket counts, which :func:`~repro.core.pdhg.
    concat_stacks` pads to the group maximum (so the key records only
    their ndim/dtype).  Returns ``None`` — never coalesce — for the
    single-lane streaming engine (``fused_structured_full`` rejects
    multi-lane stacks by design) and for unhashable configs/matvecs
    (which would retrace per call anyway)."""
    kw_items, hashable = _freeze_kw(solver_kw)
    if not hashable:
        return None
    try:
        opt_items = tuple(sorted(opts.items()))
        hash((opt_items, K_mv, KT_mv, engine))
    except TypeError:
        return None
    if isinstance(engine, StepEngine) and engine.name == "fused_structured_full":
        return None
    bare = ops._replace(structured=None)
    leaves, treedef = jax.tree.flatten(bare)
    lane_shapes = tuple((l.shape[1:], jnp.asarray(l).dtype.name)
                        for l in leaves)
    s = ops.structured
    skey = None if s is None else tuple(
        None if v is None else (v.ndim, jnp.asarray(v).dtype.name)
        for v in s)
    return (treedef, lane_shapes, skey, K_mv, KT_mv, backend, engine,
            kw_items, opt_items)


def concat_batches(batches):
    """Concatenate per-tenant ``(ops, warm_x, warm_y)`` batches on the lane
    axis into one launch-sized batch (ops via
    :func:`~repro.core.pdhg.concat_stacks`, which pads structured ELL
    widths across tenants).  Returns ``(batch, sizes)`` — undo with
    :func:`split_result`."""
    sizes = tuple(batch_size(b) for b in batches)
    ops = pdhg.concat_stacks([b[0] for b in batches])
    wx = jnp.concatenate([b[1] for b in batches])
    wy = jnp.concatenate([b[2] for b in batches])
    return (ops, wx, wy), sizes


def split_result(res: SolveResult, sizes) -> list:
    """Slice a concatenated launch's SolveResult back into per-tenant
    results (lane ranges in submission order)."""
    outs, start = [], 0
    for s in sizes:
        outs.append(jax.tree.map(
            lambda a, i0=start, i1=start + s: a[i0:i1], res))
        start += s
    return outs


def next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def pad_lanes_pow2(batch):
    """Pad a coalesced batch's lane count up to the next power of two by
    repeating lane 0 (see :func:`pad_to_multiple` — dummy lanes cannot
    perturb real ones), so variable group sizes compile O(log) distinct
    lane counts instead of one per arrival pattern.  Returns
    ``(padded, k)`` with the original k; slice results ``[:k]``."""
    return pad_to_multiple(batch, next_pow2(batch_size(batch)))


def solve_one_ex(op: OperatorLP, K_mv, KT_mv,
                 solver_kw: Optional[dict] = None,
                 backend: str = "auto", engine: EngineSpec = "auto",
                 warm=None, **opts: Any):
    """Solve ONE unbatched LP through the same substrate as the map step
    (a k=1 stack) and report what ran: returns
    ``(result, backend_name, engine_name)`` with ``"auto"`` resolved.
    The operator is batched exactly ONCE (the same stack serves the
    resolution probe and the solve); ``warm`` is an unbatched (x, y) pair
    or SolveResult-like object; the result is unbatched again."""
    opb = jax.tree.map(lambda a: jnp.asarray(a)[None], op)
    backend, engine, opts = resolve_exec(opb, K_mv, KT_mv, backend, engine,
                                         opts)
    if warm is not None:
        if hasattr(warm, "x") and hasattr(warm, "y"):
            warm = (warm.x, warm.y)
        warm = tuple(jnp.asarray(w)[None] for w in warm)
    res = solve_map(opb, K_mv, KT_mv, solver_kw, backend=backend,
                    engine=engine, warm=warm, **opts)
    jax.block_until_ready(res.x)
    return (jax.tree.map(lambda a: a[0], res), backend,
            pdhg.engine_name(engine))


def solve_one(op: OperatorLP, K_mv, KT_mv, solver_kw: Optional[dict] = None,
              backend: str = "auto", engine: EngineSpec = "auto",
              warm=None, **opts: Any) -> SolveResult:
    """:func:`solve_one_ex` without the observability tuple — full-problem
    baselines get the engine selection, the backend registry and the
    jit-cached map solver without hand-rolling the batch/unbatch dance."""
    res, _, _ = solve_one_ex(op, K_mv, KT_mv, solver_kw, backend=backend,
                             engine=engine, warm=warm, **opts)
    return res


def resolve_exec(ops: OperatorLP, K_mv, KT_mv, backend: str = "auto",
                 engine: EngineSpec = "auto",
                 opts: Optional[dict] = None):
    """Resolve ``"auto"`` specs to the (backend name, engine) that will
    actually run — the single resolution point :func:`solve_map` uses, and
    the observability hook the pipeline records into ``POPResult.backend``
    / ``.engine`` (callers and benchmarks otherwise can't see what
    ``"auto"`` picked).  Returns ``(backend_name, engine, opts)`` where
    ``engine`` is ``"matvec"`` or a resolved
    :class:`~repro.core.pdhg.StepEngine` (``pdhg.engine_name`` prints it);
    under ``backend="auto"``, ``opts`` the winning backend doesn't take
    (e.g. ``chunk=`` when vmap wins) are dropped — they are hints for
    *whichever* backend wins, not requirements.  An explicitly named
    backend keeps opts verbatim (and still rejects unknown ones when
    called)."""
    if engine == "auto" or engine is None:
        engine = pdhg.select_engine(ops, K_mv, KT_mv)
    if engine != "matvec":
        # canonical resolution/validation lives in pdhg.resolve_engine;
        # "matvec" stays a string so _build_solver takes the vmapped path
        engine = pdhg.resolve_engine(engine, ops, K_mv, KT_mv)
    opts = dict(opts or {})
    if backend == "auto":
        backend = select_backend(batch_size(ops), _n_elems_per_sub(ops))
        if opts:
            import inspect
            accepted = inspect.signature(get_backend(backend)).parameters
            opts = {k: v for k, v in opts.items() if k in accepted}
    else:
        get_backend(backend)          # fail fast on unknown names
    return backend, engine, opts


def solve_map(ops: OperatorLP, K_mv, KT_mv, solver_kw: Optional[dict] = None,
              backend: str = "auto", engine: EngineSpec = "auto",
              warm=None, **opts: Any) -> SolveResult:
    """Run the POP map step on stacked ``ops`` with the named backend
    (``"auto"`` resolves via :func:`select_backend`) and step engine
    (``"auto"`` resolves via :func:`repro.core.pdhg.select_engine`) —
    both through :func:`resolve_exec`, so callers who need to report what
    actually ran can resolve first and pass the resolved values in (the
    second resolution is a no-op).

    ``warm`` seeds every lane from a previous solve of a nearby instance
    (a SolveResult, or an (x, y) pair) — the online re-solve path."""
    solver_kw = dict(solver_kw or {})
    backend, engine, opts = resolve_exec(ops, K_mv, KT_mv, backend, engine,
                                         opts)
    batch = make_batch(ops, warm)
    return get_backend(backend)(batch, K_mv, KT_mv, solver_kw,
                                engine=engine, **opts)
