"""Restarted PDHG (PDLP-family) linear-programming solver in pure JAX.

Why PDHG and not simplex/barrier (what the paper's solvers use): every PDHG
iteration is two matrix–vector products plus element-wise projections —
MXU/VPU work with no data-dependent control flow.  That makes the solver

  * ``jax.lax``-expressible (while_loop/fori_loop),
  * **vmap-able over the POP sub-problem axis** — POP's map step becomes a
    single batched solve, and
  * ``shard_map``-able — sub-problems spread across mesh devices with zero
    collectives inside the map step (they are independent by construction).

The solver is generic over an *operator form* of the constraint matrix

    K = [G; A]   (first ``n_ineq`` rows are inequalities)

supplied as a pair of callables ``K_mv(data, x)`` / ``KT_mv(data, y)`` plus a
data pytree.  Dense problems use plain matmuls (and, on TPU, the Pallas
kernels in ``repro.kernels``); the big domain problems (traffic engineering
with >10^6 variables) supply structured matvecs so the full unpartitioned
baseline never materialises a dense K.

Algorithm: Chambolle–Pock primal–dual with
  * power-iteration estimate of ||K||,
  * step sizes tau = eta/(omega*||K||), sigma = eta*omega/||K||,
  * iterate averaging + adaptive restart to the better of {current, average}
    by KKT score (simplified PDLP restart rule),
  * primal-weight (omega) rebalancing at restarts,
  * termination on relative primal residual + relative duality gap.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .problem import BIG, LinearProgram


class OperatorLP(NamedTuple):
    """LP in operator form.  ``data`` is whatever the K_mv/KT_mv callables
    need (dense K, index arrays, ...).  All leaves are batchable."""

    c: jnp.ndarray          # [N]
    q: jnp.ndarray          # [M]    rhs for K rows
    l: jnp.ndarray          # [N]
    u: jnp.ndarray          # [N]
    ineq_mask: jnp.ndarray  # [M] bool: True → dual projected >= 0
    data: Any               # operator payload pytree


def dense_ops(lp: LinearProgram) -> OperatorLP:
    K, q, ineq = lp.stacked()
    return OperatorLP(c=lp.c, q=q, l=lp.l, u=lp.u, ineq_mask=ineq, data=(K,))


def dense_K_mv(data, x):
    (K,) = data
    return K @ x


def dense_KT_mv(data, y):
    (K,) = data
    return K.T @ y


class SolveResult(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray
    primal_obj: jnp.ndarray
    dual_obj: jnp.ndarray
    primal_res: jnp.ndarray   # relative primal infeasibility
    gap: jnp.ndarray          # relative duality gap
    iterations: jnp.ndarray
    converged: jnp.ndarray


# --------------------------------------------------------------------------
# internals
# --------------------------------------------------------------------------

def _power_iteration(K_mv, KT_mv, data, n_var, iters: int = 30):
    """||K||_2 via power iteration on K^T K (deterministic start)."""
    v0 = jnp.full((n_var,), 1.0 / jnp.sqrt(n_var), jnp.float32)

    def body(_, v):
        w = KT_mv(data, K_mv(data, v))
        return w / (jnp.linalg.norm(w) + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.sqrt(jnp.linalg.norm(KT_mv(data, K_mv(data, v)))) + 1e-12


def _kkt(op: OperatorLP, K_mv, KT_mv, x, y):
    """(primal_res_rel, gap_rel, primal_obj, dual_obj)."""
    Kx = K_mv(op.data, x)
    resid = Kx - op.q
    prim_viol = jnp.where(op.ineq_mask, jnp.maximum(resid, 0.0), resid)
    # padded rows carry q = BIG — exclude them from the relative denominator
    q_eff = jnp.where(jnp.abs(op.q) >= 0.5 * BIG, 0.0, op.q)
    prim_res = jnp.linalg.norm(prim_viol) / (1.0 + jnp.linalg.norm(q_eff))

    r = op.c + KT_mv(op.data, y)                       # reduced costs
    p_obj = jnp.dot(op.c, x)
    # g(y) = -q.y + sum_i min(l_i r_i, u_i r_i); BIG bounds act as -inf penalty
    d_obj = -jnp.dot(op.q, y) + jnp.sum(jnp.minimum(op.l * r, op.u * r))
    gap = jnp.abs(p_obj - d_obj) / (1.0 + jnp.abs(p_obj) + jnp.abs(d_obj))
    return prim_res, gap, p_obj, d_obj


class _State(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray
    x_sum: jnp.ndarray
    y_sum: jnp.ndarray
    avg_n: jnp.ndarray        # iterations accumulated since restart
    x_anchor: jnp.ndarray     # iterate at last restart (for omega update)
    y_anchor: jnp.ndarray
    omega: jnp.ndarray        # primal weight
    last_score: jnp.ndarray   # KKT score at last restart (decay test)
    it: jnp.ndarray
    done: jnp.ndarray
    prim_res: jnp.ndarray
    gap: jnp.ndarray


def _probe_norms(K_mv, KT_mv, data, n_var, n_con, d_r, d_c, key, n_probes=4):
    """Hutchinson-style row/col 2-norm estimates of the SCALED operator
    D_r K D_c without materialising K:  with Rademacher v (E[vv^T]=I),
    E[(Kv)_i^2] = sum_j K_ij^2 — i.e. squared row norms; columns dual."""
    kr, kc = jax.random.split(key)
    vs = jax.random.rademacher(kr, (n_probes, n_var), jnp.float32)
    rows = jnp.mean(jax.vmap(
        lambda v: jnp.square(d_r * K_mv(data, d_c * v)))(vs), axis=0)
    us = jax.random.rademacher(kc, (n_probes, n_con), jnp.float32)
    cols = jnp.mean(jax.vmap(
        lambda u: jnp.square(d_c * KT_mv(data, d_r * u)))(us), axis=0)
    return jnp.sqrt(rows), jnp.sqrt(cols)


def _equilibrate(op: OperatorLP, K_mv, KT_mv, iters: int = 2, n_probes: int = 4):
    """Operator-form Ruiz equilibration (EXPERIMENTS.md §Perf hillclimb 3):
    returns (d_r, d_c) diagonal scalings estimated purely through matvec
    probes — works for ANY structured operator, not just dense K."""
    n_var = op.c.shape[0]
    n_con = op.q.shape[0]
    d_r = jnp.ones(n_con)
    d_c = jnp.ones(n_var)
    key = jax.random.PRNGKey(7)
    for i in range(iters):
        rn, cn = _probe_norms(K_mv, KT_mv, op.data, n_var, n_con,
                              d_r, d_c, jax.random.fold_in(key, i), n_probes)
        d_r = d_r / jnp.sqrt(jnp.where(rn > 1e-8, rn, 1.0))
        d_c = d_c / jnp.sqrt(jnp.where(cn > 1e-8, cn, 1.0))
    return d_r, d_c


def solve(
    op: OperatorLP,
    K_mv: Callable = dense_K_mv,
    KT_mv: Callable = dense_KT_mv,
    *,
    max_iters: int = 20_000,
    check_every: int = 40,
    tol_primal: float = 1e-4,
    tol_gap: float = 1e-4,
    eta: float = 0.9,
    omega0: float = 1.0,
    equilibrate: bool = False,
    warm_x: jnp.ndarray | None = None,
    warm_y: jnp.ndarray | None = None,
) -> SolveResult:
    """Solve one LP.  Fully traceable; vmap over a batched ``op`` for POP."""
    n_var = op.c.shape[0]
    n_con = op.q.shape[0]

    if equilibrate:
        d_r, d_c = _equilibrate(op, K_mv, KT_mv)
        op_orig, K_mv_orig, KT_mv_orig = op, K_mv, KT_mv
        K_mv = lambda data, x: d_r * K_mv_orig(data, d_c * x)   # noqa: E731
        KT_mv = lambda data, y: d_c * KT_mv_orig(data, d_r * y)  # noqa: E731
        keep_l = jnp.abs(op.l) >= 0.5 * BIG
        keep_u = jnp.abs(op.u) >= 0.5 * BIG
        op = OperatorLP(
            c=op.c * d_c, q=op.q * d_r,
            l=jnp.where(keep_l, op_orig.l, op_orig.l / d_c),
            u=jnp.where(keep_u, op_orig.u, op_orig.u / d_c),
            ineq_mask=op.ineq_mask, data=op.data)

    knorm = _power_iteration(K_mv, KT_mv, op.data, n_var)

    x0 = jnp.clip(jnp.zeros(n_var), op.l, op.u) if warm_x is None else warm_x
    y0 = jnp.zeros(n_con) if warm_y is None else warm_y

    def chunk(state: _State) -> _State:
        tau = eta / (state.omega * knorm)
        sigma = eta * state.omega / knorm

        def one_iter(_, carry):
            x, y, xs, ys = carry
            x_new = jnp.clip(x - tau * (op.c + KT_mv(op.data, y)), op.l, op.u)
            x_bar = 2.0 * x_new - x
            y_new = y + sigma * (K_mv(op.data, x_bar) - op.q)
            y_new = jnp.where(op.ineq_mask, jnp.maximum(y_new, 0.0), y_new)
            return x_new, y_new, xs + x_new, ys + y_new

        x, y, xs, ys = jax.lax.fori_loop(
            0, check_every, one_iter,
            (state.x, state.y, state.x_sum, state.y_sum),
        )
        avg_n = state.avg_n + check_every

        # ---- candidate = better of {current, running average} ------------
        x_avg = xs / avg_n
        y_avg = ys / avg_n
        pr_c, gap_c, _, _ = _kkt(op, K_mv, KT_mv, x, y)
        pr_a, gap_a, _, _ = _kkt(op, K_mv, KT_mv, x_avg, y_avg)
        score_c = pr_c + gap_c
        score_a = pr_a + gap_a
        use_avg = score_a < score_c
        x_r = jnp.where(use_avg, x_avg, x)
        y_r = jnp.where(use_avg, y_avg, y)
        pr = jnp.where(use_avg, pr_a, pr_c)
        gap = jnp.where(use_avg, gap_a, gap_c)
        score = jnp.minimum(score_a, score_c)

        # ---- adaptive restart: only on sufficient KKT decay ---------------
        # (restarting every chunk kills PDHG momentum; PDLP-style decay test)
        restart = (score < 0.4 * state.last_score) | (avg_n >= 16 * check_every)

        # ---- primal weight update at restarts (PDLP eq. 10, smoothed) -----
        dx = jnp.linalg.norm(x_r - state.x_anchor)
        dy = jnp.linalg.norm(y_r - state.y_anchor)
        safe = (dx > 1e-12) & (dy > 1e-12)
        ratio = jnp.where(safe, dy / jnp.maximum(dx, 1e-12), 1.0)
        omega_new = jnp.exp(
            0.5 * jnp.log(jnp.clip(ratio, 1e-4, 1e4)) + 0.5 * jnp.log(state.omega)
        )

        conv = (pr < tol_primal) & (gap < tol_gap)
        done = state.done | conv

        def pick(on_restart, no_restart):
            return jnp.where(restart, on_restart, no_restart)

        # freeze finished lanes (matters under vmap: batch peers keep going)
        keep = lambda new, old: jnp.where(state.done, old, new)
        return _State(
            x=keep(pick(x_r, x), state.x),
            y=keep(pick(y_r, y), state.y),
            x_sum=keep(pick(jnp.zeros_like(xs), xs), state.x_sum),
            y_sum=keep(pick(jnp.zeros_like(ys), ys), state.y_sum),
            avg_n=keep(pick(jnp.zeros_like(avg_n), avg_n), state.avg_n),
            x_anchor=keep(pick(x_r, state.x_anchor), state.x_anchor),
            y_anchor=keep(pick(y_r, state.y_anchor), state.y_anchor),
            omega=keep(pick(omega_new, state.omega), state.omega),
            last_score=keep(pick(score, state.last_score), state.last_score),
            it=state.it + jnp.where(state.done, 0, check_every),
            done=done,
            prim_res=keep(pr, state.prim_res), gap=keep(gap, state.gap),
        )

    init = _State(
        x=x0, y=y0,
        x_sum=jnp.zeros_like(x0), y_sum=jnp.zeros_like(y0),
        avg_n=jnp.zeros((), jnp.float32),
        x_anchor=x0, y_anchor=y0,
        omega=jnp.asarray(omega0, jnp.float32),
        last_score=jnp.asarray(jnp.inf),
        it=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), bool),
        prim_res=jnp.asarray(jnp.inf), gap=jnp.asarray(jnp.inf),
    )

    state = jax.lax.while_loop(
        lambda s: (~s.done) & (s.it < max_iters), chunk, init
    )

    x_fin, y_fin = state.x, state.y
    if equilibrate:
        # report in ORIGINAL space
        x_fin = d_c * x_fin
        y_fin = d_r * y_fin
        op, K_mv, KT_mv = op_orig, K_mv_orig, KT_mv_orig
    pr, gap, p_obj, d_obj = _kkt(op, K_mv, KT_mv, x_fin, y_fin)
    return SolveResult(
        x=x_fin, y=y_fin, primal_obj=p_obj, dual_obj=d_obj,
        primal_res=pr, gap=gap, iterations=state.it, converged=state.done,
    )


# --------------------------------------------------------------------------
# Ruiz equilibration (dense path) — first-order methods live or die by
# conditioning; diagonal rescaling cuts PDHG iteration counts by 10-100x.
# --------------------------------------------------------------------------

def ruiz_equilibrate(op: OperatorLP, iters: int = 8):
    """Return (scaled_op, d_row, d_col) with K~ = D_r K D_c equilibrated.

    Recover original-space solutions as  x = d_col * x~,  y = d_row * y~.
    Dense-data only (needs explicit row/col norms).
    """
    (K,) = op.data
    d_r = jnp.ones(K.shape[0])
    d_c = jnp.ones(K.shape[1])

    def body(_, carry):
        d_r, d_c = carry
        Ks = K * d_r[:, None] * d_c[None, :]
        rn = jnp.sqrt(jnp.max(jnp.abs(Ks), axis=1))
        cn = jnp.sqrt(jnp.max(jnp.abs(Ks), axis=0))
        d_r = d_r / jnp.where(rn > 1e-12, rn, 1.0)
        d_c = d_c / jnp.where(cn > 1e-12, cn, 1.0)
        return d_r, d_c

    d_r, d_c = jax.lax.fori_loop(0, iters, body, (d_r, d_c))
    Ks = K * d_r[:, None] * d_c[None, :]
    scaled = OperatorLP(
        c=op.c * d_c,
        q=op.q * d_r,
        l=jnp.where(jnp.abs(op.l) >= 0.5 * BIG, op.l, op.l / d_c),
        u=jnp.where(jnp.abs(op.u) >= 0.5 * BIG, op.u, op.u / d_c),
        ineq_mask=op.ineq_mask,
        data=(Ks,),
    )
    return scaled, d_r, d_c


# --------------------------------------------------------------------------
# convenience wrappers
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iters", "tol_primal", "tol_gap"))
def solve_dense(lp: LinearProgram, max_iters: int = 20_000,
                tol_primal: float = 1e-4, tol_gap: float = 1e-4) -> SolveResult:
    op = dense_ops(lp)
    sop, d_r, d_c = ruiz_equilibrate(op)
    res = solve(sop, dense_K_mv, dense_KT_mv,
                max_iters=max_iters, tol_primal=tol_primal, tol_gap=tol_gap)
    # report objective/residuals in ORIGINAL space
    x = res.x * d_c
    y = res.y * d_r
    pr, gap, p_obj, d_obj = _kkt(op, dense_K_mv, dense_KT_mv, x, y)
    return SolveResult(x=x, y=y, primal_obj=p_obj, dual_obj=d_obj,
                       primal_res=pr, gap=gap,
                       iterations=res.iterations, converged=res.converged)


def solve_batched(op_batched: OperatorLP, K_mv=dense_K_mv, KT_mv=dense_KT_mv,
                  **kw) -> SolveResult:
    """vmap over the leading (sub-problem) axis — POP's map step on one
    device.  ``core/pop.py`` wraps this in shard_map for the mesh path."""
    return jax.vmap(lambda o: solve(o, K_mv, KT_mv, **kw))(op_batched)
