"""Restarted PDHG (PDLP-family) linear-programming solver in pure JAX.

Why PDHG and not simplex/barrier (what the paper's solvers use): every PDHG
iteration is two matrix–vector products plus element-wise projections —
MXU/VPU work with no data-dependent control flow.  That makes the solver

  * ``jax.lax``-expressible (while_loop/fori_loop),
  * **vmap-able over the POP sub-problem axis** — POP's map step becomes a
    single batched solve, and
  * ``shard_map``-able — sub-problems spread across mesh devices with zero
    collectives inside the map step (they are independent by construction).

The solver is generic over an *operator form* of the constraint matrix

    K = [G; A]   (first ``n_ineq`` rows are inequalities)

supplied as a pair of callables ``K_mv(data, x)`` / ``KT_mv(data, y)`` plus a
data pytree.  Dense problems use plain matmuls (and, on TPU, the Pallas
kernels in ``repro.kernels``); the big domain problems (traffic engineering
with >10^6 variables) supply structured matvecs so the full unpartitioned
baseline never materialises a dense K.

Step-engine contract
--------------------

The inner-loop math (primal/dual half-steps, matvecs for KKT checks and the
power iteration) is factored behind a :class:`StepEngine`.  An engine works
on a whole STACKED batch of k sub-problems at once — every array carries a
leading ``[k]`` axis and per-sub-problem scalars (step sizes) are ``[k]``
vectors, because POP sub-problems restart independently and their step
sizes diverge across the batch.  Two engines ship:

``matvec`` (:func:`matvec_engine`)
    Wraps the user's ``K_mv``/``KT_mv`` callables with ``jax.vmap`` and
    applies the element-wise tails in plain jnp.  Works for ANY structured
    operator; this is the only engine usable for non-dense problems.

``fused`` (:func:`fused_dense_engine`)
    Dense-data-only.  Routes the primal and dual half-steps through the
    batched fused kernels in ``repro.kernels.ops`` (``fused_primal_step`` /
    ``fused_dual_step``), so on TPU the matvec partials stay in VMEM and
    the axpy+projection tail runs in the SAME kernel launch — one launch
    per half-step for the whole k-stack instead of k vmapped solves.
    ``kernels/ops.py`` dispatches per platform: compiled Pallas on TPU,
    the pure-jnp reference (still algebraically fused) elsewhere, with
    ``interpret`` available for kernel debugging on CPU.

``engine="auto"`` (:func:`select_engine`) picks ``fused`` for dense
operator data on TPU and ``matvec`` otherwise.  Engines differ only in
scheduling/fusion, never in math — ``tests/test_step_engine.py`` pins them
to each other at 1e-5 on fixed iteration budgets.

:func:`solve_stacked` is the batched entry point (what the map-step
backends in ``core/backends.py`` call for the fused path);
:func:`solve` is the single-problem wrapper (a k=1 stack).

Warm starts
-----------

``solve``/``solve_stacked`` accept ``warm_x``/``warm_y`` — the previous
solution of a nearby instance.  For online re-solves (scheduler rounds,
load-balancer ticks) a warm start typically cuts iteration counts by far
more than half (``benchmarks/bench_online_resolve.py`` measures this).
With ``equilibrate=True`` the warm iterates are mapped into the scaled
space (``x/d_c``, ``y/d_r``) before iterating, so warm-starting composes
with scaling.

Algorithm: Chambolle–Pock primal–dual with
  * power-iteration estimate of ||K||,
  * step sizes tau = eta/(omega*||K||), sigma = eta*omega/||K||,
  * iterate averaging + adaptive restart to the better of {current, average}
    by KKT score (simplified PDLP restart rule),
  * primal-weight (omega) rebalancing at restarts,
  * termination on relative primal residual + relative duality gap.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from .problem import BIG, LinearProgram


class OperatorLP(NamedTuple):
    """LP in operator form.  ``data`` is whatever the K_mv/KT_mv callables
    need (dense K, index arrays, ...).  All leaves are batchable."""

    c: jnp.ndarray          # [N]
    q: jnp.ndarray          # [M]    rhs for K rows
    l: jnp.ndarray          # [N]
    u: jnp.ndarray          # [N]
    ineq_mask: jnp.ndarray  # [M] bool: True → dual projected >= 0
    data: Any               # operator payload pytree


def dense_ops(lp: LinearProgram) -> OperatorLP:
    K, q, ineq = lp.stacked()
    return OperatorLP(c=lp.c, q=q, l=lp.l, u=lp.u, ineq_mask=ineq, data=(K,))


def dense_K_mv(data, x):
    (K,) = data
    return K @ x


def dense_KT_mv(data, y):
    (K,) = data
    return K.T @ y


class SolveResult(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray
    primal_obj: jnp.ndarray
    dual_obj: jnp.ndarray
    primal_res: jnp.ndarray   # relative primal infeasibility
    gap: jnp.ndarray          # relative duality gap
    iterations: jnp.ndarray
    converged: jnp.ndarray


# --------------------------------------------------------------------------
# step engines
# --------------------------------------------------------------------------

class StepEngine(NamedTuple):
    """Batched inner-loop math for the PDHG solver (see module docstring).

    All callables take STACKED arrays (leading ``[k]`` sub-problem axis):

      K(data, x[k,N]) -> [k,M]         KT(data, y[k,M]) -> [k,N]
      primal(data, y, x, c, l, u, tau[k]) -> (x_new, x_bar)     # [k,N] each
      dual(data, x_bar, y, q, sigma[k], ineq_mask) -> y_new     # [k,M]

    ``scale_data``, if set, rescales the operator payload for Ruiz
    equilibration (``data, d_r[k,M], d_c[k,N] -> data``); engines without
    it (structured operators) get their K/KT wrapped functionally instead.
    """

    name: str
    K: Callable
    KT: Callable
    primal: Callable
    dual: Callable
    scale_data: Optional[Callable] = None


def _engine_from_matvecs(name: str, bK: Callable, bKT: Callable,
                         scale_data: Optional[Callable] = None) -> StepEngine:
    """Build the element-wise step tails from batched matvecs."""

    def primal(data, y, x, c, l, u, tau):
        x_new = jnp.clip(x - tau[:, None] * (c + bKT(data, y)), l, u)
        return x_new, 2.0 * x_new - x

    def dual(data, x_bar, y, q, sigma, ineq_mask):
        y_new = y + sigma[:, None] * (bK(data, x_bar) - q)
        return jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)

    return StepEngine(name, bK, bKT, primal, dual, scale_data)


def matvec_engine(K_mv: Callable = dense_K_mv,
                  KT_mv: Callable = dense_KT_mv) -> StepEngine:
    """Generic operator engine: vmap the per-problem matvecs over the
    sub-problem axis.  Works for any structured ``data`` pytree."""
    return _engine_from_matvecs(
        "matvec", jax.vmap(K_mv, in_axes=(0, 0)), jax.vmap(KT_mv, in_axes=(0, 0)))


@functools.lru_cache(maxsize=16)
def fused_dense_engine(kernel_backend: Optional[str] = None,
                       block_m: Optional[int] = None,
                       block_n: Optional[int] = None) -> StepEngine:
    """Dense engine over the fused Pallas kernels (``repro.kernels.ops``).

    One kernel launch covers the whole stacked batch per half-step.
    ``kernel_backend`` follows ``kernels/ops.py`` dispatch: ``None``/"auto"
    = compiled Pallas on TPU, pure-jnp reference elsewhere; "interpret" and
    "xla" force the Pallas interpreter / the reference.  Cached so repeated
    calls return the same object (keeps downstream jit caches warm).
    """
    from ..kernels import ops as kops

    kw: dict = dict(backend=kernel_backend)
    if block_m is not None:
        kw["block_m"] = block_m
    if block_n is not None:
        kw["block_n"] = block_n

    def K(data, x):
        return kops.bmatvec(data[0], x, **kw)

    def KT(data, y):
        return kops.bmatvec_t(data[0], y, **kw)

    def primal(data, y, x, c, l, u, tau):
        return kops.fused_primal_step(data[0], y, x, c, l, u, tau, **kw)

    def dual(data, x_bar, y, q, sigma, ineq_mask):
        return kops.fused_dual_step(data[0], x_bar, y, q, sigma, ineq_mask, **kw)

    def scale_data(data, d_r, d_c):
        (K_,) = data
        return (K_ * d_r[..., :, None] * d_c[..., None, :],)

    return StepEngine("fused", K, KT, primal, dual, scale_data)


def is_dense_ops(op: OperatorLP) -> bool:
    """True iff ``op.data`` is a single dense [..., M, N] constraint matrix
    (the layout :func:`dense_ops` produces) — the fused engine's requirement."""
    leaves = jax.tree.leaves(op.data)
    if len(leaves) != 1:
        return False
    K = leaves[0]
    return (K.ndim == op.c.ndim + 1
            and K.shape[-1] == op.c.shape[-1]
            and K.shape[-2] == op.q.shape[-1])


def select_engine(op: OperatorLP, K_mv: Callable = dense_K_mv,
                  KT_mv: Callable = dense_KT_mv) -> str:
    """``engine="auto"`` rule: fused needs dense data AND the dense matvecs
    AND a TPU (elsewhere XLA fuses the reference path just as well);
    structured operators always take the matvec engine."""
    dense = (K_mv is dense_K_mv and KT_mv is dense_KT_mv and is_dense_ops(op))
    if dense and jax.default_backend() == "tpu":
        return "fused"
    return "matvec"


def resolve_engine(engine: Union[None, str, StepEngine], op: OperatorLP,
                   K_mv: Callable = dense_K_mv,
                   KT_mv: Callable = dense_KT_mv) -> StepEngine:
    """Normalise an engine spec (None/"auto"/"matvec"/"fused"/StepEngine)."""
    if isinstance(engine, StepEngine):
        return engine
    if engine is None or engine == "auto":
        engine = select_engine(op, K_mv, KT_mv)
    if engine == "matvec":
        return matvec_engine(K_mv, KT_mv)
    if engine == "fused":
        if not is_dense_ops(op):
            raise ValueError(
                "engine='fused' needs dense operator data (op.data == (K,) "
                "with K [..., M, N]); structured operators use engine='matvec'")
        return fused_dense_engine()
    raise ValueError(f"unknown engine {engine!r}; "
                     "expected 'auto', 'matvec', 'fused', or a StepEngine")


# --------------------------------------------------------------------------
# scaling helpers — the ONE place BIG-sentinel bounds handling lives, shared
# by the probe-based path (solve(equilibrate=True)) and dense ruiz_equilibrate
# --------------------------------------------------------------------------

def scale_operator(op: OperatorLP, d_r: jnp.ndarray, d_c: jnp.ndarray,
                   data: Any = None) -> OperatorLP:
    """Apply diagonal scalings K~ = D_r K D_c to the LP fields.

    BIG-sentinel bounds (|l| or |u| >= BIG/2 — "effectively free") stay
    untouched so padded/free variables keep their infinite box after
    scaling.  ``data`` replaces the operator payload when the caller has a
    scaled one (dense K); by default the payload is left alone and the
    matvecs are expected to be wrapped instead.
    """
    keep_l = jnp.abs(op.l) >= 0.5 * BIG
    keep_u = jnp.abs(op.u) >= 0.5 * BIG
    return OperatorLP(
        c=op.c * d_c, q=op.q * d_r,
        l=jnp.where(keep_l, op.l, op.l / d_c),
        u=jnp.where(keep_u, op.u, op.u / d_c),
        ineq_mask=op.ineq_mask,
        data=op.data if data is None else data)


def scale_warm_start(x: jnp.ndarray, y: jnp.ndarray, d_r, d_c):
    """Original-space iterates -> scaled space (inverse of unscale)."""
    return x / d_c, y / d_r


def unscale_solution(x: jnp.ndarray, y: jnp.ndarray, d_r, d_c):
    """Scaled-space iterates -> original space: x = d_c x~, y = d_r y~."""
    return d_c * x, d_r * y


# --------------------------------------------------------------------------
# internals (all batched over the leading [k] sub-problem axis)
# --------------------------------------------------------------------------

def _vnorm(a: jnp.ndarray) -> jnp.ndarray:
    """Per-sub-problem 2-norm: [k, n] -> [k]."""
    return jnp.linalg.norm(a, axis=-1)


def _bcast(cond: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Right-pad a [k] predicate with singleton axes to broadcast against
    ``like`` ([k] or [k, n])."""
    return cond.reshape(cond.shape + (1,) * (like.ndim - cond.ndim))


def _power_iteration(engine: StepEngine, data, k: int, n_var: int,
                     iters: int = 30):
    """||K||_2 per lane via power iteration on K^T K (deterministic start)."""
    v0 = jnp.full((k, n_var), 1.0 / jnp.sqrt(n_var), jnp.float32)

    def body(_, v):
        w = engine.KT(data, engine.K(data, v))
        return w / (_vnorm(w)[:, None] + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.sqrt(_vnorm(engine.KT(data, engine.K(data, v)))) + 1e-12


def _kkt(op: OperatorLP, engine: StepEngine, x, y):
    """(primal_res_rel, gap_rel, primal_obj, dual_obj), each [k]."""
    Kx = engine.K(op.data, x)
    resid = Kx - op.q
    prim_viol = jnp.where(op.ineq_mask, jnp.maximum(resid, 0.0), resid)
    # padded rows carry q = BIG — exclude them from the relative denominator
    q_eff = jnp.where(jnp.abs(op.q) >= 0.5 * BIG, 0.0, op.q)
    prim_res = _vnorm(prim_viol) / (1.0 + _vnorm(q_eff))

    r = op.c + engine.KT(op.data, y)                  # reduced costs
    p_obj = jnp.sum(op.c * x, axis=-1)
    # g(y) = -q.y + sum_i min(l_i r_i, u_i r_i); BIG bounds act as -inf penalty
    d_obj = (-jnp.sum(op.q * y, axis=-1)
             + jnp.sum(jnp.minimum(op.l * r, op.u * r), axis=-1))
    gap = jnp.abs(p_obj - d_obj) / (1.0 + jnp.abs(p_obj) + jnp.abs(d_obj))
    return prim_res, gap, p_obj, d_obj


class _State(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray
    x_sum: jnp.ndarray
    y_sum: jnp.ndarray
    avg_n: jnp.ndarray        # [k] iterations accumulated since restart
    x_anchor: jnp.ndarray     # iterate at last restart (for omega update)
    y_anchor: jnp.ndarray
    omega: jnp.ndarray        # [k] primal weight
    last_score: jnp.ndarray   # [k] KKT score at last restart (decay test)
    it: jnp.ndarray           # [k]
    done: jnp.ndarray         # [k]
    prim_res: jnp.ndarray
    gap: jnp.ndarray


def _equilibrate(engine: StepEngine, op: OperatorLP,
                 iters: int = 2, n_probes: int = 4):
    """Operator-form Ruiz equilibration (EXPERIMENTS.md §Perf hillclimb 3):
    per-lane (d_r, d_c) diagonal scalings estimated purely through matvec
    probes (Hutchinson: with Rademacher v, E[(Kv)_i^2] = squared row norms;
    columns dual) — works for ANY structured operator, not just dense K.
    The same probe vectors are shared across the k lanes."""
    n_var = op.c.shape[-1]
    n_con = op.q.shape[-1]
    d_r = jnp.ones_like(op.q)
    d_c = jnp.ones_like(op.c)
    key = jax.random.PRNGKey(7)
    for i in range(iters):
        kr, kc = jax.random.split(jax.random.fold_in(key, i))
        vs = jax.random.rademacher(kr, (n_probes, n_var), jnp.float32)
        rows = jnp.mean(jax.vmap(
            lambda v: jnp.square(d_r * engine.K(op.data, d_c * v)))(vs), axis=0)
        us = jax.random.rademacher(kc, (n_probes, n_con), jnp.float32)
        cols = jnp.mean(jax.vmap(
            lambda u: jnp.square(d_c * engine.KT(op.data, d_r * u)))(us), axis=0)
        rn, cn = jnp.sqrt(rows), jnp.sqrt(cols)
        d_r = d_r / jnp.sqrt(jnp.where(rn > 1e-8, rn, 1.0))
        d_c = d_c / jnp.sqrt(jnp.where(cn > 1e-8, cn, 1.0))
    return d_r, d_c


def solve_stacked(
    op: OperatorLP,
    engine: Union[None, str, StepEngine] = None,
    K_mv: Callable = dense_K_mv,
    KT_mv: Callable = dense_KT_mv,
    *,
    max_iters: int = 20_000,
    check_every: int = 40,
    tol_primal: float = 1e-4,
    tol_gap: float = 1e-4,
    eta: float = 0.9,
    omega0: float = 1.0,
    equilibrate: bool = False,
    warm_x: Optional[jnp.ndarray] = None,
    warm_y: Optional[jnp.ndarray] = None,
    warm_mask: Optional[jnp.ndarray] = None,
) -> SolveResult:
    """Solve a STACK of k LPs at once (every ``op`` leaf has a leading [k]
    axis; the result carries the same axis).  This is the map-step core:
    one fori/while loop drives all k sub-problems with per-lane step sizes,
    restarts and termination, so the fused engine can hand the whole batch
    to single kernel launches.  Fully traceable.

    ``warm_mask`` ([k] bool) gates the warm start per lane: False lanes
    start cold even when ``warm_x``/``warm_y`` are given.  This is how
    churn-aware remapped warm starts (``core/plan.py``) cold-start lanes
    that matched no previous entity — a ``jnp.where`` on data, not a
    Python-level branch, so all lanes share one jitted solve.
    """
    eng = resolve_engine(engine, op, K_mv, KT_mv)
    k = op.c.shape[0]
    n_var = op.c.shape[-1]

    op_run, eng_run = op, eng
    if equilibrate:
        d_r, d_c = _equilibrate(eng, op)
        if eng.scale_data is not None:
            op_run = scale_operator(op, d_r, d_c,
                                    data=eng.scale_data(op.data, d_r, d_c))
        else:
            op_run = scale_operator(op, d_r, d_c)
            eng_run = _engine_from_matvecs(
                eng.name + "_scaled",
                lambda data, x: d_r * eng.K(data, d_c * x),
                lambda data, y: d_c * eng.KT(data, d_r * y))
        # warm iterates arrive in ORIGINAL space — map into scaled space
        if warm_x is not None:
            warm_x = warm_x / d_c
        if warm_y is not None:
            warm_y = warm_y / d_r

    knorm = _power_iteration(eng_run, op_run.data, k, n_var)   # [k]

    cold_x = jnp.clip(jnp.zeros_like(op_run.c), op_run.l, op_run.u)
    cold_y = jnp.zeros_like(op_run.q)
    x0 = cold_x if warm_x is None else jnp.asarray(warm_x, op_run.c.dtype)
    y0 = cold_y if warm_y is None else jnp.asarray(warm_y, op_run.q.dtype)
    if warm_mask is not None and (warm_x is not None or warm_y is not None):
        m = jnp.asarray(warm_mask, bool)[:, None]
        x0 = jnp.where(m, x0, cold_x)
        y0 = jnp.where(m, y0, cold_y)

    def chunk(state: _State) -> _State:
        tau = eta / (state.omega * knorm)          # [k]
        sigma = eta * state.omega / knorm          # [k]

        def one_iter(_, carry):
            x, y, xs, ys = carry
            x_new, x_bar = eng_run.primal(op_run.data, y, x, op_run.c,
                                          op_run.l, op_run.u, tau)
            y_new = eng_run.dual(op_run.data, x_bar, y, op_run.q, sigma,
                                 op_run.ineq_mask)
            return x_new, y_new, xs + x_new, ys + y_new

        x, y, xs, ys = jax.lax.fori_loop(
            0, check_every, one_iter,
            (state.x, state.y, state.x_sum, state.y_sum),
        )
        avg_n = state.avg_n + check_every

        # ---- candidate = better of {current, running average} ------------
        x_avg = xs / avg_n[:, None]
        y_avg = ys / avg_n[:, None]
        pr_c, gap_c, _, _ = _kkt(op_run, eng_run, x, y)
        pr_a, gap_a, _, _ = _kkt(op_run, eng_run, x_avg, y_avg)
        score_c = pr_c + gap_c
        score_a = pr_a + gap_a
        use_avg = score_a < score_c                # [k]
        x_r = jnp.where(use_avg[:, None], x_avg, x)
        y_r = jnp.where(use_avg[:, None], y_avg, y)
        pr = jnp.where(use_avg, pr_a, pr_c)
        gap = jnp.where(use_avg, gap_a, gap_c)
        score = jnp.minimum(score_a, score_c)

        # ---- adaptive restart: only on sufficient KKT decay ---------------
        # (restarting every chunk kills PDHG momentum; PDLP-style decay test)
        restart = (score < 0.4 * state.last_score) | (avg_n >= 16 * check_every)

        # ---- primal weight update at restarts (PDLP eq. 10, smoothed) -----
        dx = _vnorm(x_r - state.x_anchor)
        dy = _vnorm(y_r - state.y_anchor)
        safe = (dx > 1e-12) & (dy > 1e-12)
        ratio = jnp.where(safe, dy / jnp.maximum(dx, 1e-12), 1.0)
        omega_new = jnp.exp(
            0.5 * jnp.log(jnp.clip(ratio, 1e-4, 1e4)) + 0.5 * jnp.log(state.omega)
        )

        conv = (pr < tol_primal) & (gap < tol_gap)
        done = state.done | conv

        def pick(on_restart, no_restart):
            return jnp.where(_bcast(restart, on_restart), on_restart, no_restart)

        # freeze finished lanes: batch peers keep going
        def keep(new, old):
            return jnp.where(_bcast(state.done, new), old, new)

        return _State(
            x=keep(pick(x_r, x), state.x),
            y=keep(pick(y_r, y), state.y),
            x_sum=keep(pick(jnp.zeros_like(xs), xs), state.x_sum),
            y_sum=keep(pick(jnp.zeros_like(ys), ys), state.y_sum),
            avg_n=keep(pick(jnp.zeros_like(avg_n), avg_n), state.avg_n),
            x_anchor=keep(pick(x_r, state.x_anchor), state.x_anchor),
            y_anchor=keep(pick(y_r, state.y_anchor), state.y_anchor),
            omega=keep(pick(omega_new, state.omega), state.omega),
            last_score=keep(pick(score, state.last_score), state.last_score),
            it=state.it + jnp.where(state.done, 0, check_every),
            done=done,
            prim_res=keep(pr, state.prim_res), gap=keep(gap, state.gap),
        )

    init = _State(
        x=x0, y=y0,
        x_sum=jnp.zeros_like(x0), y_sum=jnp.zeros_like(y0),
        avg_n=jnp.zeros((k,), jnp.float32),
        x_anchor=x0, y_anchor=y0,
        omega=jnp.full((k,), omega0, jnp.float32),
        last_score=jnp.full((k,), jnp.inf),
        it=jnp.zeros((k,), jnp.int32),
        done=jnp.zeros((k,), bool),
        prim_res=jnp.full((k,), jnp.inf), gap=jnp.full((k,), jnp.inf),
    )

    state = jax.lax.while_loop(
        lambda s: jnp.any((~s.done) & (s.it < max_iters)), chunk, init
    )

    x_fin, y_fin = state.x, state.y
    if equilibrate:
        # report in ORIGINAL space
        x_fin, y_fin = unscale_solution(x_fin, y_fin, d_r, d_c)
    pr, gap, p_obj, d_obj = _kkt(op, eng, x_fin, y_fin)
    return SolveResult(
        x=x_fin, y=y_fin, primal_obj=p_obj, dual_obj=d_obj,
        primal_res=pr, gap=gap, iterations=state.it, converged=state.done,
    )


def solve(
    op: OperatorLP,
    K_mv: Callable = dense_K_mv,
    KT_mv: Callable = dense_KT_mv,
    *,
    max_iters: int = 20_000,
    check_every: int = 40,
    tol_primal: float = 1e-4,
    tol_gap: float = 1e-4,
    eta: float = 0.9,
    omega0: float = 1.0,
    equilibrate: bool = False,
    warm_x: Optional[jnp.ndarray] = None,
    warm_y: Optional[jnp.ndarray] = None,
    warm_mask: Optional[jnp.ndarray] = None,
    engine: Union[None, str, StepEngine] = "matvec",
) -> SolveResult:
    """Solve one LP: a k=1 stack through :func:`solve_stacked`.  Fully
    traceable; vmap over a batched ``op`` for POP (or better, hand the
    whole stack to ``solve_stacked`` / ``backends.solve_map``)."""
    opb = jax.tree.map(lambda a: jnp.asarray(a)[None], op)
    wx = None if warm_x is None else jnp.asarray(warm_x)[None]
    wy = None if warm_y is None else jnp.asarray(warm_y)[None]
    wm = None if warm_mask is None else jnp.asarray(warm_mask).reshape((1,))
    res = solve_stacked(
        opb, engine=engine, K_mv=K_mv, KT_mv=KT_mv,
        max_iters=max_iters, check_every=check_every,
        tol_primal=tol_primal, tol_gap=tol_gap, eta=eta, omega0=omega0,
        equilibrate=equilibrate, warm_x=wx, warm_y=wy, warm_mask=wm)
    return jax.tree.map(lambda a: a[0], res)


# --------------------------------------------------------------------------
# Ruiz equilibration (dense path) — first-order methods live or die by
# conditioning; diagonal rescaling cuts PDHG iteration counts by 10-100x.
# Bounds/rhs handling is shared with the probe path via scale_operator.
# --------------------------------------------------------------------------

def ruiz_equilibrate(op: OperatorLP, iters: int = 8):
    """Return (scaled_op, d_row, d_col) with K~ = D_r K D_c equilibrated.

    Recover original-space solutions as  x = d_col * x~,  y = d_row * y~
    (:func:`unscale_solution`).  Dense-data only (needs explicit row/col
    norms); the probe-based path inside ``solve(equilibrate=True)`` covers
    structured operators.
    """
    (K,) = op.data
    d_r = jnp.ones(K.shape[0])
    d_c = jnp.ones(K.shape[1])

    def body(_, carry):
        d_r, d_c = carry
        Ks = K * d_r[:, None] * d_c[None, :]
        rn = jnp.sqrt(jnp.max(jnp.abs(Ks), axis=1))
        cn = jnp.sqrt(jnp.max(jnp.abs(Ks), axis=0))
        d_r = d_r / jnp.where(rn > 1e-12, rn, 1.0)
        d_c = d_c / jnp.where(cn > 1e-12, cn, 1.0)
        return d_r, d_c

    d_r, d_c = jax.lax.fori_loop(0, iters, body, (d_r, d_c))
    Ks = K * d_r[:, None] * d_c[None, :]
    return scale_operator(op, d_r, d_c, data=(Ks,)), d_r, d_c


# --------------------------------------------------------------------------
# convenience wrappers
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iters", "tol_primal", "tol_gap"))
def solve_dense(lp: LinearProgram, max_iters: int = 20_000,
                tol_primal: float = 1e-4, tol_gap: float = 1e-4) -> SolveResult:
    op = dense_ops(lp)
    sop, d_r, d_c = ruiz_equilibrate(op)
    res = solve(sop, dense_K_mv, dense_KT_mv,
                max_iters=max_iters, tol_primal=tol_primal, tol_gap=tol_gap)
    # report objective/residuals in ORIGINAL space
    x, y = unscale_solution(res.x, res.y, d_r, d_c)
    pr, gap, p_obj, d_obj = _kkt(jax.tree.map(lambda a: a[None], op),
                                 matvec_engine(), x[None], y[None])
    squeeze = lambda a: a[0]
    return SolveResult(x=x, y=y, primal_obj=squeeze(p_obj),
                       dual_obj=squeeze(d_obj), primal_res=squeeze(pr),
                       gap=squeeze(gap),
                       iterations=res.iterations, converged=res.converged)


def solve_batched(op_batched: OperatorLP, K_mv=dense_K_mv, KT_mv=dense_KT_mv,
                  **kw) -> SolveResult:
    """vmap over the leading (sub-problem) axis — POP's map step on one
    device.  ``core/backends.py`` wraps this in shard_map for the mesh path
    and swaps in the fused engine for dense problems."""
    return jax.vmap(lambda o: solve(o, K_mv, KT_mv, **kw))(op_batched)
