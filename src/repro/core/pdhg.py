"""Restarted PDHG (PDLP-family) linear-programming solver in pure JAX.

Why PDHG and not simplex/barrier (what the paper's solvers use): every PDHG
iteration is two matrix–vector products plus element-wise projections —
MXU/VPU work with no data-dependent control flow.  That makes the solver

  * ``jax.lax``-expressible (while_loop/fori_loop),
  * **vmap-able over the POP sub-problem axis** — POP's map step becomes a
    single batched solve, and
  * ``shard_map``-able — sub-problems spread across mesh devices with zero
    collectives inside the map step (they are independent by construction).

The solver is generic over an *operator form* of the constraint matrix

    K = [G; A]   (first ``n_ineq`` rows are inequalities)

supplied as a pair of callables ``K_mv(data, x)`` / ``KT_mv(data, y)`` plus a
data pytree.  Dense problems use plain matmuls (and, on TPU, the Pallas
kernels in ``repro.kernels``); the big domain problems (traffic engineering
with >10^6 variables) supply structured matvecs so the full unpartitioned
baseline never materialises a dense K.  Structured problems can ALSO attach
a :class:`StructuredOperator` — explicit index arrays + coefficients — which
unlocks the ``fused_structured`` engine (below).

Step-engine contract
--------------------

The inner-loop math is factored behind a :class:`StepEngine`.  An engine
works on a whole STACKED batch of k sub-problems at once — every array
carries a leading ``[k]`` axis and per-sub-problem scalars (step sizes) are
``[k]`` vectors, because POP sub-problems restart independently and their
step sizes diverge across the batch.  An engine provides two *half-steps*
that each emit the matvec product they materialise:

    forward(data, x, c, l, u, tau[k], kty)          -> (x_new, K x_new)
    backward(data, y, q, sigma[k], ineq, kx, kx_-)  -> (y_new, K^T y_new)

``forward`` is the primal update ``x+ = clip(x - tau (c + K^T y), l, u)``
(consuming the CARRIED ``K^T y`` from the previous backward) followed by the
forward product ``K x+``; ``backward`` is the dual update using the
extrapolated product ``K x_bar = 2 K x+ - K x`` (linearity of K — no second
matvec for the extrapolated point) followed by the adjoint product
``K^T y+``.  Per iteration that is exactly one K and one K^T application —
the same operator work as classic PDHG — but the products now flow OUT of
the half-steps, which is what makes the in-loop KKT check free (below).
Four engines ship:

``matvec`` (:func:`matvec_engine`)
    Wraps the user's ``K_mv``/``KT_mv`` callables with ``jax.vmap`` and
    applies the element-wise tails in plain jnp.  Works for ANY structured
    operator; the fallback engine for problems without metadata.

``fused`` (:func:`fused_dense_engine`)
    Dense-data-only.  Routes each half-step through the batched fused
    kernels in ``repro.kernels.ops`` (``fused_forward_step`` /
    ``fused_backward_step``), so on TPU the matvec partials stay in VMEM
    and the axpy+projection tail runs in the SAME kernel launch — one
    launch per half-step for the whole k-stack.

``fused_structured`` (:func:`fused_structured_engine`)
    For operators with a :class:`StructuredOperator` attached (segment-sum
    /gather matvecs: Gavel per-job rows, traffic per-commodity path sums,
    LB server groups).  Each half-step is one batched Pallas
    gather/segment-reduce launch over the whole k-stack
    (``kernels/structured_pdhg_step.py``); off-TPU the dispatch in
    ``kernels/ops.py`` takes an XLA reference built on
    ``take_along_axis`` gathers — no scatters anywhere, unlike the
    ``segment_sum`` scatter-adds inside typical domain matvecs.

``fused_structured_full`` (:func:`fused_structured_full_engine`)
    The M-blocked streaming variant for the **single-lane full problem**
    (the k=1 quality baseline POP is judged against).  Tiles the nnz-major
    ELL arrays into VMEM-sized M-blocks, streams partial gather/reduces,
    folds wide-bucket contributions across blocks through the fold map
    (a gather, not a one-hot einsum), and slices the descending-sorted
    wide bucket by a static ragged block plan so padded work stays ~nnz.
    Supports int8/bf16 coefficient storage (:func:`quantize_structured`)
    with in-kernel dequantization and f32 accumulation.

``engine="auto"`` (:func:`select_engine`) picks ``fused`` for dense
operator data on TPU, ``fused_structured`` when index metadata is present
(``fused_structured_full`` when additionally single-lane with large wide
buckets), and ``matvec`` otherwise.  Engines differ only in
scheduling/fusion, never in math — ``tests/test_engine_conformance.py``
pins all engines x all map backends x the three paper domains to 1e-5 on
fixed iteration budgets.

In-loop KKT (free convergence checks)
-------------------------------------

Because the half-steps emit ``K x`` / ``K^T y``, and the running averages
of those products equal the products of the running averages (K is
linear), the per-chunk KKT check — primal residual and duality gap for
BOTH restart candidates (current iterate and running average) — is
computed entirely from carried products: **zero extra operator passes**.
The previous scheme paid two full K + two K^T applications per check.
``solve_stacked(kkt="standalone")`` keeps a verification mode that
re-derives the current candidate's products with fresh operator passes;
it must be bit-identical to the in-loop path on the CPU/XLA path
(``tests/test_engine_conformance.py`` pins this), which proves the carried
products never drift from ground truth through restarts, freezing, or
warm starts.

:func:`solve_stacked` is the batched entry point (what the map-step
backends in ``core/backends.py`` call); :func:`solve` is the
single-problem wrapper (a k=1 stack).

Warm starts
-----------

``solve``/``solve_stacked`` accept ``warm_x``/``warm_y`` — the previous
solution of a nearby instance.  For online re-solves (scheduler rounds,
load-balancer ticks) a warm start typically cuts iteration counts by far
more than half (``benchmarks/bench_online_resolve.py`` measures this).
With ``equilibrate=True`` the warm iterates are mapped into the scaled
space (``x/d_c``, ``y/d_r``) before iterating, so warm-starting composes
with scaling.

Algorithm: Chambolle–Pock primal–dual with
  * power-iteration estimate of ||K||,
  * step sizes tau = eta/(omega*||K||), sigma = eta*omega/||K||,
  * iterate averaging + adaptive restart to the better of {current, average}
    by KKT score (simplified PDLP restart rule),
  * primal-weight (omega) rebalancing at restarts,
  * termination on relative primal residual + relative duality gap.
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .problem import BIG, LinearProgram


class StructuredOperator(NamedTuple):
    """Index-array form of a sparse constraint matrix K ([M, N]): each
    matvec direction gets its own gather layout — for every row, the
    column ids + coefficients that feed it (``K x``), and for every
    column, the row ids + values (``K^T y``) — so BOTH directions are pure
    gather + reduce, no scatter.

    Per side the layout is a **skew-aware two-bucket ELL**: structured LPs
    concentrate a few very wide segments (Gavel worker-cap rows and the
    epigraph ``t`` column touch every job; LB per-server load rows touch
    every shard; hot traffic edges carry many paths) among many narrow
    ones, and a uniform-width ELL would pad every narrow segment to the
    widest.  Segments wider than ~4x the median go to a separate *wide
    bucket* — an ELL over just those ``D`` segments (``w*_idx/w*_val
    [Ww, D]`` + ``w*_ids [D]`` naming which segment each bucket column
    feeds) — whose reduced results are added back with a tiny one-hot
    accumulation.  Total padded work stays ~nnz instead of
    ~n_segments * max_width.

    Arrays are nnz-major (``[..., W, M]``: padded per-segment entry count
    W on the sublane axis, segments on the lane axis) so the reduce runs
    over the leading axis while M/N stay on the 128-wide axis — what the
    Pallas kernels in ``kernels/structured_pdhg_step.py`` want.  Padding
    entries carry ``idx 0, val 0.0`` (a zero coefficient is harmless in a
    gather-multiply-add), so no validity mask is needed, duplicate
    (row, col) entries simply sum — segment-sum semantics — and empty wide
    buckets are a single zero column feeding segment 0 with 0.0.

    Wide bucket columns are kept **sorted by descending width** and each
    side carries a *fold map* (``row_fold [M]`` / ``col_fold [N]``): the
    inverse of ``w*_ids``, sending every segment to its bucket column —
    or to the one-past-the-end zero slot ``D`` when the segment is
    narrow.  The fold map turns the wide-bucket add-back into a single
    gather (``out + wide_padded[fold]``) instead of a one-hot einsum, and
    the descending sort is what lets the M-blocked full-problem engine
    (``fused_structured_full``) slice the wide arrays into contiguous
    ragged blocks with monotone widths (sliced-ELL style) so padded work
    stays ~nnz even when one bucket column is 10x wider than the median.

    Coefficient arrays default to f32 but may be stored **quantized**
    (:func:`quantize_structured`): ``bfloat16``, or ``int8`` with a
    symmetric per-bucket scale factor in ``*_scale`` ([1] f32, ``None``
    = unscaled).  Engines dequantize in-kernel and accumulate in f32.

    All leaves batch over a leading ``[k]`` sub-problem axis like every
    other ``OperatorLP`` field; :func:`stack_ops` pads per-lane
    widths/bucket sizes to the stack maximum before stacking (fold maps
    stay lane-correct: a lane's zero slot is a padded zero column of the
    stacked wide arrays).
    """

    row_idx: jnp.ndarray    # [..., Wr, M] int32 column ids feeding each row
    row_val: jnp.ndarray    # [..., Wr, M] coefficients (f32/bf16/int8)
    wrow_idx: jnp.ndarray   # [..., Ww, Dr] wide-row bucket column ids
    wrow_val: jnp.ndarray   # [..., Ww, Dr]
    wrow_ids: jnp.ndarray   # [..., Dr] int32 row fed by each bucket column
    col_idx: jnp.ndarray    # [..., Wc, N] int32 row ids feeding each column
    col_val: jnp.ndarray    # [..., Wc, N] coefficients (f32/bf16/int8)
    wcol_idx: jnp.ndarray   # [..., Wv, Dc] wide-column bucket row ids
    wcol_val: jnp.ndarray   # [..., Wv, Dc]
    wcol_ids: jnp.ndarray   # [..., Dc] int32 column fed by each bucket column
    row_fold: Optional[jnp.ndarray] = None   # [..., M] int32 bucket col or Dr
    col_fold: Optional[jnp.ndarray] = None   # [..., N] int32 bucket col or Dc
    row_scale: Optional[jnp.ndarray] = None   # [..., 1] f32 dequant scales
    wrow_scale: Optional[jnp.ndarray] = None
    col_scale: Optional[jnp.ndarray] = None
    wcol_scale: Optional[jnp.ndarray] = None

    @property
    def coef_dtype(self) -> str:
        """Storage dtype of the coefficient payload ("float32", "bfloat16"
        or "int8" — see :func:`quantize_structured`)."""
        return str(jnp.dtype(self.row_val.dtype))


def _pack_ell(seg: np.ndarray, other: np.ndarray, vals: np.ndarray,
              n_seg: int, width_mult: int = 8):
    """Pack COO entries grouped by ``seg`` into nnz-major ELL
    ``(idx [W, n_seg], val [W, n_seg])``; W rounds up to ``width_mult``
    (stable widths across re-builds keep jit caches warm)."""
    order = np.argsort(seg, kind="stable")
    s = seg[order].astype(np.int64)
    o = other[order]
    v = vals[order]
    starts = np.searchsorted(s, np.arange(n_seg))
    pos = np.arange(s.size) - starts[s] if s.size else np.zeros(0, np.int64)
    w = int(pos.max()) + 1 if s.size else 1
    w = max(1, -(-w // width_mult) * width_mult)
    idx = np.zeros((w, n_seg), np.int32)
    val = np.zeros((w, n_seg), np.float32)
    idx[pos, s] = o
    val[pos, s] = v
    return idx, val


def _pack_side(seg: np.ndarray, other: np.ndarray, vals: np.ndarray,
               n_seg: int):
    """One gather side (rows or columns) as the two-bucket ELL: segments
    wider than ``max(16, 4 * median nonzero width)`` split into the wide
    bucket, whose columns are sorted by DESCENDING width so contiguous
    column ranges have monotone widths (what the M-blocked full engine's
    ragged wide-block plan slices).  Returns
    (idx, val, widx, wval, wids, fold) where ``fold [n_seg]`` maps every
    segment to its bucket column, or to the zero slot ``d`` (one past the
    stored bucket) when narrow."""
    seg = seg.astype(np.int64)
    counts = np.bincount(seg, minlength=n_seg) if seg.size \
        else np.zeros(n_seg, np.int64)
    nz = counts[counts > 0]
    med = int(np.median(nz)) if nz.size else 1
    cap = max(16, 4 * (-(-med // 8) * 8))
    wide = np.flatnonzero(counts > cap)
    wide = wide[np.argsort(-counts[wide], kind="stable")]
    is_wide = np.isin(seg, wide)
    idx, val = _pack_ell(seg[~is_wide], other[~is_wide], vals[~is_wide],
                         n_seg)
    d = max(int(wide.size), 1)
    bucket_of = np.zeros(n_seg, np.int64)
    bucket_of[wide] = np.arange(wide.size)
    widx, wval = _pack_ell(bucket_of[seg[is_wide]], other[is_wide],
                           vals[is_wide], d)
    wids = np.zeros(d, np.int32)
    wids[: wide.size] = wide
    fold = np.full(n_seg, d, np.int32)
    fold[wide] = np.arange(wide.size)
    return idx, val, widx, wval, wids, fold


def structured_from_coo(rows, cols, vals, n_rows: int, n_cols: int,
                        coef_dtype: str = "float32") -> StructuredOperator:
    """Build a :class:`StructuredOperator` from COO triplets (numpy, at
    problem build time).  Entries may repeat (they sum) and may carry zero
    values (kept — structural zeros give shape-stable widths).
    ``coef_dtype`` selects the coefficient storage
    (:func:`quantize_structured`): "float32" (default), "bfloat16", or
    "int8" with per-bucket scale factors."""
    rows = np.asarray(rows).ravel()
    cols = np.asarray(cols).ravel()
    vals = np.asarray(vals, np.float32).ravel()
    ri, rv, wri, wrv, wrids, rfold = _pack_side(rows, cols, vals, n_rows)
    ci, cv, wci, wcv, wcids, cfold = _pack_side(cols, rows, vals, n_cols)
    j = jnp.asarray
    s = StructuredOperator(
        row_idx=j(ri), row_val=j(rv),
        wrow_idx=j(wri), wrow_val=j(wrv), wrow_ids=j(wrids),
        col_idx=j(ci), col_val=j(cv),
        wcol_idx=j(wci), wcol_val=j(wcv), wcol_ids=j(wcids),
        row_fold=j(rfold), col_fold=j(cfold))
    return quantize_structured(s, coef_dtype)


# coefficient storage dtypes quantize_structured accepts
COEF_DTYPES = ("float32", "bfloat16", "int8")


def _quantize_val(val: jnp.ndarray, dtype: str):
    """(stored, scale) for one coefficient bucket: bf16 is a plain cast
    (scale None), int8 is symmetric per-bucket — scale = max|v| / 127."""
    v = jnp.asarray(val, jnp.float32)
    if dtype == "bfloat16":
        return v.astype(jnp.bfloat16), None
    m = jnp.max(jnp.abs(v), axis=(-2, -1))
    scale = jnp.maximum(m, 1e-30) / 127.0
    q = jnp.clip(jnp.round(v / scale[..., None, None]),
                 -127, 127).astype(jnp.int8)
    return q, jnp.reshape(scale, v.shape[:-2] + (1,))


def quantize_structured(s: StructuredOperator,
                        coef_dtype: str = "int8") -> StructuredOperator:
    """Mixed-precision ELL coefficient storage (build time): re-store the
    four value arrays as ``coef_dtype`` — "bfloat16" (plain cast) or
    "int8" (symmetric per-bucket quantization, dequant scale in the
    ``*_scale`` fields) — halving / quartering the memory-bound payload
    the step kernels stream.  Engines dequantize in-kernel and accumulate
    in f32; "float32" is the identity.  Quantize from an f32 operator
    (re-quantizing a quantized one raises)."""
    if coef_dtype not in COEF_DTYPES:
        raise ValueError(f"unknown coef_dtype {coef_dtype!r}; "
                         f"expected one of {COEF_DTYPES}")
    if coef_dtype == "float32":
        return s
    if s.coef_dtype != "float32":
        raise ValueError(f"operator already stores {s.coef_dtype} "
                         "coefficients; dequantize_structured first")
    rv, rs = _quantize_val(s.row_val, coef_dtype)
    wrv, wrs = _quantize_val(s.wrow_val, coef_dtype)
    cv, cs = _quantize_val(s.col_val, coef_dtype)
    wcv, wcs = _quantize_val(s.wcol_val, coef_dtype)
    return s._replace(row_val=rv, wrow_val=wrv, col_val=cv, wcol_val=wcv,
                      row_scale=rs, wrow_scale=wrs,
                      col_scale=cs, wcol_scale=wcs)


def _dequantize_val(val: jnp.ndarray, scale: Optional[jnp.ndarray]):
    v = jnp.asarray(val, jnp.float32)
    return v if scale is None else v * scale[..., None]


def dequantize_structured(s: StructuredOperator) -> StructuredOperator:
    """Back to plain f32 coefficient storage (scales folded in, scale
    fields cleared).  Identity for f32 operators."""
    if s.coef_dtype == "float32" and s.row_scale is None:
        return s
    return s._replace(
        row_val=_dequantize_val(s.row_val, s.row_scale),
        wrow_val=_dequantize_val(s.wrow_val, s.wrow_scale),
        col_val=_dequantize_val(s.col_val, s.col_scale),
        wcol_val=_dequantize_val(s.wcol_val, s.wcol_scale),
        row_scale=None, wrow_scale=None, col_scale=None, wcol_scale=None)


def structured_to_dense(s: StructuredOperator) -> jnp.ndarray:
    """Materialise the dense K ([..., M, N]) a StructuredOperator encodes
    — from the row-side layout alone, which fully represents K (tests +
    the conformance matrix; never used on the solve path)."""
    s = dequantize_structured(s)

    def one(ri, rv, wri, wrv, wrids, n_cols):
        m = ri.shape[1]
        rows = jnp.broadcast_to(jnp.arange(m)[None, :], ri.shape)
        k0 = jnp.zeros((m, n_cols), rv.dtype)
        k0 = k0.at[rows.ravel(), ri.ravel()].add(rv.ravel())
        wrows = jnp.broadcast_to(wrids[None, :], wri.shape)
        return k0.at[wrows.ravel(), wri.ravel()].add(wrv.ravel())
    n_cols = s.col_idx.shape[-1]
    if s.row_idx.ndim == 2:
        return one(s.row_idx, s.row_val, s.wrow_idx, s.wrow_val,
                   s.wrow_ids, n_cols)
    return jax.vmap(lambda ri, rv, wri, wrv, wrids: one(
        ri, rv, wri, wrv, wrids, n_cols))(
        s.row_idx, s.row_val, s.wrow_idx, s.wrow_val, s.wrow_ids)


def scale_structured(s: StructuredOperator, d_r: jnp.ndarray,
                     d_c: jnp.ndarray) -> StructuredOperator:
    """K~ = D_r K D_c applied to the ELL payload (batched: d_r [k, M],
    d_c [k, N]).  Padded entries stay zero (0 * anything), so fold maps
    and the wide-block plan stay valid.  Quantized storage is dequantized
    first — equilibration products are not representable in int8, so the
    scaled operator degrades to f32 coefficients (the quantized payload
    is a memory-bandwidth format, not an arithmetic one)."""
    from ..kernels.ref import _bgather as bgather
    s = dequantize_structured(s)
    return s._replace(
        row_val=s.row_val * d_r[:, None, :] * bgather(d_c, s.row_idx),
        wrow_val=(s.wrow_val * bgather(d_r, s.wrow_ids)[:, None, :]
                  * bgather(d_c, s.wrow_idx)),
        col_val=s.col_val * d_c[:, None, :] * bgather(d_r, s.col_idx),
        wcol_val=(s.wcol_val * bgather(d_c, s.wcol_ids)[:, None, :]
                  * bgather(d_r, s.wcol_idx)))


class OperatorLP(NamedTuple):
    """LP in operator form.  ``data`` is whatever the K_mv/KT_mv callables
    need (dense K, index arrays, ...).  ``structured``, when present, is
    the :class:`StructuredOperator` index metadata that lets the
    ``fused_structured`` engine run the same operator as batched
    gather/segment-reduce kernels.  All leaves are batchable."""

    c: jnp.ndarray          # [N]
    q: jnp.ndarray          # [M]    rhs for K rows
    l: jnp.ndarray          # [N]
    u: jnp.ndarray          # [N]
    ineq_mask: jnp.ndarray  # [M] bool: True → dual projected >= 0
    data: Any               # operator payload pytree
    structured: Optional[StructuredOperator] = None


def dense_ops(lp: LinearProgram) -> OperatorLP:
    K, q, ineq = lp.stacked()
    return OperatorLP(c=lp.c, q=q, l=lp.l, u=lp.u, ineq_mask=ineq, data=(K,))


def dense_K_mv(data, x):
    (K,) = data
    return K @ x


def dense_KT_mv(data, y):
    (K,) = data
    return K.T @ y


def stack_ops(subs: Sequence[OperatorLP]) -> OperatorLP:
    """Stack identically-shaped sub-LPs on a leading [k] axis.  ELL widths
    (data-dependent: how congested the fullest row is in THIS lane) are
    padded to the stack maximum first, so lanes with different structured
    widths still stack; if any lane lacks metadata the whole stack drops
    it (engines must see one consistent payload)."""
    subs = list(subs)
    structs = [s.structured for s in subs]
    bare = [s._replace(structured=None) for s in subs]
    ops = jax.tree.map(lambda *xs: jnp.stack(xs), *bare)
    if any(st is None for st in structs):
        return ops
    # mixed coefficient storage cannot stack (int8 next to f32); degrade
    # the whole stack to f32 — lanes normally share one coef_dtype anyway
    if len({st.coef_dtype for st in structs}) > 1:
        structs = [dequantize_structured(st) for st in structs]

    def padto(a, shape):
        return jnp.pad(a, [(0, t - s) for s, t in zip(a.shape, shape)])

    stacked = {}
    for f in StructuredOperator._fields:
        vals = [getattr(st, f) for st in structs]
        if any(v is None for v in vals):
            stacked[f] = None
            continue
        shape = tuple(max(v.shape[d] for v in vals)
                      for d in range(vals[0].ndim))
        stacked[f] = jnp.stack([padto(v, shape) for v in vals])
    return ops._replace(structured=StructuredOperator(**stacked))


def concat_stacks(stacks: Sequence[OperatorLP]) -> OperatorLP:
    """Concatenate already-STACKED OperatorLPs (leading ``[k_i]`` axes) into
    one ``[sum k_i]`` stack — the cross-tenant analogue of
    :func:`stack_ops`' cross-lane stacking, used by the serving dispatcher
    to coalesce concurrent tenants' sub-problem stacks into one launch.

    Structured ELL widths and wide-bucket counts (data-dependent per
    tenant) are padded to the maximum across stacks before concatenating,
    exactly like :func:`stack_ops` pads per-lane widths: padding entries
    carry ``idx 0, val 0.0`` (harmless in a gather-multiply-add) and each
    lane's fold map keeps pointing at its own zero slot, which remains a
    zero column of the widened wide arrays.  Lanes are independent in
    :func:`solve_stacked` (per-lane step sizes, restarts, termination), so
    every lane's trajectory is unchanged by who it shares a launch with.
    If any stack lacks structured metadata the result drops it; mixed
    coefficient storage dequantizes to f32 first (both mirror
    :func:`stack_ops` — the dispatcher's compatibility key never mixes
    them in practice)."""
    stacks = list(stacks)
    if len(stacks) == 1:
        return stacks[0]
    structs = [s.structured for s in stacks]
    bare = [s._replace(structured=None) for s in stacks]
    ops = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *bare)
    if any(st is None for st in structs):
        return ops
    if len({st.coef_dtype for st in structs}) > 1:
        structs = [dequantize_structured(st) for st in structs]

    def padto(a, shape):
        return jnp.pad(a, [(0, t - s) for s, t in zip(a.shape, shape)])

    merged = {}
    for f in StructuredOperator._fields:
        vals = [getattr(st, f) for st in structs]
        if any(v is None for v in vals):
            merged[f] = None
            continue
        # trailing dims (ELL widths / wide-bucket counts) pad to the max
        # across stacks; the leading [k_i] axis concatenates as-is
        trail = tuple(max(v.shape[d] for v in vals)
                      for d in range(1, vals[0].ndim))
        merged[f] = jnp.concatenate(
            [padto(v, (v.shape[0],) + trail) for v in vals])
    return ops._replace(structured=StructuredOperator(**merged))


class SolveResult(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray
    primal_obj: jnp.ndarray
    dual_obj: jnp.ndarray
    primal_res: jnp.ndarray   # relative primal infeasibility
    gap: jnp.ndarray          # relative duality gap
    iterations: jnp.ndarray
    converged: jnp.ndarray
    n_restarts: Optional[jnp.ndarray] = None   # [k] adaptive-restart count
    diverged: Optional[jnp.ndarray] = None     # [k] lane quarantined in-loop


# --------------------------------------------------------------------------
# step engines
# --------------------------------------------------------------------------

class StepEngine(NamedTuple):
    """Batched inner-loop math for the PDHG solver (see module docstring).

    All callables take STACKED arrays (leading ``[k]`` sub-problem axis):

      K(data, x[k,N]) -> [k,M]         KT(data, y[k,M]) -> [k,N]
      forward(data, x, c, l, u, tau[k], kty[k,N]) -> (x_new, kx_new)
      backward(data, y, q, sigma[k], ineq_mask, kx_new, kx_prev)
          -> (y_new, kty_new)

    ``scale_data``, if set, rescales the operator payload for Ruiz
    equilibration (``data, d_r[k,M], d_c[k,N] -> data``); engines without
    it get their K/KT wrapped functionally instead.  ``prep``, if set,
    normalises the OperatorLP once before solving (the structured engine
    moves ``op.structured`` into ``op.data`` so every downstream consumer
    sees one payload).
    """

    name: str
    K: Callable
    KT: Callable
    forward: Callable
    backward: Callable
    scale_data: Optional[Callable] = None
    prep: Optional[Callable] = None


def _engine_from_matvecs(name: str, bK: Callable, bKT: Callable,
                         scale_data: Optional[Callable] = None,
                         prep: Optional[Callable] = None) -> StepEngine:
    """Build the element-wise half-step tails from batched matvecs."""

    def forward(data, x, c, l, u, tau, kty):
        x_new = jnp.clip(x - tau[:, None] * (c + kty), l, u)
        return x_new, bK(data, x_new)

    def backward(data, y, q, sigma, ineq_mask, kx_new, kx_prev):
        y_new = y + sigma[:, None] * (2.0 * kx_new - kx_prev - q)
        y_new = jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
        return y_new, bKT(data, y_new)

    return StepEngine(name, bK, bKT, forward, backward, scale_data, prep)


@functools.lru_cache(maxsize=64)
def _matvec_engine_cached(K_mv: Callable, KT_mv: Callable) -> StepEngine:
    return _engine_from_matvecs(
        "matvec", jax.vmap(K_mv, in_axes=(0, 0)),
        jax.vmap(KT_mv, in_axes=(0, 0)))


def matvec_engine(K_mv: Callable = dense_K_mv,
                  KT_mv: Callable = dense_KT_mv) -> StepEngine:
    """Generic operator engine: vmap the per-problem matvecs over the
    sub-problem axis.  Works for any structured ``data`` pytree.
    Memoized on matvec identity so repeated resolution returns ONE engine
    object per matvec pair — keeping downstream jit caches and the
    serving dispatcher's coalesce keys stable across tenants."""
    try:
        return _matvec_engine_cached(K_mv, KT_mv)
    except TypeError:
        # unhashable matvecs cannot memoize: fresh engine per call (such
        # configs never share jit caches or coalesce anyway)
        return _engine_from_matvecs(
            "matvec", jax.vmap(K_mv, in_axes=(0, 0)),
            jax.vmap(KT_mv, in_axes=(0, 0)))


@functools.lru_cache(maxsize=16)
def fused_dense_engine(kernel_backend: Optional[str] = None,
                       block_m: Optional[int] = None,
                       block_n: Optional[int] = None) -> StepEngine:
    """Dense engine over the fused Pallas kernels (``repro.kernels.ops``).

    One kernel launch covers the whole stacked batch per half-step.
    ``kernel_backend`` follows ``kernels/ops.py`` dispatch: ``None``/"auto"
    = compiled Pallas on TPU, pure-jnp reference elsewhere; "interpret" and
    "xla" force the Pallas interpreter / the reference.  Cached so repeated
    calls return the same object (keeps downstream jit caches warm).
    """
    from ..kernels import ops as kops

    kw: dict = dict(backend=kernel_backend)
    if block_m is not None:
        kw["block_m"] = block_m
    if block_n is not None:
        kw["block_n"] = block_n

    def K(data, x):
        return kops.bmatvec(data[0], x, **kw)

    def KT(data, y):
        return kops.bmatvec_t(data[0], y, **kw)

    def forward(data, x, c, l, u, tau, kty):
        return kops.fused_forward_step(data[0], x, c, l, u, tau, kty, **kw)

    def backward(data, y, q, sigma, ineq_mask, kx_new, kx_prev):
        return kops.fused_backward_step(data[0], y, q, sigma, ineq_mask,
                                        kx_new, kx_prev, **kw)

    def scale_data(data, d_r, d_c):
        (K_,) = data
        return (K_ * d_r[..., :, None] * d_c[..., None, :],)

    return StepEngine("fused", K, KT, forward, backward, scale_data)


@functools.lru_cache(maxsize=16)
def fused_structured_engine(
        kernel_backend: Optional[str] = None) -> StepEngine:
    """Structured engine over the batched gather/segment-reduce kernels
    (``kernels/structured_pdhg_step.py`` via ``kernels/ops.py`` dispatch:
    Pallas on TPU, XLA ``take_along_axis`` reference elsewhere).  One
    launch per half-step across the whole k-lane stack.  Requires
    ``op.structured``; ``prep`` moves it into ``op.data`` so the payload
    flows through backends/jit as ordinary traced arrays."""
    from ..kernels import ops as kops

    kw: dict = dict(backend=kernel_backend)

    def K(data, x):
        return kops.smatvec(data, x)

    def KT(data, y):
        return kops.smatvec_t(data, y)

    def forward(data, x, c, l, u, tau, kty):
        return kops.structured_forward_step(data, x, c, l, u, tau, kty, **kw)

    def backward(data, y, q, sigma, ineq_mask, kx_new, kx_prev):
        return kops.structured_backward_step(data, y, q, sigma, ineq_mask,
                                             kx_new, kx_prev, **kw)

    def prep(op: OperatorLP) -> OperatorLP:
        # the lane kernels have no dequant path — quantized payloads
        # degrade to f32 here (only fused_structured_full streams them)
        return op._replace(data=dequantize_structured(op.structured),
                           structured=None)

    return StepEngine("fused_structured", K, KT, forward, backward,
                      scale_structured, prep)


@functools.lru_cache(maxsize=16)
def fused_structured_full_engine(
        kernel_backend: Optional[str] = None,
        row_plan: tuple = (), col_plan: tuple = ()) -> StepEngine:
    """Single-lane M-blocked streaming engine for the **full** problem
    (``kernels/structured_pdhg_step.py`` full-kernel family via
    ``kernels/ops.py`` dispatch).  The lane kernels assume a whole lane's
    ELL payload fits in VMEM; this engine tiles the nnz-major ``[W, M]``
    arrays into VMEM-sized M-blocks, streams partial gather/reduces per
    block and folds wide-bucket contributions across blocks through the
    fold map — so the unpartitioned k=1 baseline runs the same no-scatter
    path as POP lanes.

    ``row_plan`` / ``col_plan`` are static ragged wide-block plans: tuples
    of ``(c0, c1, wb)`` — slice bucket columns ``[c0, c1)`` at width
    ``wb`` — computed by :func:`resolve_engine` from the *concrete*
    operator (outside jit) against the descending-width sort
    ``_pack_side`` guarantees.  The slices view the one uniform wide
    array, so equilibration scaling composes with the plan for free.
    ``prep`` moves ``op.structured`` into ``op.data`` (quantized payloads
    flow through — the full kernels dequantize in-kernel)."""
    from ..kernels import ops as kops

    kw: dict = dict(backend=kernel_backend)

    def K(data, x):
        return kops.smatvec_full(data, x, plan=row_plan)

    def KT(data, y):
        return kops.smatvec_t_full(data, y, plan=col_plan)

    def forward(data, x, c, l, u, tau, kty):
        return kops.structured_full_forward_step(
            data, x, c, l, u, tau, kty, plan=row_plan, **kw)

    def backward(data, y, q, sigma, ineq_mask, kx_new, kx_prev):
        return kops.structured_full_backward_step(
            data, y, q, sigma, ineq_mask, kx_new, kx_prev,
            plan=col_plan, **kw)

    def prep(op: OperatorLP) -> OperatorLP:
        return op._replace(data=op.structured, structured=None)

    return StepEngine("fused_structured_full", K, KT, forward, backward,
                      scale_structured, prep)


# auto picks fused_structured_full only above this many stored wide-bucket
# elements: below it the one-hot fold is cheap and the lane kernels win
FULL_ENGINE_MIN_WIDE_ELEMS = 65_536
# column chunk the ragged wide-block plan is quantised to
WIDE_BLOCK_COLS = 128


def _is_single_lane(op: OperatorLP) -> bool:
    return op.c.ndim == 1 or op.c.shape[0] == 1


def _wide_elems(s: StructuredOperator) -> int:
    return (s.wrow_idx.shape[-2] * s.wrow_idx.shape[-1]
            + s.wcol_idx.shape[-2] * s.wcol_idx.shape[-1])


def _wide_block_plan(wval) -> tuple:
    """Static ragged plan ``((c0, c1, wb), ...)`` over a wide bucket's
    descending-width columns: chunks of :data:`WIDE_BLOCK_COLS` columns,
    each sliced to its own max effective width (from ``val != 0`` —
    exact, since zero coefficients contribute nothing) rounded up to the
    f32 sublane multiple.  Needs a concrete array; on tracers (a user
    jitting ``solve_stacked`` around resolution) falls back to one
    full-width block — correct, just unsliced."""
    if isinstance(wval, jax.core.Tracer):
        ww = wval.shape[-2]
        return ((0, wval.shape[-1], ww),)
    # deliberately host-side: the plan must be static (baked into the
    # lru-cached engine), and the Tracer guard above already routed any
    # traced value away — what reaches here is concrete by construction
    v = np.asarray(wval)  # popcheck: disable=host-sync-in-hot-path
    if v.ndim == 3:
        v = v[0]
    ww, d = v.shape
    nz = v != 0.0
    # per-column effective width: index of last nonzero + 1 (0 if empty)
    counts = np.where(nz.any(axis=0),
                      ww - np.argmax(nz[::-1, :], axis=0), 0)
    plan = []
    for c0 in range(0, d, WIDE_BLOCK_COLS):
        c1 = min(c0 + WIDE_BLOCK_COLS, d)
        wmax = (int(counts[c0:c1].max())  # popcheck: disable=host-sync-in-hot-path
                if c1 > c0 else 0)
        wb = min(max(8, -(-wmax // 8) * 8), ww)
        plan.append((c0, c1, wb))
    return tuple(plan) if plan else ((0, d, ww),)


def is_dense_ops(op: OperatorLP) -> bool:
    """True iff ``op.data`` is a single dense [..., M, N] constraint matrix
    (the layout :func:`dense_ops` produces) — the fused engine's requirement."""
    leaves = jax.tree.leaves(op.data)
    if len(leaves) != 1:
        return False
    K = leaves[0]
    return (K.ndim == op.c.ndim + 1
            and K.shape[-1] == op.c.shape[-1]
            and K.shape[-2] == op.q.shape[-1])


def select_engine(op: OperatorLP, K_mv: Callable = dense_K_mv,
                  KT_mv: Callable = dense_KT_mv) -> str:
    """``engine="auto"`` rule: a ``preferred_engine`` attribute on the
    problem's ``K_mv`` wins outright (the domain measured its own best —
    load balancing pins ``matvec`` because its operator is a dense
    [n, S] block where the gather-ELL path does ~2x the flops); otherwise
    fused needs dense data AND the dense matvecs AND a TPU (elsewhere XLA
    fuses the reference path just as well); operators carrying
    :class:`StructuredOperator` index metadata take the structured-fused
    engine (gather/segment-reduce, no scatters, one launch per half-step —
    measured 2-18x over vmapped segment-sum matvecs on the gather-shaped
    domains); **single-lane** structured operators whose wide buckets are
    large (>= :data:`FULL_ENGINE_MIN_WIDE_ELEMS` stored elements) take the
    M-blocked streaming ``fused_structured_full`` engine instead — the
    ``solve_full`` baseline at paper scale, where the lane path's
    uniform-width padding and one-hot fold dominate (measured 13x on the
    traffic matvec pair at 3000 demands); everything else takes
    ``matvec``."""
    pref = getattr(K_mv, "preferred_engine", None)
    if pref is not None:
        return pref
    dense = (K_mv is dense_K_mv and KT_mv is dense_KT_mv and is_dense_ops(op))
    if dense and jax.default_backend() == "tpu":
        return "fused"
    if op.structured is not None:
        s = op.structured
        if (_is_single_lane(op) and s.row_fold is not None
                and _wide_elems(s) >= FULL_ENGINE_MIN_WIDE_ELEMS):
            return "fused_structured_full"
        return "fused_structured"
    return "matvec"


# the engine spec strings resolve_engine accepts (besides a StepEngine
# object) — what ExecConfig validates at construction
ENGINE_NAMES = ("auto", "matvec", "fused", "fused_structured",
                "fused_structured_full")


def engine_name(engine: Union[str, "StepEngine"]) -> str:
    """Printable name of an engine spec (a resolved StepEngine or a str)."""
    return engine if isinstance(engine, str) else engine.name


def resolve_engine(engine: Union[None, str, StepEngine], op: OperatorLP,
                   K_mv: Callable = dense_K_mv,
                   KT_mv: Callable = dense_KT_mv) -> StepEngine:
    """Normalise an engine spec (None/"auto"/"matvec"/"fused"/
    "fused_structured"/"fused_structured_full"/StepEngine).  For the full
    engine this is also where the static ragged wide-block plans are
    computed — call it with a *concrete* operator (``backends.resolve_exec``
    does, before anything is jitted) so the plan can inspect values."""
    if isinstance(engine, StepEngine):
        return engine
    if engine is None or engine == "auto":
        engine = select_engine(op, K_mv, KT_mv)
    if engine == "matvec":
        return matvec_engine(K_mv, KT_mv)
    if engine == "fused":
        if not is_dense_ops(op):
            raise ValueError(
                "engine='fused' needs dense operator data (op.data == (K,) "
                "with K [..., M, N]); structured operators use "
                "engine='matvec' or 'fused_structured'")
        return fused_dense_engine()
    if engine == "fused_structured":
        if op.structured is None:
            raise ValueError(
                "engine='fused_structured' needs op.structured "
                "(StructuredOperator index metadata attached by the "
                "problem's build_sub); operators without it use "
                "engine='matvec'")
        return fused_structured_engine()
    if engine == "fused_structured_full":
        s = op.structured
        if s is None or s.row_fold is None:
            raise ValueError(
                "engine='fused_structured_full' needs op.structured with "
                "fold maps (operators built by structured_from_coo); "
                "operators without it use engine='matvec'")
        if not _is_single_lane(op):
            raise ValueError(
                "engine='fused_structured_full' streams the single-lane "
                "full problem (k=1); stacked sub-problems use "
                "engine='fused_structured'")
        return fused_structured_full_engine(
            row_plan=_wide_block_plan(s.wrow_val),
            col_plan=_wide_block_plan(s.wcol_val))
    raise ValueError(f"unknown engine {engine!r}; expected 'auto', "
                     "'matvec', 'fused', 'fused_structured', "
                     "'fused_structured_full', or a StepEngine")


# --------------------------------------------------------------------------
# scaling helpers — the ONE place BIG-sentinel bounds handling lives, shared
# by the probe-based path (solve(equilibrate=True)) and dense ruiz_equilibrate
# --------------------------------------------------------------------------

def scale_operator(op: OperatorLP, d_r: jnp.ndarray, d_c: jnp.ndarray,
                   data: Any = None) -> OperatorLP:
    """Apply diagonal scalings K~ = D_r K D_c to the LP fields.

    BIG-sentinel bounds (|l| or |u| >= BIG/2 — "effectively free") stay
    untouched so padded/free variables keep their infinite box after
    scaling.  ``data`` replaces the operator payload when the caller has a
    scaled one (dense K, scaled ELL); by default the payload is left alone
    and the matvecs are expected to be wrapped instead.  Any
    ``op.structured`` metadata is DROPPED — it describes the unscaled
    operator (the structured engine's ``prep`` has already moved its
    payload into ``data`` by the time scaling runs).
    """
    keep_l = jnp.abs(op.l) >= 0.5 * BIG
    keep_u = jnp.abs(op.u) >= 0.5 * BIG
    return OperatorLP(
        c=op.c * d_c, q=op.q * d_r,
        l=jnp.where(keep_l, op.l, op.l / d_c),
        u=jnp.where(keep_u, op.u, op.u / d_c),
        ineq_mask=op.ineq_mask,
        data=op.data if data is None else data,
        structured=None)


def scale_warm_start(x: jnp.ndarray, y: jnp.ndarray, d_r, d_c):
    """Original-space iterates -> scaled space (inverse of unscale)."""
    return x / d_c, y / d_r


def unscale_solution(x: jnp.ndarray, y: jnp.ndarray, d_r, d_c):
    """Scaled-space iterates -> original space: x = d_c x~, y = d_r y~."""
    return d_c * x, d_r * y


# --------------------------------------------------------------------------
# internals (all batched over the leading [k] sub-problem axis)
# --------------------------------------------------------------------------

def _vnorm(a: jnp.ndarray) -> jnp.ndarray:
    """Per-sub-problem 2-norm: [k, n] -> [k]."""
    return jnp.linalg.norm(a, axis=-1)


def _bcast(cond: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Right-pad a [k] predicate with singleton axes to broadcast against
    ``like`` ([k] or [k, n])."""
    return cond.reshape(cond.shape + (1,) * (like.ndim - cond.ndim))


def _power_iteration(engine: StepEngine, data, k: int, n_var: int,
                     iters: int = 30):
    """||K||_2 per lane via power iteration on K^T K (deterministic start)."""
    v0 = jnp.full((k, n_var), 1.0 / jnp.sqrt(n_var), jnp.float32)

    def body(_, v):
        w = engine.KT(data, engine.K(data, v))
        return w / (_vnorm(w)[:, None] + 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.sqrt(_vnorm(engine.KT(data, engine.K(data, v)))) + 1e-12


def _kkt_from_products(op: OperatorLP, x, y, kx, kty):
    """(primal_res_rel, gap_rel, primal_obj, dual_obj), each [k], from the
    already-materialised products ``kx = K x`` / ``kty = K^T y``.  The ONE
    place the KKT formulas live — the in-loop path feeds carried products,
    :func:`_kkt` feeds fresh operator passes, and both must agree bit-level
    when the products do."""
    resid = kx - op.q
    prim_viol = jnp.where(op.ineq_mask, jnp.maximum(resid, 0.0), resid)
    # padded rows carry q = BIG — exclude them from the relative denominator
    q_eff = jnp.where(jnp.abs(op.q) >= 0.5 * BIG, 0.0, op.q)
    prim_res = _vnorm(prim_viol) / (1.0 + _vnorm(q_eff))

    r = op.c + kty                                    # reduced costs
    p_obj = jnp.sum(op.c * x, axis=-1)
    # g(y) = -q.y + sum_i min(l_i r_i, u_i r_i); BIG bounds act as -inf penalty
    d_obj = (-jnp.sum(op.q * y, axis=-1)
             + jnp.sum(jnp.minimum(op.l * r, op.u * r), axis=-1))
    gap = jnp.abs(p_obj - d_obj) / (1.0 + jnp.abs(p_obj) + jnp.abs(d_obj))
    return prim_res, gap, p_obj, d_obj


def _kkt(op: OperatorLP, engine: StepEngine, x, y):
    """KKT scores via fresh operator passes (standalone reference; also the
    final original-space report)."""
    return _kkt_from_products(op, x, y, engine.K(op.data, x),
                              engine.KT(op.data, y))


class _State(NamedTuple):
    x: jnp.ndarray
    y: jnp.ndarray
    kx: jnp.ndarray           # carried K x      (current iterate's product)
    kty: jnp.ndarray          # carried K^T y
    x_sum: jnp.ndarray
    y_sum: jnp.ndarray
    kx_sum: jnp.ndarray       # running product sums: K x_avg = kx_sum/avg_n
    kty_sum: jnp.ndarray      # (linearity of K — averages cost no passes)
    avg_n: jnp.ndarray        # [k] iterations accumulated since restart
    x_anchor: jnp.ndarray     # iterate at last restart (for omega update)
    y_anchor: jnp.ndarray
    omega: jnp.ndarray        # [k] primal weight
    last_score: jnp.ndarray   # [k] KKT score at last restart (decay test)
    it: jnp.ndarray           # [k]
    done: jnp.ndarray         # [k]
    n_restarts: jnp.ndarray   # [k]
    prim_res: jnp.ndarray
    gap: jnp.ndarray
    best_score: jnp.ndarray   # [k] best KKT score seen (divergence baseline)
    diverged: jnp.ndarray     # [k] lane frozen by the divergence guard


def _equilibrate(engine: StepEngine, op: OperatorLP,
                 iters: int = 2, n_probes: int = 4):
    """Operator-form Ruiz equilibration (EXPERIMENTS.md §Perf hillclimb 3):
    per-lane (d_r, d_c) diagonal scalings estimated purely through matvec
    probes (Hutchinson: with Rademacher v, E[(Kv)_i^2] = squared row norms;
    columns dual) — works for ANY structured operator, not just dense K.
    The same probe vectors are shared across the k lanes."""
    n_var = op.c.shape[-1]
    n_con = op.q.shape[-1]
    d_r = jnp.ones_like(op.q)
    d_c = jnp.ones_like(op.c)
    key = jax.random.PRNGKey(7)
    for i in range(iters):
        kr, kc = jax.random.split(jax.random.fold_in(key, i))
        vs = jax.random.rademacher(kr, (n_probes, n_var), jnp.float32)
        rows = jnp.mean(jax.vmap(
            lambda v: jnp.square(d_r * engine.K(op.data, d_c * v)))(vs), axis=0)
        us = jax.random.rademacher(kc, (n_probes, n_con), jnp.float32)
        cols = jnp.mean(jax.vmap(
            lambda u: jnp.square(d_c * engine.KT(op.data, d_r * u)))(us), axis=0)
        rn, cn = jnp.sqrt(rows), jnp.sqrt(cols)
        d_r = d_r / jnp.sqrt(jnp.where(rn > 1e-8, rn, 1.0))
        d_c = d_c / jnp.sqrt(jnp.where(cn > 1e-8, cn, 1.0))
    return d_r, d_c


def solve_stacked(
    op: OperatorLP,
    engine: Union[None, str, StepEngine] = None,
    K_mv: Callable = dense_K_mv,
    KT_mv: Callable = dense_KT_mv,
    *,
    max_iters: int = 20_000,
    check_every: int = 40,
    tol_primal: float = 1e-4,
    tol_gap: float = 1e-4,
    eta: float = 0.9,
    omega0: float = 1.0,
    equilibrate: bool = False,
    warm_x: Optional[jnp.ndarray] = None,
    warm_y: Optional[jnp.ndarray] = None,
    warm_mask: Optional[jnp.ndarray] = None,
    kkt: str = "inloop",
    divergence_ratio: float = 1e4,
) -> SolveResult:
    """Solve a STACK of k LPs at once (every ``op`` leaf has a leading [k]
    axis; the result carries the same axis).  This is the map-step core:
    one fori/while loop drives all k sub-problems with per-lane step sizes,
    restarts and termination, so the fused engines can hand the whole batch
    to single kernel launches.  Fully traceable.

    ``warm_mask`` ([k] bool) gates the warm start per lane: False lanes
    start cold even when ``warm_x``/``warm_y`` are given.  This is how
    churn-aware remapped warm starts (``core/plan.py``) cold-start lanes
    that matched no previous entity — a ``jnp.where`` on data, not a
    Python-level branch, so all lanes share one jitted solve.

    ``kkt="inloop"`` (default) computes convergence checks entirely from
    the products the half-steps already materialised — zero extra operator
    passes per check.  ``kkt="standalone"`` re-derives the current
    candidate's products with fresh K/K^T passes each check (2 extra
    applications per chunk): the verification reference that must match
    the in-loop path bit-level on the CPU/XLA path.

    Divergence quarantine: a lane whose KKT score goes non-finite (NaN/inf
    iterates, e.g. from a poisoned warm start) or exceeds
    ``divergence_ratio`` times the best score it has seen is frozen in
    place and reported in ``SolveResult.diverged`` ([k] bool).  The guard
    is carried as loop data exactly like ``done`` — no host sync, no
    retrace — and healthy batch peers keep iterating.  Diverged lanes
    report ``converged=False``; callers (``service.PopSession``) quarantine
    the warm state and cold-restart only those lanes.
    """
    if kkt not in ("inloop", "standalone"):
        raise ValueError(f"unknown kkt mode {kkt!r}; "
                         "expected 'inloop' or 'standalone'")
    eng = resolve_engine(engine, op, K_mv, KT_mv)
    if eng.prep is not None:
        op = eng.prep(op)
    k = op.c.shape[0]
    n_var = op.c.shape[-1]

    op_run, eng_run = op, eng
    if equilibrate:
        d_r, d_c = _equilibrate(eng, op)
        if eng.scale_data is not None:
            op_run = scale_operator(op, d_r, d_c,
                                    data=eng.scale_data(op.data, d_r, d_c))
        else:
            op_run = scale_operator(op, d_r, d_c)
            eng_run = _engine_from_matvecs(
                eng.name + "_scaled",
                lambda data, x: d_r * eng.K(data, d_c * x),
                lambda data, y: d_c * eng.KT(data, d_r * y))
        # warm iterates arrive in ORIGINAL space — map into scaled space
        if warm_x is not None:
            warm_x = warm_x / d_c
        if warm_y is not None:
            warm_y = warm_y / d_r

    knorm = _power_iteration(eng_run, op_run.data, k, n_var)   # [k]

    cold_x = jnp.clip(jnp.zeros_like(op_run.c), op_run.l, op_run.u)
    cold_y = jnp.zeros_like(op_run.q)
    x0 = cold_x if warm_x is None else jnp.asarray(warm_x, op_run.c.dtype)
    y0 = cold_y if warm_y is None else jnp.asarray(warm_y, op_run.q.dtype)
    if warm_mask is not None and (warm_x is not None or warm_y is not None):
        m = jnp.asarray(warm_mask, bool)[:, None]
        x0 = jnp.where(m, x0, cold_x)
        y0 = jnp.where(m, y0, cold_y)
    # seed the carried products (once per solve; every later refresh rides
    # inside a half-step)
    kx0 = eng_run.K(op_run.data, x0)
    kty0 = eng_run.KT(op_run.data, y0)

    def chunk(state: _State) -> _State:
        tau = eta / (state.omega * knorm)          # [k]
        sigma = eta * state.omega / knorm          # [k]

        def one_iter(_, carry):
            x, y, kx, kty, xs, ys, kxs, ktys = carry
            x_new, kx_new = eng_run.forward(op_run.data, x, op_run.c,
                                            op_run.l, op_run.u, tau, kty)
            y_new, kty_new = eng_run.backward(op_run.data, y, op_run.q,
                                              sigma, op_run.ineq_mask,
                                              kx_new, kx)
            return (x_new, y_new, kx_new, kty_new,
                    xs + x_new, ys + y_new, kxs + kx_new, ktys + kty_new)

        x, y, kx, kty, xs, ys, kxs, ktys = jax.lax.fori_loop(
            0, check_every, one_iter,
            (state.x, state.y, state.kx, state.kty,
             state.x_sum, state.y_sum, state.kx_sum, state.kty_sum),
        )
        avg_n = state.avg_n + check_every

        # ---- candidate = better of {current, running average} ------------
        # products for the current candidate are carried (in-loop mode) or
        # recomputed with fresh operator passes (standalone verification
        # mode); the average candidate's products are ALWAYS the running
        # sums — K(x_avg) == avg(K x_i) by linearity, so the averages never
        # cost a pass in either mode.
        if kkt == "standalone":
            kx_cur = eng_run.K(op_run.data, x)
            kty_cur = eng_run.KT(op_run.data, y)
        else:
            kx_cur, kty_cur = kx, kty
        nrm = avg_n[:, None]
        x_avg, y_avg = xs / nrm, ys / nrm
        kx_avg, kty_avg = kxs / nrm, ktys / nrm
        pr_c, gap_c, _, _ = _kkt_from_products(op_run, x, y, kx_cur, kty_cur)
        pr_a, gap_a, _, _ = _kkt_from_products(op_run, x_avg, y_avg,
                                               kx_avg, kty_avg)
        score_c = pr_c + gap_c
        score_a = pr_a + gap_a
        use_avg = score_a < score_c                # [k]
        sel = use_avg[:, None]
        x_r = jnp.where(sel, x_avg, x)
        y_r = jnp.where(sel, y_avg, y)
        kx_r = jnp.where(sel, kx_avg, kx_cur)
        kty_r = jnp.where(sel, kty_avg, kty_cur)
        pr = jnp.where(use_avg, pr_a, pr_c)
        gap = jnp.where(use_avg, gap_a, gap_c)
        score = jnp.minimum(score_a, score_c)

        # ---- divergence guard: non-finite score, or blow-up past the best
        # score this lane ever reached.  best_score starts at +inf so the
        # ratio test cannot fire before a finite score exists.  Pure data —
        # the lane freezes via the same mechanism as `done`.
        blown = (~jnp.isfinite(score)) | (
            score > divergence_ratio * jnp.maximum(state.best_score, 1e-12))
        diverged = state.diverged | (blown & ~state.done)
        best_score = jnp.minimum(
            state.best_score, jnp.where(jnp.isfinite(score), score, jnp.inf))

        # ---- adaptive restart: only on sufficient KKT decay ---------------
        # (restarting every chunk kills PDHG momentum; PDLP-style decay test)
        restart = (score < 0.4 * state.last_score) | (avg_n >= 16 * check_every)

        # ---- primal weight update at restarts (PDLP eq. 10, smoothed) -----
        dx = _vnorm(x_r - state.x_anchor)
        dy = _vnorm(y_r - state.y_anchor)
        safe = (dx > 1e-12) & (dy > 1e-12)
        ratio = jnp.where(safe, dy / jnp.maximum(dx, 1e-12), 1.0)
        omega_new = jnp.exp(
            0.5 * jnp.log(jnp.clip(ratio, 1e-4, 1e4)) + 0.5 * jnp.log(state.omega)
        )

        conv = (pr < tol_primal) & (gap < tol_gap) & ~state.diverged
        done = state.done | conv

        def pick(on_restart, no_restart):
            return jnp.where(_bcast(restart, on_restart), on_restart, no_restart)

        # freeze finished AND quarantined lanes: batch peers keep going
        frozen = state.done | state.diverged

        def keep(new, old):
            return jnp.where(_bcast(frozen, new), old, new)

        return _State(
            x=keep(pick(x_r, x), state.x),
            y=keep(pick(y_r, y), state.y),
            # the restarted point's products restart with it (the averaged
            # products ARE the average point's products, by linearity)
            kx=keep(pick(kx_r, kx_cur), state.kx),
            kty=keep(pick(kty_r, kty_cur), state.kty),
            x_sum=keep(pick(jnp.zeros_like(xs), xs), state.x_sum),
            y_sum=keep(pick(jnp.zeros_like(ys), ys), state.y_sum),
            kx_sum=keep(pick(jnp.zeros_like(kxs), kxs), state.kx_sum),
            kty_sum=keep(pick(jnp.zeros_like(ktys), ktys), state.kty_sum),
            avg_n=keep(pick(jnp.zeros_like(avg_n), avg_n), state.avg_n),
            x_anchor=keep(pick(x_r, state.x_anchor), state.x_anchor),
            y_anchor=keep(pick(y_r, state.y_anchor), state.y_anchor),
            omega=keep(pick(omega_new, state.omega), state.omega),
            last_score=keep(pick(score, state.last_score), state.last_score),
            it=state.it + jnp.where(frozen, 0, check_every),
            done=done,
            n_restarts=state.n_restarts + jnp.where(
                frozen | ~restart, 0, 1).astype(jnp.int32),
            prim_res=keep(pr, state.prim_res), gap=keep(gap, state.gap),
            best_score=keep(best_score, state.best_score),
            diverged=diverged,
        )

    init = _State(
        x=x0, y=y0, kx=kx0, kty=kty0,
        x_sum=jnp.zeros_like(x0), y_sum=jnp.zeros_like(y0),
        kx_sum=jnp.zeros_like(kx0), kty_sum=jnp.zeros_like(kty0),
        avg_n=jnp.zeros((k,), jnp.float32),
        x_anchor=x0, y_anchor=y0,
        omega=jnp.full((k,), omega0, jnp.float32),
        last_score=jnp.full((k,), jnp.inf),
        it=jnp.zeros((k,), jnp.int32),
        done=jnp.zeros((k,), bool),
        n_restarts=jnp.zeros((k,), jnp.int32),
        prim_res=jnp.full((k,), jnp.inf), gap=jnp.full((k,), jnp.inf),
        best_score=jnp.full((k,), jnp.inf),
        diverged=jnp.zeros((k,), bool),
    )

    state = jax.lax.while_loop(
        lambda s: jnp.any((~s.done) & (~s.diverged) & (s.it < max_iters)),
        chunk, init,
    )

    x_fin, y_fin = state.x, state.y
    if equilibrate:
        # report in ORIGINAL space
        x_fin, y_fin = unscale_solution(x_fin, y_fin, d_r, d_c)
    pr, gap, p_obj, d_obj = _kkt(op, eng, x_fin, y_fin)
    return SolveResult(
        x=x_fin, y=y_fin, primal_obj=p_obj, dual_obj=d_obj,
        primal_res=pr, gap=gap, iterations=state.it, converged=state.done,
        n_restarts=state.n_restarts, diverged=state.diverged,
    )


# the keyword names a solver_kw dict may carry (everything solve_stacked
# takes except the operator/engine/warm plumbing, which the pipeline
# threads itself) — what ExecConfig validates at construction
SOLVER_KW_NAMES = frozenset(
    name for name, p in inspect.signature(solve_stacked).parameters.items()
    if p.kind is inspect.Parameter.KEYWORD_ONLY
    and not name.startswith("warm_"))


def solve(
    op: OperatorLP,
    K_mv: Callable = dense_K_mv,
    KT_mv: Callable = dense_KT_mv,
    *,
    max_iters: int = 20_000,
    check_every: int = 40,
    tol_primal: float = 1e-4,
    tol_gap: float = 1e-4,
    eta: float = 0.9,
    omega0: float = 1.0,
    equilibrate: bool = False,
    warm_x: Optional[jnp.ndarray] = None,
    warm_y: Optional[jnp.ndarray] = None,
    warm_mask: Optional[jnp.ndarray] = None,
    engine: Union[None, str, StepEngine] = "matvec",
    kkt: str = "inloop",
    divergence_ratio: float = 1e4,
) -> SolveResult:
    """Solve one LP: a k=1 stack through :func:`solve_stacked`.  Fully
    traceable; vmap over a batched ``op`` for POP (or better, hand the
    whole stack to ``solve_stacked`` / ``backends.solve_map``)."""
    opb = jax.tree.map(lambda a: jnp.asarray(a)[None], op)
    wx = None if warm_x is None else jnp.asarray(warm_x)[None]
    wy = None if warm_y is None else jnp.asarray(warm_y)[None]
    wm = None if warm_mask is None else jnp.asarray(warm_mask).reshape((1,))
    res = solve_stacked(
        opb, engine=engine, K_mv=K_mv, KT_mv=KT_mv,
        max_iters=max_iters, check_every=check_every,
        tol_primal=tol_primal, tol_gap=tol_gap, eta=eta, omega0=omega0,
        equilibrate=equilibrate, warm_x=wx, warm_y=wy, warm_mask=wm, kkt=kkt,
        divergence_ratio=divergence_ratio)
    return jax.tree.map(lambda a: a[0], res)


# --------------------------------------------------------------------------
# Ruiz equilibration (dense path) — first-order methods live or die by
# conditioning; diagonal rescaling cuts PDHG iteration counts by 10-100x.
# Bounds/rhs handling is shared with the probe path via scale_operator.
# --------------------------------------------------------------------------

def ruiz_equilibrate(op: OperatorLP, iters: int = 8):
    """Return (scaled_op, d_row, d_col) with K~ = D_r K D_c equilibrated.

    Recover original-space solutions as  x = d_col * x~,  y = d_row * y~
    (:func:`unscale_solution`).  Dense-data only (needs explicit row/col
    norms); the probe-based path inside ``solve(equilibrate=True)`` covers
    structured operators.
    """
    (K,) = op.data
    d_r = jnp.ones(K.shape[0])
    d_c = jnp.ones(K.shape[1])

    def body(_, carry):
        d_r, d_c = carry
        Ks = K * d_r[:, None] * d_c[None, :]
        rn = jnp.sqrt(jnp.max(jnp.abs(Ks), axis=1))
        cn = jnp.sqrt(jnp.max(jnp.abs(Ks), axis=0))
        d_r = d_r / jnp.where(rn > 1e-12, rn, 1.0)
        d_c = d_c / jnp.where(cn > 1e-12, cn, 1.0)
        return d_r, d_c

    d_r, d_c = jax.lax.fori_loop(0, iters, body, (d_r, d_c))
    Ks = K * d_r[:, None] * d_c[None, :]
    return scale_operator(op, d_r, d_c, data=(Ks,)), d_r, d_c


# --------------------------------------------------------------------------
# convenience wrappers
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iters", "tol_primal", "tol_gap"))
def solve_dense(lp: LinearProgram, max_iters: int = 20_000,
                tol_primal: float = 1e-4, tol_gap: float = 1e-4) -> SolveResult:
    op = dense_ops(lp)
    sop, d_r, d_c = ruiz_equilibrate(op)
    res = solve(sop, dense_K_mv, dense_KT_mv,
                max_iters=max_iters, tol_primal=tol_primal, tol_gap=tol_gap)
    # report objective/residuals in ORIGINAL space
    x, y = unscale_solution(res.x, res.y, d_r, d_c)
    pr, gap, p_obj, d_obj = _kkt(jax.tree.map(lambda a: a[None], op),
                                 matvec_engine(), x[None], y[None])
    squeeze = lambda a: a[0]
    return SolveResult(x=x, y=y, primal_obj=squeeze(p_obj),
                       dual_obj=squeeze(d_obj), primal_res=squeeze(pr),
                       gap=squeeze(gap),
                       iterations=res.iterations, converged=res.converged,
                       n_restarts=res.n_restarts, diverged=res.diverged)


def solve_batched(op_batched: OperatorLP, K_mv=dense_K_mv, KT_mv=dense_KT_mv,
                  **kw) -> SolveResult:
    """vmap over the leading (sub-problem) axis — POP's map step on one
    device.  ``core/backends.py`` wraps this in shard_map for the mesh path
    and swaps in the fused engines for dense/structured problems."""
    return jax.vmap(lambda o: solve(o, K_mv, KT_mv, **kw))(op_batched)
