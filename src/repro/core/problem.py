"""Canonical-form LP/MILP containers used throughout the POP stack.

Every allocation problem in the framework (cluster scheduling, traffic
engineering, load balancing, MoE expert placement, serving balancer) lowers
to the canonical form

    minimize    c^T x
    subject to  G x <= h          (n_ineq rows)
                A x  = b          (n_eq rows)
                l <= x <= u       (box)

The PDHG solver (``core/pdhg.py``) consumes the stacked form

    K = [G; A],  q = [h; b],  with the first ``n_ineq`` duals projected >= 0.

Problems are stored **dense** and 128-padded: on TPU, dense MXU-aligned
blocks beat gather/scatter sparsity at post-POP sub-problem sizes (see
DESIGN.md §2).  Padding is self-neutralising:

  * padded variables get  l = u = 0, c = 0        (pinned to zero)
  * padded ineq rows get  G row = 0, h = +BIG     (trivially satisfied)
  * padded eq rows get    A row = 0, b = 0        (trivially satisfied)

so a padded problem has exactly the same solution set (restricted to real
variables) as the unpadded one.  This is what makes POP's map step a
*batched* solve: ``k`` sub-problems padded to a common shape stack on a
leading axis and vmap/shard_map cleanly.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

BIG = 1e9  # stand-in for +inf in padded rows / free bounds (f32-safe)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinearProgram:
    """One canonical-form LP (optionally one slice of a batched stack).

    All fields are jnp arrays so the container is a pytree and can be
    vmapped / shard_mapped / donated.  ``n_var``/``n_ineq``/``n_eq`` are
    *static* python ints describing the real (unpadded) sizes; array shapes
    may be larger (padded).
    """

    c: jnp.ndarray          # [N]      objective
    G: jnp.ndarray          # [Mi, N]  inequality lhs
    h: jnp.ndarray          # [Mi]     inequality rhs
    A: jnp.ndarray          # [Me, N]  equality lhs
    b: jnp.ndarray          # [Me]     equality rhs
    l: jnp.ndarray          # [N]      lower bounds
    u: jnp.ndarray          # [N]      upper bounds
    n_var: int = 0          # static: real variable count
    n_ineq: int = 0         # static: real inequality count
    n_eq: int = 0           # static: real equality count

    # ---- pytree protocol (static sizes ride in aux data) -----------------
    def tree_flatten(self):
        leaves = (self.c, self.G, self.h, self.A, self.b, self.l, self.u)
        aux = (self.n_var, self.n_ineq, self.n_eq)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        c, G, h, A, b, l, u = leaves
        return cls(c, G, h, A, b, l, u, *aux)

    # ---- constructors ----------------------------------------------------
    @classmethod
    def build(
        cls,
        c: np.ndarray,
        G: Optional[np.ndarray] = None,
        h: Optional[np.ndarray] = None,
        A: Optional[np.ndarray] = None,
        b: Optional[np.ndarray] = None,
        l: Optional[np.ndarray] = None,
        u: Optional[np.ndarray] = None,
        pad_to: int = 128,
        dtype=jnp.float32,
    ) -> "LinearProgram":
        """Build (and 128-pad) an LP from numpy parts.  Missing blocks are
        zero-row placeholders so downstream code never branches."""
        c = np.asarray(c, np.float64)
        n = c.shape[0]
        G = np.zeros((0, n)) if G is None else np.asarray(G, np.float64)
        h = np.zeros((0,)) if h is None else np.asarray(h, np.float64)
        A = np.zeros((0, n)) if A is None else np.asarray(A, np.float64)
        b = np.zeros((0,)) if b is None else np.asarray(b, np.float64)
        l = np.full(n, -BIG) if l is None else np.asarray(l, np.float64)
        u = np.full(n, BIG) if u is None else np.asarray(u, np.float64)
        assert G.shape == (h.shape[0], n) and A.shape == (b.shape[0], n)

        N = _round_up(max(n, 1), pad_to)
        Mi = _round_up(max(G.shape[0], 1), pad_to)
        Me = _round_up(max(A.shape[0], 1), pad_to)

        cP = np.zeros(N); cP[:n] = c
        lP = np.zeros(N); lP[:n] = l          # padded vars pinned to 0
        uP = np.zeros(N); uP[:n] = u
        GP = np.zeros((Mi, N)); GP[: G.shape[0], :n] = G
        hP = np.full(Mi, BIG); hP[: h.shape[0]] = h
        AP = np.zeros((Me, N)); AP[: A.shape[0], :n] = A
        bP = np.zeros(Me); bP[: b.shape[0]] = b

        return cls(
            c=jnp.asarray(cP, dtype), G=jnp.asarray(GP, dtype),
            h=jnp.asarray(hP, dtype), A=jnp.asarray(AP, dtype),
            b=jnp.asarray(bP, dtype), l=jnp.asarray(lP, dtype),
            u=jnp.asarray(uP, dtype),
            n_var=n, n_ineq=G.shape[0], n_eq=A.shape[0],
        )

    # ---- derived views -----------------------------------------------------
    @property
    def shape(self) -> tuple:
        return (self.G.shape[0], self.A.shape[0], self.c.shape[0])

    def stacked(self):
        """K = [G; A], q = [h; b] and the >=0 dual mask for the K rows."""
        K = jnp.concatenate([self.G, self.A], axis=0)
        q = jnp.concatenate([self.h, self.b], axis=0)
        ineq_mask = jnp.concatenate(
            [jnp.ones(self.G.shape[0], bool), jnp.zeros(self.A.shape[0], bool)]
        )
        return K, q, ineq_mask

    def objective(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.dot(self.c, x)

    def violations(self, x: jnp.ndarray) -> dict:
        """Constraint violation report (used by tests & feasibility checks)."""
        ineq = jnp.maximum(self.G @ x - self.h, 0.0)
        eq = jnp.abs(self.A @ x - self.b)
        box = jnp.maximum(self.l - x, 0.0) + jnp.maximum(x - self.u, 0.0)
        return {
            "ineq_max": jnp.max(ineq) if ineq.size else jnp.zeros(()),
            "eq_max": jnp.max(eq) if eq.size else jnp.zeros(()),
            "box_max": jnp.max(box) if box.size else jnp.zeros(()),
        }


def stack_lps(lps: list) -> LinearProgram:
    """Stack k same-shaped LPs on a leading axis (POP's batched map step).

    All sub-problems must already share padded shapes (partitioners
    guarantee this by construction: equal-size entity splits + common
    ``pad_to``).
    """
    assert len({lp.shape for lp in lps}) == 1, "sub-problems must be same-shaped"
    leaves = [jnp.stack([getattr(lp, f) for lp in lps]) for f in
              ("c", "G", "h", "A", "b", "l", "u")]
    proto = lps[0]
    return LinearProgram(*leaves, proto.n_var, proto.n_ineq, proto.n_eq)


@dataclasses.dataclass
class MixedIntegerProgram:
    """MILP = LP + integrality mask.  Solved by relax-and-round
    (``core/rounding.py``); the mask marks binary {0,1} variables."""

    lp: LinearProgram
    binary_mask: jnp.ndarray  # [N] bool — True where x must be in {0, 1}

    @classmethod
    def build(cls, binary_mask: np.ndarray, **lp_kwargs) -> "MixedIntegerProgram":
        lp = LinearProgram.build(**lp_kwargs)
        m = np.zeros(lp.c.shape[0], bool)
        m[: binary_mask.shape[0]] = binary_mask
        return cls(lp=lp, binary_mask=jnp.asarray(m))
