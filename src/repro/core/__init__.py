"""POP core: the paper's contribution as a composable JAX module."""

from .problem import LinearProgram, MixedIntegerProgram, stack_lps, BIG
from .pdhg import (
    OperatorLP, SolveResult, solve, solve_stacked, solve_dense, solve_batched,
    dense_ops, dense_K_mv, dense_KT_mv, ruiz_equilibrate,
    StepEngine, matvec_engine, fused_dense_engine, fused_structured_engine,
    fused_structured_full_engine, select_engine,
    StructuredOperator, structured_from_coo, structured_to_dense, stack_ops,
    quantize_structured, dequantize_structured,
    scale_operator, unscale_solution,
)
from .partition import (
    random_partition, stratified_partition, stratified_partition_multidim,
    clustered_partition, skewed_partition, similarity_report,
)
from .replicate import ReplicationPlan, plan_replication, replicated_partition
from .reduce import coalesce_concat, coalesce_replicated
from .backends import (
    MAP_BACKENDS, available_backends, get_backend, register_backend,
    select_backend, resolve_exec, solve_map, solve_one, make_map_solver,
)
from .config import SolveConfig, ExecConfig
from .plan import PopPlan, SubLayout, WarmStart, remap_warm
from .pop import (POPProblem, POPResult, FullResult, pop_solve,
                  solve_instance, solve_full, solve_full_ex)
from .maxmin import epigraph_rows, maxmin_objective
from .rounding import round_relaxation

__all__ = [
    "LinearProgram", "MixedIntegerProgram", "stack_lps", "BIG",
    "OperatorLP", "SolveResult", "solve", "solve_stacked", "solve_dense",
    "solve_batched",
    "dense_ops", "dense_K_mv", "dense_KT_mv", "ruiz_equilibrate",
    "StepEngine", "matvec_engine", "fused_dense_engine",
    "fused_structured_engine", "fused_structured_full_engine",
    "select_engine",
    "StructuredOperator", "structured_from_coo", "structured_to_dense",
    "stack_ops", "quantize_structured", "dequantize_structured",
    "scale_operator", "unscale_solution",
    "random_partition", "stratified_partition", "stratified_partition_multidim",
    "clustered_partition", "skewed_partition", "similarity_report",
    "ReplicationPlan", "plan_replication", "replicated_partition",
    "coalesce_concat", "coalesce_replicated",
    "MAP_BACKENDS", "available_backends", "get_backend", "register_backend",
    "select_backend", "resolve_exec", "solve_map", "solve_one",
    "make_map_solver",
    "SolveConfig", "ExecConfig",
    "PopPlan", "SubLayout", "WarmStart", "remap_warm",
    "POPProblem", "POPResult", "FullResult", "pop_solve", "solve_instance",
    "solve_full", "solve_full_ex",
    "epigraph_rows", "maxmin_objective",
    "round_relaxation",
]
