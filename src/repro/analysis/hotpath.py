"""Rule ``host-sync-in-hot-path``: no device→host sync reachable from the
solver's inner loop.

Every ``.item()``, ``float()`` of a traced value, ``np.asarray``,
``block_until_ready`` or Python branch on device data inside
``solve_stacked`` / the StepEngine half-steps forces a blocking transfer
per call — inside a jit it forces a trace-time readback or an abstract-
value error, and outside it serialises the async dispatch pipeline.  The
rule walks an approximate call graph DOWNWARD from the hot roots
(functions named ``solve_stacked``, plus any def marked ``# popcheck:
hot`` on/above its ``def`` line) and flags host-sync constructs in any
function it reaches.

The call graph is name-based and deliberately approximate: a call
``f(...)`` or ``obj.f(...)`` reaches every *followable* def named ``f``.
Followable files are the solver substrate (``core/`` and ``kernels/``
under ``src/repro``) plus any scanned file outside ``src/repro`` (fixture
corpora, standalone scripts) — service/domain/benchmark layers run pre-
and post-solve on the host, where syncs are the point, so propagation
stops at that boundary.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from .core import FileContext, Finding, Project, rule

RULE = "host-sync-in-hot-path"

HOT_ROOT_NAMES = {"solve_stacked"}

# numpy module names whose asarray/array force a device->host transfer
_NUMPY_MODULES = {"numpy"}
# jax.numpy aliases: branches on calls through these are traced-value
# branches (concretisation errors / per-step readbacks).  Bare ``jax.*``
# calls are NOT included — jax.default_backend() and friends are host-side
# platform queries, not traced values.
_TRACED_MODULES = {"jax.numpy"}


def _followable(ctx: FileContext) -> bool:
    parts = ctx.rel.split("/")
    if "repro" in parts:
        return "core" in parts or "kernels" in parts
    return True


def _function_defs(ctx: FileContext):
    """Every (possibly nested / method) def in the file."""
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _called_names(fn: ast.AST) -> Set[str]:
    names = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                names.add(node.func.id)
            elif isinstance(node.func, ast.Attribute):
                names.add(node.func.attr)
    return names


def _is_module_attr(node: ast.AST, ctx: FileContext, modules: Set[str]) -> bool:
    """True when ``node`` is ``alias.attr`` with ``alias`` imported from one
    of ``modules`` (e.g. ``np.asarray`` with ``import numpy as np``)."""
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and ctx.module_aliases.get(node.value.id) in modules)


def _mentions_traced_call(test: ast.AST, ctx: FileContext) -> bool:
    """Does an ``if``/``while`` test call into jax/jnp (a traced-value
    branch), as opposed to comparing static Python config values?"""
    for node in ast.walk(test):
        if isinstance(node, ast.Call) and _is_module_attr(node.func, ctx,
                                                          _TRACED_MODULES):
            return True
    return False


def _violations_in(fn: ast.AST, ctx: FileContext, where: str) -> List[Finding]:
    out = []

    def flag(node, msg):
        out.append(Finding(RULE, ctx.rel, node.lineno, f"{where}: {msg}"))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "item" and not node.args:
                    flag(node, ".item() forces a device->host sync")
                elif f.attr == "block_until_ready":
                    flag(node, "block_until_ready() stalls the dispatch "
                               "pipeline inside the hot path")
                elif f.attr == "device_get" and _is_module_attr(
                        f, ctx, {"jax"}):
                    flag(node, "jax.device_get forces a host transfer")
                elif f.attr in ("asarray", "array") and _is_module_attr(
                        f, ctx, _NUMPY_MODULES):
                    flag(node, f"np.{f.attr}() on (potentially) device data "
                               "forces a host transfer; use jnp inside the "
                               "hot path")
            elif isinstance(f, ast.Name) and f.id in ("float", "int", "bool"):
                if node.args and not isinstance(node.args[0], ast.Constant):
                    flag(node, f"{f.id}() on a non-literal concretises a "
                               "traced value (host sync / trace error)")
        elif isinstance(node, (ast.If, ast.While)):
            if _mentions_traced_call(node.test, ctx):
                kind = "if" if isinstance(node, ast.If) else "while"
                flag(node, f"Python `{kind}` on a jax/jnp expression "
                           "branches on a traced value; use jnp.where / "
                           "lax.cond")
    return out


@rule(RULE)
def check_hot_path(project: Project) -> List[Finding]:
    # index: bare name -> [(ctx, def node)] over followable files
    index: Dict[str, List[Tuple[FileContext, ast.AST]]] = {}
    roots: List[Tuple[FileContext, ast.AST]] = []
    for ctx in project.files:
        if ctx.tree is None or not _followable(ctx):
            continue
        for fn in _function_defs(ctx):
            index.setdefault(fn.name, []).append((ctx, fn))
            marked = (fn.lineno in ctx.hot_marker_lines
                      or any(ln in ctx.hot_marker_lines
                             for ln in range(max(1, fn.lineno - 1 - len(
                                 fn.decorator_list)), fn.lineno + 1)))
            if fn.name in HOT_ROOT_NAMES or marked:
                roots.append((ctx, fn))

    # propagate hotness to a fixpoint over bare-name call edges
    hot: Set[int] = set()
    hot_entries: List[Tuple[FileContext, ast.AST, str]] = []
    work = [(ctx, fn, fn.name) for ctx, fn in roots]
    while work:
        ctx, fn, via = work.pop()
        if id(fn) in hot:
            continue
        hot.add(id(fn))
        hot_entries.append((ctx, fn, via))
        for name in _called_names(fn):
            for tctx, tfn in index.get(name, ()):  # followable defs only
                if id(tfn) not in hot:
                    work.append((tctx, tfn, f"{via} -> {name}"))

    findings: List[Finding] = []
    seen = set()
    for ctx, fn, via in hot_entries:
        for f in _violations_in(fn, ctx, f"hot via {via}"):
            key = (f.path, f.line, f.message)
            if key not in seen:
                seen.add(key)
                findings.append(f)
    return findings
