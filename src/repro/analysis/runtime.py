"""Runtime sanitizers: retrace counting + host-transfer tripwire.

The static rules catch hazard *shapes*; these guards catch the hazards
themselves, at test time, with zero instrumentation in the production
code:

:func:`retrace_guard`
    Counts XLA compilations inside the ``with`` block by listening to
    JAX's compile logging (``jax_log_compiles``) and raises
    :class:`RetraceError` when the count exceeds ``max_retraces``.
    Steady-state online re-solves must compile NOTHING — a retrace means
    a cache key churned (fresh callable, unhashable config, changed
    shape).

:func:`host_sync_tripwire`
    Raises :class:`HostSyncError` on device→host readbacks inside the
    block: enables JAX's device-to-host transfer guard (authoritative on
    accelerators) and additionally patches the np.asarray/np.array doors
    and ``jax.block_until_ready`` / ``jax.device_get``, which the
    transfer guard does not intercept for committed CPU arrays.

:func:`steady_state_guard`
    The combination the tests use: a retrace guard over the whole block
    plus the host-sync tripwire scoped to the map-step backend execution
    (every entry of ``backends.MAP_BACKENDS`` is wrapped for the duration)
    — result readback and warm-state capture AFTER the solve are
    legitimate host syncs, so the tripwire arms only around the hot
    region.  Yields :class:`SanitizerStats`; on exit asserts the retrace
    budget.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import logging
import threading
from typing import Iterator, Optional

import jax
import numpy as np

__all__ = ["RetraceError", "HostSyncError", "SanitizerStats",
           "retrace_guard", "host_sync_tripwire", "steady_state_guard"]


class RetraceError(AssertionError):
    """A jitted solver recompiled inside a region declared steady-state."""


class HostSyncError(AssertionError):
    """A device->host transfer happened inside the guarded hot region."""


@dataclasses.dataclass
class SanitizerStats:
    """What the guards observed (populated progressively, readable after
    the ``with`` block exits)."""

    compiles: int = 0
    compiled_names: list = dataclasses.field(default_factory=list)
    hot_backend_calls: int = 0


class _CompileCounter(logging.Handler):
    def __init__(self, stats: SanitizerStats):
        super().__init__(level=logging.DEBUG)
        self.stats = stats

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            self.stats.compiles += 1
            self.stats.compiled_names.append(msg.split()[1])


@contextlib.contextmanager
def retrace_guard(max_retraces: int = 0,
                  stats: Optional[SanitizerStats] = None
                  ) -> Iterator[SanitizerStats]:
    """Raise :class:`RetraceError` if more than ``max_retraces`` XLA
    compilations happen inside the block."""
    stats = stats if stats is not None else SanitizerStats()
    logger = logging.getLogger("jax")
    handler = _CompileCounter(stats)
    old_propagate = logger.propagate
    logger.addHandler(handler)
    # compile records propagate up from jax._src.* at WARNING level when
    # jax_log_compiles is on; stop them at our handler so test output
    # stays quiet
    logger.propagate = False
    jax.config.update("jax_log_compiles", True)
    baseline = stats.compiles
    try:
        yield stats
    finally:
        jax.config.update("jax_log_compiles", False)
        logger.removeHandler(handler)
        logger.propagate = old_propagate
    seen = stats.compiles - baseline
    if seen > max_retraces:
        raise RetraceError(
            f"{seen} compilation(s) inside a steady-state region "
            f"(budget {max_retraces}): {stats.compiled_names[-seen:]} — "
            "a jit cache key churned (fresh callable, unhashable config, "
            "or an unstable shape)")


def _is_device_array(x) -> bool:
    return isinstance(x, jax.Array)


class _TripwireRegistry:
    """Shared state for :func:`host_sync_tripwire` — THREAD-SCOPED arming.

    The guards patch process-global doors (np.asarray, np.array,
    jax.block_until_ready, jax.device_get), but a serving dispatcher runs
    the guarded hot region on a worker thread WHILE client threads
    legitimately read results back (finish/extract).  So the patches
    install once (refcounted across nested/concurrent guards) and deny
    only on threads that are currently inside a tripwire block; every
    other thread falls through to the originals."""

    def __init__(self):
        self.lock = threading.Lock()
        self.depth = 0
        self.armed: dict = {}            # thread ident -> nesting depth
        self.origs = None

    def active(self) -> bool:
        return threading.get_ident() in self.armed

    def enter(self) -> None:
        with self.lock:
            if self.depth == 0:
                self._install()
            self.depth += 1
            ident = threading.get_ident()
            self.armed[ident] = self.armed.get(ident, 0) + 1

    def exit(self) -> None:
        with self.lock:
            ident = threading.get_ident()
            n = self.armed.get(ident, 1) - 1
            if n <= 0:
                self.armed.pop(ident, None)
            else:
                self.armed[ident] = n
            self.depth -= 1
            if self.depth == 0:
                self._restore()

    def _install(self) -> None:
        def deny(what: str):
            raise HostSyncError(
                f"{what} inside the guarded hot region forces a "
                "device->host sync; keep the hot path on-device (jnp) and "
                "read back only at the map-step boundary")

        orig_asarray, orig_array = np.asarray, np.array
        orig_block, orig_get = jax.block_until_ready, jax.device_get
        self.origs = (orig_asarray, orig_array, orig_block, orig_get)

        @functools.wraps(orig_asarray)
        def guarded_asarray(a, *args, **kw):
            if self.active() and _is_device_array(a):
                deny("np.asarray(jax.Array)")
            return orig_asarray(a, *args, **kw)

        @functools.wraps(orig_array)
        def guarded_array(a, *args, **kw):
            if self.active() and _is_device_array(a):
                deny("np.array(jax.Array)")
            return orig_array(a, *args, **kw)

        def guarded_block(x):
            if self.active():
                deny("jax.block_until_ready")
            return orig_block(x)

        def guarded_get(x):
            if self.active():
                deny("jax.device_get")
            return orig_get(x)

        np.asarray, np.array = guarded_asarray, guarded_array
        jax.block_until_ready, jax.device_get = guarded_block, guarded_get

    def _restore(self) -> None:
        (np.asarray, np.array,
         jax.block_until_ready, jax.device_get) = self.origs
        self.origs = None


_TRIPWIRE = _TripwireRegistry()


@contextlib.contextmanager
def host_sync_tripwire() -> Iterator[None]:
    """Block device->host readbacks on the CURRENT thread for the duration
    of the block.  Arming is per-thread and composes across concurrent
    guards (see :class:`_TripwireRegistry`): the dispatcher thread's hot
    launch stays guarded while other threads' legitimate post-solve
    readbacks pass through."""
    _TRIPWIRE.enter()
    try:
        # authoritative on accelerator platforms (and itself thread-local);
        # on CPU, committed arrays are host-resident so the np patches in
        # the registry do the catching
        with jax.transfer_guard_device_to_host("disallow"):
            yield
    finally:
        _TRIPWIRE.exit()


@contextlib.contextmanager
def steady_state_guard(max_retraces: int = 0) -> Iterator[SanitizerStats]:
    """Assert a block performs zero retraces anywhere and zero host syncs
    inside the map-step backends (the solver hot region)."""
    from ..core import backends as backends_mod

    stats = SanitizerStats()
    saved = dict(backends_mod.MAP_BACKENDS)

    def wrap(fn):
        @functools.wraps(fn)
        def run(*args, **kw):
            stats.hot_backend_calls += 1
            with host_sync_tripwire():
                return fn(*args, **kw)
        return run

    for name, fn in saved.items():
        backends_mod.MAP_BACKENDS[name] = wrap(fn)
    try:
        with retrace_guard(max_retraces, stats=stats):
            yield stats
    finally:
        backends_mod.MAP_BACKENDS.clear()
        backends_mod.MAP_BACKENDS.update(saved)
