"""Pallas kernel invariant rules.

``pallas-vmem-budget``
    Sum the statically-resolvable BlockSpec block shapes of every
    ``pl.pallas_call`` (4 bytes/element — the kernels accumulate f32) and
    flag launches whose resident blocks exceed the ~16 MiB/core TPU VMEM
    budget.  Dims resolve through module constants, keyword defaults
    (``BLOCK_M``/``FULL_BLOCK_*``), integer arithmetic (``+ - * // %``)
    and ``min(...)``/``max(...)`` over resolvable operands — which is how
    the M-blocked streaming kernels' shrink-to-extent tiles
    (``min(block_m, ...)``) are bounded by their keyword defaults.
    ``scratch_shapes=[pltpu.VMEM((dims), dtype)]`` entries are counted
    too, at the dtype's width.  Data-dependent dims (the structured
    kernels' per-lane ``s.row_idx.shape[1:]`` blocks) are skipped —
    their bound is the padding contract, not a literal.

``pallas-block-align``
    Constant block dims must respect the f32 TPU tiling: the last dim a
    multiple of 128 (or exactly 1 for scalar / broadcast blocks), the
    second-to-last a multiple of 8 (or 1).  Misaligned blocks silently
    waste lanes at best and fail to lower at worst.

``pallas-no-scatter``
    The structured kernels' whole design is gather + one-hot fold — no
    scatter anywhere (``kernels/`` module docstrings are explicit).  Flag
    ``.at[...]`` updates and ``segment_sum`` inside ``kernels/`` files;
    the scatter-free layout is what keeps the TPU lowering dense and the
    transpose precomputable.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from .core import FileContext, Finding, Project, rule

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # ~VMEM per TPU core
BYTES_PER_ELEM = 4                     # kernels are f32 end to end
LANE_MULT = 128                        # last-dim tiling (f32)
SUBLANE_MULT = 8                       # second-to-last-dim tiling (f32)


def _module_constants(ctx: FileContext) -> Dict[str, int]:
    consts = {}
    for node in ctx.tree.body:
        if isinstance(node, ast.Assign) and isinstance(node.value,
                                                       ast.Constant):
            if isinstance(node.value.value, int):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        consts[t.id] = node.value.value
    return consts


class _Resolver:
    """Resolve int-valued AST expressions through local keyword defaults,
    module constants and single-hop imported-module constants."""

    def __init__(self, project: Project, ctx: FileContext,
                 fn: Optional[ast.FunctionDef]):
        self.project = project
        self.ctx = ctx
        self.consts = dict(_module_constants(ctx))
        # imported names: "from .pdhg_matvec import BLOCK_M"
        for local, origin in ctx.imported_names.items():
            mod, _, attr = origin.rpartition(".")
            for other in project.files:
                if other.tree and other.rel.endswith(
                        mod.split(".")[-1] + ".py"):
                    val = _module_constants(other).get(attr)
                    if val is not None:
                        self.consts.setdefault(local, val)
        if fn is not None:
            args = fn.args
            defaults = args.defaults
            params = args.args[len(args.args) - len(defaults):]
            for p, d in zip(params, defaults):
                v = self.resolve(d)
                if v is not None:
                    self.consts.setdefault(p.arg, v)
            for p, d in zip(args.kwonlyargs, args.kw_defaults):
                if d is not None:
                    v = self.resolve(d)
                    if v is not None:
                        self.consts.setdefault(p.arg, v)

    def _resolve_via_tables(self, node: ast.AST) -> Optional[int]:
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return node.value
        if isinstance(node, ast.Name):
            return self.consts.get(node.id)
        if isinstance(node, ast.Attribute) and isinstance(node.value,
                                                          ast.Name):
            # _mv.BLOCK_M: find the aliased module's constant
            origin = self.ctx.module_aliases.get(node.value.id)
            if origin:
                stem = origin.split(".")[-1]
                for other in self.project.files:
                    if other.tree and other.rel.endswith(stem + ".py"):
                        val = _module_constants(other).get(node.attr)
                        if val is not None:
                            return val
            return None
        return None

    _BINOPS = {
        ast.Add: lambda a, b: a + b,
        ast.Sub: lambda a, b: a - b,
        ast.Mult: lambda a, b: a * b,
        ast.FloorDiv: lambda a, b: a // b if b else None,
        ast.Mod: lambda a, b: a % b if b else None,
    }

    def resolve(self, node: ast.AST) -> Optional[int]:
        v = self._resolve_via_tables(node)
        if v is not None:
            return v
        if isinstance(node, ast.BinOp):
            op = self._BINOPS.get(type(node.op))
            left = self.resolve(node.left)
            right = self.resolve(node.right)
            if op is not None and left is not None and right is not None:
                return op(left, right)
            return None
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.resolve(node.operand)
            return -v if v is not None else None
        # min/max over fully-resolvable operands (the shrink-to-extent
        # tile pattern: min(block_m, padded_extent) is bounded by either
        # arm, so full resolvability is required for an exact value)
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("min", "max") \
                and node.args and not node.keywords:
            vals = [self.resolve(a) for a in node.args]
            if any(v is None for v in vals):
                return None
            return min(vals) if node.func.id == "min" else max(vals)
        return None

    def resolve_tuple(self, node: ast.AST) -> Optional[Tuple[int, ...]]:
        if not isinstance(node, ast.Tuple):
            return None
        dims = []
        for el in node.elts:
            v = self.resolve(el)
            if v is None:
                return None
            dims.append(v)
        return tuple(dims)


_DTYPE_BYTES = {
    "float64": 8, "int64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}


def _scratch_bytes(call: ast.Call, res: _Resolver) -> Optional[int]:
    """Byte size of a ``pltpu.VMEM((dims), dtype)`` scratch allocation,
    if the dims tuple resolves.  SMEM scratch is counted too — it is a
    different (smaller) memory, but an unresolvable/huge SMEM block is
    just as much a bug."""
    f = call.func
    name = f.attr if isinstance(f, ast.Attribute) else \
        f.id if isinstance(f, ast.Name) else ""
    if name not in ("VMEM", "SMEM") or not call.args:
        return None
    dims = res.resolve_tuple(call.args[0])
    if dims is None:
        return None
    bytes_per = BYTES_PER_ELEM
    if len(call.args) > 1:
        d = call.args[1]
        dname = d.attr if isinstance(d, ast.Attribute) else \
            d.id if isinstance(d, ast.Name) else ""
        bytes_per = _DTYPE_BYTES.get(dname, BYTES_PER_ELEM)
    elems = 1
    for dim in dims:
        elems *= dim
    return elems * bytes_per


def _blockspec_shape(call: ast.Call, res: _Resolver) \
        -> Optional[Tuple[int, ...]]:
    """Block tuple of a ``pl.BlockSpec((dims), index_map)`` call, if every
    dim resolves to a constant."""
    f = call.func
    name = f.attr if isinstance(f, ast.Attribute) else \
        f.id if isinstance(f, ast.Name) else ""
    if name != "BlockSpec" or not call.args:
        return None
    return res.resolve_tuple(call.args[0])


def _enclosing_fn(node: ast.AST, ctx: FileContext) -> Optional[ast.FunctionDef]:
    best = None
    for fn in ast.walk(ctx.tree):
        if isinstance(fn, ast.FunctionDef) and \
                fn.lineno <= node.lineno <= (fn.end_lineno or fn.lineno):
            if best is None or fn.lineno > best.lineno:
                best = fn
    return best


def _iter_spec_exprs(node: ast.AST):
    """Flatten a specs expression into its element expressions: plain
    list/tuple literals, ``+``-concatenations of them, and ``list * n``
    repetitions (counted once — the repeated blocks are the pinned
    scalar/vector blocks; counting one of each is the resolvable floor)."""
    if isinstance(node, (ast.List, ast.Tuple)):
        yield from node.elts
    elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        yield from _iter_spec_exprs(node.left)
        yield from _iter_spec_exprs(node.right)
    elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        yield from _iter_spec_exprs(node.left)
        yield from _iter_spec_exprs(node.right)
    else:
        yield node


def _iter_pallas_calls(ctx: FileContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                f.id if isinstance(f, ast.Name) else ""
            if name == "pallas_call":
                yield node


@rule("pallas-vmem-budget")
def check_vmem(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None or "pallas_call" not in ctx.text:
            continue
        for call in _iter_pallas_calls(ctx):
            res = _Resolver(project, ctx, _enclosing_fn(call, ctx))
            total = 0
            for kw in call.keywords:
                if kw.arg not in ("in_specs", "out_specs",
                                  "scratch_shapes"):
                    continue
                for spec in _iter_spec_exprs(kw.value):
                    if not isinstance(spec, ast.Call):
                        continue
                    if kw.arg == "scratch_shapes":
                        nbytes = _scratch_bytes(spec, res)
                        if nbytes is not None:
                            total += nbytes
                        continue
                    shape = _blockspec_shape(spec, res)
                    if shape:
                        elems = 1
                        for d in shape:
                            elems *= d
                        total += elems * BYTES_PER_ELEM
            if total > VMEM_BUDGET_BYTES:
                findings.append(Finding(
                    "pallas-vmem-budget", ctx.rel, call.lineno,
                    f"pallas_call resident blocks ~{total / 2**20:.1f} MiB "
                    f"exceed the ~{VMEM_BUDGET_BYTES // 2**20} MiB VMEM "
                    "budget; shrink the BlockSpec tiles"))
    return findings


@rule("pallas-block-align")
def check_align(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None or "BlockSpec" not in ctx.text:
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            res = _Resolver(project, ctx, _enclosing_fn(node, ctx))
            shape = _blockspec_shape(node, res)
            if not shape:
                continue
            if len(shape) >= 1:
                last = shape[-1]
                if last != 1 and last % LANE_MULT != 0:
                    findings.append(Finding(
                        "pallas-block-align", ctx.rel, node.lineno,
                        f"BlockSpec last dim {last} is neither 1 nor a "
                        f"multiple of {LANE_MULT} (f32 lane tiling); pad "
                        "via kernels/ops.py _pad_to"))
            if len(shape) >= 2:
                sub = shape[-2]
                if sub != 1 and sub % SUBLANE_MULT != 0:
                    findings.append(Finding(
                        "pallas-block-align", ctx.rel, node.lineno,
                        f"BlockSpec second-to-last dim {sub} is neither 1 "
                        f"nor a multiple of {SUBLANE_MULT} (f32 sublane "
                        "tiling)"))
    return findings


@rule("pallas-no-scatter")
def check_no_scatter(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.in_dir("kernels"):
        if ctx.tree is None:
            continue
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Subscript) and isinstance(
                    node.value, ast.Attribute) and node.value.attr == "at":
                findings.append(Finding(
                    "pallas-no-scatter", ctx.rel, node.lineno,
                    ".at[...] scatter update in a kernels/ module — the "
                    "structured kernels are gather + one-hot fold by "
                    "design (precomputed transpose layout)"))
            elif isinstance(node, ast.Call):
                f = node.func
                name = f.attr if isinstance(f, ast.Attribute) else \
                    f.id if isinstance(f, ast.Name) else ""
                if name == "segment_sum":
                    findings.append(Finding(
                        "pallas-no-scatter", ctx.rel, node.lineno,
                        "segment_sum scatter-add in a kernels/ module — "
                        "use the precomputed gather layout "
                        "(StructuredOperator) instead"))
    return findings
