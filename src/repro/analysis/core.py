"""popcheck rule framework: findings, suppressions, baselines, the runner.

A *rule* is a function ``rule(project) -> list[Finding]`` registered under
a kebab-case name via :func:`rule`.  The :class:`Project` hands every rule
the parsed ASTs, per-module import-alias tables and source lines of the
scanned files, so rules stay small and declarative.

Suppression syntax (checked per finding line):

``# popcheck: disable=<rule>[,<rule>...]``
    on (or immediately above) the offending line silences those rules for
    that line.  ``disable=all`` silences everything.
``# popcheck: disable-file=<rule>[,<rule>...]``
    anywhere in a file silences those rules for the whole file.

Baselines: :func:`write_baseline` snapshots the surviving findings as
stable fingerprints (rule + path + message — line numbers excluded so
unrelated edits don't churn the file); :func:`run_popcheck` subtracts a
loaded baseline so only NEW findings fail CI (``make lint-pop-baseline``
/ ``make lint-pop``).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

__all__ = [
    "Finding", "FileContext", "Project", "RULES", "rule",
    "run_popcheck", "load_baseline", "write_baseline", "DEFAULT_SCAN_DIRS",
]

# directories scripts/popcheck.py scans by default, relative to repo root
DEFAULT_SCAN_DIRS = ("src/repro", "examples", "benchmarks")

_SUPPRESS_RE = re.compile(r"#\s*popcheck:\s*disable=([\w\-,]+)")
_SUPPRESS_FILE_RE = re.compile(r"#\s*popcheck:\s*disable-file=([\w\-,]+)")
_HOT_RE = re.compile(r"#\s*popcheck:\s*hot\b")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str      # repo-relative, '/'-separated
    line: int
    message: str

    def fingerprint(self) -> str:
        """Line-number-free identity used by baseline files, so editing an
        unrelated part of a module does not churn the baseline."""
        return f"{self.rule}::{self.path}::{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class FileContext:
    """One parsed source file plus the lookup tables rules need."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:  # surfaced as a finding by the runner
            self.parse_error = f"syntax error: {e.msg} (line {e.lineno})"
        # module-alias tables: local name -> dotted module / origin
        self.module_aliases: Dict[str, str] = {}   # np -> numpy, pop -> repro.core.pop
        self.imported_names: Dict[str, str] = {}   # pop_solve -> repro.core.pop.pop_solve
        if self.tree is not None:
            self._index_imports()
        self.file_suppressed = set()
        for m in _SUPPRESS_FILE_RE.finditer(text):
            self.file_suppressed.update(m.group(1).split(","))
        # per-line suppressions: line -> set of rule names (or {"all"})
        self.line_suppressed: Dict[int, set] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                self.line_suppressed[i] = set(m.group(1).split(","))
        self.hot_marker_lines = {
            i for i, line in enumerate(self.lines, start=1)
            if _HOT_RE.search(line)}

    def _index_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.module_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                base = node.module
                for a in node.names:
                    local = a.asname or a.name
                    # "from repro.core import pop" imports a MODULE; track
                    # it in both tables (rules resolve either way)
                    self.module_aliases.setdefault(local,
                                                   f"{base}.{a.name}")
                    self.imported_names[local] = f"{base}.{a.name}"

    def suppressed(self, rule_name: str, line: int) -> bool:
        if rule_name in self.file_suppressed or "all" in self.file_suppressed:
            return True
        for ln in (line, line - 1):   # same line or the line above
            rules = self.line_suppressed.get(ln)
            if rules and (rule_name in rules or "all" in rules):
                return True
        return False


class Project:
    """The scanned file set handed to every rule."""

    def __init__(self, files: Sequence[FileContext],
                 repo_root: Optional[Path] = None):
        self.files = list(files)
        self.repo_root = repo_root

    @classmethod
    def from_paths(cls, paths: Iterable[Path],
                   repo_root: Optional[Path] = None) -> "Project":
        root = Path(repo_root) if repo_root else None
        files = []
        for p in sorted(set(Path(p) for p in paths)):
            if p.is_dir():
                todo = sorted(p.rglob("*.py"))
            else:
                todo = [p]
            for f in todo:
                rel = (f.relative_to(root) if root and f.is_relative_to(root)
                       else f)
                files.append(FileContext(f, rel.as_posix(),
                                         f.read_text(encoding="utf-8")))
        return cls(files, repo_root=root)

    def in_dir(self, fragment: str) -> List[FileContext]:
        """Files whose repo-relative path contains ``fragment`` as a
        path component (e.g. ``"kernels"``)."""
        return [f for f in self.files if fragment in Path(f.rel).parts]


Rule = Callable[[Project], List[Finding]]

RULES: Dict[str, Rule] = {}


def rule(name: str) -> Callable[[Rule], Rule]:
    def deco(fn: Rule) -> Rule:
        fn.rule_name = name
        RULES[name] = fn
        return fn
    return deco


# --------------------------------------------------------------------------
# baseline snapshots
# --------------------------------------------------------------------------

def write_baseline(findings: Sequence[Finding], path: Path) -> None:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.fingerprint()] = counts.get(f.fingerprint(), 0) + 1
    payload = {
        "comment": "popcheck suppression baseline — regenerate with "
                   "`make lint-pop-baseline`; entries are known findings "
                   "that do not fail `make lint-pop`",
        "findings": [{"fingerprint": fp, "count": n}
                     for fp, n in sorted(counts.items())],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def load_baseline(path: Path) -> Dict[str, int]:
    if not Path(path).exists():
        return {}
    data = json.loads(Path(path).read_text())
    return {e["fingerprint"]: int(e.get("count", 1))
            for e in data.get("findings", [])}


def apply_baseline(findings: Sequence[Finding],
                   baseline: Dict[str, int]) -> List[Finding]:
    budget = dict(baseline)
    fresh = []
    for f in findings:
        fp = f.fingerprint()
        if budget.get(fp, 0) > 0:
            budget[fp] -= 1
        else:
            fresh.append(f)
    return fresh


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

def run_popcheck(paths: Iterable[Path],
                 rules: Optional[Iterable[str]] = None,
                 baseline: Optional[Dict[str, int]] = None,
                 repo_root: Optional[Path] = None) -> List[Finding]:
    """Scan ``paths`` with the named rules (default: all registered),
    drop suppressed findings, subtract ``baseline``, and return the rest
    sorted by location."""
    project = Project.from_paths(paths, repo_root=repo_root)
    findings: List[Finding] = []
    for f in project.files:
        if f.parse_error:
            findings.append(Finding("parse-error", f.rel, 1, f.parse_error))
    selected = list(rules) if rules is not None else sorted(RULES)
    for name in selected:
        if name not in RULES:
            raise ValueError(f"unknown popcheck rule {name!r}; registered: "
                             f"{sorted(RULES)}")
        for found in RULES[name](project):
            ctx = next((f for f in project.files if f.rel == found.path),
                       None)
            if ctx is not None and ctx.suppressed(found.rule, found.line):
                continue
            findings.append(found)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    if baseline:
        findings = apply_baseline(findings, baseline)
    return findings
