"""Rule ``api-drift``: the live public surface must match the committed
snapshot.

Reuses ``scripts/api_surface.py`` (the same renderer ``make api-snapshot``
and ``tests/test_api_surface.py`` use): a fresh render of the public
modules is diffed against ``docs/api_surface.txt``.  Drift is a finding —
intentional surface changes regenerate the snapshot so the diff shows up
in review, accidental ones fail ``make lint-pop``.

Unlike the AST rules this one imports the live package; when the renderer
or snapshot are unavailable (fixture-only runs, missing repo root) the
rule degrades to silence rather than inventing findings.
"""

from __future__ import annotations

import difflib
import importlib.util
import sys
from pathlib import Path
from typing import List, Optional

from .core import Finding, Project, rule

SNAPSHOT_REL = Path("docs") / "api_surface.txt"
RENDERER_REL = Path("scripts") / "api_surface.py"


def _load_renderer(repo_root: Path):
    spec = importlib.util.spec_from_file_location(
        "_popcheck_api_surface", repo_root / RENDERER_REL)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def render_surface(repo_root: Path) -> Optional[str]:
    renderer = repo_root / RENDERER_REL
    if not renderer.exists():
        return None
    src = str(repo_root / "src")
    added = src not in sys.path
    if added:
        sys.path.insert(0, src)
    try:
        return _load_renderer(repo_root).render()
    finally:
        if added and src in sys.path:
            sys.path.remove(src)


def diff_surface(repo_root: Path,
                 snapshot_path: Optional[Path] = None) -> List[Finding]:
    """The api-drift comparison, parameterised for tests: diff a fresh
    render against ``snapshot_path`` (default: the committed snapshot)."""
    snapshot_path = snapshot_path or repo_root / SNAPSHOT_REL
    if not snapshot_path.exists():
        return []
    fresh = render_surface(repo_root)
    if fresh is None:
        return []
    committed = snapshot_path.read_text()
    if fresh == committed:
        return []
    delta = [l for l in difflib.unified_diff(
        committed.splitlines(), fresh.splitlines(),
        "docs/api_surface.txt", "live surface", lineterm="", n=0)
        if l.startswith(("+", "-")) and not l.startswith(("+++", "---"))]
    head = "; ".join(delta[:6]) + (" ..." if len(delta) > 6 else "")
    return [Finding(
        "api-drift", SNAPSHOT_REL.as_posix(), 1,
        f"public API surface drifted from the committed snapshot "
        f"({len(delta)} line(s)): {head} — intentional changes run "
        "`make api-snapshot` and commit the diff")]


@rule("api-drift")
def check_api_drift(project: Project) -> List[Finding]:
    if project.repo_root is None:
        return []
    root = Path(project.repo_root)
    if not (root / RENDERER_REL).exists() or \
            not (root / SNAPSHOT_REL).exists():
        return []
    return diff_surface(root)
