"""Rule ``retrace-hazard``: fresh objects must not reach the jit caches.

The map-step substrate keys compiled solvers on *identity and hashability*
— ``backends._cached_solver`` is an ``lru_cache`` over ``(K_mv, KT_mv,
kw_items, engine)``, and ``jax.jit``'s own cache keys on the wrapped
callable's identity.  Two hazard shapes defeat both:

1. passing a definitely-fresh / unhashable object (a lambda, a list/dict/
   set literal or comprehension) as an argument to an ``lru_cache``-
   decorated function: either a ``TypeError`` or a guaranteed cache miss
   per call;
2. jitting (or pmapping) a freshly-constructed callable and calling the
   result inside the same function — ``jax.jit(lambda ...)(x)`` or
   ``fn = jax.jit(make(...)); fn(x)`` outside a memoized builder — which
   recompiles the whole solver on EVERY invocation (this is exactly the
   recompile-per-call bug this PR fixes in ``solve_chunked_vmap`` /
   ``solve_shard_map`` / ``solve_pmap``).

Builders that RETURN a jitted callable (``return jax.jit(...)``) are fine
— caching is then the caller's contract — and jit calls inside functions
decorated with ``functools.lru_cache``/``functools.cache`` are the blessed
memoized-builder pattern.
"""

from __future__ import annotations

import ast
from typing import List, Set

from .core import FileContext, Finding, Project, rule

RULE = "retrace-hazard"

_FRESH_NODES = (ast.Lambda, ast.ListComp, ast.SetComp, ast.DictComp,
                ast.GeneratorExp, ast.List, ast.Dict, ast.Set)


def _is_cache_decorator(dec: ast.AST) -> bool:
    """functools.lru_cache / functools.cache, bare or called."""
    if isinstance(dec, ast.Call):
        dec = dec.func
    name = dec.attr if isinstance(dec, ast.Attribute) else \
        dec.id if isinstance(dec, ast.Name) else ""
    return name in ("lru_cache", "cache")


def _cached_def_names(project: Project) -> Set[str]:
    names = set()
    for ctx in project.files:
        if ctx.tree is None:
            continue
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef) and any(
                    _is_cache_decorator(d) for d in node.decorator_list):
                names.add(node.name)
    return names


def _called_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _is_jax_wrap(call: ast.Call, ctx: FileContext) -> bool:
    """jax.jit(...) / jax.pmap(...) (by alias) or bare imported jit/pmap."""
    f = call.func
    if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
        return (f.attr in ("jit", "pmap")
                and ctx.module_aliases.get(f.value.id) == "jax")
    if isinstance(f, ast.Name):
        return ctx.imported_names.get(f.id, "") in ("jax.jit", "jax.pmap")
    return False


def _check_function(ctx: FileContext, fn: ast.FunctionDef,
                    cached_names: Set[str], findings: List[Finding]) -> None:
    if any(_is_cache_decorator(d) for d in fn.decorator_list):
        return  # memoized builder: fresh jits inside are built once per key

    # names assigned from defs / lambdas / calls inside this function are
    # fresh per invocation
    fresh_local: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.FunctionDef) and node is not fn:
            fresh_local.add(node.name)
        elif isinstance(node, ast.Assign) and isinstance(
                node.value, (ast.Lambda, ast.Call)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    fresh_local.add(t.id)

    jitted_fresh: Set[str] = set()   # locals holding a fresh jitted callable
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        # (1) unhashable/fresh args into an lru_cached function
        if _called_name(node) in cached_names:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, _FRESH_NODES):
                    findings.append(Finding(
                        RULE, ctx.rel, arg.lineno,
                        f"fresh/unhashable {type(arg).__name__} argument to "
                        f"lru_cached '{_called_name(node)}' — guaranteed "
                        "cache miss (or TypeError) every call"))
        # (2) jit/pmap of a fresh callable, called in the same function
        if _is_jax_wrap(node, ctx) and node.args:
            target = node.args[0]
            fresh = (isinstance(target, (ast.Lambda, ast.Call))
                     or (isinstance(target, ast.Name)
                         and target.id in fresh_local))
            if fresh:
                parent = getattr(node, "_pc_parent", None)
                if isinstance(parent, ast.Call) and parent.func is node:
                    # jax.jit(...)(x): jitted and invoked in one expression
                    findings.append(Finding(
                        RULE, ctx.rel, node.lineno,
                        "jit/pmap of a freshly-constructed callable invoked "
                        "in place — recompiles on every call; memoize the "
                        "builder (functools.lru_cache)"))
                else:
                    for t in _assign_targets(node):
                        jitted_fresh.add(t)
    if jitted_fresh:
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in jitted_fresh):
                findings.append(Finding(
                    RULE, ctx.rel, node.lineno,
                    f"'{node.func.id}' holds a per-call jit/pmap of a fresh "
                    "callable and is invoked here — recompiles on every "
                    "call; memoize the builder (functools.lru_cache)"))


def _assign_targets(value_node: ast.Call) -> List[str]:
    parent = getattr(value_node, "_pc_parent", None)
    if isinstance(parent, ast.Assign) and parent.value is value_node:
        return [t.id for t in parent.targets if isinstance(t, ast.Name)]
    return []


def _link_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._pc_parent = node


@rule(RULE)
def check_retrace(project: Project) -> List[Finding]:
    cached_names = _cached_def_names(project)
    cached_names.add("_cached_solver")   # the canonical jit-cache door
    findings: List[Finding] = []
    for ctx in project.files:
        if ctx.tree is None:
            continue
        _link_parents(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.FunctionDef):
                _check_function(ctx, node, cached_names, findings)
    # dedup (nested defs are walked by their parents too)
    seen, out = set(), []
    for f in findings:
        key = (f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out
