"""Fault injection for the serving layer — the chaos half of popcheck.

docs/ROBUSTNESS.md specifies a degradation ladder; this module provides
the faults that push a live :class:`~repro.service.PopSession` /
checkpoint blob onto each rung, so the chaos suite (``tests/test_faults.py``,
``make test-faults``) and the session bench can assert — not hope — that
every failure mode lands where the contract says:

====================  =============================================
injector              intended rung / fault string
====================  =============================================
poison_warm           ``recovered`` via ``divergence:<n>`` (lane
                      quarantine, healthy lanes keep iterates)
drop_warm_plan        ``recovered`` via ``warm-state-mismatch``
mismatch_warm         ``recovered`` via ``warm-state-mismatch``
                      (iterate shapes disagree with the plan)
inflate_rates         ``degraded`` (``deadline:capped``/
                      ``deadline:best-effort``) or ``fallback``
                      (``deadline``) depending on the factor
truncate_checkpoint   cold restore, ``checkpoint_failures`` += 1
corrupt_checkpoint    cold restore, ``checkpoint_failures`` += 1
====================  =============================================

Injectors mutate in place (sessions) or return the damaged blob
(checkpoints); none of them touch solver internals — they only forge the
states a real deployment produces (a NaN'd iterate from a pathological
re-solve, a half-written checkpoint file, a machine running slow).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["FAULTS", "poison_warm", "drop_warm_plan", "mismatch_warm",
           "inflate_rates", "truncate_checkpoint", "corrupt_checkpoint"]


def poison_warm(session, lanes: Sequence[int] = (0,),
                value: float = np.nan) -> None:
    """NaN (or otherwise poison) the warm iterates of ``lanes`` — the state
    a diverging re-solve leaves behind.  The next ``step()`` must
    quarantine exactly those lanes and report ``divergence:<n>``."""
    warm = session._warm
    if warm is None or getattr(warm, "x", None) is None:
        raise ValueError("session has no pop warm state to poison — "
                         "step() it at least once first")
    # POPResult.x is a read-only view of a device array: copy-then-replace
    x = np.asarray(warm.x).copy()
    x[np.asarray(lanes, int), :] = value
    warm.x = x


def drop_warm_plan(session) -> None:
    """Drop the plan out from under the warm iterates — the shape of a bad
    deserialization or a stale hand-seeded result.  The next ``step()``
    must flag ``warm-state-mismatch`` and restart cold (no crash)."""
    warm = session._warm
    if warm is None:
        raise ValueError("session has no warm state to damage")
    warm.plan = None


def mismatch_warm(session, extra_cols: int = 3) -> None:
    """Resize the warm iterates so they no longer match the plan's shapes —
    a warm state carried across an instance-size change without a remap.
    Caught by the pre-solve shape check, never by the solver."""
    warm = session._warm
    if warm is None or getattr(warm, "x", None) is None:
        raise ValueError("session has no pop warm state to damage")
    x = np.asarray(warm.x)
    warm.x = np.concatenate(
        [x, np.zeros((x.shape[0], extra_cols), x.dtype)], axis=1)


def inflate_rates(service, factor: float = 100.0,
                  key: Optional[tuple] = None) -> None:
    """Inflate the measured per-iteration solve rate(s) — the budget model
    now believes every iteration takes ``factor``x longer, which is what a
    thermally-throttled or oversubscribed host looks like.  Deadline-bound
    steps must degrade (capped/best-effort) or fall back, never blow the
    deadline silently."""
    keys = [key] if key is not None else list(service._rates)
    if not keys:
        raise ValueError("service has no measured rates yet — run at "
                         "least one fault-free step first")
    for k in keys:
        service._rates[k] = service._rates[k] * factor


def truncate_checkpoint(blob: bytes, keep_fraction: float = 0.5) -> bytes:
    """A torn write: keep only the first ``keep_fraction`` of the blob.
    ``restore()`` must report a failure and start cold, never crash."""
    return blob[:int(len(blob) * keep_fraction)]


def corrupt_checkpoint(blob: bytes, offset: Optional[int] = None) -> bytes:
    """Flip one byte (default: middle of the payload) — bit rot / a bad
    copy.  The payload hash check must catch it at restore time."""
    if not blob:
        raise ValueError("empty checkpoint blob")
    i = len(blob) // 2 if offset is None else offset
    out = bytearray(blob)
    out[i] ^= 0xFF
    return bytes(out)


# name -> injector, for table-driven chaos suites and the session bench
FAULTS = {
    "poison-warm": poison_warm,
    "drop-warm-plan": drop_warm_plan,
    "mismatch-warm": mismatch_warm,
    "inflate-rates": inflate_rates,
    "truncate-checkpoint": truncate_checkpoint,
    "corrupt-checkpoint": corrupt_checkpoint,
}
