"""``popcheck``: static analysis + runtime sanitizers for the POP hot path.

POP's pitch is sub-second online re-solves, and this repo's hot path rests
on invariants nothing in Python enforces: jit caches keyed on hashable
configs/operator identity, zero host sync inside ``solve_stacked``, Pallas
blocks that fit VMEM, domains that declare the hooks their fill style
needs.  This package machine-checks them:

* :mod:`repro.analysis.core` — the rule framework (findings, suppression
  comments, baseline snapshots, the runner ``scripts/popcheck.py`` wraps).
* :mod:`repro.analysis.hotpath` — host-sync-in-hot-path rule.
* :mod:`repro.analysis.retrace` — retrace-hazard rule.
* :mod:`repro.analysis.pallas` — Pallas VMEM / block-alignment /
  no-scatter rules.
* :mod:`repro.analysis.contracts` — deprecated-door, dtype-promotion,
  registry-contract and config-hashability rules.
* :mod:`repro.analysis.profiles` — profile-staleness rule (TuningProfile
  reads must go through ``check_profile``).
* :mod:`repro.analysis.surface` — public-API drift vs
  ``docs/api_surface.txt``.
* :mod:`repro.analysis.runtime` — runtime sanitizers: a retrace-counter
  guard and a host-transfer tripwire for asserting steady-state
  ``PopSession.step()`` is retrace- and sync-free.
* :mod:`repro.analysis.faults` — fault injection for the serving layer
  (poisoned/dropped warm state, damaged checkpoints, inflated solve
  rates) driving the chaos suite behind docs/ROBUSTNESS.md.

Rule catalog + suppression syntax: ``docs/LINTS.md``.
"""

from __future__ import annotations

from .core import (  # noqa: F401
    Finding,
    Project,
    RULES,
    load_baseline,
    run_popcheck,
    write_baseline,
)
from .faults import (  # noqa: F401
    FAULTS,
    corrupt_checkpoint,
    drop_warm_plan,
    inflate_rates,
    mismatch_warm,
    poison_warm,
    truncate_checkpoint,
)
from .runtime import (  # noqa: F401
    HostSyncError,
    RetraceError,
    SanitizerStats,
    host_sync_tripwire,
    retrace_guard,
    steady_state_guard,
)

# importing the rule modules registers their rules in RULES
from . import hotpath as _hotpath      # noqa: F401,E402
from . import retrace as _retrace      # noqa: F401,E402
from . import pallas as _pallas        # noqa: F401,E402
from . import contracts as _contracts  # noqa: F401,E402
from . import profiles as _profiles    # noqa: F401,E402
from . import surface as _surface      # noqa: F401,E402

__all__ = [
    "Finding",
    "Project",
    "RULES",
    "run_popcheck",
    "load_baseline",
    "write_baseline",
    "RetraceError",
    "HostSyncError",
    "SanitizerStats",
    "retrace_guard",
    "host_sync_tripwire",
    "steady_state_guard",
    "FAULTS",
    "poison_warm",
    "drop_warm_plan",
    "mismatch_warm",
    "inflate_rates",
    "truncate_checkpoint",
    "corrupt_checkpoint",
]
