"""Tuning-profile hygiene rules.

``profile-staleness``
    A :class:`~repro.tuning.TuningProfile` is a *committed measurement
    artifact*: it encodes quality/latency curves for one profile-format
    version, sealed by a content digest.  ``load_profile`` deliberately
    does NOT validate — ``check_profile`` is the gate that rejects a
    stale format version, a hand-edited (digest-mismatched) file, or a
    profile measured on a different platform.  Code that loads a profile
    and never checks it will happily tune the service from garbage.

    The rule flags every resolved call to ``load_profile`` (imported
    from ``repro.tuning`` / ``repro.tuning.profile``, directly or via a
    module alias) in a function or module scope that contains no
    ``check_profile`` call.  ``check_profile(load_profile(path))`` is
    the idiomatic clean form.  The defining module
    (``tuning/profile.py``) is exempt — findings there would be the
    implementation itself.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from .core import FileContext, Finding, Project, rule

_PROFILE_MODULES = {"repro.tuning", "repro.tuning.profile"}
_LOADER = "load_profile"
_CHECKER = "check_profile"

_FUNCS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _resolved_call(ctx: FileContext, func: ast.AST) -> Optional[str]:
    """The tuning-door function a call's func node resolves to
    (``load_profile``/``check_profile``), or None.  Mirrors the
    deprecated-door resolution: names imported from the tuning modules
    (asname-aware via the recorded origin) and attribute access on a
    tuning module alias; a ``load_profile`` *method* on some unrelated
    object is not flagged."""
    if isinstance(func, ast.Name):
        origin = ctx.imported_names.get(func.id, "")
        base, _, leaf = origin.rpartition(".")
        if base in _PROFILE_MODULES and leaf in (_LOADER, _CHECKER):
            return leaf
    elif isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
        alias = ctx.module_aliases.get(func.value.id, "")
        if alias in _PROFILE_MODULES and func.attr in (_LOADER, _CHECKER):
            return func.attr
    return None


def _scope_nodes(tree: ast.AST) -> Iterator[ast.AST]:
    """Every scope the rule reasons over: the module plus each function
    (methods included), innermost scopes owning their own calls."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, _FUNCS):
            yield node


def _iter_scope(scope: ast.AST) -> Iterator[ast.AST]:
    """Nodes belonging to ``scope`` without descending into nested
    function scopes (a helper that checks is its own scope)."""
    stack = list(getattr(scope, "body", []))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _FUNCS):
            stack.extend(ast.iter_child_nodes(node))


@rule("profile-staleness")
def check_profile_staleness(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None or ctx.rel.endswith("tuning/profile.py"):
            continue
        if _LOADER not in ctx.text:
            continue
        for scope in _scope_nodes(ctx.tree):
            loads: List[ast.Call] = []
            checked = False
            for node in _iter_scope(scope):
                if not isinstance(node, ast.Call):
                    continue
                resolved = _resolved_call(ctx, node.func)
                if resolved == _LOADER:
                    loads.append(node)
                elif resolved == _CHECKER:
                    checked = True
            if checked:
                continue
            for call in loads:
                findings.append(Finding(
                    "profile-staleness", ctx.rel, call.lineno,
                    "load_profile without check_profile in the same scope "
                    "— a stale or hand-edited TuningProfile (version/digest "
                    "mismatch) silently tunes the service; wrap the read: "
                    "check_profile(load_profile(path))"))
    return findings
