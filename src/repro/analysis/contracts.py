"""Contract rules: deprecated doors, dtype promotion, registry hooks,
config hashability.

``deprecated-door``
    Internal code must go through the one public surface (``solve_instance``
    / ``solve_full_ex`` / ``PopService`` sessions), not the kept-for-compat
    forwarders: module-level ``pop_solve`` / ``solve_full`` (tuple form),
    ``GavelScheduler``, ``serve.balance_requests``.  Method calls named
    ``pop_solve``/``solve_full`` on problem objects
    (``LoadBalanceProblem.pop_solve``) are the problem's OWN surface and
    are not flagged — only calls through a ``repro.core``/``repro.core.pop``
    module alias or a name imported from there.

``dtype-promotion``
    The kernels and their XLA references are f32 end to end; a stray
    ``float64``/``np.double`` literal (or flipping ``jax_enable_x64``)
    silently doubles VMEM footprints and detiles the (8, 128) layout.
    Scoped to ``kernels/`` files (plus the x64 flag anywhere).

``registry-contract``
    Statically mirrors (and extends) ``DomainSpec.__post_init__``: a spec
    must pick exactly one fill style.  Flags (a) specs with none of
    ``problem=`` / ``step_override=`` / the six declarative hooks, (b)
    ``step_override`` combined with pipeline hooks the override silently
    ignores, (c) ``problem=`` combined with declarative builder hooks
    (two conflicting fill styles).

``config-hashability``
    Frozen config dataclasses key the jit/plan caches, so every field must
    stay hashable: flags dict/list/set/ndarray-annotated fields of
    ``@dataclass(frozen=True)`` classes that are not re-frozen in
    ``__post_init__``, and any class defining ``__eq__`` without
    ``__hash__`` (Python then silently sets ``__hash__ = None``).

``silent-except``
    The fault-tolerant serving layer (docs/ROBUSTNESS.md) turns every
    caught exception into a recorded fault — an ``except`` that swallows
    silently hides exactly the divergence/deadline/corruption events the
    ladder exists to count.  Flags, inside ``core/`` and ``service.py``:
    bare ``except:`` (catches ``KeyboardInterrupt``/``SystemExit`` too),
    and ``except Exception:`` / ``except BaseException:`` whose body is
    only ``pass``/``...``.  Typed handlers (``except ValueError: pass``)
    and broad handlers that record/re-raise are fine.
"""

from __future__ import annotations

import ast
from typing import List, Set

from .core import FileContext, Finding, Project, rule

_DOOR_MODULES = {"repro.core", "repro.core.pop", "repro.sched",
                 "repro.sched.gavel_service", "repro.serve",
                 "repro.serve.engine"}
_DOOR_NAMES = {
    "pop_solve": "pop.solve_instance(problem, SolveConfig, ExecConfig) or a "
                 "PopService session",
    "solve_full": "pop.solve_full_ex(problem, exec_cfg=...)",
    "GavelScheduler": "repro.service.PopService().session(domain='gavel')",
    "balance_requests": "repro.service.PopService().session("
                        "domain='load_balance')",
}
# modules that DEFINE the doors (the forwarders themselves + their tests
# live outside the scan roots); findings there are the implementation
_DOOR_DEFINING = ("src/repro/core/pop.py", "src/repro/sched/",
                  "src/repro/serve/")


@rule("deprecated-door")
def check_deprecated_door(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None or ctx.rel.startswith(_DOOR_DEFINING):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            name, hit = None, False
            if isinstance(f, ast.Name) and f.id in _DOOR_NAMES:
                origin = ctx.imported_names.get(f.id, "")
                hit = origin.rpartition(".")[0] in _DOOR_MODULES
                name = f.id
            elif (isinstance(f, ast.Attribute) and f.attr in _DOOR_NAMES
                  and isinstance(f.value, ast.Name)):
                # only module-alias calls: pop.solve_full(...), not
                # prob.solve_full(...) (the problem's own method)
                alias = ctx.module_aliases.get(f.value.id, "")
                hit = alias in _DOOR_MODULES
                name = f.attr
            if hit:
                findings.append(Finding(
                    "deprecated-door", ctx.rel, node.lineno,
                    f"call to deprecated forwarder '{name}'; use "
                    f"{_DOOR_NAMES[name]}"))
    return findings


_F64_TOKENS = {"float64", "double"}


@rule("dtype-promotion")
def check_dtype(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None:
            continue
        in_kernels = "kernels" in ctx.rel.split("/")
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "update"):
                args = [a.value for a in node.args
                        if isinstance(a, ast.Constant)]
                if "jax_enable_x64" in args:
                    truthy = any(
                        isinstance(a, ast.Constant) and a.value is True
                        for a in node.args)
                    if truthy:
                        findings.append(Finding(
                            "dtype-promotion", ctx.rel, node.lineno,
                            "jax_enable_x64 flipped on — doubles every "
                            "buffer and breaks the f32 (8, 128) kernel "
                            "tiling repo-wide"))
            if not in_kernels:
                continue
            if isinstance(node, ast.Attribute) and node.attr in _F64_TOKENS:
                findings.append(Finding(
                    "dtype-promotion", ctx.rel, node.lineno,
                    f"{node.attr} in a kernels/ module — the kernel "
                    "contract is f32 end to end (weak-type f64 promotion "
                    "detiles VMEM blocks)"))
            elif (isinstance(node, ast.Constant)
                  and node.value in _F64_TOKENS):
                findings.append(Finding(
                    "dtype-promotion", ctx.rel, node.lineno,
                    f"dtype string '{node.value}' in a kernels/ module — "
                    "the kernel contract is f32 end to end"))
    return findings


_DECLARATIVE = ("n_entities", "entity_attrs", "build_sub", "K_mv", "KT_mv",
                "extract")
_IGNORED_UNDER_OVERRIDE = ("problem", "build_sub", "K_mv", "KT_mv",
                           "extract", "sub_layout", "entity_attrs",
                           "entity_scores", "n_entities")


@rule("registry-contract")
def check_registry(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None or "DomainSpec" not in ctx.text:
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                f.id if isinstance(f, ast.Name) else ""
            if name != "DomainSpec":
                continue
            kw: Set[str] = {k.arg for k in node.keywords if k.arg}
            has_override = "step_override" in kw
            has_problem = "problem" in kw
            declarative = [h for h in _DECLARATIVE if h in kw]
            if not has_override and not has_problem and \
                    len(declarative) < len(_DECLARATIVE):
                missing = sorted(set(_DECLARATIVE) - set(declarative))
                findings.append(Finding(
                    "registry-contract", ctx.rel, node.lineno,
                    "DomainSpec picks no fill style: provide problem=, "
                    "step_override=, or all declarative hooks (missing: "
                    f"{missing})"))
            if has_override:
                ignored = sorted(set(_IGNORED_UNDER_OVERRIDE) & kw)
                if ignored:
                    findings.append(Finding(
                        "registry-contract", ctx.rel, node.lineno,
                        f"DomainSpec(step_override=...) also sets {ignored} "
                        "— the override runs its own pipeline and these "
                        "hooks are silently ignored"))
            if has_problem and not has_override:
                conflicting = sorted(
                    {"build_sub", "K_mv", "KT_mv", "extract"} & kw)
                if conflicting:
                    findings.append(Finding(
                        "registry-contract", ctx.rel, node.lineno,
                        f"DomainSpec(problem=...) also sets {conflicting} — "
                        "the problem factory path takes hooks from the "
                        "problem object; mixing fill styles is ambiguous"))
    return findings


_UNHASHABLE_ANNOS = {"dict", "Dict", "list", "List", "set", "Set",
                     "ndarray", "np.ndarray", "numpy.ndarray"}


def _anno_names(anno: ast.AST) -> Set[str]:
    """Top-level type heads of a field annotation.  Unwraps Optional/Union
    one level; does NOT descend into other subscripts — ``Callable[[Any],
    np.ndarray]`` describes a hashable callable, not an ndarray field."""
    if isinstance(anno, ast.Subscript):
        heads = _anno_names(anno.value)
        if heads & {"Optional", "Union"}:
            elts = anno.slice.elts if isinstance(anno.slice, ast.Tuple) \
                else [anno.slice]
            for e in elts:
                heads = heads | _anno_names(e)
        return heads
    if isinstance(anno, ast.Name):
        return {anno.id}
    if isinstance(anno, ast.Attribute):
        return {f"{anno.value.id}.{anno.attr}"
                if isinstance(anno.value, ast.Name) else anno.attr,
                anno.attr}
    if isinstance(anno, ast.Constant) and isinstance(anno.value, str):
        return {anno.value.split("[")[0]}
    return set()


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        call = dec if isinstance(dec, ast.Call) else None
        target = call.func if call else dec
        name = target.attr if isinstance(target, ast.Attribute) else \
            target.id if isinstance(target, ast.Name) else ""
        if name == "dataclass" and call is not None:
            for k in call.keywords:
                if k.arg == "frozen" and isinstance(k.value, ast.Constant) \
                        and k.value.value is True:
                    return True
    return False


@rule("config-hashability")
def check_config_hash(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None:
            continue
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            # a suppression on the class (or decorator) line covers every
            # field finding in the class body
            if any(ctx.suppressed("config-hashability", ln)
                   for ln in range(cls.lineno - len(cls.decorator_list),
                                   cls.lineno + 1)):
                continue
            methods = {n.name for n in cls.body
                       if isinstance(n, ast.FunctionDef)}
            if "__eq__" in methods and "__hash__" not in methods:
                findings.append(Finding(
                    "config-hashability", ctx.rel, cls.lineno,
                    f"class {cls.name} defines __eq__ without __hash__ — "
                    "Python sets __hash__ = None and instances can no "
                    "longer key the jit/plan caches"))
            if not _is_frozen_dataclass(cls):
                continue
            post = next((n for n in cls.body
                         if isinstance(n, ast.FunctionDef)
                         and n.name == "__post_init__"), None)
            refrozen: Set[str] = set()
            if post is not None:
                for node in ast.walk(post):
                    # object.__setattr__(self, "field", _freeze...(...))
                    if (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Attribute)
                            and node.func.attr == "__setattr__"
                            and len(node.args) >= 2
                            and isinstance(node.args[1], ast.Constant)):
                        refrozen.add(node.args[1].value)
            for stmt in cls.body:
                if not isinstance(stmt, ast.AnnAssign) or \
                        not isinstance(stmt.target, ast.Name):
                    continue
                field = stmt.target.id
                if field in refrozen:
                    continue
                bad = _anno_names(stmt.annotation) & _UNHASHABLE_ANNOS
                if bad:
                    findings.append(Finding(
                        "config-hashability", ctx.rel, stmt.lineno,
                        f"frozen dataclass {cls.name}.{field} is annotated "
                        f"{sorted(bad)} (unhashable) and never re-frozen "
                        "in __post_init__ — it will poison every cache "
                        "keyed on the config"))
    return findings


_BROAD_EXC = {"Exception", "BaseException"}


def _silent_except_scope(rel: str) -> bool:
    """Which files the silent-except rule polices: the serving hot path
    (``core/`` + ``service.py``) inside the package; everything handed to
    the runner outside it (so fixtures pin the rule)."""
    parts = rel.split("/")
    if "repro" in parts and "src" in parts:
        return "core" in parts or parts[-1] == "service.py"
    return True


def _is_silent_body(body) -> bool:
    return all(
        isinstance(stmt, ast.Pass)
        or (isinstance(stmt, ast.Expr)
            and isinstance(stmt.value, ast.Constant)
            and stmt.value.value is ...)
        for stmt in body)


def _exc_names(node) -> Set[str]:
    """Exception-class names a handler catches (unwraps tuples)."""
    if node is None:
        return set()
    elts = node.elts if isinstance(node, ast.Tuple) else [node]
    names = set()
    for e in elts:
        if isinstance(e, ast.Name):
            names.add(e.id)
        elif isinstance(e, ast.Attribute):
            names.add(e.attr)
    return names


@rule("silent-except")
def check_silent_except(project: Project) -> List[Finding]:
    findings = []
    for ctx in project.files:
        if ctx.tree is None or not _silent_except_scope(ctx.rel):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                findings.append(Finding(
                    "silent-except", ctx.rel, node.lineno,
                    "bare 'except:' — catches KeyboardInterrupt/SystemExit "
                    "and hides the fault from the serving ladder; catch a "
                    "typed exception and record it as a fault"))
                continue
            broad = _exc_names(node.type) & _BROAD_EXC
            if broad and _is_silent_body(node.body):
                findings.append(Finding(
                    "silent-except", ctx.rel, node.lineno,
                    f"'except {sorted(broad)[0]}' with a pass-only body "
                    "swallows faults silently — record the fault "
                    "(Allocation.faults / stats counters) or re-raise"))
    return findings
