"""xlstm-350m [ssm]: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (d_ff=0: xLSTM blocks carry their own projections,
no separate FFN).  [arXiv:2405.04517; unverified]

Pattern: (mLSTM x5, sLSTM) x4 = 24 layers (xLSTM interleaves a minority of
sLSTM blocks; sLSTM is sequential — see DESIGN.md).
"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment


def config() -> ArchCfg:
    m = BlockCfg(mixer="mlstm", ffn="none")
    s = BlockCfg(mixer="slstm", ffn="none")
    return ArchCfg(
        name="xlstm-350m",
        d_model=1024, n_heads=4, n_kv=4, head_dim=256,
        d_ff=0, vocab=50304,
        segments=(Segment(period=(m,) * 5 + (s,), n_periods=4),),
        act="silu", tied_embeddings=True,
        family="ssm",
        supports_long=True,    # O(d^2) recurrent state, no KV cache
    )


def reduced_config() -> ArchCfg:
    m = BlockCfg(mixer="mlstm", ffn="none")
    s = BlockCfg(mixer="slstm", ffn="none")
    return ArchCfg(
        name="xlstm-350m-reduced",
        d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=0, vocab=256,
        segments=(Segment(period=(m, m, s), n_periods=2),),
        act="silu", tied_embeddings=True, family="ssm", supports_long=True,
    )
