"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""

from repro.models.transformer import ArchCfg, BlockCfg, MoECfg, Segment

SWA_WINDOW = 4096


def config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="moe", window=SWA_WINDOW)
    return ArchCfg(
        name="mixtral-8x22b",
        d_model=6144, n_heads=48, n_kv=8, head_dim=128,
        d_ff=16384, vocab=32768,
        segments=(Segment(period=(block,), n_periods=56),),
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=16384),
        rope_theta=1_000_000.0, act="silu", tied_embeddings=False,
        family="moe",
        supports_long=True,    # SWA bounds the KV cache
    )


def reduced_config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="moe", window=32)
    return ArchCfg(
        name="mixtral-8x22b-reduced",
        d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256,
        segments=(Segment(period=(block,), n_periods=2),),
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=128,
                   capacity_factor=4.0),
        act="silu", tied_embeddings=False, family="moe", supports_long=True,
    )
