"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 — encoder-decoder, multimodal.  The speech frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (per assignment).
[arXiv:2308.11596; hf]

Interpreted as 12 encoder + 12 decoder layers (m4t-medium text stack).
"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment


def config() -> ArchCfg:
    enc = BlockCfg(mixer="attn", ffn="dense", window=None)
    dec = BlockCfg(mixer="attn", ffn="dense", window=None, cross_attn=True)
    return ArchCfg(
        name="seamless-m4t-medium",
        d_model=1024, n_heads=16, n_kv=16, head_dim=64,
        d_ff=4096, vocab=256206,
        segments=(Segment(period=(dec,), n_periods=12),),
        enc_segments=(Segment(period=(enc,), n_periods=12),),
        rope_theta=10_000.0, act="silu", tied_embeddings=True,
        frontend="audio",
        family="audio",
        supports_long=False,   # full self+cross attention decoder
    )


def reduced_config() -> ArchCfg:
    enc = BlockCfg(mixer="attn", ffn="dense", window=None)
    dec = BlockCfg(mixer="attn", ffn="dense", window=None, cross_attn=True)
    return ArchCfg(
        name="seamless-m4t-medium-reduced",
        d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=512,
        segments=(Segment(period=(dec,), n_periods=2),),
        enc_segments=(Segment(period=(enc,), n_periods=2),),
        act="silu", tied_embeddings=True, frontend="audio",
        family="audio", supports_long=False,
    )
