"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — alternating local/global attention, logit softcap.
[arXiv:2408.00118; hf]"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment

LOCAL_WINDOW = 4096
ATTN_SOFTCAP = 50.0


def _segments(local_window):
    loc = BlockCfg(mixer="attn", ffn="dense", window=local_window)
    glob = BlockCfg(mixer="attn", ffn="dense", window=None)
    return (Segment(period=(loc, glob), n_periods=23),)


def config() -> ArchCfg:
    return ArchCfg(
        name="gemma2-27b",
        d_model=4608, n_heads=32, n_kv=16, head_dim=144,
        d_ff=36864, vocab=256000,
        segments=_segments(LOCAL_WINDOW),
        softcap=ATTN_SOFTCAP,
        rope_theta=10_000.0, act="gelu", tied_embeddings=True,
        family="dense",
        supports_long=False,   # half the layers are full-attention globals
    )


def reduced_config() -> ArchCfg:
    loc = BlockCfg(mixer="attn", ffn="dense", window=16)
    glob = BlockCfg(mixer="attn", ffn="dense", window=None)
    return ArchCfg(
        name="gemma2-27b-reduced",
        d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=192, vocab=512,
        segments=(Segment(period=(loc, glob), n_periods=2),),
        softcap=ATTN_SOFTCAP, act="gelu", tied_embeddings=True,
        family="dense", supports_long=False,
    )
