"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.models.transformer import ArchCfg, BlockCfg, MoECfg, Segment


def config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="moe", window=None)
    return ArchCfg(
        name="qwen2-moe-a2.7b",
        d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=151936,
        segments=(Segment(period=(block,), n_periods=24),),
        moe=MoECfg(n_experts=60, top_k=4, d_ff_expert=1408,
                   n_shared=4, d_ff_shared=5632),
        rope_theta=1_000_000.0, act="silu", tied_embeddings=True,
        family="moe",
        supports_long=False,   # full attention
    )


def reduced_config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="moe", window=None)
    return ArchCfg(
        name="qwen2-moe-a2.7b-reduced",
        d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=64, vocab=512,
        segments=(Segment(period=(block,), n_periods=2),),
        moe=MoECfg(n_experts=8, top_k=4, d_ff_expert=64,
                   n_shared=2, d_ff_shared=128, capacity_factor=4.0),
        act="silu", tied_embeddings=True, family="moe", supports_long=False,
    )
