"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k-vocab.  [arXiv:2407.21783; unverified]"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment


def config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=None)
    return ArchCfg(
        name="llama3-8b",
        d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=14336, vocab=128256,
        segments=(Segment(period=(block,), n_periods=32),),
        rope_theta=500_000.0, act="silu", tied_embeddings=False,
        family="dense",
        supports_long=False,   # pure full attention
    )


def reduced_config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=None)
    return ArchCfg(
        name="llama3-8b-reduced",
        d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=160, vocab=512,
        segments=(Segment(period=(block,), n_periods=2),),
        act="silu", tied_embeddings=False, family="dense", supports_long=False,
    )
