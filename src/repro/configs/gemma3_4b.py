"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

34 layers = 5 periods of (5 local + 1 global) + 4 trailing local layers.
"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment

LOCAL_WINDOW = 1024


def _segments(local_window, n_full_periods, n_tail):
    loc = BlockCfg(mixer="attn", ffn="dense", window=local_window)
    glob = BlockCfg(mixer="attn", ffn="dense", window=None)
    segs = (Segment(period=(loc,) * 5 + (glob,), n_periods=n_full_periods),)
    if n_tail:
        segs += (Segment(period=(loc,) * n_tail, n_periods=1),)
    return segs


def config() -> ArchCfg:
    return ArchCfg(
        name="gemma3-4b",
        d_model=2560, n_heads=8, n_kv=4, head_dim=320,
        d_ff=10240, vocab=262144,
        segments=_segments(LOCAL_WINDOW, 5, 4),
        rope_theta=1_000_000.0, act="gelu", tied_embeddings=True,
        family="dense",
        # 5:1 local:global — globals decode O(S) per step with seq-sharded
        # KV; locals hold 1k ring buffers.  Runnable at 500k (DESIGN.md §5).
        supports_long=True,
    )


def reduced_config() -> ArchCfg:
    return ArchCfg(
        name="gemma3-4b-reduced",
        d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=512,
        segments=_segments(16, 1, 2),
        act="gelu", tied_embeddings=True, family="dense", supports_long=True,
    )
