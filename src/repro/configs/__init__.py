"""Assigned architecture registry: ``get_config(arch_id)`` returns the
full-size ArchCfg; ``get_reduced(arch_id)`` a smoke-test-sized config of the
same family (same block pattern, tiny dims)."""

from importlib import import_module

ARCH_IDS = [
    "h2o_danube3_4b",
    "gemma3_4b",
    "gemma2_27b",
    "llama3_8b",
    "mixtral_8x22b",
    "qwen2_moe_a2_7b",
    "zamba2_2_7b",
    "seamless_m4t_medium",
    "chameleon_34b",
    "xlstm_350m",
]

# external ids (with dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "zamba2-2.7b": "zamba2_2_7b",
})


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id)
    return import_module(f"repro.configs.{name}")


def get_config(arch_id: str):
    return _module(arch_id).config()


def get_reduced(arch_id: str):
    return _module(arch_id).reduced_config()
