"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment

SWA_WINDOW = 4096


def config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=SWA_WINDOW)
    return ArchCfg(
        name="h2o-danube-3-4b",
        d_model=3840, n_heads=32, n_kv=8, head_dim=120,
        d_ff=10240, vocab=32000,
        segments=(Segment(period=(block,), n_periods=24),),
        rope_theta=10_000.0, act="silu", tied_embeddings=True,
        family="dense",
        supports_long=True,            # SWA bounds the KV cache
    )


def reduced_config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=32)
    return ArchCfg(
        name="h2o-danube-3-4b-reduced",
        d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256,
        segments=(Segment(period=(block,), n_periods=2),),
        act="silu", tied_embeddings=True, family="dense", supports_long=True,
    )
