"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion: images are discrete VQ tokens inside the
vocabulary, so the "frontend" is the shared token embedding itself
(input_specs supplies mixed text+VQ token ids).  [arXiv:2405.09818;
unverified]"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment


def config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=None)
    return ArchCfg(
        name="chameleon-34b",
        d_model=8192, n_heads=64, n_kv=8, head_dim=128,
        d_ff=22016, vocab=65536,
        segments=(Segment(period=(block,), n_periods=48),),
        rope_theta=10_000.0, act="silu", tied_embeddings=False,
        family="vlm",
        supports_long=False,   # pure full attention
    )


def reduced_config() -> ArchCfg:
    block = BlockCfg(mixer="attn", ffn="dense", window=None)
    return ArchCfg(
        name="chameleon-34b-reduced",
        d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=160, vocab=512,
        segments=(Segment(period=(block,), n_periods=2),),
        act="silu", tied_embeddings=False, family="vlm", supports_long=False,
    )
