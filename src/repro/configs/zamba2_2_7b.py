"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention blocks
(one set of attention+MLP weights applied every 6 mamba layers).
[arXiv:2411.15242; hf]"""

from repro.models.transformer import ArchCfg, BlockCfg, Segment


def _segments(n_periods):
    mamba = BlockCfg(mixer="mamba2", ffn="none")
    shared = BlockCfg(mixer="shared_attn", ffn="dense")
    return (Segment(period=(mamba,) * 6 + (shared,), n_periods=n_periods),)


def config() -> ArchCfg:
    return ArchCfg(
        name="zamba2-2.7b",
        d_model=2560, n_heads=32, n_kv=32, head_dim=80,
        d_ff=10240, vocab=32000,
        segments=_segments(9),          # 54 mamba + 9 shared-attn applications
        ssm_state=64, ssm_expand=2, ssm_head_dim=64,
        rope_theta=10_000.0, act="gelu", tied_embeddings=True,
        family="hybrid",
        supports_long=True,             # O(1) SSM state dominates
    )


def reduced_config() -> ArchCfg:
    mamba = BlockCfg(mixer="mamba2", ffn="none")
    shared = BlockCfg(mixer="shared_attn", ffn="dense")
    return ArchCfg(
        name="zamba2-2.7b-reduced",
        d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=256,
        segments=(Segment(period=(mamba, mamba, shared), n_periods=2),),
        ssm_state=16, ssm_expand=2, ssm_head_dim=16,
        act="gelu", tied_embeddings=True, family="hybrid", supports_long=True,
    )
