"""ShapeDtypeStruct stand-ins for every (arch x input-shape) dry-run cell.

No device memory is ever allocated here: params, optimizer state, caches
and batches are all ``jax.eval_shape`` products, which is what lets the
40-cell x 2-mesh matrix lower full-size 22B-140B configs on a CPU host.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tf
from ..train import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# encoder memory length for enc-dec archs (speech frames, precomputed
# embeddings per the frontend-stub assignment)
ENC_MEMORY_LEN = 4_096


def microbatches_for(cell: ShapeCell, n_dp: int) -> int:
    """Grad-accumulation depth: keep per-device micro batch ~1 sequence at
    4k, so activation carries stay bounded (see DESIGN.md §6)."""
    if cell.kind != "train":
        return 1
    per_dev = max(cell.global_batch // n_dp, 1)
    return min(per_dev, 8)


def params_shape(cfg: tf.ArchCfg):
    return jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def opt_shape(p_shape):
    return jax.eval_shape(opt_mod.init_state, p_shape)


def cache_shape(cfg: tf.ArchCfg, batch: int, seq: int):
    return jax.eval_shape(lambda: tf.init_cache(cfg, batch, seq))


def batch_specs(cfg: tf.ArchCfg, cell: ShapeCell) -> dict:
    """Training/prefill batch ShapeDtypeStructs."""
    B, S = cell.global_batch, cell.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.enc_segments:
        out["enc_embeddings"] = jax.ShapeDtypeStruct(
            (B, ENC_MEMORY_LEN, cfg.d_model), jnp.float32)
    return out


def decode_specs(cfg: tf.ArchCfg, cell: ShapeCell):
    """(token, cache, memory?) ShapeDtypeStructs for serve_step."""
    B, S = cell.global_batch, cell.seq_len
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache = cache_shape(cfg, B, S)
    memory = None
    if cfg.enc_segments:
        memory = jax.ShapeDtypeStruct((B, ENC_MEMORY_LEN, cfg.d_model),
                                      jnp.bfloat16)
    return token, cache, memory


def cell_is_runnable(cfg: tf.ArchCfg, cell: ShapeCell) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §5)."""
    if cell.name == "long_500k" and not cfg.supports_long:
        return False, ("full-attention arch: 500k-token KV decode is "
                       "quadratic-prefill / unbounded-KV — skipped per "
                       "DESIGN.md §5")
    return True, ""
