"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; everything else
sees the real device count).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (CI / examples / tests)."""
    n = len(jax.devices())
    model = max(1, min(model_parallel, n))
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
