"""GSPMD sharding rules for params, optimizer state, activations, caches.

Policy (single-pod mesh ("data", "model"); multi-pod prepends "pod"):

  * batch dims           -> all data-parallel axes ("pod", "data")
  * attention heads      -> "model" when head count divides the axis,
    else head_dim when IT divides, else replicated (e.g. danube's kv=8,
    head_dim=120 KV projections — 14 MB, cheap to replicate)
  * ffn hidden / experts' ffn hidden / vocab  -> "model"
  * mamba/xlstm inner dims -> "model"
  * norms, routers, gates  -> replicated
  * KV caches: batch -> data axes; heads/head_dim -> "model" by the same
    divisibility rule.  long_500k (batch=1): cache SEQUENCE -> "data"
    (sequence-parallel decode).

Rules are keyed on the leaf's path name and apply to its TRAILING dims, so
the same rule covers scan-stacked leaves (leading [n_periods] axis) and
unstacked ones (shared blocks, embeddings).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """All pure data-parallel axes present in the mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axis: str = "model") -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def _leaf_rule(name: str, shape: tuple, mesh: Mesh) -> P:
    """Partial spec for the SEMANTIC (trailing) dims of a leaf."""
    m = "model"

    def pick(*cands):
        """cands: (dim_index_from_end, ndim) pairs — first divisible wins."""
        ndim = len(shape)
        spec = [None] * ndim
        for di in cands:
            if _div(shape[di], mesh):
                spec[di] = m
                return P(*spec)
        return P(*spec)

    if name == "table":                       # embedding [V, D]
        return pick(-2, -1)
    if name in ("wq",):                       # [D, H, hd]
        return pick(-2, -1)
    if name in ("wk", "wv"):                  # [D, Kv, hd]
        # Kv heads when divisible; otherwise REPLICATE (few MB) — sharding
        # head_dim here would force a psum over [B,H,S,T] score tensors in
        # training, far costlier than replicating the projection
        return pick(-2)
    if name == "wo":                          # [H, hd, D]
        return pick(-3, -2)
    if name in ("w_gate", "w_up"):            # [.., D, F] (dense or expert)
        return pick(-1)
    if name == "w_down":                      # [.., F, D]
        return pick(-2)
    if name in ("w_z", "w_x"):                # mamba [D, d_inner]
        return pick(-1)
    if name == "conv_w":                      # [W, d_inner]
        return pick(-1)
    if name == "w_out":                       # [d_inner|D, D]
        return pick(-2)
    if name == "w_in":                        # slstm [D, H, 4hd]
        return pick(-1)
    if name == "r":                           # slstm [H, hd, 4hd]
        return pick(-1)
    if name == "wo_gate":                     # mlstm [D, D]
        return pick(-1)
    if name == "w" and len(shape) == 2:       # dense (unembed/frontend) [D, V]
        return pick(-1)
    # norms, routers, scalars, gates, a_log, dt_bias, ...
    return P(*([None] * len(shape)))


def param_specs(params, mesh: Mesh):
    """PartitionSpec tree matching ``params``' structure."""
    def spec_of(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        base = _leaf_rule(name or "", leaf.shape, mesh)
        # left-pad for scan-stacked leading axes
        pad = leaf.ndim - len(base)
        return P(*([None] * pad + list(base)))

    return jax.tree_util.tree_map_with_path(spec_of, params)


def param_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh))


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh) -> P:
    """[B, S] token batches."""
    return P(dp_axes(mesh), None)


def activation_spec(mesh: Mesh) -> P:
    """[B, S, D] hidden states."""
    return P(dp_axes(mesh), None, None)


def kv_cache_specs(cache, mesh: Mesh, batch: int, shard_seq: bool = False,
                   seq_on_model: bool = False):
    """Specs for a decode cache pytree (see transformer.init_cache).

    shard_seq=True is the long-context mode: batch is tiny (1), so the
    cache SEQUENCE dim carries the data axes instead (sequence-parallel
    attention over the cache).

    seq_on_model=True (§Perf, flash-decode layout): batch stays on the
    data axes and the cache SEQUENCE shards over `model` — attention over
    the cache then reduces to per-shard partial softmax + tiny psums,
    instead of resharding/gathering the cache to match head layouts.
    """
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    batch_ok = batch % max(n_dp, 1) == 0 and not shard_seq

    def spec_of(path, leaf):
        names = [str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
        if "pos" in names:
            return P()
        ndim = leaf.ndim
        # KVCache leaves: [n_periods, B, L, Kv, hd].  KVCache is a
        # NamedTuple, so its fields appear as SequenceKey entries (not
        # DictKey); SSM/xLSTM states are dicts and end in a DictKey.
        is_kv = (ndim == 5 and path and
                 isinstance(path[-1], jax.tree_util.SequenceKey))
        if is_kv:
            b = dp if batch_ok else None
            if seq_on_model and _div(leaf.shape[2], mesh):
                return P(None, b, "model", None, None)
            s = dp if (shard_seq and leaf.shape[2] % max(n_dp, 1) == 0) else None
            kv_dim, hd_dim = None, None
            if _div(leaf.shape[3], mesh):
                kv_dim = "model"
            elif _div(leaf.shape[4], mesh):
                hd_dim = "model"
            return P(None, b, s, kv_dim, hd_dim)
        # SSM / xLSTM states: [n_periods, B, ...] — shard batch + widest
        # trailing dim divisible by model
        spec = [None] * ndim
        if ndim >= 2 and batch_ok:
            spec[1] = dp
        for di in range(ndim - 1, 1, -1):
            if _div(leaf.shape[di], mesh):
                spec[di] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def opt_state_specs(param_spec_tree):
    """Adam m/v mirror the param specs; scalars replicated."""
    return param_spec_tree
