import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) cell lowers,
SPMD-partitions, and compiles on the production meshes, and extract the
roofline inputs (FLOPs, bytes, collective bytes, per-device memory) from
the compiled artifact.

MUST be run as its own process (the XLA_FLAGS line above has to execute
before any other jax-touching import — which is why it is the very first
statement of the module).

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--mesh single|multi|both]
    python -m repro.launch.dryrun --all --shard 0/4     # split across procs

Outputs one JSON per cell under experiments/dryrun/<mesh>/.
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models import transformer as tf
from ..train import optimizer as opt_mod
from ..train.train_step import TrainConfig, make_train_step
from ..serve.engine import ServeConfig, make_serve_step
from ..launch import shardings as sh
from ..launch import specs as sp
from ..launch.mesh import make_production_mesh, mesh_chip_count
from jax.sharding import NamedSharding, PartitionSpec as P

OUT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "dryrun")

from .hlo_stats import (COLLECTIVE_OPS, _INSTR_RE, _SHAPE_RE,
                        _shape_bytes, active_param_counts,
                        collective_bytes)


def _probe_cfg(cfg, seg_periods, moe_cf=None):
    """Config clone with per-segment period counts replaced (and optionally
    a different MoE capacity factor — §Perf experiments)."""
    import dataclasses as dc
    segs = tuple(dc.replace(s, n_periods=n)
                 for s, n in zip(cfg.segments, seg_periods))
    moe = cfg.moe
    if moe_cf is not None and moe is not None:
        moe = dc.replace(moe, capacity_factor=float(moe_cf))
    return dc.replace(cfg, segments=segs, enc_segments=cfg.enc_segments,
                      moe=moe)


def _lower_probe(cfg, cell, mesh, n_dp, flags=None):
    """Lower ONE probe (no scan-over-micro; depth from cfg) and return
    (flops, bytes, collective_bytes) per device from the compiled artifact.
    ``flags``: extra TrainConfig/ServeConfig fields (perf experiments)."""
    flags = flags or {}
    p_shape = sp.params_shape(cfg)
    p_specs = sh.param_specs(p_shape, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)

    with mesh:
        if cell.kind == "train":
            n_micro = sp.microbatches_for(cell, n_dp)
            micro_b = max(cell.global_batch // n_micro, n_dp)
            mcell = sp.ShapeCell(cell.name, cell.seq_len, micro_b, "train")
            tcfg = TrainConfig(n_microbatches=1, unroll_segments=True,
                               **{k: v for k, v in flags.items()
                                  if k in ("sp_residual", "bf16_barrier",
                                           "gather_once")})
            o_shape = sp.opt_shape(p_shape)
            o_shard = opt_mod.AdamWState(
                step=NamedSharding(mesh, P()),
                m=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs),
                v=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs))
            b_shape = sp.batch_specs(cfg, mcell)
            b_shard = jax.tree.map(
                lambda a: NamedSharding(
                    mesh, sh.batch_spec(mesh) if a.ndim == 2
                    else P(sh.dp_axes(mesh), *([None] * (a.ndim - 1)))),
                b_shape)
            step = make_train_step(cfg, tcfg, mesh)
            lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                              donate_argnums=(0, 1)).lower(
                                  p_shape, o_shape, b_shape)
        elif cell.kind == "prefill":
            def fwd(params, batch):
                return tf.forward_train(params, cfg, batch["tokens"],
                                        enc_embeddings=batch.get(
                                            "enc_embeddings"),
                                        remat=False, unroll=True)
            b_shape = sp.batch_specs(cfg, cell)
            b_shape.pop("labels")
            b_shard = jax.tree.map(
                lambda a: NamedSharding(
                    mesh, sh.batch_spec(mesh) if a.ndim == 2
                    else P(sh.dp_axes(mesh), *([None] * (a.ndim - 1)))),
                b_shape)
            lowered = jax.jit(fwd, in_shardings=(p_shard, b_shard)).lower(
                p_shape, b_shape)
        else:
            token, cache, memory = sp.decode_specs(cfg, cell)
            scfg = ServeConfig(batch=cell.global_batch, max_seq=cell.seq_len,
                               shard_cache_seq=flags.get(
                                   "shard_cache_seq",
                                   cell.name == "long_500k"),
                               unroll_segments=True,
                               cache_seq_on_model=flags.get(
                                   "cache_seq_on_model", False))
            c_specs = sh.kv_cache_specs(cache, mesh, scfg.batch,
                                        shard_seq=scfg.shard_cache_seq,
                                        seq_on_model=flags.get(
                                            "cache_seq_on_model", False))
            c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
            dp = sh.dp_axes(mesh)
            b_ok = scfg.batch % max(n_dp, 1) == 0
            t_shard = NamedSharding(mesh,
                                    P(dp, None) if b_ok else P(None, None))
            step = make_serve_step(cfg, scfg, mesh)
            in_sh = [p_shard, c_shard, t_shard]
            args = [p_shape, cache, token]
            if memory is not None:
                in_sh.append(NamedSharding(
                    mesh, P(dp, None, None) if b_ok else P(None, None, None)))
                args.append(memory)
            lowered = jax.jit(step, in_shardings=tuple(in_sh),
                              donate_argnums=(1,)).lower(*args)

        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total"]))


def probe_costs(cfg, cell, mesh, n_dp, flags=None) -> dict:
    """Scan-aware per-device cost reconstruction.

    XLA's HloCostAnalysis counts while-loop bodies ONCE (verified
    empirically — flops are flat in trip count), so scanned-layer and
    grad-accumulation costs must be reconstructed:

        total = n_micro * (base + sum_s delta_s * (n_periods_s - 1))

    where base = probe with every segment at 1 period (at micro batch),
    and delta_s = probe with segment s at 2 periods, minus base.
    The optimizer update is over-counted (n_micro-1) extra times —
    O(20 flops/param), noise at these scales.
    """
    ones = [1] * len(cfg.segments)
    moe_cf = (flags or {}).get("moe_cf")
    base = _lower_probe(_probe_cfg(cfg, ones, moe_cf), cell, mesh, n_dp, flags)
    totals = list(base)
    for si, seg in enumerate(cfg.segments):
        if seg.n_periods == 1:
            continue
        two = list(ones)
        two[si] = 2
        probe = _lower_probe(_probe_cfg(cfg, two, moe_cf), cell, mesh, n_dp,
                             flags)
        for j in range(3):
            totals[j] += (probe[j] - base[j]) * (seg.n_periods - 1)
    n_micro = sp.microbatches_for(cell, n_dp) if cell.kind == "train" else 1
    return {
        "flops_per_device": totals[0] * n_micro,
        "bytes_per_device": totals[1] * n_micro,
        "collective_bytes_per_device": totals[2] * n_micro,
        "n_micro": n_micro,
        "probe_base": base,
    }


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch_id)
    cell = sp.SHAPES[shape_name]
    ok, reason = sp.cell_is_runnable(cfg, cell)
    if not ok:
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in sh.dp_axes(mesh)]))
    t0 = time.perf_counter()

    with mesh:
        p_shape = sp.params_shape(cfg)
        p_specs = sh.param_specs(p_shape, mesh)
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)

        if cell.kind == "train":
            n_micro = sp.microbatches_for(cell, n_dp)
            tcfg = TrainConfig(n_microbatches=n_micro)
            o_shape = sp.opt_shape(p_shape)
            o_shard = opt_mod.AdamWState(
                step=NamedSharding(mesh, P()),
                m=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs),
                v=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs))
            b_shape = sp.batch_specs(cfg, cell)
            b_shard = jax.tree.map(
                lambda a: NamedSharding(
                    mesh, sh.batch_spec(mesh) if a.ndim == 2
                    else P(sh.dp_axes(mesh), *([None] * (a.ndim - 1)))),
                b_shape)
            step = make_train_step(cfg, tcfg, mesh)
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(p_shape, o_shape, b_shape)
        elif cell.kind == "prefill":
            from ..train.train_step import make_loss_fn
            tcfg = TrainConfig(n_microbatches=1, remat=False)

            def fwd(params, batch):
                return tf.forward_train(params, cfg, batch["tokens"],
                                        enc_embeddings=batch.get(
                                            "enc_embeddings"),
                                        remat=False)
            b_shape = sp.batch_specs(cfg, cell)
            b_shape.pop("labels")
            b_shard = jax.tree.map(
                lambda a: NamedSharding(
                    mesh, sh.batch_spec(mesh) if a.ndim == 2
                    else P(sh.dp_axes(mesh), *([None] * (a.ndim - 1)))),
                b_shape)
            jitted = jax.jit(fwd, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(p_shape, b_shape)
        else:                                     # decode
            token, cache, memory = sp.decode_specs(cfg, cell)
            scfg = ServeConfig(batch=cell.global_batch, max_seq=cell.seq_len,
                               shard_cache_seq=(cell.name == "long_500k"))
            c_specs = sh.kv_cache_specs(cache, mesh, scfg.batch,
                                        shard_seq=scfg.shard_cache_seq)
            c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
            dp = sh.dp_axes(mesh)
            b_ok = scfg.batch % max(n_dp, 1) == 0
            t_shard = NamedSharding(mesh, P(dp, None) if b_ok else P(None, None))
            step = make_serve_step(cfg, scfg, mesh)
            in_sh = [p_shard, c_shard, t_shard]
            args = [p_shape, cache, token]
            if memory is not None:
                in_sh.append(NamedSharding(
                    mesh, P(dp, None, None) if b_ok else P(None, None, None)))
                args.append(memory)
            jitted = jax.jit(step, in_shardings=tuple(in_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(*args)

        t_lower = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1

        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)

    counts = active_param_counts(cfg)
    non_embed = counts["active"] - counts["embed"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 6.0 * non_embed * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        model_flops = 2.0 * non_embed * tokens
    else:
        model_flops = 2.0 * non_embed * cell.global_batch

    # scan-aware roofline inputs (single-pod only — §Roofline is per-pod)
    probes = None
    if not multi_pod:
        probes = probe_costs(cfg, cell, mesh, n_dp)

    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "chips": chips,
        "n_dp": n_dp,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
        "params_total": counts["total"],
        "params_active": counts["active"],
        "params_embed": counts["embed"],
        "model_flops": model_flops,
        "hlo_bytes": len(hlo),
        "probes": probes,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                result[f"mem_{attr}"] = int(v)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--shard", default=None, help="i/n split of the cell list")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(sp.SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))
    if args.shard:
        i, n = map(int, args.shard.split("/"))
        cells = cells[i::n]

    failures = 0
    for a, s, m in cells:
        mesh_name = "multi" if m else "single"
        out_dir = os.path.join(OUT_ROOT, mesh_name)
        os.makedirs(out_dir, exist_ok=True)
        out_path = os.path.join(out_dir, f"{a}__{s}.json")
        if os.path.exists(out_path):
            print(f"[skip-cached] {a} {s} {mesh_name}")
            continue
        print(f"[lower+compile] {a} {s} {mesh_name} ...", flush=True)
        try:
            res = lower_cell(a, s, m)
        except Exception as e:                               # noqa: BLE001
            res = {"arch": a, "shape": s, "mesh": mesh_name,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"   -> {res['status']}"
              + (f" compile={res.get('compile_s')}s flops={res.get('flops'):.3g}"
                 if res["status"] == "ok" else
                 f" ({res.get('reason', res.get('error', ''))[:120]})"),
              flush=True)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
