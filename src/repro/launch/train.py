"""Training driver: config-driven, mesh-aware, fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b \
        --steps 100 [--reduced] [--ckpt-dir DIR]

On this CPU container only ``--reduced`` is practical; on a TPU pod the
same driver runs the full config with the production mesh.  Per-arch
performance policies (EXPERIMENTS.md §Perf) are applied automatically:
sequence-parallel residual only for archs whose head count is below the
model-axis width (e.g. gemma3), where it repairs the TP pathology.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import get_config, get_reduced
from ..data import TokenPipeline
from ..models import init_params
from ..train import optimizer as opt_mod
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_host_mesh


def perf_policy(cfg, mesh) -> dict:
    """§Perf per-arch flags: SP residual pays off exactly when attention
    cannot use the full model axis (heads < axis) — measured in
    EXPERIMENTS.md §Perf (gemma3: -57% collective; llama3: 3x WORSE)."""
    if mesh is None or "model" not in mesh.axis_names:
        return {}
    return {"sp_residual": cfg.n_heads < mesh.shape["model"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    a = ap.parse_args()

    cfg = get_reduced(a.arch) if a.reduced else get_config(a.arch)
    mesh = make_host_mesh(model_parallel=1) if len(jax.devices()) > 1 else None
    tcfg = TrainConfig(
        n_microbatches=a.microbatches,
        adamw=opt_mod.AdamWConfig(peak_lr=3e-3, warmup_steps=10,
                                  total_steps=a.steps),
        **perf_policy(cfg, mesh))

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_mod.init_state(params)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n/1e6:.1f}M params, {a.steps} steps")

    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=a.batch, seq=a.seq, seed=0,
                         enc_seq=64 if cfg.enc_segments else 0,
                         d_model=cfg.d_model)
    ck = Checkpointer(a.ckpt_dir) if a.ckpt_dir else None
    start = 0
    if ck and ck.latest() is not None:
        restored, extras = ck.restore(ck.latest(),
                                      {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        pipe.restore(extras["pipeline"])
        start = extras["step"]
        print(f"resumed from step {start}")

    it = iter(pipe)
    for s in range(start, a.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        t0 = time.perf_counter()
        # one-shot driver: jitted once, reused  # popcheck: disable=retrace-hazard
        params, opt, m = step_fn(params, opt, batch)
        if ck and s and s % a.ckpt_every == 0:
            ck.save_async(s, {"params": params, "opt": opt},
                          extras={"pipeline": pipe.state(), "step": s})
        if s % 10 == 0:
            print(f"step {s:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"({time.perf_counter()-t0:.2f}s)")
    if ck:
        ck.wait()
    print(f"done: final loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
