import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb harness: measure roofline terms for (arch, shape) under
named optimization flags, printing before/after-comparable lines.

    python -m repro.launch.perf --arch gemma3_4b --shape train_4k \
        --flags sp_residual,bf16_barrier
"""

import argparse
import json

import numpy as np

from ..configs import get_config
from ..launch import specs as sp
from ..launch import shardings as sh
from ..launch.dryrun import probe_costs
from ..launch.mesh import make_production_mesh
from ..launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, fmt_s


def measure(arch: str, shape: str, flags: dict, mesh_shape=None) -> dict:
    import jax
    cfg = get_config(arch)
    cell = sp.SHAPES[shape]
    if mesh_shape is not None:
        mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=False)
    n_dp = int(np.prod([mesh.shape[a] for a in sh.dp_axes(mesh)]))
    p = probe_costs(cfg, cell, mesh, n_dp, flags=flags)
    out = {
        "arch": arch, "shape": shape, "flags": flags,
        "flops_per_device": p["flops_per_device"],
        "bytes_per_device": p["bytes_per_device"],
        "collective_bytes_per_device": p["collective_bytes_per_device"],
        "compute_s": p["flops_per_device"] / PEAK_FLOPS,
        "memory_s": p["bytes_per_device"] / HBM_BW,
        "collective_s": p["collective_bytes_per_device"] / ICI_BW,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--flags", default="")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 32x8 (default: production 16x16)")
    args = ap.parse_args()
    flags = {}
    for f in args.flags.split(","):
        if not f:
            continue
        if "=" in f:
            k, v = f.split("=")
            try:
                flags[k] = float(v)
            except ValueError:
                flags[k] = v in ("1", "true", "True")
        else:
            flags[f] = True
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)
    r = measure(args.arch, args.shape, flags, mesh_shape=mesh_shape)
    tag = args.tag or (",".join(sorted(flags)) or "baseline")
    print(f"[perf] {args.arch}/{args.shape} [{tag}] "
          f"compute={fmt_s(r['compute_s'])} memory={fmt_s(r['memory_s'])} "
          f"collective={fmt_s(r['collective_s'])} "
          f"(coll_bytes={r['collective_bytes_per_device']:.3e})")
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "perf")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"{args.arch}__{args.shape}__{tag}.json"),
              "w") as f:
        json.dump(r, f, indent=1)


if __name__ == "__main__":
    main()
