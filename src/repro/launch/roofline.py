"""Roofline analysis: aggregate the dry-run JSONs into the §Dry-run and
§Roofline tables of EXPERIMENTS.md.

Hardware model (TPU v5e-class, per chip):
    peak bf16            197 TFLOP/s
    HBM bandwidth        819 GB/s
    ICI per link         ~50 GB/s

Per (arch x shape) on the single-pod 256-chip mesh:

    compute term    = HLO_FLOPs_per_device / 197e12          [s]
    memory term     = HLO_bytes_per_device / 819e9           [s]
    collective term = collective_bytes_per_device / 50e9     [s]

(The prompt's global formulation — HLO_FLOPs / (chips * peak) — equals the
per-device form because SPMD distributes evenly; probes report per-device.)

Caveats recorded in EXPERIMENTS.md:
  * FLOPs/collective bytes come from scan-UNROLLED probe compiles
    (HloCostAnalysis counts while bodies once — measured, see dryrun.py).
  * memory bytes from the CPU-backend HLO over-count vs a TPU compile
    (elementwise chains that TPU fusion would collapse), so the memory
    term is an upper bound; an analytic floor (params+cache traffic) is
    reported alongside.
"""

from __future__ import annotations

import json
import os
from glob import glob

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

HERE = os.path.dirname(__file__)
DRYRUN_DIR = os.path.normpath(os.path.join(HERE, "..", "..", "..",
                                           "experiments", "dryrun"))

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str = "single"):
    cells = {}
    for path in glob(os.path.join(DRYRUN_DIR, mesh, "*.json")):
        with open(path) as f:
            d = json.load(f)
        cells[(d["arch"], d["shape"])] = d
    return cells


def analytic_bytes_floor(d: dict) -> float:
    """Per-device lower bound on HBM traffic for one step: every resident
    param read once per microbatch (+ grads/opt write ~2x for train), plus
    the KV/state cache read+write for decode."""
    chips = d.get("chips", 256)
    params_local = d["params_total"] * 4.0 / chips
    if d["shape"].startswith("train"):
        n_micro = (d.get("probes") or {}).get("n_micro", 1)
        return params_local * (n_micro + 3)
    cache = d.get("mem_argument_size_in_bytes", 0) - params_local
    return params_local + max(cache, 0) * 2.0 / 1.0


def roofline_row(d: dict) -> dict:
    p = d.get("probes") or {}
    fl = p.get("flops_per_device", 0.0)
    by = p.get("bytes_per_device", 0.0)
    co = p.get("collective_bytes_per_device", 0.0)
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW              # HLO upper bound (CPU backend, unfused)
    t_x = co / ICI_BW
    floor = analytic_bytes_floor(d)
    t_mf = floor / HBM_BW          # analytic floor (params+cache traffic)
    # bottleneck judged on (compute, collective, memory FLOOR): the HLO
    # byte count is an unfused upper bound that would call everything
    # memory-bound; the floor is what a fused TPU compile must still move
    dom = max(("compute", t_c), ("memory", t_mf), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    model = d.get("model_flops", 0.0)
    hlo_global = fl * d.get("chips", 256)
    useful = (model / hlo_global) if hlo_global else 0.0
    # roofline fraction = useful/actual on the DOMINANT term:
    #   compute-bound  -> MODEL_FLOPs / HLO_FLOPs   (remat/redundancy waste)
    #   memory-bound   -> floor_bytes / HLO_bytes   (fusion/layout waste)
    #   collective-bound -> what fraction of wire time is unavoidable
    #                       (approximated by memory-floor/collective: the
    #                       collectives POP/TP strictly need scale with it)
    if dom == "compute":
        frac = useful
    elif dom == "memory":
        frac = floor / by if by else 0.0
    else:
        frac = min(1.0, t_mf / t_x) if t_x else 0.0
    return {
        "arch": d["arch"], "shape": d["shape"],
        "compute_s": t_c, "memory_s": t_m, "memory_floor_s": t_mf,
        "collective_s": t_x,
        "bottleneck": dom,
        "model_flops": model,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_frac": frac,
    }


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(cells_single, cells_multi) -> str:
    lines = [
        "| arch | shape | single-pod (16x16) | multi-pod (2x16x16) | "
        "compile s/m | per-dev args (GB) | collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    archs = sorted({a for a, _ in cells_single})
    for a in archs:
        for s in SHAPE_ORDER:
            d1 = cells_single.get((a, s))
            d2 = cells_multi.get((a, s))
            if d1 is None and d2 is None:
                continue
            st1 = (d1 or {}).get("status", "-")
            st2 = (d2 or {}).get("status", "-")
            if st1 == "skipped":
                lines.append(f"| {a} | {s} | SKIP | SKIP | - | - | "
                             f"{(d1 or {}).get('reason', '')[:60]} |")
                continue
            comp = f"{(d1 or {}).get('compile_s', '-')}/" \
                   f"{(d2 or {}).get('compile_s', '-')}"
            arg = (d1 or {}).get("mem_argument_size_in_bytes", 0) / 2**30
            cnt = ((d1 or {}).get("collectives") or {}).get("count", "-")
            lines.append(f"| {a} | {s} | {st1} | {st2} | {comp} | "
                         f"{arg:.2f} | {cnt} |")
    return "\n".join(lines)


def roofline_table(cells_single):
    lines = [
        "| arch | shape | compute | mem(floor) | mem(HLO ub) | collective | "
        "bottleneck | MODEL TFLOPs | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({a for a, _ in cells_single})
    rows = []
    for a in archs:
        for s in SHAPE_ORDER:
            d = cells_single.get((a, s))
            if d is None or d.get("status") != "ok" or not d.get("probes"):
                continue
            r = roofline_row(d)
            rows.append(r)
            lines.append(
                f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_floor_s'])} | {fmt_s(r['memory_s'])} | "
                f"{fmt_s(r['collective_s'])} | "
                f"**{r['bottleneck']}** | {r['model_flops']/1e12:.1f} | "
                f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} |")
    return "\n".join(lines), rows


def main():
    single = load_cells("single")
    multi = load_cells("multi")
    n_ok_s = sum(1 for d in single.values() if d["status"] == "ok")
    n_ok_m = sum(1 for d in multi.values() if d["status"] == "ok")
    n_skip = sum(1 for d in single.values() if d["status"] == "skipped")
    n_err = sum(1 for d in list(single.values()) + list(multi.values())
                if d["status"] == "error")
    print(f"single-pod: {n_ok_s} ok, multi-pod: {n_ok_m} ok, "
          f"{n_skip} documented skips, {n_err} errors")
    print()
    print(dryrun_table(single, multi))
    print()
    tbl, rows = roofline_table(single)
    print(tbl)
    if rows:
        worst = min(rows, key=lambda r: r["roofline_frac"])
        coll = max(rows, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_frac']:.3f})")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']} "
              f"({fmt_s(coll['collective_s'])})")


if __name__ == "__main__":
    main()
