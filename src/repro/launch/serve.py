"""Serving driver: batched decode with per-arch cache-layout policy.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm_350m \
        --reduced --batch 8 --tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_reduced
from ..models import init_cache, init_params
from ..serve.engine import ServeConfig, make_serve_step


def cache_policy(cfg, seq: int) -> dict:
    """§Perf: flash-decode (cache sequence over `model`) pays off for
    full-attention archs with large caches (chameleon/llama3: -94%
    collective); SWA/SSM archs keep head/state layouts (gemma3 long:
    regression, measured)."""
    full_attn = any(b.window is None and b.mixer in ("attn", "shared_attn")
                    for s in cfg.segments for b in s.period)
    return {"cache_seq_on_model": full_attn and seq >= 16_384}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm_350m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--tokens", type=int, default=32)
    a = ap.parse_args()

    cfg = get_reduced(a.arch) if a.reduced else get_config(a.arch)
    scfg = ServeConfig(batch=a.batch, max_seq=a.max_seq,
                       **cache_policy(cfg, a.max_seq))
    params = init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_serve_step(cfg, scfg))
    cache = init_cache(cfg, a.batch, a.max_seq)
    tok = jnp.zeros((a.batch, 1), jnp.int32)

    t0 = time.perf_counter()
    for i in range(a.tokens):
        # one-shot driver: jitted once, reused  # popcheck: disable=retrace-hazard
        tok, cache = step(params, cache, tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {a.tokens} steps x batch {a.batch} "
          f"= {a.tokens*a.batch} tokens in {dt:.2f}s "
          f"({a.tokens*a.batch/dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
