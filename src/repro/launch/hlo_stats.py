"""HLO statistics + parameter accounting shared by the dry-run, the
roofline aggregator, and tests.  Import-safe: unlike ``launch.dryrun``,
importing this module does NOT set XLA_FLAGS."""

from __future__ import annotations

import re

import jax
import numpy as np

from ..launch import specs as sp

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

# one HLO instruction:  %name = <result-type> <opcode>(operands...), ...
# result-type is either `f32[2,4,8]{2,1,0}` or a tuple `(f32[...], f32[...])`
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(.*?\)|[\w\[\]{},\d]+)\s+([\w\-]+)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT-tensor bytes of every collective op, by op kind.

    Opcode is taken from the instruction's rhs (never the lhs variable
    name, which XLA often names after the op).  ``-start`` variants are
    counted; their ``-done`` halves are not (same tensor)."""
    out = {op: 0 for op in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        m = _INSTR_RE.match(ls)
        if not m:
            continue
        result_type, opcode = m.groups()
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in COLLECTIVE_OPS:
            out[base] += _shape_bytes(result_type)
            out["count"] += 1
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    return out


def active_param_counts(cfg) -> dict:
    """(total, active) param counts — MoE counts top_k of n_experts."""
    p_shape = sp.params_shape(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(p_shape)
    total = active = embed = 0
    for path, leaf in flat:
        names = [str(getattr(e, "key", "")) for e in path]
        n = int(np.prod(leaf.shape))
        total += n
        if "table" in names or "unembed" in names:
            embed += n
            active += n
            continue
        if any(x in names for x in ("w_gate", "w_up", "w_down")) and \
                leaf.ndim >= 3 and cfg.moe is not None and \
                leaf.shape[-3 if leaf.ndim == 3 else -3] == cfg.moe.n_experts:
            active += int(n * cfg.moe.top_k / cfg.moe.n_experts)
        else:
            active += n
    return {"total": total, "active": active, "embed": embed}
