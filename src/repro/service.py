"""PopService: the one public door to the paper's technique.

Every scenario — cluster scheduling, traffic engineering, load balancing,
MoE expert placement, anything registered in ``repro.domains`` — is solved
the same way:

    from repro.service import PopService
    from repro.core import SolveConfig, ExecConfig

    service = PopService()                        # long-lived, multi-tenant
    session = service.session("tenant-a", instance)   # domain inferred
    alloc = session.step(instance)                # -> Allocation
    ...
    alloc = session.step(updated_instance)        # warm-started re-solve

A :class:`PopService` is a long-lived object owning the config defaults,
the jit/plan caches (plans live on the per-tenant warm state; compiled
solvers are shared process-wide through ``core/backends.py``, keyed by the
hashable :class:`~repro.core.config.ExecConfig` contents), and the
per-tenant warm state.  A :class:`PopSession` is one tenant's stateful
view: ``step(instance)`` is the single online entry point — plan reuse,
incremental plan repair under churn (``core/plan.repair_plan``),
cross-plan warm-start remapping (``core/plan.remap_warm``), stable-id
threading and ``warm_fraction`` reporting all happen inside, so callers
stop hand-carrying ``POPResult``s between ticks.

Every step returns an :class:`Allocation` that reports the backend and
engine that ACTUALLY ran (``"auto"`` resolved — invisible to callers
before this layer existed) and how the plan cache behaved (``"hit"`` /
``"repair"`` / ``"miss"`` / ``"full"``); the service aggregates those into
:meth:`PopService.stats` for fleet dashboards and the session bench.

Serving is fault-tolerant (docs/ROBUSTNESS.md): ``step`` never returns a
non-finite allocation.  Diverged solver lanes (``POPResult.diverged``,
detected in-loop by ``pdhg.solve_stacked``) quarantine the poisoned warm
state and cold-restart only the affected lanes; ``step(deadline_s=...)``
budgets iterations from a measured per-iteration rate and degrades
through a ladder (full solve → capped/relaxed solve → best-effort chunk →
previous allocation / domain greedy); ``Allocation.status`` reports the
rung taken (``ok``/``degraded``/``recovered``/``fallback``).
:meth:`PopService.checkpoint` / :meth:`PopService.restore` serialize every
tenant's warm state to bytes (``repro.checkpoint.session_state``) for
rolling restarts — corrupt or stale blobs degrade to cold starts.

Serving at fleet scale (docs/SERVING.md): a service constructed with
``dispatch=`` runs every session's map-step launch through a
**micro-batching dispatcher** that coalesces concurrent tenants'
same-shape sub-problem stacks into ONE ``solve_stacked`` launch
(``core/backends.py:coalesce_key`` decides compatibility,
``pdhg.concat_stacks`` pads structured ELL widths across tenants), and
``max_resident=`` bounds how many tenants keep live warm state — cold
tenants page out to a host-memory blob store
(``repro.checkpoint.paged``) and restore transparently on ``session()``
re-entry.  ``PopSession.step_async`` is the concurrent entry point;
results are bit-identical per tenant to the synchronous path because
solver lanes are independent by construction.

Domains enter through the declarative registry (``repro.domains``) — the
legacy doors (``pop_solve``, ``GavelScheduler``, ``balance_requests``)
forward here and warn.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import json
import queue
import threading
import time
from collections import OrderedDict
from typing import Any, Dict, Optional, Union

import jax
import numpy as np

from .checkpoint import paged as paged_mod
from .checkpoint import session_state as ckpt_mod
from .core import backends as backends_mod
from .core import pop as pop_mod
from .core.config import ExecConfig, SolveConfig
from .core.pdhg import SolveResult
from .core.plan import PopPlan
from .domains import DomainSpec, StepOutcome, registry as registry_mod
from .tuning import (OnlineTuner, SLOTarget, TuningProfile,
                     check_profile, launch_defaults, load_profile)

__all__ = ["Allocation", "DispatchConfig", "MicroBatchDispatcher",
           "PopService", "PopSession"]

# default cap on the deadline ladder's per-(path, domain, config, shape)
# rate/overhead EMA maps — diverse instance shapes would otherwise grow
# them without bound (each key is a few hundred bytes, but a fleet churns
# through shapes forever)
RATE_CACHE_SIZE = 4096


class _BoundedLRU(OrderedDict):
    """Bounded LRU mapping for the rate/overhead EMA caches: reads and
    writes refresh recency, inserts beyond ``maxsize`` evict the coldest
    key and count it.  NOT itself thread-safe — PopService holds its lock
    around every access."""

    def __init__(self, maxsize: int):
        super().__init__()
        self.maxsize = int(maxsize)
        self.evictions = 0

    def get(self, key, default=None):
        if key in self:
            super().move_to_end(key)
            return super().__getitem__(key)
        return default

    def __setitem__(self, key, value):
        if key in self:
            super().move_to_end(key)
        super().__setitem__(key, value)
        while len(self) > self.maxsize:
            super().popitem(last=False)
            self.evictions += 1


@dataclasses.dataclass
class Allocation:
    """One session step's outcome — the uniform cross-domain result.

    ``alloc`` is the domain allocation (per-job throughputs, per-demand
    flows, a placement vector, ...), already through the domain's rounding
    hook when it has one; ``raw`` is the underlying
    :class:`~repro.core.pop.POPResult` / :class:`~repro.core.pop.FullResult`
    / domain result for callers that need solver state or sub-LP detail.

    ``status`` is the degradation-ladder rung the step landed on
    (docs/ROBUSTNESS.md): ``"ok"`` (normal solve), ``"degraded"`` (solve
    ran with a deadline-capped iteration budget / relaxed tolerance),
    ``"recovered"`` (a fault — diverged lanes, poisoned warm state — was
    quarantined and re-solved), ``"fallback"`` (no solve result; ``alloc``
    is the previous allocation or the domain's greedy).  ``faults`` lists
    what happened on the way (``"divergence:2"``, ``"deadline:capped"``,
    ``"warm-state-mismatch"``, ...); empty on clean steps.
    """

    domain: str
    tenant: str
    step: int
    alloc: np.ndarray
    metrics: dict
    # observability: what ACTUALLY ran ("auto" resolved), and how the plan
    # cache behaved: "hit" (previous plan reused verbatim), "repair"
    # (incrementally repaired under churn), "miss" (fresh plan), "full"
    # (unpartitioned k=1 path), "fallback" (no solve ran)
    backend: Optional[str]
    engine: Optional[str]
    plan_cache: str
    k: int
    warm_fraction: Optional[float]
    solve_time_s: float
    build_time_s: float
    iterations: int
    raw: Any = None
    status: str = "ok"
    faults: tuple = ()

    @property
    def objective(self) -> Optional[float]:
        return self.metrics.get("objective")


def _zeros() -> dict:
    return {"steps": 0, "plan_hits": 0, "plan_repairs": 0, "plan_misses": 0,
            "full_solves": 0, "solve_time_s": 0.0, "warm_fraction_sum": 0.0,
            "warm_steps": 0,
            # fault-tolerance counters (docs/ROBUSTNESS.md): ladder rungs
            # taken, solver lanes cold-restarted by the divergence guard,
            # total faults recorded, checkpoint restore outcomes
            "degraded_steps": 0, "recovered_steps": 0, "fallback_steps": 0,
            "quarantined_lanes": 0, "faults": 0,
            "checkpoint_restores": 0, "checkpoint_failures": 0,
            # SLO auto-tuning counters (docs/TUNING.md): steps whose
            # measured latency/quality breached the session's SLOTarget,
            # and config moves the online tuner made in response
            "slo_violations": 0, "retunes": 0,
            # resolved step-engine observability: engine name -> steps
            # that actually ran it ("auto" already resolved)
            "engines": {}}


def _tally(stats: dict, alloc: Allocation) -> None:
    stats["steps"] += 1
    if alloc.status == "fallback":
        pass        # no solve ran — the plan cache was never consulted
    else:
        key = {"hit": "plan_hits", "repair": "plan_repairs",
               "full": "full_solves"}.get(alloc.plan_cache, "plan_misses")
        stats[key] += 1
    if alloc.status != "ok":
        stats[alloc.status + "_steps"] += 1
    stats["faults"] += len(alloc.faults)
    stats["solve_time_s"] += alloc.solve_time_s
    if alloc.engine:
        eng = stats["engines"]
        eng[alloc.engine] = eng.get(alloc.engine, 0) + 1
    if alloc.warm_fraction is not None:
        stats["warm_fraction_sum"] += alloc.warm_fraction
        stats["warm_steps"] += 1


def _finite(alloc) -> bool:
    """Is every numeric entry of an allocation finite?"""
    try:
        arr = np.asarray(alloc, dtype=float)
    except (TypeError, ValueError):
        return True     # non-numeric allocation: nothing to check
    return bool(np.isfinite(arr).all())


def _pop_warm_ok(warm) -> bool:
    """Is a pop-mode warm state internally consistent (plan present,
    iterates present and shaped like the plan says)?  Catches dropped or
    mismatched warm state — a bad restore, an injector, a stale seed —
    BEFORE it reaches the solver."""
    plan = getattr(warm, "plan", None)
    x, y = getattr(warm, "x", None), getattr(warm, "y", None)
    if plan is None or x is None or y is None:
        return False
    shapes = getattr(plan, "shapes", None) or {}
    for name, arr in (("x", x), ("y", y)):
        want = shapes.get(name)
        if want is not None and tuple(np.shape(arr)) != tuple(want):
            return False
    return True


def _count_diverged(res) -> int:
    div = getattr(res, "diverged", None)
    return 0 if div is None else int(np.asarray(div).sum())


# --------------------------------------------------------------------------
# the micro-batching dispatcher: cross-tenant coalesced map-step launches
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Tuning for :class:`MicroBatchDispatcher`.

    ``max_lanes`` caps a coalesced launch's total lane count (sum of the
    grouped tenants' k); ``max_wait_ms`` is the micro-batch window —
    measured from the first ticket's arrival, the dispatcher collects
    company until the window closes or ``max_lanes`` fills (a saturated
    queue fills the group with zero added wait, so the window only costs
    latency under sparse traffic); ``pad_pow2`` pads each coalesced
    launch's lane
    count up to the next power of two with replica lanes so variable
    group sizes compile O(log max_lanes) distinct solvers instead of one
    per arrival pattern; ``workers`` sizes the service's
    ``step_async`` thread pool."""

    max_lanes: int = 64
    max_wait_ms: float = 2.0
    pad_pow2: bool = True
    workers: int = 8


class _Ticket:
    """One tenant's prepared map-step launch, queued for dispatch."""

    __slots__ = ("key", "batch", "prep", "K_mv", "KT_mv", "future")

    def __init__(self, key, batch, prep, K_mv, KT_mv, future):
        self.key = key
        self.batch = batch
        self.prep = prep
        self.K_mv = K_mv
        self.KT_mv = KT_mv
        self.future = future


class MicroBatchDispatcher:
    """Coalesces concurrent tenants' prepared map-step launches.

    Sessions prepare their solves on their own threads
    (``pop.prepare_instance`` / ``pop.prepare_full``) and submit the
    launch here; a single worker thread drains the queue, groups tickets
    by :func:`repro.core.backends.coalesce_key` (same matvecs, resolved
    backend/engine, solver config and per-lane layout — structured ELL
    widths may differ; ``pdhg.concat_stacks`` pads them), runs ONE map
    backend call per group, and slices per-tenant results back out.
    Lanes are independent in ``solve_stacked``, so each tenant's result
    is bit-identical to a solo launch; warm chains, plan provenance and
    the degradation ladder all live in the session layer above and never
    see the sharing.

    A failed group launch falls back to per-ticket solo launches, so one
    tenant's pathological batch cannot fail its peers — only its own
    caller sees the exception (which the session ladder then handles)."""

    def __init__(self, cfg: Optional[DispatchConfig] = None):
        self.cfg = cfg or DispatchConfig()
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._gate = threading.Event()
        self._gate.set()
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._counts = {
            "requests": 0, "launches": 0, "lanes": 0,
            "coalesced_launches": 0, "coalesced_requests": 0,
            "solo_launches": 0, "group_fallbacks": 0, "max_group": 0}
        self._thread = threading.Thread(target=self._loop,
                                        name="pop-dispatch", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- client --
    def solve_prepared(self, prep, K_mv, KT_mv):
        """Run one :class:`~repro.core.pop.PreparedSolve`'s map-step
        launch, blocking until its :class:`SolveResult` is ready.
        Returns ``(result, solve_time_s)`` where the time is this
        tenant's lane-weighted share of the launch wall time.
        Launches that cannot share (single-lane streaming engine,
        unhashable configs) run inline on the calling thread."""
        batch = backends_mod.make_batch(prep.ops, prep.warm)
        key = backends_mod.coalesce_key(prep.ops, K_mv, KT_mv, prep.backend,
                                        prep.engine, prep.solver_kw,
                                        prep.opts)
        with self._lock:
            self._counts["requests"] += 1
        if key is None or not self._thread.is_alive():
            tk = _Ticket(None, batch, prep, K_mv, KT_mv, None)
            t1 = time.perf_counter()
            res = self._launch(batch, tk)
            wall = time.perf_counter() - t1
            with self._lock:
                self._counts["launches"] += 1
                self._counts["solo_launches"] += 1
                self._counts["lanes"] += backends_mod.batch_size(batch)
            return res, wall
        fut: "concurrent.futures.Future" = concurrent.futures.Future()
        self._q.put(_Ticket(key, batch, prep, K_mv, KT_mv, fut))
        return fut.result()

    def hold(self):
        """Context manager pausing batch collection: requests queue up
        while held and dispatch in one sweep on release — deterministic
        maximal coalescing for tests and benchmarks."""
        dispatcher = self

        class _Hold:
            def __enter__(self):
                dispatcher._gate.clear()
                return dispatcher

            def __exit__(self, *exc):
                dispatcher._gate.set()
                return False

        return _Hold()

    def stats(self) -> dict:
        """Observability counters + derived ratios.  ``batching_ratio``
        is served requests per device launch (> 1 means coalescing is
        happening); ``lanes_per_launch`` the mean stacked lane count."""
        with self._lock:
            s = dict(self._counts)
        served = s["coalesced_requests"] + s["solo_launches"]
        s["batching_ratio"] = served / max(s["launches"], 1)
        s["lanes_per_launch"] = s["lanes"] / max(s["launches"], 1)
        return s

    def close(self) -> None:
        self._stop.set()
        self._gate.set()
        self._q.put(None)
        self._thread.join(timeout=5.0)

    # ------------------------------------------------------------- worker --
    def _loop(self) -> None:
        while not self._stop.is_set():
            self._gate.wait(timeout=0.25)
            if not self._gate.is_set():
                continue
            try:
                first = self._q.get(timeout=0.25)
            except queue.Empty:
                continue
            if first is None:
                continue
            # a hold() that began while we were blocked in get(): keep the
            # ticket and wait the hold out so it joins the released sweep
            while not self._gate.is_set() and not self._stop.is_set():
                self._gate.wait(timeout=0.25)
            tickets = [first]
            lanes = backends_mod.batch_size(first.batch)
            lanes = self._drain(tickets, lanes)
            if lanes < self.cfg.max_lanes and self.cfg.max_wait_ms > 0:
                # micro-batch window: from first-ticket arrival, collect
                # company until the window closes or the lane budget fills.
                # A saturated queue fills the group with zero added wait;
                # the window only costs latency when traffic is sparse.
                deadline = time.perf_counter() + self.cfg.max_wait_ms / 1e3
                while lanes < self.cfg.max_lanes:
                    rem = deadline - time.perf_counter()
                    if rem <= 0:
                        break
                    try:
                        t = self._q.get(timeout=rem)
                    except queue.Empty:
                        break
                    if t is None:
                        continue
                    tickets.append(t)
                    lanes += backends_mod.batch_size(t.batch)
                    lanes = self._drain(tickets, lanes)
            groups: "OrderedDict[tuple, list]" = OrderedDict()
            for t in tickets:
                groups.setdefault(t.key, []).append(t)
            for grp in groups.values():
                self._run_group(grp)

    def _drain(self, tickets: list, lanes: int) -> int:
        while lanes < self.cfg.max_lanes:
            try:
                t = self._q.get_nowait()
            except queue.Empty:
                return lanes
            if t is None:
                continue
            tickets.append(t)
            lanes += backends_mod.batch_size(t.batch)
        return lanes

    def _launch(self, batch, tk):
        prep = tk.prep
        res = backends_mod.get_backend(prep.backend)(
            batch, tk.K_mv, tk.KT_mv, dict(prep.solver_kw),
            engine=prep.engine, **prep.opts)
        jax.block_until_ready(res.x)
        return res

    def _run_group(self, grp: list) -> None:
        if len(grp) > 1:
            t1 = time.perf_counter()
            try:
                batch, sizes = backends_mod.concat_batches(
                    [t.batch for t in grp])
                total = sum(sizes)
                if self.cfg.pad_pow2:
                    batch, _ = backends_mod.pad_lanes_pow2(batch)
                res = self._launch(batch, grp[0])
                res = jax.tree.map(lambda a: a[:total], res)
                parts = backends_mod.split_result(res, sizes)
                wall = time.perf_counter() - t1
                with self._lock:
                    self._counts["launches"] += 1
                    self._counts["lanes"] += total
                    self._counts["coalesced_launches"] += 1
                    self._counts["coalesced_requests"] += len(grp)
                    self._counts["max_group"] = max(
                        self._counts["max_group"], len(grp))
                for tk, part, s in zip(grp, parts, sizes):
                    tk.future.set_result((part, wall * (s / total)))
                return
            except Exception:
                # a shared launch must not take peers down with one bad
                # tenant: retry every ticket solo; only the bad tenant's
                # caller sees its exception (handled by the session ladder)
                with self._lock:
                    self._counts["group_fallbacks"] += 1
        for tk in grp:
            t1 = time.perf_counter()
            try:
                res = self._launch(tk.batch, tk)
                wall = time.perf_counter() - t1
                with self._lock:
                    self._counts["launches"] += 1
                    self._counts["solo_launches"] += 1
                    self._counts["lanes"] += backends_mod.batch_size(tk.batch)
                tk.future.set_result((res, wall))
            except BaseException as e:      # noqa: BLE001 — forwarded
                tk.future.set_exception(e)


class PopSession:
    """One tenant's stateful solving loop for one domain.

    Holds the warm state (previous plan + iterates) between steps; every
    ``step(instance)`` re-solves the updated instance warm wherever the
    domain's layout allows, cold otherwise — the caller never touches
    solver state.  Create through :meth:`PopService.session`.
    """

    def __init__(self, service: "PopService", tenant: str, spec: DomainSpec,
                 solve_cfg: SolveConfig, exec_cfg: ExecConfig,
                 slo: Optional[SLOTarget] = None,
                 tuner: Optional[OnlineTuner] = None):
        self.service = service
        self.tenant = tenant
        self.spec = spec
        self.solve_cfg = solve_cfg
        self.exec_cfg = exec_cfg
        # the SLO contract + online tuner (None = untuned; the fault-free
        # untuned path is byte-identical to pre-tuning behavior).  The
        # tuner retunes by REPLACING solve_cfg between steps; the change
        # flows through prepare_instance's repair/remap path so warm
        # state survives (docs/TUNING.md)
        self.slo = slo
        self._tuner = tuner
        self.steps = 0
        self.last: Optional[Allocation] = None
        self.stats = _zeros()
        # serializes step()/checkpoint/page-out for THIS tenant.  Lock
        # order: a session lock may take the service lock (stats tally,
        # rate notes) but NEVER the reverse — service-side paths that need
        # both (eviction, checkpoint) release the service lock first
        self._lock = threading.RLock()
        # warm state: a POPResult (pop path), a SolveResult (+ the ids it
        # is FOR, full path), or whatever a step_override domain carries
        self._warm: Any = None
        self._mode: Optional[str] = None
        self._full_ids: Optional[tuple] = None
        # wall time of the most recent step (the deadline predictor for
        # step_override domains, which have no iteration-rate model)
        self._last_wall: Optional[float] = None

    # ------------------------------------------------------------------ api --
    def seed(self, warm_state: Any, mode: Optional[str] = None,
             entity_ids=None) -> "PopSession":
        """Adopt externally carried warm state (restores a session from a
        previous process / the legacy hand-carried-result surface).

        ``mode`` is inferred from the state's type when omitted: a
        :class:`~repro.core.pop.POPResult` seeds the pop path, a
        :class:`~repro.core.pop.FullResult` / ``SolveResult`` the k=1 full
        path, anything else the domain's own ``step_override`` state.
        An explicit ``mode`` is validated against the state's type — a
        mismatch raises here, with a clear message, instead of failing
        deep inside ``solve_instance``.  Restoring FULL-path state
        additionally needs ``entity_ids`` — the ids the iterates are FOR
        (pass the plain entity COUNT for domains without an
        ``entity_ids`` hook; the flat LP has no per-entity remap, only an
        alignment check); without them the first step safely starts cold."""
        if warm_state is None:
            self._warm, self._mode = None, None
            return self
        if mode is None:
            if isinstance(warm_state, pop_mod.POPResult):
                mode = "pop"
            elif isinstance(warm_state, (pop_mod.FullResult, SolveResult)):
                mode = "full"
            else:
                mode = "domain"
        elif mode not in ("pop", "full", "domain"):
            raise ValueError(f"seed(): unknown mode {mode!r}; expected "
                             "'pop', 'full' or 'domain'")
        if mode == "pop":
            if not isinstance(warm_state, pop_mod.POPResult):
                raise TypeError(
                    f"seed(mode='pop') needs a POPResult, got "
                    f"{type(warm_state).__name__} — pass mode='full' for "
                    "FullResult/SolveResult state or mode='domain' for a "
                    "step_override domain's own state")
            if warm_state.x is None or warm_state.y is None:
                raise ValueError(
                    "seed(mode='pop'): POPResult carries no solver "
                    "iterates (x/y are None) — it cannot warm-start")
        if mode == "full":
            if not isinstance(warm_state, (pop_mod.FullResult, SolveResult)):
                raise TypeError(
                    f"seed(mode='full') needs a FullResult or SolveResult, "
                    f"got {type(warm_state).__name__} — pass mode='pop' "
                    "for POPResult state")
            if isinstance(warm_state, pop_mod.FullResult):
                warm_state = warm_state.res
            if entity_ids is None:
                self._full_ids = None
            elif np.isscalar(entity_ids):
                # positional domains: ids ARE positions, so the alignment
                # key is just the entity count (see _step_full)
                self._full_ids = ("pos", int(entity_ids))
            else:
                self._full_ids = tuple(np.asarray(entity_ids).tolist())
        self._warm = warm_state
        self._mode = mode
        return self

    def step(self, instance: Any, *,
             deadline_s: Optional[float] = None) -> Allocation:
        """Solve the (updated) instance; warm-start from the previous step
        wherever the domain allows.  The single online entry point.

        ``deadline_s`` bounds the step's wall time: the iteration budget
        is derived from the measured per-iteration rate of previous steps
        with the same (domain, ExecConfig, shape) and the solve degrades
        down the ladder (docs/ROBUSTNESS.md) when the budget is short —
        the returned :class:`Allocation` reports the rung in ``status``.
        Without a deadline the fault-free path is byte-identical to the
        pre-deadline behavior (same jit cache keys, zero retraces)."""
        with self._lock:
            self.service._reattach(self)
            t0 = time.perf_counter()
            if self.spec.step_override is not None:
                alloc = self._step_override(instance, deadline_s, t0)
            else:
                alloc = self._step_generic(instance, deadline_s, t0)
            self.steps += 1
            self._last_wall = time.perf_counter() - t0
            if self._tuner is not None and alloc.status != "fallback":
                self._observe_tuned(alloc)
            _tally(self.stats, alloc)
            with self.service._lock:
                _tally(self.service._stats, alloc)
            self.last = alloc
        self.service._after_step(self)
        return alloc

    def step_async(self, instance: Any, *,
                   deadline_s: Optional[float] = None
                   ) -> "concurrent.futures.Future":
        """Submit :meth:`step` to the service's thread pool; returns a
        ``Future[Allocation]``.  Steps of ONE session serialize on the
        session lock (warm chains stay ordered); steps of DIFFERENT
        sessions run concurrently, and when the service has a dispatcher
        their map-step launches coalesce into shared device launches."""
        return self.service._submit(self.step, instance,
                                    deadline_s=deadline_s)

    # ------------------------------------------------- step_override domains --
    def _step_override(self, instance: Any, deadline_s: Optional[float],
                       t0: float) -> Allocation:
        faults: list = []
        # no iteration-rate model for domain-run pipelines: if the last
        # step's wall time already blows the deadline, skip the solve
        if (deadline_s is not None and self._last_wall is not None
                and self._last_wall > deadline_s
                and (self.last is not None or self.spec.greedy is not None)):
            return self._fallback(instance, ["deadline"], t0)
        out = None
        attempts = [self._warm] + ([None] if self._warm is not None else [])
        for i, warm in enumerate(attempts):
            try:
                cand: StepOutcome = self.spec.step_override(
                    instance, self.solve_cfg, self.exec_cfg, warm)
            except Exception as e:
                faults.append(f"step-error:{type(e).__name__}")
                continue
            if not _finite(cand.alloc):
                faults.append("nonfinite-alloc")
                continue
            out = cand
            if i > 0:
                faults.append("warm-quarantined")
            break
        if out is None:
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0)
        self._warm, self._mode = out.warm_state, "domain"
        return self._wrap(
            instance, out.alloc, out.metrics, backend=out.backend,
            engine=out.engine, plan_cache=out.plan_cache, k=out.k,
            warm_fraction=out.warm_fraction,
            solve_time_s=out.solve_time_s,
            build_time_s=out.build_time_s,
            iterations=out.iterations, raw=out.raw,
            status="recovered" if faults else "ok", faults=tuple(faults))

    # ------------------------------------------------------- generic domains --
    def _step_generic(self, instance: Any, deadline_s: Optional[float],
                      t0: float) -> Allocation:
        spec = self.spec
        problem = spec.make_problem(instance)
        eids = spec.ids_of(instance)
        if self._tuner is not None:
            # sessions created without an instance plan on first step
            cfg = self._tuner.ensure_planned(problem.n_entities,
                                             self.solve_cfg)
            if cfg is not None:
                self.solve_cfg = cfg
        k = self.solve_cfg.k_for(problem.n_entities)
        if k > 1:
            return self._step_pop(instance, problem, eids, k, deadline_s, t0)
        return self._step_full(instance, problem, eids, deadline_s, t0)

    def _step_pop(self, instance, problem, eids, k: int,
                  deadline_s: Optional[float], t0: float) -> Allocation:
        faults: list = []
        warm = self._warm if self._mode == "pop" else None
        if warm is not None and not _pop_warm_ok(warm):
            faults.append("warm-state-mismatch")
            self._warm, self._mode = None, None
            warm = None
        scfg = dataclasses.replace(self.solve_cfg, k=k)
        rkey = ("pop", self.spec.name, self.exec_cfg, k, problem.n_entities)
        exec_run, rung = self._ladder(rkey, deadline_s, t0)
        if rung == "fallback":
            return self._fallback(instance, faults + ["deadline"], t0,
                                  problem=problem)
        if rung is not None:
            faults.append(f"deadline:{rung}")

        def _solve(w, **kw):
            return self.service._solve_instance(problem, scfg, exec_run,
                                                warm=w, entity_ids=eids, **kw)

        try:
            res = _solve(warm)
        except Exception as e:
            if warm is None:
                raise     # cold-solve errors (bad instance data) are real
            faults.append(f"warm-solve-error:{type(e).__name__}")
            self._warm, self._mode = None, None
            warm = None
            res = _solve(None)

        n_div = _count_diverged(res)
        if n_div and warm is not None:
            # quarantine: cold-restart ONLY the diverged lanes, keep the
            # plan and the healthy lanes' iterates
            faults.append(f"divergence:{n_div}")
            self._note_quarantine(n_div)
            retry = None
            try:
                retry = _solve(warm, plan=res.plan, cold_lanes=res.diverged)
            except Exception as e:
                faults.append(f"warm-solve-error:{type(e).__name__}")
            if retry is None or _count_diverged(retry):
                # quarantine didn't clear it: drop the warm state entirely
                if retry is not None:
                    self._note_quarantine(_count_diverged(retry))
                faults.append("warm-dropped")
                self._warm, self._mode = None, None
                warm = None
                res = _solve(None)
            else:
                res = retry
            n_div = _count_diverged(res)
        if n_div:
            # a COLD solve diverged: the instance itself is pathological
            # at this config — nothing left to quarantine
            faults.append(f"cold-divergence:{n_div}")
            self._note_quarantine(n_div)
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)
        if not _finite(res.alloc):
            faults.append("nonfinite-alloc")
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)

        self._warm, self._mode = res, "pop"
        self._note_rate(rkey, int(np.asarray(res.iterations).max(initial=0)),
                        res.solve_time_s, time.perf_counter() - t0)
        cache = {"reused": "hit", "repaired": "repair"}.get(
            res.plan_source, "miss")
        wf = res.warm_stats["warm_fraction"] if res.warm_stats else None
        return self._wrap(
            instance, res.alloc, None, problem=problem,
            backend=res.backend, engine=res.engine, plan_cache=cache,
            k=res.plan.k if res.plan is not None else 0,
            warm_fraction=wf, solve_time_s=res.solve_time_s,
            build_time_s=res.build_time_s,
            iterations=int(np.asarray(res.iterations).sum()), raw=res,
            status=self._status_of(faults, rung), faults=tuple(faults))

    def _step_full(self, instance, problem, eids,
                   deadline_s: Optional[float], t0: float) -> Allocation:
        # ---- k=1: the unpartitioned full problem through the same substrate.
        # The flat LP has no per-entity remap, so warm only while the entity
        # identity sequence is unchanged (a same-size swap would silently
        # misalign rows); crossing the pop<->full mode boundary drops warm.
        faults: list = []
        ids_key = (tuple(np.asarray(eids).tolist()) if eids is not None
                   else ("pos", problem.n_entities))
        warm = self._warm if self._mode == "full" else None
        if warm is not None and (self._full_ids is None
                                 or ids_key != self._full_ids):
            warm = None
        rkey = ("full", self.spec.name, self.exec_cfg, 1, problem.n_entities)
        exec_run, rung = self._ladder(rkey, deadline_s, t0)
        if rung == "fallback":
            return self._fallback(instance, faults + ["deadline"], t0,
                                  problem=problem)
        if rung is not None:
            faults.append(f"deadline:{rung}")

        try:
            fr = self.service._solve_full(problem, warm, exec_run)
        except Exception as e:
            if warm is None:
                raise
            faults.append(f"warm-solve-error:{type(e).__name__}")
            self._warm, self._mode = None, None
            warm = None
            fr = self.service._solve_full(problem, None, exec_run)
        if _count_diverged(fr.res) and warm is not None:
            # k=1 has a single lane: quarantine == full cold restart
            faults.append("divergence:1")
            self._note_quarantine(1)
            self._warm, self._mode = None, None
            warm = None
            fr = self.service._solve_full(problem, None, exec_run)
        if _count_diverged(fr.res):
            faults.append("cold-divergence:1")
            self._note_quarantine(1)
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)
        if not _finite(fr.alloc):
            faults.append("nonfinite-alloc")
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)

        self._warm, self._mode = fr.res, "full"
        self._full_ids = ids_key
        self._note_rate(rkey, int(np.asarray(fr.res.iterations).max(initial=0)),
                        fr.solve_time_s, time.perf_counter() - t0)
        return self._wrap(
            instance, fr.alloc, None, problem=problem, backend=fr.backend,
            engine=fr.engine, plan_cache="full", k=1,
            warm_fraction=None if warm is None else 1.0,
            solve_time_s=fr.solve_time_s, build_time_s=fr.build_time_s,
            iterations=int(np.asarray(fr.res.iterations).sum()), raw=fr,
            status=self._status_of(faults, rung), faults=tuple(faults))

    # ---------------------------------------------- degradation ladder rungs --
    @staticmethod
    def _status_of(faults: list, rung: Optional[str]) -> str:
        if any(not f.startswith("deadline") for f in faults):
            return "recovered"
        return "degraded" if rung is not None else "ok"

    def _ladder(self, rkey: tuple, deadline_s: Optional[float],
                t0: float):
        """Pick the ExecConfig for this step under the deadline.

        Returns ``(exec_cfg, rung)`` with rung ``None`` (full budget —
        and, critically, the UNMODIFIED session ExecConfig, so the
        no-deadline path keeps byte-identical jit cache keys),
        ``"capped"`` (iteration cap + relaxed tolerance), ``"best-effort"``
        (a single convergence-check chunk), or ``"fallback"`` (not even
        one chunk fits — skip the solve).  Iteration budgets are quantized
        to power-of-two multiples of ``check_every`` so the ladder only
        ever creates O(log) distinct solver compilations per config."""
        if deadline_s is None:
            return self.exec_cfg, None
        with self.service._lock:
            rate = self.service._rates.get(rkey)
            overhead = self.service._overheads.get(rkey, 0.0)
        if rate is None or rate <= 0.0:
            return self.exec_cfg, None     # no measurement yet: run full
        remaining = deadline_s - (time.perf_counter() - t0) - overhead
        kw = self.exec_cfg.solver_dict()
        max_it = int(kw.get("max_iters", 20_000))
        ce = int(kw.get("check_every", 40))
        budget = int(remaining / rate) if remaining > 0 else 0
        if budget >= max_it:
            return self.exec_cfg, None
        if budget < ce:
            return None, "fallback"
        q = ce
        while q * 2 <= budget:
            q *= 2
        kw["max_iters"] = int(min(q, max_it))
        # a capped solve gets one tolerance notch back: better a looser
        # answer within budget than a tight one we never reach
        kw["tol_primal"] = float(kw.get("tol_primal", 1e-4)) * 10.0
        kw["tol_gap"] = float(kw.get("tol_gap", 1e-4)) * 10.0
        rung = "best-effort" if q == ce else "capped"
        return dataclasses.replace(self.exec_cfg, solver_kw=kw), rung

    def _note_rate(self, rkey: tuple, iters: int, solve_time_s: float,
                   wall_s: float) -> None:
        """EMA-update the measured per-iteration rate + per-step overhead
        for this (domain, ExecConfig, shape) — what _ladder budgets from."""
        if iters <= 0 or solve_time_s <= 0.0:
            return
        with self.service._lock:
            rates = self.service._rates
            r = solve_time_s / iters
            old = rates.get(rkey)
            rates[rkey] = r if old is None else 0.5 * old + 0.5 * r
            overheads = self.service._overheads
            ov = max(wall_s - solve_time_s, 0.0)
            o = overheads.get(rkey)
            overheads[rkey] = ov if o is None else 0.5 * o + 0.5 * ov

    def _note_quarantine(self, n: int) -> None:
        self.stats["quarantined_lanes"] += n
        with self.service._lock:
            self.service._stats["quarantined_lanes"] += n

    # ------------------------------------------------- SLO online refiner --
    def _observe_tuned(self, alloc: Allocation) -> None:
        """Feed one fault-free step into the session's OnlineTuner; count
        SLO violations and apply a retuned SolveConfig for the NEXT step
        (this step's allocation is already final).  Called under the
        session lock."""
        quality = self.spec.quality_of(alloc.metrics)
        ev = self._tuner.observe(alloc.k, alloc.solve_time_s, quality)
        if ev.violation is not None:
            self.stats["slo_violations"] += 1
            with self.service._lock:
                self.service._stats["slo_violations"] += 1
        if ev.new_solve is not None and ev.new_solve != self.solve_cfg:
            self.solve_cfg = ev.new_solve
            self.stats["retunes"] += 1
            with self.service._lock:
                self.service._stats["retunes"] += 1

    def _fallback(self, instance, faults: list, t0: float,
                  problem=None) -> Allocation:
        """The ladder's last rung: repeat the previous allocation, else ask
        the domain's greedy hook.  Never returns non-finite data; raises
        only when there is literally nothing to serve."""
        spec = self.spec
        alloc, source = None, None
        if self.last is not None and _finite(self.last.alloc):
            alloc, source = self.last.alloc, "previous-allocation"
        elif spec.greedy is not None:
            alloc, source = np.asarray(spec.greedy(instance)), "greedy"
        if alloc is None:
            raise RuntimeError(
                f"tenant {self.tenant!r} ({spec.name}): cannot produce an "
                f"allocation — solve failed ({', '.join(faults) or 'n/a'}) "
                "and the session has no previous allocation and the domain "
                "registers no greedy= fallback hook")
        try:
            metrics = dict(spec.metrics_of(instance, problem, alloc))
        except Exception as e:
            # fallback must not die computing metrics for an allocation
            # that was never meant for this exact instance
            metrics = {"metrics_error": f"{type(e).__name__}: {e}"}
        metrics["fallback_source"] = source
        # NOTE: no rounding hook here — a previous allocation is already
        # rounded, and greedy hooks return final allocations by contract
        return Allocation(
            domain=spec.name, tenant=self.tenant, step=self.steps,
            alloc=alloc, metrics=metrics, backend=None, engine=None,
            plan_cache="fallback", k=0, warm_fraction=None,
            solve_time_s=time.perf_counter() - t0, build_time_s=0.0,
            iterations=0, raw=None, status="fallback",
            faults=tuple(faults) if faults else ("deadline",))

    def _wrap(self, instance, raw_alloc, metrics, *, backend, engine,
              plan_cache, k, warm_fraction, solve_time_s, build_time_s=0.0,
              iterations=0, raw=None, problem=None, status="ok",
              faults=()) -> Allocation:
        alloc = raw_alloc
        if self.spec.round is not None and self.spec.step_override is None:
            alloc = self.spec.round(instance, raw_alloc)
        if metrics is None:
            metrics = self.spec.metrics_of(instance, problem, alloc)
        return Allocation(
            domain=self.spec.name, tenant=self.tenant, step=self.steps,
            alloc=alloc, metrics=metrics, backend=backend, engine=engine,
            plan_cache=plan_cache, k=k, warm_fraction=warm_fraction,
            solve_time_s=solve_time_s, build_time_s=build_time_s,
            iterations=iterations, raw=raw, status=status,
            faults=tuple(faults))

    # ------------------------------------------------------ checkpoint hooks --
    def _checkpoint_payload(self, prefix: str):
        """(meta, arrays) for this session — see PopService.checkpoint."""
        base = {
            "prefix": prefix,
            "domain": self.spec.name,
            "steps": int(self.steps),
            "solve_cfg": {
                "k": self.solve_cfg.k, "strategy": self.solve_cfg.strategy,
                "seed": self.solve_cfg.seed,
                "replicate_threshold": self.solve_cfg.replicate_threshold,
                "min_per_sub": self.solve_cfg.min_per_sub},
            "exec_cfg": {
                "backend": self.exec_cfg.backend,
                "engine": self.exec_cfg.engine,
                "solver_kw": self.exec_cfg.solver_dict(),
                "backend_opts": self.exec_cfg.opts_dict()},
            "digest": ckpt_mod.config_digest(self.solve_cfg, self.exec_cfg),
        }
        if self._mode == "pop" and isinstance(self._warm, pop_mod.POPResult):
            w = self._warm
            plan = w.plan
            if (plan is None or w.x is None or w.y is None
                    or plan.replication is not None):
                return {**base, "mode": "skipped",
                        "reason": "pop warm state without a serializable "
                                  "plan (replicated plans are v1-excluded)"}, {}
            meta = {**base, "mode": "pop", "plan": {
                "k": int(plan.k), "n_entities": int(plan.n_entities),
                "strategy": plan.strategy, "seed": int(plan.seed),
                "shapes": {name: list(v)
                           for name, v in (plan.shapes or {}).items()},
                "has_ids": plan.entity_ids is not None}}
            arrays = {f"{prefix}/x": w.x, f"{prefix}/y": w.y,
                      f"{prefix}/idx": plan.idx,
                      f"{prefix}/entity_of_slot": plan.entity_of_slot,
                      f"{prefix}/alloc": w.alloc,
                      f"{prefix}/iterations": w.iterations,
                      f"{prefix}/converged": w.converged}
            if plan.entity_ids is not None:
                arrays[f"{prefix}/entity_ids"] = plan.entity_ids
            return meta, arrays
        if self._mode == "full" and isinstance(self._warm, SolveResult):
            r = self._warm
            if self._full_ids is None:
                ids_kind, ids_val = "none", None
            elif self._full_ids[0] == "pos":
                ids_kind, ids_val = "pos", int(self._full_ids[1])
            else:
                ids_kind, ids_val = "ids", list(self._full_ids)
            meta = {**base, "mode": "full", "full_ids_kind": ids_kind,
                    "full_ids": ids_val}
            arrays = {f"{prefix}/x": np.asarray(r.x),
                      f"{prefix}/y": np.asarray(r.y),
                      f"{prefix}/iterations": np.asarray(r.iterations),
                      f"{prefix}/converged": np.asarray(r.converged),
                      f"{prefix}/primal_obj": np.asarray(r.primal_obj)}
            return meta, arrays
        if self._mode == "domain":
            return {**base, "mode": "skipped",
                    "reason": "step_override domains carry opaque warm "
                              "state (not serialized in v1)"}, {}
        return {**base, "mode": "cold"}, {}

    def _restore_payload(self, tmeta: dict, arrays: Dict[str, np.ndarray]):
        """Rebuild this session's warm state from checkpoint meta+arrays;
        raises CheckpointError on any misalignment."""
        mode = tmeta.get("mode", "cold")
        if mode in ("cold", "skipped"):
            return
        prefix = tmeta.get("prefix", "")

        def arr(name: str) -> np.ndarray:
            key = f"{prefix}/{name}"
            if key not in arrays:
                raise ckpt_mod.CheckpointError(
                    f"checkpoint payload missing array {key!r}")
            return arrays[key]

        if mode == "pop":
            pm = tmeta.get("plan") or {}
            k, n = int(pm["k"]), int(pm["n_entities"])
            idx, eos = arr("idx"), arr("entity_of_slot")
            x, y = arr("x"), arr("y")
            shapes = {name: tuple(v)
                      for name, v in (pm.get("shapes") or {}).items()}
            if idx.ndim != 2 or idx.shape[0] != k or eos.shape != idx.shape:
                raise ckpt_mod.CheckpointError(
                    f"plan arrays misaligned: idx {idx.shape} / "
                    f"entity_of_slot {eos.shape} for k={k}")
            for name, a in (("x", x), ("y", y)):
                want = shapes.get(name)
                if want is not None and tuple(a.shape) != want:
                    raise ckpt_mod.CheckpointError(
                        f"iterate {name} has shape {tuple(a.shape)}, plan "
                        f"says {want} — stale or corrupt warm state")
            ids = arr("entity_ids") if pm.get("has_ids") else None
            if ids is not None and ids.shape[0] != n:
                raise ckpt_mod.CheckpointError(
                    f"entity_ids has {ids.shape[0]} entries for "
                    f"{n} entities")
            plan = PopPlan(k=k, n_entities=n, idx=idx, entity_of_slot=eos,
                           strategy=pm.get("strategy", "stratified"),
                           seed=int(pm.get("seed", 0)), replication=None,
                           entity_ids=ids, similarity=None, layout=None,
                           shapes=shapes or None)
            res = pop_mod.POPResult(
                alloc=arr("alloc"), idx=idx, solve_time_s=0.0,
                build_time_s=0.0, iterations=arr("iterations"),
                converged=arr("converged"), similarity={},
                sub_objectives=np.zeros(k, np.float32), x=x, y=y, plan=plan)
            self.seed(res, mode="pop")
            return
        if mode == "full":
            x, y = arr("x"), arr("y")
            res = SolveResult(
                x=x, y=y, primal_obj=arr("primal_obj"),
                dual_obj=np.float32(0.0), primal_res=np.float32(np.inf),
                gap=np.float32(np.inf), iterations=arr("iterations"),
                converged=arr("converged"))
            kind = tmeta.get("full_ids_kind", "none")
            if kind == "pos":
                entity_ids = int(tmeta["full_ids"])
            elif kind == "ids":
                entity_ids = tmeta["full_ids"]
            else:
                entity_ids = None
            self.seed(res, mode="full", entity_ids=entity_ids)
            return
        raise ckpt_mod.CheckpointError(
            f"unknown session checkpoint mode {mode!r}")


class PopService:
    """Long-lived, multi-tenant POP solving service.

    Owns the default configs and the per-tenant sessions (warm state +
    plans); compiled solvers are shared across sessions whose
    :class:`ExecConfig` matches (the configs are hashable and key the jit
    caches in ``core/backends.py``).

    All shared state (the session table, stats, the deadline ladder's
    rate maps, the LRU/pager bookkeeping) mutates under one service lock;
    per-tenant warm state mutates under that tenant's session lock.
    ``dispatch=`` turns on the cross-tenant micro-batching dispatcher,
    ``max_resident=`` the host-memory paging of cold tenants — see the
    module docstring and docs/SERVING.md."""

    def __init__(self, solve: Optional[SolveConfig] = None,
                 exec: Optional[ExecConfig] = None, *,
                 dispatch: Union[bool, DispatchConfig, None] = None,
                 max_resident: Optional[int] = None,
                 rate_cache_size: int = RATE_CACHE_SIZE,
                 profile: Union[TuningProfile, str, None]
                 = None):
        # None means "not set" (domain defaults win); an explicit config —
        # even one equal to the library default — overrides them
        self._service_solve = solve
        self._service_exec = exec
        self.solve_cfg = solve or SolveConfig()
        self.exec_cfg = exec or ExecConfig()
        # the measured TuningProfile (docs/TUNING.md): validated here
        # (version + digest seal), it feeds session(slo=...) planning,
        # installs measured backend="auto" thresholds, and sizes
        # DispatchConfig defaults from the launch-cost line
        if profile is not None and not isinstance(profile,
                                                  TuningProfile):
            profile = load_profile(profile)
        if profile is not None:
            check_profile(profile)
            backends_mod.install_tuned_thresholds(profile.backend_thresholds)
        self.profile = profile
        self._lock = threading.RLock()
        self._sessions: Dict[str, PopSession] = {}
        # tenant -> None, oldest-stepped first: the page-out victim order
        self._lru: "OrderedDict[str, None]" = OrderedDict()
        self._stats = _zeros()
        self._stats.update({"paged_out": 0, "paged_in": 0,
                            "page_restore_failures": 0,
                            "session_reentries": 0})
        # measured per-iteration solve rates + per-step overheads, keyed
        # (path, domain, ExecConfig, k, n_entities) — the deadline ladder's
        # budget model, warmed by every fault-free step; bounded so a
        # fleet's shape churn cannot grow them without limit
        self._rates: "_BoundedLRU" = _BoundedLRU(rate_cache_size)
        self._overheads: "_BoundedLRU" = _BoundedLRU(rate_cache_size)
        self._pager = paged_mod.PagedSessionStore()
        self.max_resident = (None if max_resident is None
                             else max(int(max_resident), 1))
        if dispatch:
            if isinstance(dispatch, DispatchConfig):
                cfg = dispatch
            else:
                # dispatch=True with a profile: batching window + lane cap
                # from the measured launch-cost line instead of the
                # hard-coded defaults
                tuned = (launch_defaults(profile)
                         if profile is not None else None)
                cfg = DispatchConfig(**tuned) if tuned else None
            self.dispatcher: Optional[MicroBatchDispatcher] = \
                MicroBatchDispatcher(cfg)
        else:
            self.dispatcher = None
        self._executor: \
            Optional[concurrent.futures.ThreadPoolExecutor] = None
        self.created = time.time()

    # ------------------------------------------------------ solve funnels --
    def _solve_instance(self, problem, scfg, exec_cfg, *, warm,
                        entity_ids, **kw) -> "pop_mod.POPResult":
        """Every session pop-path solve funnels through here: without a
        dispatcher this IS the legacy call (same bytes, same jit keys);
        with one, the pre/post stages run on the calling thread and only
        the map-step launch goes through the dispatcher."""
        if self.dispatcher is None:
            return pop_mod.solve_instance(problem, scfg, exec_cfg,
                                          warm=warm, entity_ids=entity_ids,
                                          **kw)
        prep = pop_mod.prepare_instance(problem, scfg, exec_cfg, warm=warm,
                                        entity_ids=entity_ids, **kw)
        res, solve_s = self.dispatcher.solve_prepared(
            prep, problem.K_mv, problem.KT_mv)
        return pop_mod.finish_prepared(prep, res, solve_s)

    def _solve_full(self, problem, warm, exec_cfg) -> "pop_mod.FullResult":
        """The k=1 counterpart of :meth:`_solve_instance`."""
        if self.dispatcher is None:
            return pop_mod.solve_full_ex(problem, warm=warm,
                                         exec_cfg=exec_cfg)
        prep = pop_mod.prepare_full(problem, warm=warm, exec_cfg=exec_cfg)
        res, solve_s = self.dispatcher.solve_prepared(
            prep, problem.K_mv, problem.KT_mv)
        return pop_mod.finish_full(prep, res, solve_s)

    def _submit(self, fn, *args, **kw) -> "concurrent.futures.Future":
        with self._lock:
            if self._executor is None:
                workers = (self.dispatcher.cfg.workers if self.dispatcher
                           else DispatchConfig.workers)
                self._executor = concurrent.futures.ThreadPoolExecutor(
                    max_workers=workers, thread_name_prefix="pop-step")
            ex = self._executor
        return ex.submit(fn, *args, **kw)

    def session(self, tenant: str, instance: Any = None, *,
                domain: Optional[str] = None,
                solve: Optional[SolveConfig] = None,
                exec: Optional[ExecConfig] = None,
                slo: Optional[SLOTarget] = None) -> PopSession:
        """The session for ``tenant``, created on first use.

        The domain comes from ``domain=`` (a registry name) or is inferred
        from ``instance``'s type (``repro.domains.spec_for``).  Configs
        default to the domain's registered defaults, overridden by the
        service-level configs only where the caller set them explicitly at
        service construction, then by ``solve=`` / ``exec=`` here.  An
        existing session is returned as-is (its configs are pinned at
        creation); asking for the same tenant with a DIFFERENT domain is
        an error — tenants are per-domain state.

        ``slo=`` (an :class:`repro.tuning.SLOTarget`) makes the session
        **auto-tuned**: the service's measured ``profile=`` plans the
        initial ``SolveConfig`` for the instance (``solve=`` then only
        sets the strategy/seed baseline the planner starts from) and an
        online refiner re-plans on violated or newly-slack SLOs
        (docs/TUNING.md).  The SLO is pinned like the configs.

        A tenant whose session was paged out to host memory (see
        ``max_resident=``) is restored transparently here: same warm
        state, same step counter — callers cannot tell it was ever cold
        (``stats()["paged_in"]`` can)."""
        if slo is not None and not isinstance(slo, SLOTarget):
            raise TypeError(f"slo= takes a repro.tuning.SLOTarget, got "
                            f"{type(slo).__name__}")
        with self._lock:
            sess = self._sessions.get(tenant)
            if sess is None and tenant in self._pager:
                sess = self._page_in(tenant)
                if sess is not None:
                    self._stats["session_reentries"] += 1
            if sess is not None:
                # configs are pinned at creation: explicitly asking for a
                # DIFFERENT one must not be silently ignored
                if slo is not None and slo != sess.slo:
                    raise ValueError(
                        f"tenant {tenant!r} session is pinned to SLO "
                        f"{sess.slo}; end_session() it to re-create with "
                        f"{slo} (the SLO is set at session creation)")
                # a tuned session's solve_cfg drifts by design: the pin to
                # compare against is the baseline the planner started from
                pinned_solve = (sess._tuner.base_solve
                                if sess._tuner is not None
                                else sess.solve_cfg)
                if solve is not None and solve != pinned_solve:
                    raise ValueError(
                        f"tenant {tenant!r} session is pinned to "
                        f"{pinned_solve}; end_session() it to re-create "
                        f"with {solve} (configs are set at session creation)")
                if exec is not None and exec != sess.exec_cfg:
                    raise ValueError(
                        f"tenant {tenant!r} session is pinned to "
                        f"{sess.exec_cfg}; end_session() it to re-create "
                        f"with {exec} (configs are set at session creation)")
            if domain is not None:
                spec = registry_mod.get(domain)
            elif instance is not None:
                spec = registry_mod.spec_for(instance)
                if spec is None:
                    raise ValueError(
                        f"no registered domain matches instance type "
                        f"{type(instance).__name__!r}; register a DomainSpec "
                        "with that instance_types or pass domain=")
            elif sess is not None:
                return sess              # re-entry by tenant name alone
            else:
                raise ValueError("session() needs an instance (to infer the "
                                 "domain) or an explicit domain= name")
            if sess is not None:
                if sess.spec.name != spec.name:
                    raise ValueError(
                        f"tenant {tenant!r} already has a {sess.spec.name!r} "
                        f"session; one tenant cannot switch to {spec.name!r} "
                        "(sessions are per-domain warm state)")
                return sess
            solve_cfg = solve or self._service_solve or spec.default_solve
            exec_cfg = exec or self._service_exec or spec.default_exec
            tuner = None
            if slo is not None:
                tuner = OnlineTuner(self.profile, spec.name,
                                               slo, solve_cfg, exec_cfg)
                if instance is not None and spec.step_override is None:
                    n = spec.make_problem(instance).n_entities
                    solve_cfg = tuner.plan_initial(n)
                # no instance yet: the first generic step plans
                # (ensure_planned) once it knows the entity count
            sess = PopSession(self, tenant, spec, solve_cfg, exec_cfg,
                              slo=slo, tuner=tuner)
            self._sessions[tenant] = sess
            self._lru[tenant] = None
        self._maybe_evict(keep=tenant)
        return sess

    def end_session(self, tenant: str) -> None:
        """Drop a tenant's session — live warm state, cached plan, LRU
        slot AND any paged-out blob; the tenant is fully forgotten."""
        with self._lock:
            self._sessions.pop(tenant, None)
            self._lru.pop(tenant, None)
        self._pager.discard(tenant)

    def tenants(self) -> tuple:
        """Every known tenant, resident or paged out."""
        with self._lock:
            names = set(self._sessions)
        return tuple(sorted(names | set(self._pager.tenants())))

    # ----------------------------------------------------- paging (LRU) --
    def _page_in(self, tenant: str) -> Optional[PopSession]:
        """Rebuild a resident session from the tenant's paged blob.
        Called under the service lock.  A corrupt/unreadable blob counts
        ``page_restore_failures`` and returns None (the caller then
        creates a fresh cold session)."""
        try:
            got = self._pager.take(tenant)
        except ckpt_mod.CheckpointError:
            got = None
        if got is None:
            self._stats["page_restore_failures"] += 1
            return None
        tmeta, arrays = got
        try:
            spec = registry_mod.get(tmeta["domain"])
            sess = PopSession(self, tenant, spec, self._cfg_solve(tmeta),
                              self._cfg_exec(tmeta))
        except Exception:
            # unknown domain / mangled config meta: the blob cannot seed a
            # session — fall back to fresh creation by the caller
            self._stats["page_restore_failures"] += 1
            return None
        sess.steps = int(tmeta.get("steps", 0))
        st = tmeta.get("stats")
        if isinstance(st, dict):
            sess.stats = {**_zeros(), **st}
        try:
            sess._restore_payload(tmeta, arrays)
        except Exception:
            # warm state didn't survive; the session itself did (cold)
            self._stats["page_restore_failures"] += 1
        self._sessions[tenant] = sess
        self._lru[tenant] = None
        self._stats["paged_in"] += 1
        return sess

    def _reattach(self, sess: PopSession) -> None:
        """First thing every ``step`` does (under the session lock): make
        sure this object IS the resident session.  A handle whose tenant
        was paged out re-registers and reloads its warm state from the
        blob; a handle that still carries live state just re-registers."""
        with self._lock:
            if self._sessions.get(sess.tenant) is sess:
                return
            self._sessions[sess.tenant] = sess
            self._lru[sess.tenant] = None
            self._lru.move_to_end(sess.tenant)
        if sess._warm is not None:
            # the handle still carries its own (newest) state; any blob is
            # stale — drop it rather than resurrect old iterates later
            self._pager.discard(sess.tenant)
            return
        try:
            got = self._pager.take(sess.tenant)
        except ckpt_mod.CheckpointError:
            got = None
            with self._lock:
                self._stats["page_restore_failures"] += 1
        if got is None:
            return
        tmeta, arrays = got
        try:
            sess._restore_payload(tmeta, arrays)
            sess.steps = int(tmeta.get("steps", sess.steps))
            with self._lock:
                self._stats["paged_in"] += 1
        except Exception:
            with self._lock:
                self._stats["page_restore_failures"] += 1

    def _after_step(self, sess: PopSession) -> None:
        with self._lock:
            if sess.tenant in self._sessions:
                self._lru[sess.tenant] = None
                self._lru.move_to_end(sess.tenant)
        self._maybe_evict(keep=sess.tenant)

    def _maybe_evict(self, keep: Optional[str] = None) -> None:
        """Page the coldest resident sessions out until at most
        ``max_resident`` stay live.  One pass over the current LRU order:
        victims busy in a step (non-blocking try-acquire — lock order
        forbids waiting on a session lock from service paths) or carrying
        unserializable warm state are skipped, so the cap is best-effort
        under pathological loads, exact in steady state."""
        if self.max_resident is None:
            return
        with self._lock:
            over = len(self._sessions) - self.max_resident
            if over <= 0:
                return
            candidates = [t for t in self._lru
                          if t != keep and t in self._sessions]
        for tenant in candidates:
            if over <= 0:
                return
            with self._lock:
                victim = self._sessions.get(tenant)
            if victim is not None and self._page_out(victim):
                over -= 1

    def _page_out(self, sess: PopSession) -> bool:
        """Move one resident session's state to the host-memory pager.
        Returns False without side effects when the session is mid-step,
        its warm state cannot serialize (step_override domains, replicated
        plans — evicting those would DESTROY state), or the codec balks."""
        if not sess._lock.acquire(blocking=False):
            return False
        try:
            meta, arrays = sess._checkpoint_payload("t0")
            if meta.get("mode") == "skipped":
                return False
            meta = {**meta, "stats": dict(sess.stats,
                                          engines=dict(sess.stats["engines"]))}
            try:
                json.dumps(meta)
                self._pager.put(sess.tenant, meta, arrays)
            except (ckpt_mod.CheckpointError, TypeError, ValueError):
                return False
            # strip the object so its device arrays free even while the
            # caller keeps a handle; a later step on the handle reloads
            # from the blob (see _reattach)
            sess._warm, sess._mode = None, None
            sess.last = None
            with self._lock:
                self._sessions.pop(sess.tenant, None)
                self._lru.pop(sess.tenant, None)
                self._stats["paged_out"] += 1
        finally:
            sess._lock.release()
        return True

    # --------------------------------------------------- checkpoint/restore --
    def checkpoint(self) -> bytes:
        """Serialize every tenant session's warm state to one bytes blob.

        The blob (format: ``repro.checkpoint.session_state``) carries, per
        tenant: the domain name, the pinned configs + their digest, the
        step counter, and the warm state — PopPlan arrays + solver
        iterates + entity ids (pop path) or the flat iterates + id key
        (full path).  Warm state the format cannot express (replicated
        plans, step_override domains' opaque state) is recorded as
        ``skipped`` and restores cold.  Paged-out tenants are folded in
        from their blobs WITHOUT touching device memory.  Safe mid-traffic
        (each session snapshots under its own lock; the service lock is
        never held while waiting on one).  Round-trip with
        :meth:`restore`."""
        with self._lock:
            resident = dict(self._sessions)
        paged: Dict[str, tuple] = {}
        for tenant in self._pager.tenants():
            if tenant in resident:
                continue
            blob = self._pager.peek_packed(tenant)
            if blob is None:
                continue
            try:
                paged[tenant] = ckpt_mod.unpack_state(blob)
            except ckpt_mod.CheckpointError:
                with self._lock:
                    self._stats["checkpoint_failures"] += 1
        tenants_meta: Dict[str, dict] = {}
        arrays: Dict[str, np.ndarray] = {}
        for i, tenant in enumerate(sorted(set(resident) | set(paged))):
            prefix = f"t{i}"
            if tenant in resident:
                sess = resident[tenant]
                with sess._lock:
                    meta, arrs = sess._checkpoint_payload(prefix)
                try:
                    json.dumps(meta)
                except (TypeError, ValueError):
                    meta = {"prefix": prefix, "domain": sess.spec.name,
                            "mode": "skipped",
                            "reason": "non-JSON-serializable session config"}
                    arrs = {}
            else:
                # a paged blob is itself a single-tenant checkpoint under
                # the "t0" prefix: remap keys onto this blob's slot
                tmeta, tarrs = paged[tenant]
                meta = {k: v for k, v in tmeta.items() if k != "stats"}
                meta["prefix"] = prefix
                arrs = {f"{prefix}/{k.split('/', 1)[1]}": v
                        for k, v in tarrs.items()}
            tenants_meta[tenant] = meta
            arrays.update(arrs)
        return ckpt_mod.pack_state({"tenants": tenants_meta}, arrays)

    def restore(self, data: bytes, *, strict: bool = False) -> dict:
        """Restore tenant sessions from a :meth:`checkpoint` blob.

        Integrity (content hash, magic, version) is checked by the format;
        alignment (config digest, plan-vs-iterate shapes, entity-id
        counts) per tenant here.  Any failure DEGRADES: the blob — or just
        the offending tenant — restores cold and the failure lands in the
        returned report (``{"restored": [...], "cold": [...], "errors":
        {...}}``) and ``stats()["checkpoint_failures"]``; nothing raises
        unless ``strict=True``."""
        report = {"restored": [], "cold": [], "errors": {}}
        try:
            meta, arrays = ckpt_mod.unpack_state(data)
            tenants = meta["tenants"]
            if not isinstance(tenants, dict):
                raise ckpt_mod.CheckpointError("manifest meta lacks a "
                                               "tenants table")
        except (ckpt_mod.CheckpointError, KeyError, TypeError) as e:
            with self._lock:
                self._stats["checkpoint_failures"] += 1
            if strict:
                raise
            report["errors"]["<checkpoint>"] = f"{type(e).__name__}: {e}"
            return report
        for tenant in sorted(tenants):
            tmeta = tenants[tenant]
            try:
                sess = self.session(tenant, domain=tmeta["domain"],
                                    solve=self._cfg_solve(tmeta),
                                    exec=self._cfg_exec(tmeta))
                if ckpt_mod.config_digest(sess.solve_cfg, sess.exec_cfg) \
                        != tmeta.get("digest"):
                    raise ckpt_mod.CheckpointError(
                        "config digest mismatch (stale checkpoint or "
                        "changed config schema)")
                sess.steps = int(tmeta.get("steps", 0))
                with sess._lock:
                    sess._restore_payload(tmeta, arrays)
            except Exception as e:
                with self._lock:
                    self._stats["checkpoint_failures"] += 1
                if strict:
                    raise
                report["errors"][tenant] = f"{type(e).__name__}: {e}"
                report["cold"].append(tenant)
                continue
            if sess._warm is not None:
                with self._lock:
                    self._stats["checkpoint_restores"] += 1
                report["restored"].append(tenant)
            else:
                report["cold"].append(tenant)
        return report

    @staticmethod
    def _cfg_solve(tmeta: dict) -> SolveConfig:
        return SolveConfig(**dict(tmeta["solve_cfg"]))

    @staticmethod
    def _cfg_exec(tmeta: dict) -> ExecConfig:
        e = dict(tmeta["exec_cfg"])
        return ExecConfig(backend=e["backend"], engine=e["engine"],
                          solver_kw=dict(e.get("solver_kw") or {}),
                          backend_opts=dict(e.get("backend_opts") or {}))

    def stats(self) -> dict:
        """Service-wide observability: step counts, plan-cache hit rates,
        aggregate solve time, mean warm fraction, per-engine step counts
        (``engines``: the resolved engine that actually ran each step),
        and the fault-tolerance counters (degraded/recovered/fallback
        steps, quarantined lanes, checkpoint restore outcomes), plus the
        SLO auto-tuning counters (``slo_violations`` / ``retunes`` —
        docs/TUNING.md).

        Fleet-scale additions: ``resident_sessions`` / ``paged_tenants``
        / ``paged_bytes`` (the paging tier), ``paged_out`` / ``paged_in``
        / ``page_restore_failures`` / ``session_reentries`` (its
        traffic), ``rate_evictions`` / ``rate_keys`` (the bounded ladder
        caches), and — when the service has a dispatcher — a
        ``dispatch`` sub-dict (:meth:`MicroBatchDispatcher.stats`)."""
        with self._lock:
            s = dict(self._stats)
            s["engines"] = dict(s["engines"])
            s["rate_evictions"] = (self._rates.evictions
                                   + self._overheads.evictions)
            s["rate_keys"] = len(self._rates) + len(self._overheads)
            resident = len(self._sessions)
        steps = max(s["steps"], 1)
        s["plan_hit_rate"] = s["plan_hits"] / steps
        s["warm_fraction_mean"] = (s["warm_fraction_sum"] / s["warm_steps"]
                                   if s["warm_steps"] else None)
        s["resident_sessions"] = resident
        s["paged_tenants"] = len(self._pager)
        s["paged_bytes"] = self._pager.nbytes()
        s["n_sessions"] = resident + s["paged_tenants"]
        if self.dispatcher is not None:
            s["dispatch"] = self.dispatcher.stats()
        return s

    def close(self) -> None:
        """Shut down the dispatcher thread and the ``step_async`` pool
        (idempotent).  Sessions, paged blobs and stats stay readable;
        later synchronous steps fall back to inline launches."""
        with self._lock:
            ex, self._executor = self._executor, None
        if ex is not None:
            ex.shutdown(wait=True)
        if self.dispatcher is not None:
            self.dispatcher.close()

    def __enter__(self) -> "PopService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
