"""PopService: the one public door to the paper's technique.

Every scenario — cluster scheduling, traffic engineering, load balancing,
MoE expert placement, anything registered in ``repro.domains`` — is solved
the same way:

    from repro.service import PopService
    from repro.core import SolveConfig, ExecConfig

    service = PopService()                        # long-lived, multi-tenant
    session = service.session("tenant-a", instance)   # domain inferred
    alloc = session.step(instance)                # -> Allocation
    ...
    alloc = session.step(updated_instance)        # warm-started re-solve

A :class:`PopService` is a long-lived object owning the config defaults,
the jit/plan caches (plans live on the per-tenant warm state; compiled
solvers are shared process-wide through ``core/backends.py``, keyed by the
hashable :class:`~repro.core.config.ExecConfig` contents), and the
per-tenant warm state.  A :class:`PopSession` is one tenant's stateful
view: ``step(instance)`` is the single online entry point — plan reuse,
incremental plan repair under churn (``core/plan.repair_plan``),
cross-plan warm-start remapping (``core/plan.remap_warm``), stable-id
threading and ``warm_fraction`` reporting all happen inside, so callers
stop hand-carrying ``POPResult``s between ticks.

Every step returns an :class:`Allocation` that reports the backend and
engine that ACTUALLY ran (``"auto"`` resolved — invisible to callers
before this layer existed) and how the plan cache behaved (``"hit"`` /
``"repair"`` / ``"miss"`` / ``"full"``); the service aggregates those into
:meth:`PopService.stats` for fleet dashboards and the session bench.

Domains enter through the declarative registry (``repro.domains``) — the
legacy doors (``pop_solve``, ``GavelScheduler``, ``balance_requests``)
forward here and warn.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import numpy as np

from .core import pop as pop_mod
from .core.config import ExecConfig, SolveConfig
from .core.pdhg import SolveResult
from .domains import DomainSpec, StepOutcome, registry as registry_mod

__all__ = ["Allocation", "PopService", "PopSession"]


@dataclasses.dataclass
class Allocation:
    """One session step's outcome — the uniform cross-domain result.

    ``alloc`` is the domain allocation (per-job throughputs, per-demand
    flows, a placement vector, ...), already through the domain's rounding
    hook when it has one; ``raw`` is the underlying
    :class:`~repro.core.pop.POPResult` / :class:`~repro.core.pop.FullResult`
    / domain result for callers that need solver state or sub-LP detail.
    """

    domain: str
    tenant: str
    step: int
    alloc: np.ndarray
    metrics: dict
    # observability: what ACTUALLY ran ("auto" resolved), and how the plan
    # cache behaved: "hit" (previous plan reused verbatim), "repair"
    # (incrementally repaired under churn), "miss" (fresh plan), "full"
    # (unpartitioned k=1 path)
    backend: Optional[str]
    engine: Optional[str]
    plan_cache: str
    k: int
    warm_fraction: Optional[float]
    solve_time_s: float
    build_time_s: float
    iterations: int
    raw: Any = None

    @property
    def objective(self) -> Optional[float]:
        return self.metrics.get("objective")


def _zeros() -> dict:
    return {"steps": 0, "plan_hits": 0, "plan_repairs": 0, "plan_misses": 0,
            "full_solves": 0, "solve_time_s": 0.0, "warm_fraction_sum": 0.0,
            "warm_steps": 0}


def _tally(stats: dict, alloc: Allocation) -> None:
    stats["steps"] += 1
    key = {"hit": "plan_hits", "repair": "plan_repairs",
           "full": "full_solves"}.get(alloc.plan_cache, "plan_misses")
    stats[key] += 1
    stats["solve_time_s"] += alloc.solve_time_s
    if alloc.warm_fraction is not None:
        stats["warm_fraction_sum"] += alloc.warm_fraction
        stats["warm_steps"] += 1


class PopSession:
    """One tenant's stateful solving loop for one domain.

    Holds the warm state (previous plan + iterates) between steps; every
    ``step(instance)`` re-solves the updated instance warm wherever the
    domain's layout allows, cold otherwise — the caller never touches
    solver state.  Create through :meth:`PopService.session`.
    """

    def __init__(self, service: "PopService", tenant: str, spec: DomainSpec,
                 solve_cfg: SolveConfig, exec_cfg: ExecConfig):
        self.service = service
        self.tenant = tenant
        self.spec = spec
        self.solve_cfg = solve_cfg
        self.exec_cfg = exec_cfg
        self.steps = 0
        self.last: Optional[Allocation] = None
        self.stats = _zeros()
        # warm state: a POPResult (pop path), a SolveResult (+ the ids it
        # is FOR, full path), or whatever a step_override domain carries
        self._warm: Any = None
        self._mode: Optional[str] = None
        self._full_ids: Optional[tuple] = None

    # ------------------------------------------------------------------ api --
    def seed(self, warm_state: Any, mode: Optional[str] = None,
             entity_ids=None) -> "PopSession":
        """Adopt externally carried warm state (restores a session from a
        previous process / the legacy hand-carried-result surface).

        ``mode`` is inferred from the state's type when omitted: a
        :class:`~repro.core.pop.POPResult` seeds the pop path, a
        :class:`~repro.core.pop.FullResult` / ``SolveResult`` the k=1 full
        path, anything else the domain's own ``step_override`` state.
        Restoring FULL-path state additionally needs ``entity_ids`` — the
        ids the iterates are FOR (pass the plain entity COUNT for domains
        without an ``entity_ids`` hook; the flat LP has no per-entity
        remap, only an alignment check); without them the first step
        safely starts cold."""
        if mode is None:
            if isinstance(warm_state, pop_mod.POPResult):
                mode = "pop"
            elif isinstance(warm_state, (pop_mod.FullResult, SolveResult)):
                mode = "full"
            else:
                mode = "domain"
        if mode == "full":
            if isinstance(warm_state, pop_mod.FullResult):
                warm_state = warm_state.res
            if entity_ids is None:
                self._full_ids = None
            elif np.isscalar(entity_ids):
                # positional domains: ids ARE positions, so the alignment
                # key is just the entity count (see _step_generic)
                self._full_ids = ("pos", int(entity_ids))
            else:
                self._full_ids = tuple(np.asarray(entity_ids).tolist())
        self._warm = warm_state
        self._mode = mode if warm_state is not None else None
        return self

    def step(self, instance: Any) -> Allocation:
        """Solve the (updated) instance; warm-start from the previous step
        wherever the domain allows.  The single online entry point."""
        if self.spec.step_override is not None:
            out: StepOutcome = self.spec.step_override(
                instance, self.solve_cfg, self.exec_cfg, self._warm)
            self._warm, self._mode = out.warm_state, "domain"
            alloc = self._wrap(
                instance, out.alloc, out.metrics, backend=out.backend,
                engine=out.engine, plan_cache=out.plan_cache, k=out.k,
                warm_fraction=out.warm_fraction,
                solve_time_s=out.solve_time_s,
                build_time_s=out.build_time_s,
                iterations=out.iterations, raw=out.raw)
        else:
            alloc = self._step_generic(instance)
        self.steps += 1
        _tally(self.stats, alloc)
        _tally(self.service._stats, alloc)
        self.last = alloc
        return alloc

    # ------------------------------------------------------- generic domains --
    def _step_generic(self, instance: Any) -> Allocation:
        spec = self.spec
        problem = spec.make_problem(instance)
        eids = spec.ids_of(instance)
        k = self.solve_cfg.k_for(problem.n_entities)
        if k > 1:
            warm = self._warm if self._mode == "pop" else None
            res = pop_mod.solve_instance(
                problem, dataclasses.replace(self.solve_cfg, k=k),
                self.exec_cfg, warm=warm, entity_ids=eids)
            self._warm, self._mode = res, "pop"
            raw_alloc = res.alloc
            cache = {"reused": "hit", "repaired": "repair"}.get(
                res.plan_source, "miss")
            wf = res.warm_stats["warm_fraction"] if res.warm_stats else None
            out = self._wrap(
                instance, raw_alloc, None, problem=problem,
                backend=res.backend, engine=res.engine, plan_cache=cache,
                k=k, warm_fraction=wf, solve_time_s=res.solve_time_s,
                build_time_s=res.build_time_s,
                iterations=int(np.asarray(res.iterations).sum()), raw=res)
            return out
        # ---- k=1: the unpartitioned full problem through the same substrate.
        # The flat LP has no per-entity remap, so warm only while the entity
        # identity sequence is unchanged (a same-size swap would silently
        # misalign rows); crossing the pop<->full mode boundary drops warm.
        ids_key = (tuple(np.asarray(eids).tolist()) if eids is not None
                   else ("pos", problem.n_entities))
        warm = self._warm if self._mode == "full" else None
        if warm is not None and (self._full_ids is None
                                 or ids_key != self._full_ids):
            warm = None
        fr = pop_mod.solve_full_ex(problem, warm=warm, exec_cfg=self.exec_cfg)
        self._warm, self._mode = fr.res, "full"
        self._full_ids = ids_key
        return self._wrap(
            instance, fr.alloc, None, problem=problem, backend=fr.backend,
            engine=fr.engine, plan_cache="full", k=1,
            warm_fraction=None if warm is None else 1.0,
            solve_time_s=fr.solve_time_s, build_time_s=fr.build_time_s,
            iterations=int(np.asarray(fr.res.iterations).sum()), raw=fr)

    def _wrap(self, instance, raw_alloc, metrics, *, backend, engine,
              plan_cache, k, warm_fraction, solve_time_s, build_time_s=0.0,
              iterations=0, raw=None, problem=None) -> Allocation:
        alloc = raw_alloc
        if self.spec.round is not None and self.spec.step_override is None:
            alloc = self.spec.round(instance, raw_alloc)
        if metrics is None:
            metrics = self.spec.metrics_of(instance, problem, alloc)
        return Allocation(
            domain=self.spec.name, tenant=self.tenant, step=self.steps,
            alloc=alloc, metrics=metrics, backend=backend, engine=engine,
            plan_cache=plan_cache, k=k, warm_fraction=warm_fraction,
            solve_time_s=solve_time_s, build_time_s=build_time_s,
            iterations=iterations, raw=raw)


class PopService:
    """Long-lived, multi-tenant POP solving service.

    Owns the default configs and the per-tenant sessions (warm state +
    plans); compiled solvers are shared across sessions whose
    :class:`ExecConfig` matches (the configs are hashable and key the jit
    caches in ``core/backends.py``)."""

    def __init__(self, solve: Optional[SolveConfig] = None,
                 exec: Optional[ExecConfig] = None):
        # None means "not set" (domain defaults win); an explicit config —
        # even one equal to the library default — overrides them
        self._service_solve = solve
        self._service_exec = exec
        self.solve_cfg = solve or SolveConfig()
        self.exec_cfg = exec or ExecConfig()
        self._sessions: Dict[str, PopSession] = {}
        self._stats = _zeros()
        self.created = time.time()

    def session(self, tenant: str, instance: Any = None, *,
                domain: Optional[str] = None,
                solve: Optional[SolveConfig] = None,
                exec: Optional[ExecConfig] = None) -> PopSession:
        """The session for ``tenant``, created on first use.

        The domain comes from ``domain=`` (a registry name) or is inferred
        from ``instance``'s type (``repro.domains.spec_for``).  Configs
        default to the domain's registered defaults, overridden by the
        service-level configs only where the caller set them explicitly at
        service construction, then by ``solve=`` / ``exec=`` here.  An
        existing session is returned as-is (its configs are pinned at
        creation); asking for the same tenant with a DIFFERENT domain is
        an error — tenants are per-domain state."""
        sess = self._sessions.get(tenant)
        if sess is not None:
            # configs are pinned at creation: explicitly asking for a
            # DIFFERENT one must not be silently ignored
            if solve is not None and solve != sess.solve_cfg:
                raise ValueError(
                    f"tenant {tenant!r} session is pinned to "
                    f"{sess.solve_cfg}; end_session() it to re-create with "
                    f"{solve} (configs are set at session creation)")
            if exec is not None and exec != sess.exec_cfg:
                raise ValueError(
                    f"tenant {tenant!r} session is pinned to "
                    f"{sess.exec_cfg}; end_session() it to re-create with "
                    f"{exec} (configs are set at session creation)")
        if domain is not None:
            spec = registry_mod.get(domain)
        elif instance is not None:
            spec = registry_mod.spec_for(instance)
            if spec is None:
                raise ValueError(
                    f"no registered domain matches instance type "
                    f"{type(instance).__name__!r}; register a DomainSpec "
                    "with that instance_types or pass domain=")
        elif sess is not None:
            return sess                  # re-entry by tenant name alone
        else:
            raise ValueError("session() needs an instance (to infer the "
                             "domain) or an explicit domain= name")
        if sess is not None:
            if sess.spec.name != spec.name:
                raise ValueError(
                    f"tenant {tenant!r} already has a {sess.spec.name!r} "
                    f"session; one tenant cannot switch to {spec.name!r} "
                    "(sessions are per-domain warm state)")
            return sess
        sess = PopSession(
            self, tenant, spec,
            solve or self._service_solve or spec.default_solve,
            exec or self._service_exec or spec.default_exec)
        self._sessions[tenant] = sess
        return sess

    def end_session(self, tenant: str) -> None:
        """Drop a tenant's session (and its warm state / cached plan)."""
        self._sessions.pop(tenant, None)

    def tenants(self) -> tuple:
        return tuple(sorted(self._sessions))

    def stats(self) -> dict:
        """Service-wide observability: step counts, plan-cache hit rates,
        aggregate solve time, mean warm fraction."""
        s = dict(self._stats)
        steps = max(s["steps"], 1)
        s["plan_hit_rate"] = s["plan_hits"] / steps
        s["warm_fraction_mean"] = (s["warm_fraction_sum"] / s["warm_steps"]
                                   if s["warm_steps"] else None)
        s["n_sessions"] = len(self._sessions)
        return s
