"""PopService: the one public door to the paper's technique.

Every scenario — cluster scheduling, traffic engineering, load balancing,
MoE expert placement, anything registered in ``repro.domains`` — is solved
the same way:

    from repro.service import PopService
    from repro.core import SolveConfig, ExecConfig

    service = PopService()                        # long-lived, multi-tenant
    session = service.session("tenant-a", instance)   # domain inferred
    alloc = session.step(instance)                # -> Allocation
    ...
    alloc = session.step(updated_instance)        # warm-started re-solve

A :class:`PopService` is a long-lived object owning the config defaults,
the jit/plan caches (plans live on the per-tenant warm state; compiled
solvers are shared process-wide through ``core/backends.py``, keyed by the
hashable :class:`~repro.core.config.ExecConfig` contents), and the
per-tenant warm state.  A :class:`PopSession` is one tenant's stateful
view: ``step(instance)`` is the single online entry point — plan reuse,
incremental plan repair under churn (``core/plan.repair_plan``),
cross-plan warm-start remapping (``core/plan.remap_warm``), stable-id
threading and ``warm_fraction`` reporting all happen inside, so callers
stop hand-carrying ``POPResult``s between ticks.

Every step returns an :class:`Allocation` that reports the backend and
engine that ACTUALLY ran (``"auto"`` resolved — invisible to callers
before this layer existed) and how the plan cache behaved (``"hit"`` /
``"repair"`` / ``"miss"`` / ``"full"``); the service aggregates those into
:meth:`PopService.stats` for fleet dashboards and the session bench.

Serving is fault-tolerant (docs/ROBUSTNESS.md): ``step`` never returns a
non-finite allocation.  Diverged solver lanes (``POPResult.diverged``,
detected in-loop by ``pdhg.solve_stacked``) quarantine the poisoned warm
state and cold-restart only the affected lanes; ``step(deadline_s=...)``
budgets iterations from a measured per-iteration rate and degrades
through a ladder (full solve → capped/relaxed solve → best-effort chunk →
previous allocation / domain greedy); ``Allocation.status`` reports the
rung taken (``ok``/``degraded``/``recovered``/``fallback``).
:meth:`PopService.checkpoint` / :meth:`PopService.restore` serialize every
tenant's warm state to bytes (``repro.checkpoint.session_state``) for
rolling restarts — corrupt or stale blobs degrade to cold starts.

Domains enter through the declarative registry (``repro.domains``) — the
legacy doors (``pop_solve``, ``GavelScheduler``, ``balance_requests``)
forward here and warn.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Optional

import numpy as np

from .checkpoint import session_state as ckpt_mod
from .core import pop as pop_mod
from .core.config import ExecConfig, SolveConfig
from .core.pdhg import SolveResult
from .core.plan import PopPlan
from .domains import DomainSpec, StepOutcome, registry as registry_mod

__all__ = ["Allocation", "PopService", "PopSession"]


@dataclasses.dataclass
class Allocation:
    """One session step's outcome — the uniform cross-domain result.

    ``alloc`` is the domain allocation (per-job throughputs, per-demand
    flows, a placement vector, ...), already through the domain's rounding
    hook when it has one; ``raw`` is the underlying
    :class:`~repro.core.pop.POPResult` / :class:`~repro.core.pop.FullResult`
    / domain result for callers that need solver state or sub-LP detail.

    ``status`` is the degradation-ladder rung the step landed on
    (docs/ROBUSTNESS.md): ``"ok"`` (normal solve), ``"degraded"`` (solve
    ran with a deadline-capped iteration budget / relaxed tolerance),
    ``"recovered"`` (a fault — diverged lanes, poisoned warm state — was
    quarantined and re-solved), ``"fallback"`` (no solve result; ``alloc``
    is the previous allocation or the domain's greedy).  ``faults`` lists
    what happened on the way (``"divergence:2"``, ``"deadline:capped"``,
    ``"warm-state-mismatch"``, ...); empty on clean steps.
    """

    domain: str
    tenant: str
    step: int
    alloc: np.ndarray
    metrics: dict
    # observability: what ACTUALLY ran ("auto" resolved), and how the plan
    # cache behaved: "hit" (previous plan reused verbatim), "repair"
    # (incrementally repaired under churn), "miss" (fresh plan), "full"
    # (unpartitioned k=1 path), "fallback" (no solve ran)
    backend: Optional[str]
    engine: Optional[str]
    plan_cache: str
    k: int
    warm_fraction: Optional[float]
    solve_time_s: float
    build_time_s: float
    iterations: int
    raw: Any = None
    status: str = "ok"
    faults: tuple = ()

    @property
    def objective(self) -> Optional[float]:
        return self.metrics.get("objective")


def _zeros() -> dict:
    return {"steps": 0, "plan_hits": 0, "plan_repairs": 0, "plan_misses": 0,
            "full_solves": 0, "solve_time_s": 0.0, "warm_fraction_sum": 0.0,
            "warm_steps": 0,
            # fault-tolerance counters (docs/ROBUSTNESS.md): ladder rungs
            # taken, solver lanes cold-restarted by the divergence guard,
            # total faults recorded, checkpoint restore outcomes
            "degraded_steps": 0, "recovered_steps": 0, "fallback_steps": 0,
            "quarantined_lanes": 0, "faults": 0,
            "checkpoint_restores": 0, "checkpoint_failures": 0,
            # resolved step-engine observability: engine name -> steps
            # that actually ran it ("auto" already resolved)
            "engines": {}}


def _tally(stats: dict, alloc: Allocation) -> None:
    stats["steps"] += 1
    if alloc.status == "fallback":
        pass        # no solve ran — the plan cache was never consulted
    else:
        key = {"hit": "plan_hits", "repair": "plan_repairs",
               "full": "full_solves"}.get(alloc.plan_cache, "plan_misses")
        stats[key] += 1
    if alloc.status != "ok":
        stats[alloc.status + "_steps"] += 1
    stats["faults"] += len(alloc.faults)
    stats["solve_time_s"] += alloc.solve_time_s
    if alloc.engine:
        eng = stats["engines"]
        eng[alloc.engine] = eng.get(alloc.engine, 0) + 1
    if alloc.warm_fraction is not None:
        stats["warm_fraction_sum"] += alloc.warm_fraction
        stats["warm_steps"] += 1


def _finite(alloc) -> bool:
    """Is every numeric entry of an allocation finite?"""
    try:
        arr = np.asarray(alloc, dtype=float)
    except (TypeError, ValueError):
        return True     # non-numeric allocation: nothing to check
    return bool(np.isfinite(arr).all())


def _pop_warm_ok(warm) -> bool:
    """Is a pop-mode warm state internally consistent (plan present,
    iterates present and shaped like the plan says)?  Catches dropped or
    mismatched warm state — a bad restore, an injector, a stale seed —
    BEFORE it reaches the solver."""
    plan = getattr(warm, "plan", None)
    x, y = getattr(warm, "x", None), getattr(warm, "y", None)
    if plan is None or x is None or y is None:
        return False
    shapes = getattr(plan, "shapes", None) or {}
    for name, arr in (("x", x), ("y", y)):
        want = shapes.get(name)
        if want is not None and tuple(np.shape(arr)) != tuple(want):
            return False
    return True


def _count_diverged(res) -> int:
    div = getattr(res, "diverged", None)
    return 0 if div is None else int(np.asarray(div).sum())


class PopSession:
    """One tenant's stateful solving loop for one domain.

    Holds the warm state (previous plan + iterates) between steps; every
    ``step(instance)`` re-solves the updated instance warm wherever the
    domain's layout allows, cold otherwise — the caller never touches
    solver state.  Create through :meth:`PopService.session`.
    """

    def __init__(self, service: "PopService", tenant: str, spec: DomainSpec,
                 solve_cfg: SolveConfig, exec_cfg: ExecConfig):
        self.service = service
        self.tenant = tenant
        self.spec = spec
        self.solve_cfg = solve_cfg
        self.exec_cfg = exec_cfg
        self.steps = 0
        self.last: Optional[Allocation] = None
        self.stats = _zeros()
        # warm state: a POPResult (pop path), a SolveResult (+ the ids it
        # is FOR, full path), or whatever a step_override domain carries
        self._warm: Any = None
        self._mode: Optional[str] = None
        self._full_ids: Optional[tuple] = None
        # wall time of the most recent step (the deadline predictor for
        # step_override domains, which have no iteration-rate model)
        self._last_wall: Optional[float] = None

    # ------------------------------------------------------------------ api --
    def seed(self, warm_state: Any, mode: Optional[str] = None,
             entity_ids=None) -> "PopSession":
        """Adopt externally carried warm state (restores a session from a
        previous process / the legacy hand-carried-result surface).

        ``mode`` is inferred from the state's type when omitted: a
        :class:`~repro.core.pop.POPResult` seeds the pop path, a
        :class:`~repro.core.pop.FullResult` / ``SolveResult`` the k=1 full
        path, anything else the domain's own ``step_override`` state.
        An explicit ``mode`` is validated against the state's type — a
        mismatch raises here, with a clear message, instead of failing
        deep inside ``solve_instance``.  Restoring FULL-path state
        additionally needs ``entity_ids`` — the ids the iterates are FOR
        (pass the plain entity COUNT for domains without an
        ``entity_ids`` hook; the flat LP has no per-entity remap, only an
        alignment check); without them the first step safely starts cold."""
        if warm_state is None:
            self._warm, self._mode = None, None
            return self
        if mode is None:
            if isinstance(warm_state, pop_mod.POPResult):
                mode = "pop"
            elif isinstance(warm_state, (pop_mod.FullResult, SolveResult)):
                mode = "full"
            else:
                mode = "domain"
        elif mode not in ("pop", "full", "domain"):
            raise ValueError(f"seed(): unknown mode {mode!r}; expected "
                             "'pop', 'full' or 'domain'")
        if mode == "pop":
            if not isinstance(warm_state, pop_mod.POPResult):
                raise TypeError(
                    f"seed(mode='pop') needs a POPResult, got "
                    f"{type(warm_state).__name__} — pass mode='full' for "
                    "FullResult/SolveResult state or mode='domain' for a "
                    "step_override domain's own state")
            if warm_state.x is None or warm_state.y is None:
                raise ValueError(
                    "seed(mode='pop'): POPResult carries no solver "
                    "iterates (x/y are None) — it cannot warm-start")
        if mode == "full":
            if not isinstance(warm_state, (pop_mod.FullResult, SolveResult)):
                raise TypeError(
                    f"seed(mode='full') needs a FullResult or SolveResult, "
                    f"got {type(warm_state).__name__} — pass mode='pop' "
                    "for POPResult state")
            if isinstance(warm_state, pop_mod.FullResult):
                warm_state = warm_state.res
            if entity_ids is None:
                self._full_ids = None
            elif np.isscalar(entity_ids):
                # positional domains: ids ARE positions, so the alignment
                # key is just the entity count (see _step_full)
                self._full_ids = ("pos", int(entity_ids))
            else:
                self._full_ids = tuple(np.asarray(entity_ids).tolist())
        self._warm = warm_state
        self._mode = mode
        return self

    def step(self, instance: Any, *,
             deadline_s: Optional[float] = None) -> Allocation:
        """Solve the (updated) instance; warm-start from the previous step
        wherever the domain allows.  The single online entry point.

        ``deadline_s`` bounds the step's wall time: the iteration budget
        is derived from the measured per-iteration rate of previous steps
        with the same (domain, ExecConfig, shape) and the solve degrades
        down the ladder (docs/ROBUSTNESS.md) when the budget is short —
        the returned :class:`Allocation` reports the rung in ``status``.
        Without a deadline the fault-free path is byte-identical to the
        pre-deadline behavior (same jit cache keys, zero retraces)."""
        t0 = time.perf_counter()
        if self.spec.step_override is not None:
            alloc = self._step_override(instance, deadline_s, t0)
        else:
            alloc = self._step_generic(instance, deadline_s, t0)
        self.steps += 1
        self._last_wall = time.perf_counter() - t0
        _tally(self.stats, alloc)
        _tally(self.service._stats, alloc)
        self.last = alloc
        return alloc

    # ------------------------------------------------- step_override domains --
    def _step_override(self, instance: Any, deadline_s: Optional[float],
                       t0: float) -> Allocation:
        faults: list = []
        # no iteration-rate model for domain-run pipelines: if the last
        # step's wall time already blows the deadline, skip the solve
        if (deadline_s is not None and self._last_wall is not None
                and self._last_wall > deadline_s
                and (self.last is not None or self.spec.greedy is not None)):
            return self._fallback(instance, ["deadline"], t0)
        out = None
        attempts = [self._warm] + ([None] if self._warm is not None else [])
        for i, warm in enumerate(attempts):
            try:
                cand: StepOutcome = self.spec.step_override(
                    instance, self.solve_cfg, self.exec_cfg, warm)
            except Exception as e:
                faults.append(f"step-error:{type(e).__name__}")
                continue
            if not _finite(cand.alloc):
                faults.append("nonfinite-alloc")
                continue
            out = cand
            if i > 0:
                faults.append("warm-quarantined")
            break
        if out is None:
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0)
        self._warm, self._mode = out.warm_state, "domain"
        return self._wrap(
            instance, out.alloc, out.metrics, backend=out.backend,
            engine=out.engine, plan_cache=out.plan_cache, k=out.k,
            warm_fraction=out.warm_fraction,
            solve_time_s=out.solve_time_s,
            build_time_s=out.build_time_s,
            iterations=out.iterations, raw=out.raw,
            status="recovered" if faults else "ok", faults=tuple(faults))

    # ------------------------------------------------------- generic domains --
    def _step_generic(self, instance: Any, deadline_s: Optional[float],
                      t0: float) -> Allocation:
        spec = self.spec
        problem = spec.make_problem(instance)
        eids = spec.ids_of(instance)
        k = self.solve_cfg.k_for(problem.n_entities)
        if k > 1:
            return self._step_pop(instance, problem, eids, k, deadline_s, t0)
        return self._step_full(instance, problem, eids, deadline_s, t0)

    def _step_pop(self, instance, problem, eids, k: int,
                  deadline_s: Optional[float], t0: float) -> Allocation:
        faults: list = []
        warm = self._warm if self._mode == "pop" else None
        if warm is not None and not _pop_warm_ok(warm):
            faults.append("warm-state-mismatch")
            self._warm, self._mode = None, None
            warm = None
        scfg = dataclasses.replace(self.solve_cfg, k=k)
        rkey = ("pop", self.spec.name, self.exec_cfg, k, problem.n_entities)
        exec_run, rung = self._ladder(rkey, deadline_s, t0)
        if rung == "fallback":
            return self._fallback(instance, faults + ["deadline"], t0,
                                  problem=problem)
        if rung is not None:
            faults.append(f"deadline:{rung}")

        def _solve(w, **kw):
            return pop_mod.solve_instance(problem, scfg, exec_run, warm=w,
                                          entity_ids=eids, **kw)

        try:
            res = _solve(warm)
        except Exception as e:
            if warm is None:
                raise     # cold-solve errors (bad instance data) are real
            faults.append(f"warm-solve-error:{type(e).__name__}")
            self._warm, self._mode = None, None
            warm = None
            res = _solve(None)

        n_div = _count_diverged(res)
        if n_div and warm is not None:
            # quarantine: cold-restart ONLY the diverged lanes, keep the
            # plan and the healthy lanes' iterates
            faults.append(f"divergence:{n_div}")
            self._note_quarantine(n_div)
            retry = None
            try:
                retry = _solve(warm, plan=res.plan, cold_lanes=res.diverged)
            except Exception as e:
                faults.append(f"warm-solve-error:{type(e).__name__}")
            if retry is None or _count_diverged(retry):
                # quarantine didn't clear it: drop the warm state entirely
                if retry is not None:
                    self._note_quarantine(_count_diverged(retry))
                faults.append("warm-dropped")
                self._warm, self._mode = None, None
                warm = None
                res = _solve(None)
            else:
                res = retry
            n_div = _count_diverged(res)
        if n_div:
            # a COLD solve diverged: the instance itself is pathological
            # at this config — nothing left to quarantine
            faults.append(f"cold-divergence:{n_div}")
            self._note_quarantine(n_div)
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)
        if not _finite(res.alloc):
            faults.append("nonfinite-alloc")
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)

        self._warm, self._mode = res, "pop"
        self._note_rate(rkey, int(np.asarray(res.iterations).max(initial=0)),
                        res.solve_time_s, time.perf_counter() - t0)
        cache = {"reused": "hit", "repaired": "repair"}.get(
            res.plan_source, "miss")
        wf = res.warm_stats["warm_fraction"] if res.warm_stats else None
        return self._wrap(
            instance, res.alloc, None, problem=problem,
            backend=res.backend, engine=res.engine, plan_cache=cache,
            k=res.plan.k if res.plan is not None else 0,
            warm_fraction=wf, solve_time_s=res.solve_time_s,
            build_time_s=res.build_time_s,
            iterations=int(np.asarray(res.iterations).sum()), raw=res,
            status=self._status_of(faults, rung), faults=tuple(faults))

    def _step_full(self, instance, problem, eids,
                   deadline_s: Optional[float], t0: float) -> Allocation:
        # ---- k=1: the unpartitioned full problem through the same substrate.
        # The flat LP has no per-entity remap, so warm only while the entity
        # identity sequence is unchanged (a same-size swap would silently
        # misalign rows); crossing the pop<->full mode boundary drops warm.
        faults: list = []
        ids_key = (tuple(np.asarray(eids).tolist()) if eids is not None
                   else ("pos", problem.n_entities))
        warm = self._warm if self._mode == "full" else None
        if warm is not None and (self._full_ids is None
                                 or ids_key != self._full_ids):
            warm = None
        rkey = ("full", self.spec.name, self.exec_cfg, 1, problem.n_entities)
        exec_run, rung = self._ladder(rkey, deadline_s, t0)
        if rung == "fallback":
            return self._fallback(instance, faults + ["deadline"], t0,
                                  problem=problem)
        if rung is not None:
            faults.append(f"deadline:{rung}")

        try:
            fr = pop_mod.solve_full_ex(problem, warm=warm, exec_cfg=exec_run)
        except Exception as e:
            if warm is None:
                raise
            faults.append(f"warm-solve-error:{type(e).__name__}")
            self._warm, self._mode = None, None
            warm = None
            fr = pop_mod.solve_full_ex(problem, warm=None, exec_cfg=exec_run)
        if _count_diverged(fr.res) and warm is not None:
            # k=1 has a single lane: quarantine == full cold restart
            faults.append("divergence:1")
            self._note_quarantine(1)
            self._warm, self._mode = None, None
            warm = None
            fr = pop_mod.solve_full_ex(problem, warm=None, exec_cfg=exec_run)
        if _count_diverged(fr.res):
            faults.append("cold-divergence:1")
            self._note_quarantine(1)
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)
        if not _finite(fr.alloc):
            faults.append("nonfinite-alloc")
            self._warm, self._mode = None, None
            return self._fallback(instance, faults, t0, problem=problem)

        self._warm, self._mode = fr.res, "full"
        self._full_ids = ids_key
        self._note_rate(rkey, int(np.asarray(fr.res.iterations).max(initial=0)),
                        fr.solve_time_s, time.perf_counter() - t0)
        return self._wrap(
            instance, fr.alloc, None, problem=problem, backend=fr.backend,
            engine=fr.engine, plan_cache="full", k=1,
            warm_fraction=None if warm is None else 1.0,
            solve_time_s=fr.solve_time_s, build_time_s=fr.build_time_s,
            iterations=int(np.asarray(fr.res.iterations).sum()), raw=fr,
            status=self._status_of(faults, rung), faults=tuple(faults))

    # ---------------------------------------------- degradation ladder rungs --
    @staticmethod
    def _status_of(faults: list, rung: Optional[str]) -> str:
        if any(not f.startswith("deadline") for f in faults):
            return "recovered"
        return "degraded" if rung is not None else "ok"

    def _ladder(self, rkey: tuple, deadline_s: Optional[float],
                t0: float):
        """Pick the ExecConfig for this step under the deadline.

        Returns ``(exec_cfg, rung)`` with rung ``None`` (full budget —
        and, critically, the UNMODIFIED session ExecConfig, so the
        no-deadline path keeps byte-identical jit cache keys),
        ``"capped"`` (iteration cap + relaxed tolerance), ``"best-effort"``
        (a single convergence-check chunk), or ``"fallback"`` (not even
        one chunk fits — skip the solve).  Iteration budgets are quantized
        to power-of-two multiples of ``check_every`` so the ladder only
        ever creates O(log) distinct solver compilations per config."""
        if deadline_s is None:
            return self.exec_cfg, None
        rate = self.service._rates.get(rkey)
        if rate is None or rate <= 0.0:
            return self.exec_cfg, None     # no measurement yet: run full
        overhead = self.service._overheads.get(rkey, 0.0)
        remaining = deadline_s - (time.perf_counter() - t0) - overhead
        kw = self.exec_cfg.solver_dict()
        max_it = int(kw.get("max_iters", 20_000))
        ce = int(kw.get("check_every", 40))
        budget = int(remaining / rate) if remaining > 0 else 0
        if budget >= max_it:
            return self.exec_cfg, None
        if budget < ce:
            return None, "fallback"
        q = ce
        while q * 2 <= budget:
            q *= 2
        kw["max_iters"] = int(min(q, max_it))
        # a capped solve gets one tolerance notch back: better a looser
        # answer within budget than a tight one we never reach
        kw["tol_primal"] = float(kw.get("tol_primal", 1e-4)) * 10.0
        kw["tol_gap"] = float(kw.get("tol_gap", 1e-4)) * 10.0
        rung = "best-effort" if q == ce else "capped"
        return dataclasses.replace(self.exec_cfg, solver_kw=kw), rung

    def _note_rate(self, rkey: tuple, iters: int, solve_time_s: float,
                   wall_s: float) -> None:
        """EMA-update the measured per-iteration rate + per-step overhead
        for this (domain, ExecConfig, shape) — what _ladder budgets from."""
        if iters <= 0 or solve_time_s <= 0.0:
            return
        rates = self.service._rates
        r = solve_time_s / iters
        old = rates.get(rkey)
        rates[rkey] = r if old is None else 0.5 * old + 0.5 * r
        overheads = self.service._overheads
        ov = max(wall_s - solve_time_s, 0.0)
        o = overheads.get(rkey)
        overheads[rkey] = ov if o is None else 0.5 * o + 0.5 * ov

    def _note_quarantine(self, n: int) -> None:
        self.stats["quarantined_lanes"] += n
        self.service._stats["quarantined_lanes"] += n

    def _fallback(self, instance, faults: list, t0: float,
                  problem=None) -> Allocation:
        """The ladder's last rung: repeat the previous allocation, else ask
        the domain's greedy hook.  Never returns non-finite data; raises
        only when there is literally nothing to serve."""
        spec = self.spec
        alloc, source = None, None
        if self.last is not None and _finite(self.last.alloc):
            alloc, source = self.last.alloc, "previous-allocation"
        elif spec.greedy is not None:
            alloc, source = np.asarray(spec.greedy(instance)), "greedy"
        if alloc is None:
            raise RuntimeError(
                f"tenant {self.tenant!r} ({spec.name}): cannot produce an "
                f"allocation — solve failed ({', '.join(faults) or 'n/a'}) "
                "and the session has no previous allocation and the domain "
                "registers no greedy= fallback hook")
        try:
            metrics = dict(spec.metrics_of(instance, problem, alloc))
        except Exception as e:
            # fallback must not die computing metrics for an allocation
            # that was never meant for this exact instance
            metrics = {"metrics_error": f"{type(e).__name__}: {e}"}
        metrics["fallback_source"] = source
        # NOTE: no rounding hook here — a previous allocation is already
        # rounded, and greedy hooks return final allocations by contract
        return Allocation(
            domain=spec.name, tenant=self.tenant, step=self.steps,
            alloc=alloc, metrics=metrics, backend=None, engine=None,
            plan_cache="fallback", k=0, warm_fraction=None,
            solve_time_s=time.perf_counter() - t0, build_time_s=0.0,
            iterations=0, raw=None, status="fallback",
            faults=tuple(faults) if faults else ("deadline",))

    def _wrap(self, instance, raw_alloc, metrics, *, backend, engine,
              plan_cache, k, warm_fraction, solve_time_s, build_time_s=0.0,
              iterations=0, raw=None, problem=None, status="ok",
              faults=()) -> Allocation:
        alloc = raw_alloc
        if self.spec.round is not None and self.spec.step_override is None:
            alloc = self.spec.round(instance, raw_alloc)
        if metrics is None:
            metrics = self.spec.metrics_of(instance, problem, alloc)
        return Allocation(
            domain=self.spec.name, tenant=self.tenant, step=self.steps,
            alloc=alloc, metrics=metrics, backend=backend, engine=engine,
            plan_cache=plan_cache, k=k, warm_fraction=warm_fraction,
            solve_time_s=solve_time_s, build_time_s=build_time_s,
            iterations=iterations, raw=raw, status=status,
            faults=tuple(faults))

    # ------------------------------------------------------ checkpoint hooks --
    def _checkpoint_payload(self, prefix: str):
        """(meta, arrays) for this session — see PopService.checkpoint."""
        base = {
            "prefix": prefix,
            "domain": self.spec.name,
            "steps": int(self.steps),
            "solve_cfg": {
                "k": self.solve_cfg.k, "strategy": self.solve_cfg.strategy,
                "seed": self.solve_cfg.seed,
                "replicate_threshold": self.solve_cfg.replicate_threshold,
                "min_per_sub": self.solve_cfg.min_per_sub},
            "exec_cfg": {
                "backend": self.exec_cfg.backend,
                "engine": self.exec_cfg.engine,
                "solver_kw": self.exec_cfg.solver_dict(),
                "backend_opts": self.exec_cfg.opts_dict()},
            "digest": ckpt_mod.config_digest(self.solve_cfg, self.exec_cfg),
        }
        if self._mode == "pop" and isinstance(self._warm, pop_mod.POPResult):
            w = self._warm
            plan = w.plan
            if (plan is None or w.x is None or w.y is None
                    or plan.replication is not None):
                return {**base, "mode": "skipped",
                        "reason": "pop warm state without a serializable "
                                  "plan (replicated plans are v1-excluded)"}, {}
            meta = {**base, "mode": "pop", "plan": {
                "k": int(plan.k), "n_entities": int(plan.n_entities),
                "strategy": plan.strategy, "seed": int(plan.seed),
                "shapes": {name: list(v)
                           for name, v in (plan.shapes or {}).items()},
                "has_ids": plan.entity_ids is not None}}
            arrays = {f"{prefix}/x": w.x, f"{prefix}/y": w.y,
                      f"{prefix}/idx": plan.idx,
                      f"{prefix}/entity_of_slot": plan.entity_of_slot,
                      f"{prefix}/alloc": w.alloc,
                      f"{prefix}/iterations": w.iterations,
                      f"{prefix}/converged": w.converged}
            if plan.entity_ids is not None:
                arrays[f"{prefix}/entity_ids"] = plan.entity_ids
            return meta, arrays
        if self._mode == "full" and isinstance(self._warm, SolveResult):
            r = self._warm
            if self._full_ids is None:
                ids_kind, ids_val = "none", None
            elif self._full_ids[0] == "pos":
                ids_kind, ids_val = "pos", int(self._full_ids[1])
            else:
                ids_kind, ids_val = "ids", list(self._full_ids)
            meta = {**base, "mode": "full", "full_ids_kind": ids_kind,
                    "full_ids": ids_val}
            arrays = {f"{prefix}/x": np.asarray(r.x),
                      f"{prefix}/y": np.asarray(r.y),
                      f"{prefix}/iterations": np.asarray(r.iterations),
                      f"{prefix}/converged": np.asarray(r.converged),
                      f"{prefix}/primal_obj": np.asarray(r.primal_obj)}
            return meta, arrays
        if self._mode == "domain":
            return {**base, "mode": "skipped",
                    "reason": "step_override domains carry opaque warm "
                              "state (not serialized in v1)"}, {}
        return {**base, "mode": "cold"}, {}

    def _restore_payload(self, tmeta: dict, arrays: Dict[str, np.ndarray]):
        """Rebuild this session's warm state from checkpoint meta+arrays;
        raises CheckpointError on any misalignment."""
        mode = tmeta.get("mode", "cold")
        if mode in ("cold", "skipped"):
            return
        prefix = tmeta.get("prefix", "")

        def arr(name: str) -> np.ndarray:
            key = f"{prefix}/{name}"
            if key not in arrays:
                raise ckpt_mod.CheckpointError(
                    f"checkpoint payload missing array {key!r}")
            return arrays[key]

        if mode == "pop":
            pm = tmeta.get("plan") or {}
            k, n = int(pm["k"]), int(pm["n_entities"])
            idx, eos = arr("idx"), arr("entity_of_slot")
            x, y = arr("x"), arr("y")
            shapes = {name: tuple(v)
                      for name, v in (pm.get("shapes") or {}).items()}
            if idx.ndim != 2 or idx.shape[0] != k or eos.shape != idx.shape:
                raise ckpt_mod.CheckpointError(
                    f"plan arrays misaligned: idx {idx.shape} / "
                    f"entity_of_slot {eos.shape} for k={k}")
            for name, a in (("x", x), ("y", y)):
                want = shapes.get(name)
                if want is not None and tuple(a.shape) != want:
                    raise ckpt_mod.CheckpointError(
                        f"iterate {name} has shape {tuple(a.shape)}, plan "
                        f"says {want} — stale or corrupt warm state")
            ids = arr("entity_ids") if pm.get("has_ids") else None
            if ids is not None and ids.shape[0] != n:
                raise ckpt_mod.CheckpointError(
                    f"entity_ids has {ids.shape[0]} entries for "
                    f"{n} entities")
            plan = PopPlan(k=k, n_entities=n, idx=idx, entity_of_slot=eos,
                           strategy=pm.get("strategy", "stratified"),
                           seed=int(pm.get("seed", 0)), replication=None,
                           entity_ids=ids, similarity=None, layout=None,
                           shapes=shapes or None)
            res = pop_mod.POPResult(
                alloc=arr("alloc"), idx=idx, solve_time_s=0.0,
                build_time_s=0.0, iterations=arr("iterations"),
                converged=arr("converged"), similarity={},
                sub_objectives=np.zeros(k, np.float32), x=x, y=y, plan=plan)
            self.seed(res, mode="pop")
            return
        if mode == "full":
            x, y = arr("x"), arr("y")
            res = SolveResult(
                x=x, y=y, primal_obj=arr("primal_obj"),
                dual_obj=np.float32(0.0), primal_res=np.float32(np.inf),
                gap=np.float32(np.inf), iterations=arr("iterations"),
                converged=arr("converged"))
            kind = tmeta.get("full_ids_kind", "none")
            if kind == "pos":
                entity_ids = int(tmeta["full_ids"])
            elif kind == "ids":
                entity_ids = tmeta["full_ids"]
            else:
                entity_ids = None
            self.seed(res, mode="full", entity_ids=entity_ids)
            return
        raise ckpt_mod.CheckpointError(
            f"unknown session checkpoint mode {mode!r}")


class PopService:
    """Long-lived, multi-tenant POP solving service.

    Owns the default configs and the per-tenant sessions (warm state +
    plans); compiled solvers are shared across sessions whose
    :class:`ExecConfig` matches (the configs are hashable and key the jit
    caches in ``core/backends.py``)."""

    def __init__(self, solve: Optional[SolveConfig] = None,
                 exec: Optional[ExecConfig] = None):
        # None means "not set" (domain defaults win); an explicit config —
        # even one equal to the library default — overrides them
        self._service_solve = solve
        self._service_exec = exec
        self.solve_cfg = solve or SolveConfig()
        self.exec_cfg = exec or ExecConfig()
        self._sessions: Dict[str, PopSession] = {}
        self._stats = _zeros()
        # measured per-iteration solve rates + per-step overheads, keyed
        # (path, domain, ExecConfig, k, n_entities) — the deadline ladder's
        # budget model, warmed by every fault-free step
        self._rates: Dict[tuple, float] = {}
        self._overheads: Dict[tuple, float] = {}
        self.created = time.time()

    def session(self, tenant: str, instance: Any = None, *,
                domain: Optional[str] = None,
                solve: Optional[SolveConfig] = None,
                exec: Optional[ExecConfig] = None) -> PopSession:
        """The session for ``tenant``, created on first use.

        The domain comes from ``domain=`` (a registry name) or is inferred
        from ``instance``'s type (``repro.domains.spec_for``).  Configs
        default to the domain's registered defaults, overridden by the
        service-level configs only where the caller set them explicitly at
        service construction, then by ``solve=`` / ``exec=`` here.  An
        existing session is returned as-is (its configs are pinned at
        creation); asking for the same tenant with a DIFFERENT domain is
        an error — tenants are per-domain state."""
        sess = self._sessions.get(tenant)
        if sess is not None:
            # configs are pinned at creation: explicitly asking for a
            # DIFFERENT one must not be silently ignored
            if solve is not None and solve != sess.solve_cfg:
                raise ValueError(
                    f"tenant {tenant!r} session is pinned to "
                    f"{sess.solve_cfg}; end_session() it to re-create with "
                    f"{solve} (configs are set at session creation)")
            if exec is not None and exec != sess.exec_cfg:
                raise ValueError(
                    f"tenant {tenant!r} session is pinned to "
                    f"{sess.exec_cfg}; end_session() it to re-create with "
                    f"{exec} (configs are set at session creation)")
        if domain is not None:
            spec = registry_mod.get(domain)
        elif instance is not None:
            spec = registry_mod.spec_for(instance)
            if spec is None:
                raise ValueError(
                    f"no registered domain matches instance type "
                    f"{type(instance).__name__!r}; register a DomainSpec "
                    "with that instance_types or pass domain=")
        elif sess is not None:
            return sess                  # re-entry by tenant name alone
        else:
            raise ValueError("session() needs an instance (to infer the "
                             "domain) or an explicit domain= name")
        if sess is not None:
            if sess.spec.name != spec.name:
                raise ValueError(
                    f"tenant {tenant!r} already has a {sess.spec.name!r} "
                    f"session; one tenant cannot switch to {spec.name!r} "
                    "(sessions are per-domain warm state)")
            return sess
        sess = PopSession(
            self, tenant, spec,
            solve or self._service_solve or spec.default_solve,
            exec or self._service_exec or spec.default_exec)
        self._sessions[tenant] = sess
        return sess

    def end_session(self, tenant: str) -> None:
        """Drop a tenant's session (and its warm state / cached plan)."""
        self._sessions.pop(tenant, None)

    def tenants(self) -> tuple:
        return tuple(sorted(self._sessions))

    # --------------------------------------------------- checkpoint/restore --
    def checkpoint(self) -> bytes:
        """Serialize every tenant session's warm state to one bytes blob.

        The blob (format: ``repro.checkpoint.session_state``) carries, per
        tenant: the domain name, the pinned configs + their digest, the
        step counter, and the warm state — PopPlan arrays + solver
        iterates + entity ids (pop path) or the flat iterates + id key
        (full path).  Warm state the format cannot express (replicated
        plans, step_override domains' opaque state) is recorded as
        ``skipped`` and restores cold.  Round-trip with
        :meth:`restore`."""
        tenants_meta: Dict[str, dict] = {}
        arrays: Dict[str, np.ndarray] = {}
        for i, tenant in enumerate(sorted(self._sessions)):
            sess = self._sessions[tenant]
            meta, arrs = sess._checkpoint_payload(f"t{i}")
            try:
                json.dumps(meta)
            except (TypeError, ValueError):
                meta = {"prefix": f"t{i}", "domain": sess.spec.name,
                        "mode": "skipped",
                        "reason": "non-JSON-serializable session config"}
                arrs = {}
            tenants_meta[tenant] = meta
            arrays.update(arrs)
        return ckpt_mod.pack_state({"tenants": tenants_meta}, arrays)

    def restore(self, data: bytes, *, strict: bool = False) -> dict:
        """Restore tenant sessions from a :meth:`checkpoint` blob.

        Integrity (content hash, magic, version) is checked by the format;
        alignment (config digest, plan-vs-iterate shapes, entity-id
        counts) per tenant here.  Any failure DEGRADES: the blob — or just
        the offending tenant — restores cold and the failure lands in the
        returned report (``{"restored": [...], "cold": [...], "errors":
        {...}}``) and ``stats()["checkpoint_failures"]``; nothing raises
        unless ``strict=True``."""
        report = {"restored": [], "cold": [], "errors": {}}
        try:
            meta, arrays = ckpt_mod.unpack_state(data)
            tenants = meta["tenants"]
            if not isinstance(tenants, dict):
                raise ckpt_mod.CheckpointError("manifest meta lacks a "
                                               "tenants table")
        except (ckpt_mod.CheckpointError, KeyError, TypeError) as e:
            self._stats["checkpoint_failures"] += 1
            if strict:
                raise
            report["errors"]["<checkpoint>"] = f"{type(e).__name__}: {e}"
            return report
        for tenant in sorted(tenants):
            tmeta = tenants[tenant]
            try:
                sess = self.session(tenant, domain=tmeta["domain"],
                                    solve=self._cfg_solve(tmeta),
                                    exec=self._cfg_exec(tmeta))
                if ckpt_mod.config_digest(sess.solve_cfg, sess.exec_cfg) \
                        != tmeta.get("digest"):
                    raise ckpt_mod.CheckpointError(
                        "config digest mismatch (stale checkpoint or "
                        "changed config schema)")
                sess.steps = int(tmeta.get("steps", 0))
                sess._restore_payload(tmeta, arrays)
            except Exception as e:
                self._stats["checkpoint_failures"] += 1
                if strict:
                    raise
                report["errors"][tenant] = f"{type(e).__name__}: {e}"
                report["cold"].append(tenant)
                continue
            if self._sessions[tenant]._warm is not None:
                self._stats["checkpoint_restores"] += 1
                report["restored"].append(tenant)
            else:
                report["cold"].append(tenant)
        return report

    @staticmethod
    def _cfg_solve(tmeta: dict) -> SolveConfig:
        return SolveConfig(**dict(tmeta["solve_cfg"]))

    @staticmethod
    def _cfg_exec(tmeta: dict) -> ExecConfig:
        e = dict(tmeta["exec_cfg"])
        return ExecConfig(backend=e["backend"], engine=e["engine"],
                          solver_kw=dict(e.get("solver_kw") or {}),
                          backend_opts=dict(e.get("backend_opts") or {}))

    def stats(self) -> dict:
        """Service-wide observability: step counts, plan-cache hit rates,
        aggregate solve time, mean warm fraction, per-engine step counts
        (``engines``: the resolved engine that actually ran each step),
        and the fault-tolerance counters (degraded/recovered/fallback
        steps, quarantined lanes, checkpoint restore outcomes)."""
        s = dict(self._stats)
        s["engines"] = dict(s["engines"])
        steps = max(s["steps"], 1)
        s["plan_hit_rate"] = s["plan_hits"] / steps
        s["warm_fraction_mean"] = (s["warm_fraction_sum"] / s["warm_steps"]
                                   if s["warm_steps"] else None)
        s["n_sessions"] = len(self._sessions)
        return s
