"""Data pipeline: synthetic sharded token streams with prefetch."""
from .pipeline import TokenPipeline, DevicePrefetcher
