"""Synthetic sharded data pipeline: deterministic token streams with
host-side prefetch, shard-aware placement, and mid-epoch restore (the
checkpointer records the pipeline cursor so restarts are exactly-once)."""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from ..launch import shardings as sh


class TokenPipeline:
    """Deterministic synthetic LM batches.

    Yields {"tokens": [B, S], "labels": [B, S]} numpy batches; ``state()``
    returns the cursor for checkpointing, ``restore(cursor)`` resumes.
    Structure mirrors a real pipeline (file shards -> sample iterator ->
    batcher -> device placement) with the file layer replaced by a PRNG.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 enc_seq: int = 0, d_model: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.enc_seq, self.d_model = enc_seq, d_model
        self.seed = seed
        self._cursor = 0

    def state(self) -> dict:
        return {"cursor": self._cursor, "seed": self.seed}

    def restore(self, state: dict):
        self._cursor = int(state["cursor"])
        self.seed = int(state["seed"])

    def _make(self, idx: int) -> dict:
        rng = np.random.default_rng((self.seed, idx))
        # zipf-ish marginal over the vocab — realistic logit scales
        z = rng.zipf(1.3, (self.batch, self.seq + 1))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        if self.enc_seq:
            out["enc_embeddings"] = rng.normal(
                0, 1, (self.batch, self.enc_seq, self.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self._make(self._cursor)
            self._cursor += 1
            yield b


class DevicePrefetcher:
    """Background thread that stages the next N batches onto devices with
    the training sharding — keeps the TPU step loop input-bound-free."""

    def __init__(self, pipeline: TokenPipeline, mesh: Optional[Mesh],
                 depth: int = 2):
        self.pipeline = pipeline
        self.mesh = mesh
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _place(self, batch: dict):
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        out = {}
        for k, v in batch.items():
            spec = (sh.batch_spec(self.mesh) if v.ndim == 2
                    else jax.sharding.PartitionSpec(
                        sh.dp_axes(self.mesh), *([None] * (v.ndim - 1))))
            out[k] = jax.device_put(v, NamedSharding(self.mesh, spec))
        return out

    def _run(self):
        it = iter(self.pipeline)
        while not self._stop.is_set():
            batch = next(it)
            try:
                self.q.put(self._place(batch), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    break
                self.q.put(self._place(batch))

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
