"""Serving engine: batched prefill + decode with donated caches.

``serve_step`` is the unit the decode_32k / long_500k dry-run cells lower:
one new token against a KV/state cache of ``seq_len``, cache donated so the
update is in-place at the XLA level.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import transformer as tf
from ..launch import shardings as sh


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    max_seq: int
    compute_dtype: str = "bfloat16"
    shard_cache_seq: bool = False     # long-context mode (batch too small)
    unroll_segments: bool = False     # cost-probe mode (see launch/dryrun.py)
    cache_seq_on_model: bool = False  # §Perf: flash-decode cache layout


def make_serve_step(cfg: tf.ArchCfg, scfg: ServeConfig,
                    mesh: Optional[Mesh] = None):
    dtype = jnp.bfloat16 if scfg.compute_dtype == "bfloat16" else jnp.float32
    opts = tf.ModelOpts(cache_seq_on_model=scfg.cache_seq_on_model, mesh=mesh)

    def serve_step(params, cache, token, enc_memory=None):
        logits, cache = tf.forward_decode(params, cfg, token, cache,
                                          enc_memory=enc_memory,
                                          compute_dtype=dtype,
                                          unroll=scfg.unroll_segments,
                                          opts=opts)
        # greedy next token (sampling plugs in here)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token[:, None], cache

    return serve_step


def jit_serve_step(cfg: tf.ArchCfg, scfg: ServeConfig, mesh: Mesh,
                   params_shape, cache_shape, has_memory: bool = False):
    p_shard = sh.param_shardings(params_shape, mesh)
    c_specs = sh.kv_cache_specs(cache_shape, mesh, scfg.batch,
                                shard_seq=scfg.shard_cache_seq,
                                seq_on_model=scfg.cache_seq_on_model)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    dp = sh.dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tok_spec = P(dp, None) if scfg.batch % max(n_dp, 1) == 0 else P(None, None)
    t_shard = NamedSharding(mesh, tok_spec)

    in_sh = [p_shard, c_shard, t_shard]
    if has_memory:
        mem_spec = (P(dp, None, None) if scfg.batch % max(n_dp, 1) == 0
                    else P(None, None, None))
        in_sh.append(NamedSharding(mesh, mem_spec))

    step = make_serve_step(cfg, scfg, mesh)
    return jax.jit(
        step,
        in_shardings=tuple(in_sh),
        out_shardings=(t_shard, c_shard),
        donate_argnums=(1,),          # cache updated in place
    )


def prefill(params, cfg: tf.ArchCfg, tokens, cache,
            compute_dtype=jnp.bfloat16):
    """Sequential prefill via the decode path (correct for ring buffers and
    SSM state; a fused chunked prefill is a serving optimisation tracked in
    EXPERIMENTS.md §Perf)."""
    def body(cache, tok):
        _, cache = tf.forward_decode(params, cfg, tok[:, None], cache,
                                     compute_dtype=compute_dtype)
        return cache, None
    cache, _ = jax.lax.scan(body, cache, tokens.T)
    return cache
