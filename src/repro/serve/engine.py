"""Serving engine: batched prefill + decode with donated caches, plus the
POP request balancer that places request groups onto decode replicas.

``serve_step`` is the unit the decode_32k / long_500k dry-run cells lower:
one new token against a KV/state cache of ``seq_len``, cache donated so the
update is in-place at the XLA level.

``balance_requests`` is the serving-path use of the paper: request groups
are shards, replicas are servers, and the §3.3 load-balancing MILP is
solved through POP with a pluggable map-step backend
(``core/backends.py``) — so the balancer itself scales with the mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import transformer as tf
from ..launch import shardings as sh


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    max_seq: int
    compute_dtype: str = "bfloat16"
    shard_cache_seq: bool = False     # long-context mode (batch too small)
    unroll_segments: bool = False     # cost-probe mode (see launch/dryrun.py)
    cache_seq_on_model: bool = False  # §Perf: flash-decode cache layout


def make_serve_step(cfg: tf.ArchCfg, scfg: ServeConfig,
                    mesh: Optional[Mesh] = None):
    dtype = jnp.bfloat16 if scfg.compute_dtype == "bfloat16" else jnp.float32
    opts = tf.ModelOpts(cache_seq_on_model=scfg.cache_seq_on_model, mesh=mesh)

    def serve_step(params, cache, token, enc_memory=None):
        logits, cache = tf.forward_decode(params, cfg, token, cache,
                                          enc_memory=enc_memory,
                                          compute_dtype=dtype,
                                          unroll=scfg.unroll_segments,
                                          opts=opts)
        # greedy next token (sampling plugs in here)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token[:, None], cache

    return serve_step


def jit_serve_step(cfg: tf.ArchCfg, scfg: ServeConfig, mesh: Mesh,
                   params_shape, cache_shape, has_memory: bool = False):
    p_shard = sh.param_shardings(params_shape, mesh)
    c_specs = sh.kv_cache_specs(cache_shape, mesh, scfg.batch,
                                shard_seq=scfg.shard_cache_seq,
                                seq_on_model=scfg.cache_seq_on_model)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs)
    dp = sh.dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tok_spec = P(dp, None) if scfg.batch % max(n_dp, 1) == 0 else P(None, None)
    t_shard = NamedSharding(mesh, tok_spec)

    in_sh = [p_shard, c_shard, t_shard]
    if has_memory:
        mem_spec = (P(dp, None, None) if scfg.batch % max(n_dp, 1) == 0
                    else P(None, None, None))
        in_sh.append(NamedSharding(mesh, mem_spec))

    step = make_serve_step(cfg, scfg, mesh)
    return jax.jit(
        step,
        in_shardings=tuple(in_sh),
        out_shardings=(t_shard, c_shard),
        donate_argnums=(1,),          # cache updated in place
    )


@dataclasses.dataclass
class BalanceResult:
    placement: np.ndarray        # replica id per request group
    moved: int                   # sticky groups that changed replica
    max_load_dev: float
    solve_time_s: float
    # full LBResult (carries the PDHG warm-start state) — pass back as
    # ``warm=`` on the next balancing tick for a warm-started re-solve
    lb: Optional[object] = None
    # share of request groups whose previous iterates seeded this solve
    # (1.0 = stable population, None = cold solve)
    warm_fraction: Optional[float] = None


def balance_requests(load: np.ndarray, n_replicas: int,
                     current: Optional[np.ndarray] = None,
                     *, pop_k: int = 2, eps_frac: float = 0.25,
                     backend: str = "auto", engine: str = "auto",
                     solver_kw: Optional[dict] = None,
                     warm: Optional[BalanceResult] = None,
                     group_ids: Optional[np.ndarray] = None) -> BalanceResult:
    """DEPRECATED: place request groups onto decode replicas — the paper's
    §3.3 MILP with request groups as shards — by forwarding onto the one
    public API, a :class:`repro.service.PopService` session over the
    registered ``load_balance`` domain (results are bit-identical).  New
    code should hold a long-lived session instead of hand-carrying the
    previous tick's :class:`BalanceResult` through ``warm=``:

        session = service.session("balancer", BalanceInstance(...))
        alloc = session.step(BalanceInstance(load, n_replicas, current,
                                             eps_frac=0.25, ids=group_ids))

    — the session chains warm state through load drift AND group churn
    (stable ``ids`` match surviving groups; ``alloc.warm_fraction``
    reports the matched share) without any caller-side threading."""
    import warnings

    from ..core.config import ExecConfig, SolveConfig
    from ..domains.load_balance import BalanceInstance
    from ..service import PopService

    warnings.warn(
        "balance_requests is deprecated: use repro.service.PopService"
        ".session(tenant, repro.domains.BalanceInstance(...)) — this "
        "function forwards onto that session (results are identical)",
        DeprecationWarning, stacklevel=2)
    load = np.asarray(load, np.float64)
    if current is None:
        current = np.arange(load.shape[0]) % n_replicas
    if solver_kw is None:           # explicit {} means "solver defaults"
        solver_kw = dict(max_iters=6_000)
    inst = BalanceInstance(load=load, n_targets=n_replicas,
                           current=np.asarray(current, np.int64),
                           eps_frac=eps_frac, ids=group_ids)
    session = PopService().session(
        "serve.balance_requests", inst,
        solve=SolveConfig(k=pop_k),
        exec=ExecConfig(backend=backend, engine=engine,
                        solver_kw=dict(solver_kw)))
    session.seed(None if warm is None else warm.lb)
    out = session.step(inst)
    res = out.raw
    return BalanceResult(
        placement=res.placement,
        moved=int((res.placement != current).sum()),
        max_load_dev=float(res.max_load_dev),
        solve_time_s=float(res.solve_time_s),
        lb=res,
        warm_fraction=res.extra.get("warm_fraction"),
    )


def prefill(params, cfg: tf.ArchCfg, tokens, cache,
            compute_dtype=jnp.bfloat16):
    """Sequential prefill via the decode path (correct for ring buffers and
    SSM state; a fused chunked prefill is a serving optimisation tracked in
    EXPERIMENTS.md §Perf)."""
    def body(cache, tok):
        _, cache = tf.forward_decode(params, cfg, tok[:, None], cache,
                                     compute_dtype=compute_dtype)
        return cache, None
    cache, _ = jax.lax.scan(body, cache, tokens.T)
    return cache
