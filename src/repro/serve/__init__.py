"""Serving substrate: KV/state-cached decode engine + POP request balancer."""
from .engine import ServeConfig, make_serve_step, jit_serve_step, prefill
