"""Serving substrate: KV/state-cached decode engine + POP request balancer."""
from .engine import (BalanceResult, ServeConfig, balance_requests,
                     jit_serve_step, make_serve_step, prefill)
