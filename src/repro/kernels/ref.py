"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; ``tests/test_kernels.py`` sweeps
shapes/dtypes and asserts the Pallas implementations (interpret mode on CPU,
compiled on TPU) match these to tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def bmatvec(A: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[k, m] = sum_n A[k, m, n] * x[k, n]   (f32 accumulation)."""
    return jnp.einsum("kmn,kn->km", A, x,
                      preferred_element_type=jnp.float32)


def bmatvec_t(A: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """x[k, n] = sum_m A[k, m, n] * y[k, m]   (A read transposed)."""
    return jnp.einsum("kmn,km->kn", A, y,
                      preferred_element_type=jnp.float32)


def fused_primal_step(A, y, x, c, l, u, tau):
    """PDHG primal update + extrapolation:

        g     = c + A^T y
        x_new = clip(x - tau * g, l, u)
        x_bar = 2 * x_new - x

    Returns (x_new, x_bar).  The Pallas version fuses the A^T matvec with
    the element-wise tail so the gradient never round-trips HBM.
    """
    g = c + bmatvec_t(A, y)
    x_new = jnp.clip(x - tau * g, l, u)
    return x_new, 2.0 * x_new - x


def fused_dual_step(A, x_bar, y, q, sigma, ineq_mask):
    """PDHG dual update:

        y_new = y + sigma * (A x_bar - q)
        y_new = max(y_new, 0) where ineq_mask  (inequality duals)
    """
    y_new = y + sigma * (bmatvec(A, x_bar) - q)
    return jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
