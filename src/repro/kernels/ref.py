"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; ``tests/test_kernels.py`` sweeps
shapes/dtypes and asserts the Pallas implementations (interpret mode on CPU,
compiled on TPU) match these to tolerance.  Off-TPU these ARE the dispatch
targets (``ops.py``), so they are written to be XLA-friendly: the
structured paths use ``take_along_axis`` gathers and axis reductions — no
scatters anywhere.
"""

from __future__ import annotations

import jax.numpy as jnp


def bmatvec(A: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[k, m] = sum_n A[k, m, n] * x[k, n]   (f32 accumulation)."""
    return jnp.einsum("kmn,kn->km", A, x,
                      preferred_element_type=jnp.float32)


def bmatvec_t(A: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """x[k, n] = sum_m A[k, m, n] * y[k, m]   (A read transposed)."""
    return jnp.einsum("kmn,km->kn", A, y,
                      preferred_element_type=jnp.float32)


def _bgather(v: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """v[k, n] gathered per lane by idx [k, ...] -> [k, ...]."""
    k = idx.shape[0]
    return jnp.take_along_axis(v, idx.reshape(k, -1), axis=1).reshape(idx.shape)


def _gather_side(idx, val, widx, wval, wids, v, n_out):
    """One direction of the two-bucket ELL matvec (``K x`` through the row
    side, ``K^T y`` through the column side):

        out = sum_w val[:, w, :] * v[idx[:, w, :]]              (narrow)
        out += scatter(wids, sum_w wval[:, w, :] * v[widx[:, w, :]])

    All gathers; the wide-bucket results land via a one-hot accumulation
    (bucket ids are distinct, so order never matters).  Padding entries
    (idx 0, val 0) and empty buckets contribute exact zeros.
    """
    out = jnp.sum(val * _bgather(v, idx), axis=-2)           # [k, n_out]
    wide = jnp.sum(wval * _bgather(v, widx), axis=-2)        # [k, D]
    onehot = (wids[:, :, None] == jnp.arange(n_out)[None, None, :])
    return out + jnp.einsum("kd,kdm->km", wide,
                            onehot.astype(wide.dtype))


def smatvec(s, x):
    """kx[k, m] = (K x) through the row-side gather layout of a
    ``core/pdhg.StructuredOperator`` (padding entries carry val 0)."""
    return _gather_side(s.row_idx, s.row_val, s.wrow_idx, s.wrow_val,
                        s.wrow_ids, x, s.row_idx.shape[-1])


def smatvec_t(s, y):
    """kty[k, n] = (K^T y) through the column-side gather layout."""
    return _gather_side(s.col_idx, s.col_val, s.wcol_idx, s.wcol_val,
                        s.wcol_ids, y, s.col_idx.shape[-1])


def fused_forward_step(A, x, c, l, u, tau, kty):
    """PDHG primal half-step + forward product:

        x_new = clip(x - tau * (c + kty), l, u)       (kty = carried K^T y)
        kx    = A @ x_new

    Returns (x_new, kx).  The Pallas version fuses the tail with the
    matvec so x_new feeds the product without an HBM round-trip.
    """
    x_new = jnp.clip(x - tau * (c + kty), l, u)
    return x_new, bmatvec(A, x_new)


def fused_backward_step(A, y, q, sigma, ineq_mask, kx_new, kx_prev):
    """PDHG dual half-step + adjoint product:

        y_new = y + sigma * (2*kx_new - kx_prev - q)   (K x_bar by linearity)
        y_new = max(y_new, 0) where ineq_mask          (inequality duals)
        kty   = A^T @ y_new

    Returns (y_new, kty).
    """
    y_new = y + sigma * (2.0 * kx_new - kx_prev - q)
    y_new = jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
    return y_new, bmatvec_t(A, y_new)


def structured_forward_step(s, x, c, l, u, tau, kty):
    """Structured-operator forward half-step (ELL gather-reduce matvec)."""
    x_new = jnp.clip(x - tau * (c + kty), l, u)
    return x_new, smatvec(s, x_new)


def structured_backward_step(s, y, q, sigma, ineq_mask, kx_new, kx_prev):
    """Structured-operator backward half-step."""
    y_new = y + sigma * (2.0 * kx_new - kx_prev - q)
    y_new = jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
    return y_new, smatvec_t(s, y_new)
