"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; ``tests/test_kernels.py`` sweeps
shapes/dtypes and asserts the Pallas implementations (interpret mode on CPU,
compiled on TPU) match these to tolerance.  Off-TPU these ARE the dispatch
targets (``ops.py``), so they are written to be XLA-friendly: the
structured paths use ``take_along_axis`` gathers and axis reductions — no
scatters anywhere.
"""

from __future__ import annotations

import jax.numpy as jnp


def bmatvec(A: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[k, m] = sum_n A[k, m, n] * x[k, n]   (f32 accumulation)."""
    return jnp.einsum("kmn,kn->km", A, x,
                      preferred_element_type=jnp.float32)


def bmatvec_t(A: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """x[k, n] = sum_m A[k, m, n] * y[k, m]   (A read transposed)."""
    return jnp.einsum("kmn,km->kn", A, y,
                      preferred_element_type=jnp.float32)


def _bgather(v: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """v[k, n] gathered per lane by idx [k, ...] -> [k, ...]."""
    k = idx.shape[0]
    return jnp.take_along_axis(v, idx.reshape(k, -1), axis=1).reshape(idx.shape)


def _gather_side(idx, val, widx, wval, wids, v, n_out):
    """One direction of the two-bucket ELL matvec (``K x`` through the row
    side, ``K^T y`` through the column side):

        out = sum_w val[:, w, :] * v[idx[:, w, :]]              (narrow)
        out += scatter(wids, sum_w wval[:, w, :] * v[widx[:, w, :]])

    All gathers; the wide-bucket results land via a one-hot accumulation
    (bucket ids are distinct, so order never matters).  Padding entries
    (idx 0, val 0) and empty buckets contribute exact zeros.
    """
    out = jnp.sum(val * _bgather(v, idx), axis=-2)           # [k, n_out]
    wide = jnp.sum(wval * _bgather(v, widx), axis=-2)        # [k, D]
    onehot = (wids[:, :, None] == jnp.arange(n_out)[None, None, :])
    return out + jnp.einsum("kd,kdm->km", wide,
                            onehot.astype(wide.dtype))


def smatvec(s, x):
    """kx[k, m] = (K x) through the row-side gather layout of a
    ``core/pdhg.StructuredOperator`` (padding entries carry val 0)."""
    return _gather_side(s.row_idx, s.row_val, s.wrow_idx, s.wrow_val,
                        s.wrow_ids, x, s.row_idx.shape[-1])


def smatvec_t(s, y):
    """kty[k, n] = (K^T y) through the column-side gather layout."""
    return _gather_side(s.col_idx, s.col_val, s.wcol_idx, s.wcol_val,
                        s.wcol_ids, y, s.col_idx.shape[-1])


def fused_forward_step(A, x, c, l, u, tau, kty):
    """PDHG primal half-step + forward product:

        x_new = clip(x - tau * (c + kty), l, u)       (kty = carried K^T y)
        kx    = A @ x_new

    Returns (x_new, kx).  The Pallas version fuses the tail with the
    matvec so x_new feeds the product without an HBM round-trip.
    """
    x_new = jnp.clip(x - tau * (c + kty), l, u)
    return x_new, bmatvec(A, x_new)


def fused_backward_step(A, y, q, sigma, ineq_mask, kx_new, kx_prev):
    """PDHG dual half-step + adjoint product:

        y_new = y + sigma * (2*kx_new - kx_prev - q)   (K x_bar by linearity)
        y_new = max(y_new, 0) where ineq_mask          (inequality duals)
        kty   = A^T @ y_new

    Returns (y_new, kty).
    """
    y_new = y + sigma * (2.0 * kx_new - kx_prev - q)
    y_new = jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
    return y_new, bmatvec_t(A, y_new)


def structured_forward_step(s, x, c, l, u, tau, kty):
    """Structured-operator forward half-step (ELL gather-reduce matvec)."""
    x_new = jnp.clip(x - tau * (c + kty), l, u)
    return x_new, smatvec(s, x_new)


def structured_backward_step(s, y, q, sigma, ineq_mask, kx_new, kx_prev):
    """Structured-operator backward half-step."""
    y_new = y + sigma * (2.0 * kx_new - kx_prev - q)
    y_new = jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
    return y_new, smatvec_t(s, y_new)


# --------------------------------------------------------------------------
# full-problem (single-lane, M-blocked) oracles — the fused_structured_full
# engine's semantics: fold-map wide add-back instead of the one-hot einsum,
# ragged wide-block plan over the descending-sorted bucket, and in-graph
# dequantization of int8/bf16 coefficient storage (f32 accumulation)
# --------------------------------------------------------------------------

def _deq(val, scale):
    """Coefficients to f32: cast, then fold in the per-bucket dequant
    scale when the payload is int8-quantized (scale [k, 1] or None)."""
    v = val.astype(jnp.float32)
    return v if scale is None else v * scale[..., None]


def _gather_wide_sorted(widx, wval, wscale, fold, v, plan):
    """Wide-bucket reduce + fold-map add-back:

        wide[d] = sum_w wval[:, w, d] * v[widx[:, w, d]]     per plan block
        out     = pad(wide, 1)[fold]                          (a gather)

    ``plan`` is the static ragged block plan ``((c0, c1, wb), ...)`` from
    ``pdhg._wide_block_plan``: bucket columns are sorted by descending
    width, so slicing block ``[c0, c1)`` at its own max width ``wb`` skips
    the padding a uniform-width reduce would burn.  The fold map sends
    narrow segments to the one-past-the-end zero slot, hence the pad.
    """
    if not plan:
        plan = ((0, wval.shape[-1], wval.shape[-2]),)
    parts = [
        jnp.sum(_deq(wval[:, :wb, c0:c1], wscale)
                * _bgather(v, widx[:, :wb, c0:c1]), axis=-2)
        for (c0, c1, wb) in plan]
    wide = jnp.concatenate(parts, axis=-1)            # [k, D]
    wide = jnp.pad(wide, ((0, 0), (0, 1)))            # zero slot at D
    return _bgather(wide, fold)


def smatvec_full(s, x, plan=()):
    """kx = K x for the single-lane full problem: narrow ELL reduce plus
    the fold-map wide add-back (no one-hot einsum — at paper scale the
    one-hot materialises ~n_segments * D elements per matvec)."""
    narrow = jnp.sum(_deq(s.row_val, s.row_scale)
                     * _bgather(x, s.row_idx), axis=-2)
    return narrow + _gather_wide_sorted(
        s.wrow_idx, s.wrow_val, s.wrow_scale, s.row_fold, x, plan)


def smatvec_t_full(s, y, plan=()):
    """kty = K^T y through the column-side layout (see smatvec_full)."""
    narrow = jnp.sum(_deq(s.col_val, s.col_scale)
                     * _bgather(y, s.col_idx), axis=-2)
    return narrow + _gather_wide_sorted(
        s.wcol_idx, s.wcol_val, s.wcol_scale, s.col_fold, y, plan)


def structured_full_forward_step(s, x, c, l, u, tau, kty, plan=()):
    """Full-problem forward half-step: element-wise tail fused in front
    of the blocked row-side matvec."""
    x_new = jnp.clip(x - tau * (c + kty), l, u)
    return x_new, smatvec_full(s, x_new, plan)


def structured_full_backward_step(s, y, q, sigma, ineq_mask, kx_new,
                                  kx_prev, plan=()):
    """Full-problem backward half-step (column side)."""
    y_new = y + sigma * (2.0 * kx_new - kx_prev - q)
    y_new = jnp.where(ineq_mask, jnp.maximum(y_new, 0.0), y_new)
    return y_new, smatvec_t_full(s, y_new, plan)
