"""Pallas TPU kernels for the PDHG hot loop (validated in interpret mode on
CPU; compiled on TPU).  ``ops`` is the public jit'd API, ``ref`` the oracle."""

from . import ops, ref
from .pdhg_matvec import BLOCK_M, BLOCK_N

__all__ = ["ops", "ref", "BLOCK_M", "BLOCK_N"]
