"""Pallas TPU kernels: FUSED PDHG half-steps for STRUCTURED operators.

The structured LPs (Gavel per-job rows, traffic per-commodity path sums,
load-balancing server groups) apply K through segment-sums and gathers, not
dense matmuls.  In the two-bucket ELL index form
(``core/pdhg.StructuredOperator``) both matvec directions become *gather +
multiply + reduce over the nnz axis* — no scatter anywhere, because the
transpose layout is precomputed at build time and the few wide segments
(worker-cap rows, hot edges, per-server load rows) live in their own
compact bucket whose results are folded back with a one-hot accumulation.
These kernels run one half-step for the WHOLE stacked k-lane batch per
launch, with the element-wise tail (axpy + projection) fused in front of
the gathers so the updated iterate never round-trips HBM between the tail
and the matvec that consumes it:

  structured_forward_step :
      x_new = clip(x - tau*(c + kty), l, u)           (kty = carried K^T y)
      kx    = narrow_rows(x_new) + onehot(wrow_ids) . wide_rows(x_new)
  structured_backward_step:
      y_new = proj_{>=0 on ineq}(y + sigma*(2*kx - kx_prev - q))
      kty   = narrow_cols(y_new) + onehot(wcol_ids) . wide_cols(y_new)

Grid is ``(k,)``: each program owns one lane, whose vectors live entirely
in VMEM (POP sub-problems are small by construction — the k^2 variable
reduction is the paper's point — so a lane's [N] + [W, M] blocks fit
comfortably).  The nnz axis rides the sublanes (arrays are [W, M]
nnz-major) so the reduce is a sublane reduction and rows/cols stay on the
128-wide lane axis.  Scalars (tau, sigma) ride in (1, 1) blocks so the
kernel stays shape-polymorphic over the POP batch.

The FULL unpartitioned problem at paper scale does NOT fit a lane in
VMEM; it takes the **M-blocked streaming family** below
(``structured_full_forward_step`` / ``structured_full_backward_step``):
a phased 1-D grid ``(1 + num_wide_blocks + num_m_blocks,)`` per
half-step —

  phase 0                  element-wise tail into a pinned full-vector
                           output block (readable by later phases);
  wide phases              stream ``(FULL_BLOCK_W, FULL_BLOCK_D)`` tiles
                           of the wide bucket, accumulating partial
                           reduces into a pinned ``[1, D]`` accumulator
                           output (flushed only once, at the end);
  narrow phases            stream ``(W, FULL_BLOCK_M)`` tiles of the
                           narrow ELL, each emitting one output block =
                           narrow reduce + ``accum[fold]`` — the
                           wide-bucket add-back is a gather through the
                           fold map, not a one-hot einsum.

Coefficient tiles may be int8/bf16 (``core/pdhg.quantize_structured``);
they are dequantized in-register (``* scale``) and accumulated in f32.
Each tile is <= FULL_BLOCK_W x FULL_BLOCK_M x 4 B, so VMEM stays bounded
regardless of problem size; off-TPU the dispatch in ``kernels/ops.py``
takes the XLA reference (``ref.smatvec_full``), which additionally
applies the fully ragged wide-block plan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_side(idx, val, widx, wval, wids, v, n_out):
    """One matvec direction from VMEM-resident blocks: narrow ELL
    gather-reduce + wide-bucket gather-reduce folded in via one-hot
    (bucket ids are distinct; padded bucket columns feed id 0 with 0.0)."""
    out = jnp.sum(val * jnp.take(v, idx, axis=0), axis=0)       # [n_out]
    wide = jnp.sum(wval * jnp.take(v, widx, axis=0), axis=0)    # [D]
    onehot = (wids[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (wids.shape[0], n_out),
                                          1))
    return out + jnp.sum(wide[:, None] * onehot.astype(wide.dtype), axis=0)


def _forward_kernel(ri_ref, rv_ref, wri_ref, wrv_ref, wrids_ref,
                    x_ref, c_ref, l_ref, u_ref, kty_ref, tau_ref,
                    xn_ref, kx_ref):
    """grid = (k,): one lane per program, everything VMEM-resident."""
    tau = tau_ref[0, 0]
    x_new = jnp.clip(x_ref[0] - tau * (c_ref[0] + kty_ref[0]),
                     l_ref[0], u_ref[0])
    xn_ref[0, :] = x_new.astype(xn_ref.dtype)
    kx = _gather_side(ri_ref[0], rv_ref[0], wri_ref[0], wrv_ref[0],
                      wrids_ref[0], x_new, kx_ref.shape[-1])
    kx_ref[0, :] = kx.astype(kx_ref.dtype)


def _backward_kernel(ci_ref, cv_ref, wci_ref, wcv_ref, wcids_ref,
                     y_ref, q_ref, mask_ref, kxn_ref, kxp_ref, sig_ref,
                     yn_ref, kty_ref):
    """grid = (k,): dual tail + adjoint gather-reduce."""
    sigma = sig_ref[0, 0]
    y_new = y_ref[0] + sigma * (2.0 * kxn_ref[0] - kxp_ref[0] - q_ref[0])
    y_new = jnp.where(mask_ref[0], jnp.maximum(y_new, 0.0), y_new)
    yn_ref[0, :] = y_new.astype(yn_ref.dtype)
    kty = _gather_side(ci_ref[0], cv_ref[0], wci_ref[0], wcv_ref[0],
                       wcids_ref[0], y_new, kty_ref.shape[-1])
    kty_ref[0, :] = kty.astype(kty_ref.dtype)


def _vec(b):
    """BlockSpec for a per-lane [1, ...] full block."""
    return pl.BlockSpec(b, lambda i: (i,) + (0,) * (len(b) - 1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def structured_forward_step(s, x, c, l, u, tau, kty, *,
                            interpret: bool = False):
    """Returns (x_new, kx).  ``s`` is a batched StructuredOperator
    (row-side leaves [k, Wr, M] / [k, Ww, Dr] / [k, Dr]); x/c/l/u/kty:
    [k, N]; tau: [k] (per-sub-problem step size — POP sub-problems restart
    independently, so step sizes diverge across the batch)."""
    k, wr, M = s.row_idx.shape
    N = x.shape[1]
    out = [jax.ShapeDtypeStruct((k, N), jnp.float32),
           jax.ShapeDtypeStruct((k, M), jnp.float32)]
    return pl.pallas_call(
        _forward_kernel,
        grid=(k,),
        in_specs=[
            _vec((1,) + s.row_idx.shape[1:]),
            _vec((1,) + s.row_val.shape[1:]),
            _vec((1,) + s.wrow_idx.shape[1:]),
            _vec((1,) + s.wrow_val.shape[1:]),
            _vec((1,) + s.wrow_ids.shape[1:]),
            _vec((1, N)), _vec((1, N)), _vec((1, N)), _vec((1, N)),
            _vec((1, N)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[_vec((1, N)), _vec((1, M))],
        out_shape=out,
        interpret=interpret,
    )(s.row_idx, s.row_val, s.wrow_idx, s.wrow_val, s.wrow_ids,
      x, c, l, u, kty, tau[:, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def structured_backward_step(s, y, q, ineq_mask, kx_new, kx_prev, sigma, *,
                             interpret: bool = False):
    """Returns (y_new, kty).  ``s`` carries the column-side leaves
    ([k, Wc, N] / [k, Wv, Dc] / [k, Dc]); y/q/ineq_mask/kx_new/kx_prev:
    [k, M]; sigma: [k]."""
    k, wc, N = s.col_idx.shape
    M = y.shape[1]
    out = [jax.ShapeDtypeStruct((k, M), jnp.float32),
           jax.ShapeDtypeStruct((k, N), jnp.float32)]
    return pl.pallas_call(
        _backward_kernel,
        grid=(k,),
        in_specs=[
            _vec((1,) + s.col_idx.shape[1:]),
            _vec((1,) + s.col_val.shape[1:]),
            _vec((1,) + s.wcol_idx.shape[1:]),
            _vec((1,) + s.wcol_val.shape[1:]),
            _vec((1,) + s.wcol_ids.shape[1:]),
            _vec((1, M)), _vec((1, M)), _vec((1, M)), _vec((1, M)),
            _vec((1, M)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[_vec((1, M)), _vec((1, N))],
        out_shape=out,
        interpret=interpret,
    )(s.col_idx, s.col_val, s.wcol_idx, s.wcol_val, s.wcol_ids,
      y, q, ineq_mask, kx_new, kx_prev, sigma[:, None])


# --------------------------------------------------------------------------
# M-blocked streaming family: the single-lane FULL problem
# --------------------------------------------------------------------------

# per-tile block sizes for the streaming full kernels; every VMEM-resident
# tile is bounded by these regardless of problem size (popcheck's
# pallas-vmem-budget rule resolves them through the keyword defaults below)
FULL_BLOCK_M = 512   # output-segment lane-axis tile (kx rows / kty cols)
FULL_BLOCK_W = 512   # wide-bucket nnz (sublane) tile
FULL_BLOCK_D = 512   # wide-bucket column (lane) tile


def _full_forward_kernel(ri_ref, rv_ref, rs_ref, wri_ref, wrv_ref, wrs_ref,
                         fold_ref, x_ref, c_ref, l_ref, u_ref, kty_ref,
                         tau_ref, xn_ref, ws_ref, kx_ref, *,
                         nwv: int, nww: int):
    """Phased grid (1 + nwv + nm,): tail, then nwv wide tiles
    (nww sublane-tiles per column-tile), then the M-blocked narrow
    phases.  ``xn`` and ``ws`` are pinned outputs that double as
    cross-phase VMEM state (their block index never changes, so they are
    flushed exactly once)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _tail():
        tau = tau_ref[0, 0]
        xn_ref[0, :] = jnp.clip(
            x_ref[0] - tau * (c_ref[0] + kty_ref[0]), l_ref[0], u_ref[0])

    @pl.when((i >= 1) & (i < 1 + nwv))
    def _wide():
        p = i - 1
        wb = p % nww
        db = p // nww
        wv = wrv_ref[0].astype(jnp.float32) * wrs_ref[0, 0]
        part = jnp.sum(wv * jnp.take(xn_ref[0], wri_ref[0], axis=0), axis=0)
        bd = part.shape[0]
        sl = pl.ds(db * bd, bd)
        prev = jnp.where(wb == 0, jnp.zeros_like(part), ws_ref[0, sl])
        ws_ref[0, sl] = prev + part

    @pl.when(i >= 1 + nwv)
    def _narrow():
        rv = rv_ref[0].astype(jnp.float32) * rs_ref[0, 0]
        out = jnp.sum(rv * jnp.take(xn_ref[0], ri_ref[0], axis=0), axis=0)
        kx_ref[0, :] = out + jnp.take(ws_ref[0], fold_ref[0], axis=0)


def _full_backward_kernel(ci_ref, cv_ref, cs_ref, wci_ref, wcv_ref, wcs_ref,
                          fold_ref, y_ref, q_ref, mask_ref, kxn_ref, kxp_ref,
                          sig_ref, yn_ref, ws_ref, kty_ref, *,
                          nwv: int, nww: int):
    """Backward mirror: dual tail, column-side wide tiles, N-blocked
    narrow phases."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _tail():
        sigma = sig_ref[0, 0]
        y_new = y_ref[0] + sigma * (2.0 * kxn_ref[0] - kxp_ref[0] - q_ref[0])
        yn_ref[0, :] = jnp.where(mask_ref[0], jnp.maximum(y_new, 0.0), y_new)

    @pl.when((i >= 1) & (i < 1 + nwv))
    def _wide():
        p = i - 1
        wb = p % nww
        db = p // nww
        wv = wcv_ref[0].astype(jnp.float32) * wcs_ref[0, 0]
        part = jnp.sum(wv * jnp.take(yn_ref[0], wci_ref[0], axis=0), axis=0)
        bd = part.shape[0]
        sl = pl.ds(db * bd, bd)
        prev = jnp.where(wb == 0, jnp.zeros_like(part), ws_ref[0, sl])
        ws_ref[0, sl] = prev + part

    @pl.when(i >= 1 + nwv)
    def _narrow():
        cv = cv_ref[0].astype(jnp.float32) * cs_ref[0, 0]
        out = jnp.sum(cv * jnp.take(yn_ref[0], ci_ref[0], axis=0), axis=0)
        kty_ref[0, :] = out + jnp.take(ws_ref[0], fold_ref[0], axis=0)


def _pin(b):
    """BlockSpec for a block pinned at the origin for every phase."""
    return pl.BlockSpec(b, lambda i: (0,) * len(b))


def _full_call(kernel, narrow, wide, fold, vectors, scalars,
               bm=FULL_BLOCK_M, bw=FULL_BLOCK_W, bd=FULL_BLOCK_D,
               interpret=False):
    """Shared launcher for the streaming full kernels.

    ``narrow`` = (idx, val, scale) [1, W, S]-shaped (S = blocked output
    segments), ``wide`` = (widx, wval, wscale) [1, Ww, D]-shaped,
    ``vectors`` = the [1, V] tail operands, ``scalars`` = the (1, 1)
    step-size blocks.  Grid = (1 + nwv + nm,) with all index maps
    clip-pinned so a block only moves (and is only re-copied / flushed)
    in the phases that use it."""
    _, wr, s_pad = narrow[0].shape
    _, ww, d_pad = wide[0].shape
    nv_shape = vectors[0].shape[1]
    nm = s_pad // bm
    nww = ww // bw
    nd = d_pad // bd
    nwv = nww * nd

    def wide_map(i):
        p = jnp.clip(i - 1, 0, nwv - 1)
        return (0, p % nww, p // nww)

    def narrow_map3(i):
        return (0, 0, jnp.clip(i - 1 - nwv, 0, nm - 1))

    def narrow_map2(i):
        return (0, jnp.clip(i - 1 - nwv, 0, nm - 1))

    out = [jax.ShapeDtypeStruct((1, nv_shape), jnp.float32),
           jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
           jax.ShapeDtypeStruct((1, s_pad), jnp.float32)]
    res = pl.pallas_call(
        functools.partial(kernel, nwv=nwv, nww=nww),
        grid=(1 + nwv + nm,),
        in_specs=[
            pl.BlockSpec((1, wr, bm), narrow_map3),
            pl.BlockSpec((1, wr, bm), narrow_map3),
            _pin((1, 1)),
            pl.BlockSpec((1, bw, bd), wide_map),
            pl.BlockSpec((1, bw, bd), wide_map),
            _pin((1, 1)),
            pl.BlockSpec((1, bm), narrow_map2),
        ] + [_pin((1, nv_shape))] * len(vectors)
          + [_pin((1, 1))] * len(scalars),
        out_specs=[_pin((1, nv_shape)), _pin((1, d_pad)),
                   pl.BlockSpec((1, bm), narrow_map2)],
        out_shape=out,
        interpret=interpret,
    )(*narrow, *wide, fold, *vectors, *scalars)
    xn, _, kx = res
    return xn, kx


@functools.partial(jax.jit, static_argnames=("block_m", "block_w",
                                             "block_d", "interpret"))
def structured_full_forward_step(ri, rv, rs, wri, wrv, wrs, fold,
                                 x, c, l, u, kty, tau, *,
                                 block_m: int = FULL_BLOCK_M,
                                 block_w: int = FULL_BLOCK_W,
                                 block_d: int = FULL_BLOCK_D,
                                 interpret: bool = False):
    """Streaming full forward half-step.  Returns (x_new, kx).

    Row-side inputs are pre-padded by ``kernels/ops.py``: ``ri/rv``
    [1, Wr, M_pad] with M_pad a ``block_m`` multiple, ``wri/wrv``
    [1, Ww_pad, D_pad] with Ww_pad / D_pad multiples of
    ``block_w`` / ``block_d`` and D_pad > D (the fold map's zero slot
    lands in an all-padding column), ``fold`` [1, M_pad], vectors
    [1, N_pad], scales / tau (1, 1).  ``rv``/``wrv`` may be f32, bf16 or
    int8 — dequantized in-register against the scale blocks."""
    return _full_call(_full_forward_kernel, (ri, rv, rs), (wri, wrv, wrs),
                      fold, (x, c, l, u, kty), (tau,),
                      block_m, block_w, block_d, interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_w",
                                             "block_d", "interpret"))
def structured_full_backward_step(ci, cv, cs, wci, wcv, wcs, fold,
                                  y, q, ineq_mask, kx_new, kx_prev, sigma, *,
                                  block_m: int = FULL_BLOCK_M,
                                  block_w: int = FULL_BLOCK_W,
                                  block_d: int = FULL_BLOCK_D,
                                  interpret: bool = False):
    """Streaming full backward half-step (column side; ``block_m`` tiles
    the N output segments).  Returns (y_new, kty)."""
    return _full_call(_full_backward_kernel, (ci, cv, cs), (wci, wcv, wcs),
                      fold, (y, q, ineq_mask, kx_new, kx_prev), (sigma,),
                      block_m, block_w, block_d, interpret)
