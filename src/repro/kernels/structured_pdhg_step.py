"""Pallas TPU kernels: FUSED PDHG half-steps for STRUCTURED operators.

The structured LPs (Gavel per-job rows, traffic per-commodity path sums,
load-balancing server groups) apply K through segment-sums and gathers, not
dense matmuls.  In the two-bucket ELL index form
(``core/pdhg.StructuredOperator``) both matvec directions become *gather +
multiply + reduce over the nnz axis* — no scatter anywhere, because the
transpose layout is precomputed at build time and the few wide segments
(worker-cap rows, hot edges, per-server load rows) live in their own
compact bucket whose results are folded back with a one-hot accumulation.
These kernels run one half-step for the WHOLE stacked k-lane batch per
launch, with the element-wise tail (axpy + projection) fused in front of
the gathers so the updated iterate never round-trips HBM between the tail
and the matvec that consumes it:

  structured_forward_step :
      x_new = clip(x - tau*(c + kty), l, u)           (kty = carried K^T y)
      kx    = narrow_rows(x_new) + onehot(wrow_ids) . wide_rows(x_new)
  structured_backward_step:
      y_new = proj_{>=0 on ineq}(y + sigma*(2*kx - kx_prev - q))
      kty   = narrow_cols(y_new) + onehot(wcol_ids) . wide_cols(y_new)

Grid is ``(k,)``: each program owns one lane, whose vectors live entirely
in VMEM (POP sub-problems are small by construction — the k^2 variable
reduction is the paper's point — so a lane's [N] + [W, M] blocks fit
comfortably; the FULL unpartitioned problem at paper scale would not, and
takes the XLA reference path via ``kernels/ops.py`` dispatch instead).
The nnz axis rides the sublanes (arrays are [W, M] nnz-major) so the
reduce is a sublane reduction and rows/cols stay on the 128-wide lane
axis.  Scalars (tau, sigma) ride in (1, 1) blocks so the kernel stays
shape-polymorphic over the POP batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_side(idx, val, widx, wval, wids, v, n_out):
    """One matvec direction from VMEM-resident blocks: narrow ELL
    gather-reduce + wide-bucket gather-reduce folded in via one-hot
    (bucket ids are distinct; padded bucket columns feed id 0 with 0.0)."""
    out = jnp.sum(val * jnp.take(v, idx, axis=0), axis=0)       # [n_out]
    wide = jnp.sum(wval * jnp.take(v, widx, axis=0), axis=0)    # [D]
    onehot = (wids[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (wids.shape[0], n_out),
                                          1))
    return out + jnp.sum(wide[:, None] * onehot.astype(wide.dtype), axis=0)


def _forward_kernel(ri_ref, rv_ref, wri_ref, wrv_ref, wrids_ref,
                    x_ref, c_ref, l_ref, u_ref, kty_ref, tau_ref,
                    xn_ref, kx_ref):
    """grid = (k,): one lane per program, everything VMEM-resident."""
    tau = tau_ref[0, 0]
    x_new = jnp.clip(x_ref[0] - tau * (c_ref[0] + kty_ref[0]),
                     l_ref[0], u_ref[0])
    xn_ref[0, :] = x_new.astype(xn_ref.dtype)
    kx = _gather_side(ri_ref[0], rv_ref[0], wri_ref[0], wrv_ref[0],
                      wrids_ref[0], x_new, kx_ref.shape[-1])
    kx_ref[0, :] = kx.astype(kx_ref.dtype)


def _backward_kernel(ci_ref, cv_ref, wci_ref, wcv_ref, wcids_ref,
                     y_ref, q_ref, mask_ref, kxn_ref, kxp_ref, sig_ref,
                     yn_ref, kty_ref):
    """grid = (k,): dual tail + adjoint gather-reduce."""
    sigma = sig_ref[0, 0]
    y_new = y_ref[0] + sigma * (2.0 * kxn_ref[0] - kxp_ref[0] - q_ref[0])
    y_new = jnp.where(mask_ref[0], jnp.maximum(y_new, 0.0), y_new)
    yn_ref[0, :] = y_new.astype(yn_ref.dtype)
    kty = _gather_side(ci_ref[0], cv_ref[0], wci_ref[0], wcv_ref[0],
                       wcids_ref[0], y_new, kty_ref.shape[-1])
    kty_ref[0, :] = kty.astype(kty_ref.dtype)


def _vec(b):
    """BlockSpec for a per-lane [1, ...] full block."""
    return pl.BlockSpec(b, lambda i: (i,) + (0,) * (len(b) - 1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def structured_forward_step(s, x, c, l, u, tau, kty, *,
                            interpret: bool = False):
    """Returns (x_new, kx).  ``s`` is a batched StructuredOperator
    (row-side leaves [k, Wr, M] / [k, Ww, Dr] / [k, Dr]); x/c/l/u/kty:
    [k, N]; tau: [k] (per-sub-problem step size — POP sub-problems restart
    independently, so step sizes diverge across the batch)."""
    k, wr, M = s.row_idx.shape
    N = x.shape[1]
    out = [jax.ShapeDtypeStruct((k, N), jnp.float32),
           jax.ShapeDtypeStruct((k, M), jnp.float32)]
    return pl.pallas_call(
        _forward_kernel,
        grid=(k,),
        in_specs=[
            _vec((1,) + s.row_idx.shape[1:]),
            _vec((1,) + s.row_val.shape[1:]),
            _vec((1,) + s.wrow_idx.shape[1:]),
            _vec((1,) + s.wrow_val.shape[1:]),
            _vec((1,) + s.wrow_ids.shape[1:]),
            _vec((1, N)), _vec((1, N)), _vec((1, N)), _vec((1, N)),
            _vec((1, N)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[_vec((1, N)), _vec((1, M))],
        out_shape=out,
        interpret=interpret,
    )(s.row_idx, s.row_val, s.wrow_idx, s.wrow_val, s.wrow_ids,
      x, c, l, u, kty, tau[:, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def structured_backward_step(s, y, q, ineq_mask, kx_new, kx_prev, sigma, *,
                             interpret: bool = False):
    """Returns (y_new, kty).  ``s`` carries the column-side leaves
    ([k, Wc, N] / [k, Wv, Dc] / [k, Dc]); y/q/ineq_mask/kx_new/kx_prev:
    [k, M]; sigma: [k]."""
    k, wc, N = s.col_idx.shape
    M = y.shape[1]
    out = [jax.ShapeDtypeStruct((k, M), jnp.float32),
           jax.ShapeDtypeStruct((k, N), jnp.float32)]
    return pl.pallas_call(
        _backward_kernel,
        grid=(k,),
        in_specs=[
            _vec((1,) + s.col_idx.shape[1:]),
            _vec((1,) + s.col_val.shape[1:]),
            _vec((1,) + s.wcol_idx.shape[1:]),
            _vec((1,) + s.wcol_val.shape[1:]),
            _vec((1,) + s.wcol_ids.shape[1:]),
            _vec((1, M)), _vec((1, M)), _vec((1, M)), _vec((1, M)),
            _vec((1, M)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[_vec((1, M)), _vec((1, N))],
        out_shape=out,
        interpret=interpret,
    )(s.col_idx, s.col_val, s.wcol_idx, s.wcol_val, s.wcol_ids,
      y, q, ineq_mask, kx_new, kx_prev, sigma[:, None])
