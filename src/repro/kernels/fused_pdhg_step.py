"""Pallas TPU kernels: FUSED PDHG primal/dual updates.

The unfused PDHG iteration writes two full-length intermediates to HBM per
step (the gradient ``c + K^T y`` and the pre-projection dual ``y + sigma *
(K x_bar - q)``).  At PDHG's arithmetic intensity (~2 flop/byte, far below
the TPU v5e ridge of ~240) every avoided HBM round-trip is pure wall-clock.

These kernels keep the matvec partials in VMEM and apply the element-wise
tail (axpy + projection + extrapolation) in the SAME kernel invocation on
the final reduction block:

  fused_primal_step : x_new = clip(x - tau*(c + K^T y), l, u); x_bar = 2*x_new - x
  fused_dual_step   : y_new = proj_{>=0 on ineq}(y + sigma*(K x_bar - q))

Scalars (tau, sigma) ride in SMEM-like (1, 1) blocks so the kernel stays
shape-polymorphic over the POP batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pdhg_matvec import BLOCK_M, BLOCK_N


def _fused_primal_kernel(a_ref, y_ref, x_ref, c_ref, l_ref, u_ref, tau_ref,
                         xn_ref, xb_ref, acc_ref):
    """grid = (k, N/bn, M/bm); contracts over M, finishes on the last block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]                        # [bm, bn]
    y = y_ref[0]                        # [bm]
    acc_ref[...] += jax.lax.dot_general(
        a, y[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finish():
        tau = tau_ref[0, 0]
        g = c_ref[0] + acc_ref[...]                     # c + K^T y
        x = x_ref[0]
        x_new = jnp.clip(x - tau * g, l_ref[0], u_ref[0])
        xn_ref[0, :] = x_new.astype(xn_ref.dtype)
        xb_ref[0, :] = (2.0 * x_new - x).astype(xb_ref.dtype)


def _fused_dual_kernel(a_ref, xb_ref, y_ref, q_ref, mask_ref, sig_ref,
                       yn_ref, acc_ref):
    """grid = (k, M/bm, N/bn); contracts over N, finishes on the last block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]
    xb = xb_ref[0]
    acc_ref[...] += jax.lax.dot_general(
        a, xb[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finish():
        sigma = sig_ref[0, 0]
        y_new = y_ref[0] + sigma * (acc_ref[...] - q_ref[0])
        y_new = jnp.where(mask_ref[0], jnp.maximum(y_new, 0.0), y_new)
        yn_ref[0, :] = y_new.astype(yn_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def fused_primal_step(A, y, x, c, l, u, tau, *,
                      block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                      interpret: bool = False):
    """Returns (x_new, x_bar).  A: [k, M, N]; x/c/l/u: [k, N]; y: [k, M];
    tau: [k] (per-sub-problem step size — POP sub-problems restart
    independently, so step sizes diverge across the batch)."""
    k, M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0
    grid = (k, N // block_n, M // block_m)
    vec_n = pl.BlockSpec((1, block_n), lambda b, j, i: (b, j))
    out = [jax.ShapeDtypeStruct((k, N), jnp.float32)] * 2
    return pl.pallas_call(
        _fused_primal_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
            vec_n, vec_n, vec_n, vec_n,
            pl.BlockSpec((1, 1), lambda b, j, i: (b, 0)),
        ],
        out_specs=[vec_n, vec_n],
        out_shape=out,
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        interpret=interpret,
    )(A, y, x, c, l, u, tau[:, None])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def fused_dual_step(A, x_bar, y, q, sigma, ineq_mask, *,
                    block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                    interpret: bool = False):
    """Returns y_new.  A: [k, M, N]; x_bar: [k, N]; y/q: [k, M];
    ineq_mask: [k, M] bool; sigma: [k]."""
    k, M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0
    grid = (k, M // block_m, N // block_n)
    vec_m = pl.BlockSpec((1, block_m), lambda b, i, j: (b, i))
    return pl.pallas_call(
        _fused_dual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, block_n), lambda b, i, j: (b, j)),
            vec_m, vec_m, vec_m,
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
        ],
        out_specs=vec_m,
        out_shape=jax.ShapeDtypeStruct((k, M), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m,), jnp.float32)],
        interpret=interpret,
    )(A, x_bar, y, q, ineq_mask, sigma[:, None])
