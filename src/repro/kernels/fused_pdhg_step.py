"""Pallas TPU kernels: FUSED PDHG half-steps for DENSE operators.

The unfused PDHG iteration writes two full-length intermediates to HBM per
step (the primal gradient and the pre-projection dual).  At PDHG's
arithmetic intensity (~2 flop/byte, far below the TPU v5e ridge of ~240)
every avoided HBM round-trip is pure wall-clock.

These kernels fuse each half-step's element-wise tail with the matvec that
FOLLOWS it, in the same launch, and emit the matvec product as a second
output — the product the in-loop KKT check in ``core/pdhg.solve_stacked``
consumes for free:

  fused_forward_step  : x_new = clip(x - tau*(c + kty), l, u);  kx = K x_new
  fused_backward_step : y_new = proj_{>=0 on ineq}(y + sigma*(2*kx - kx_prev - q))
                        kty   = K^T y_new

(``kty`` in the forward step is the CARRIED K^T y from the previous
backward step; the dual extrapolation uses 2*K x_new - K x_prev — linearity
of K — instead of a second matvec on the extrapolated point.)

Scalars (tau, sigma) ride in SMEM-like (1, 1) blocks so the kernel stays
shape-polymorphic over the POP batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pdhg_matvec import BLOCK_M, BLOCK_N


def _fused_forward_kernel(a_ref, x_ref, c_ref, l_ref, u_ref, kty_ref,
                          tau_ref, xn_ref, kx_ref, acc_ref):
    """grid = (k, M/bm, N/bn); contracts over N, finishes on the last block.

    The x_new tail for column block j is (cheaply) recomputed at every row
    block i — deterministic, so the repeated xn writes all carry the same
    value — while the fresh x_new block feeds the accumulating matvec
    without ever leaving VMEM."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tau = tau_ref[0, 0]
    x_new = jnp.clip(x_ref[0] - tau * (c_ref[0] + kty_ref[0]),
                     l_ref[0], u_ref[0])
    xn_ref[0, :] = x_new.astype(xn_ref.dtype)
    a = a_ref[0]                        # [bm, bn]
    acc_ref[...] += jax.lax.dot_general(
        a, x_new[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finish():
        kx_ref[0, :] = acc_ref[...].astype(kx_ref.dtype)


def _fused_backward_kernel(a_ref, y_ref, q_ref, mask_ref, kxn_ref, kxp_ref,
                           sig_ref, yn_ref, kty_ref, acc_ref):
    """grid = (k, N/bn, M/bm); contracts over M, finishes on the last block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sigma = sig_ref[0, 0]
    y_new = y_ref[0] + sigma * (2.0 * kxn_ref[0] - kxp_ref[0] - q_ref[0])
    y_new = jnp.where(mask_ref[0], jnp.maximum(y_new, 0.0), y_new)
    yn_ref[0, :] = y_new.astype(yn_ref.dtype)
    a = a_ref[0]                        # [bm, bn]
    acc_ref[...] += jax.lax.dot_general(
        a, y_new[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _finish():
        kty_ref[0, :] = acc_ref[...].astype(kty_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def fused_forward_step(A, x, c, l, u, tau, kty, *,
                       block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                       interpret: bool = False):
    """Returns (x_new, kx).  A: [k, M, N]; x/c/l/u/kty: [k, N]; tau: [k]
    (per-sub-problem step size — POP sub-problems restart independently,
    so step sizes diverge across the batch)."""
    k, M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0
    grid = (k, M // block_m, N // block_n)
    vec_n = pl.BlockSpec((1, block_n), lambda b, i, j: (b, j))
    vec_m = pl.BlockSpec((1, block_m), lambda b, i, j: (b, i))
    out = [jax.ShapeDtypeStruct((k, N), jnp.float32),
           jax.ShapeDtypeStruct((k, M), jnp.float32)]
    return pl.pallas_call(
        _fused_forward_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda b, i, j: (b, i, j)),
            vec_n, vec_n, vec_n, vec_n, vec_n,
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
        ],
        out_specs=[vec_n, vec_m],
        out_shape=out,
        scratch_shapes=[pltpu.VMEM((block_m,), jnp.float32)],
        interpret=interpret,
    )(A, x, c, l, u, kty, tau[:, None])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def fused_backward_step(A, y, q, sigma, ineq_mask, kx_new, kx_prev, *,
                        block_m: int = BLOCK_M, block_n: int = BLOCK_N,
                        interpret: bool = False):
    """Returns (y_new, kty).  A: [k, M, N]; y/q/kx_new/kx_prev: [k, M];
    ineq_mask: [k, M] bool; sigma: [k]."""
    k, M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0
    grid = (k, N // block_n, M // block_m)
    vec_m = pl.BlockSpec((1, block_m), lambda b, j, i: (b, i))
    vec_n = pl.BlockSpec((1, block_n), lambda b, j, i: (b, j))
    out = [jax.ShapeDtypeStruct((k, M), jnp.float32),
           jax.ShapeDtypeStruct((k, N), jnp.float32)]
    return pl.pallas_call(
        _fused_backward_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda b, j, i: (b, i, j)),
            vec_m, vec_m, vec_m, vec_m, vec_m,
            pl.BlockSpec((1, 1), lambda b, j, i: (b, 0)),
        ],
        out_specs=[vec_m, vec_n],
        out_shape=out,
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        interpret=interpret,
    )(A, y, q, ineq_mask, kx_new, kx_prev, sigma[:, None])
