"""Public jit'd wrappers around the Pallas kernels.

Handles (a) padding arbitrary shapes up to block multiples and slicing
results back, and (b) platform dispatch.  Every wrapper takes a
``backend`` keyword:

``None`` / ``"auto"``
    The fast path for the platform: compiled Pallas on TPU, the pure-jnp
    reference (``ref.py`` — algebraically identical, XLA-fused) everywhere
    else.  Interpret-mode Pallas is ~1000x too slow for a PDHG inner loop,
    so it is never chosen implicitly.
``"pallas"``
    Force compiled Pallas (fails off-TPU — debugging aid).
``"interpret"``
    Force the Pallas interpreter (runs anywhere; exercises the real kernel
    bodies + padding logic on CPU — what ``tests/test_kernels.py`` and the
    step-engine padding tests use).
``"xla"``
    Force the pure-jnp reference (A/B benchmarking escape hatch).

Two kernel families back the step engines in ``core/pdhg.py``:

* dense (``fused_forward_step`` / ``fused_backward_step`` +
  ``bmatvec``/``bmatvec_t``) — blocked matmul kernels over an explicit
  [k, M, N] constraint matrix (``kernels/fused_pdhg_step.py``);
* structured (``structured_forward_step`` / ``structured_backward_step`` +
  ``smatvec``/``smatvec_t``) — gather/segment-reduce kernels over ELL
  index metadata (``core/pdhg.StructuredOperator``,
  ``kernels/structured_pdhg_step.py``).  Off-TPU the reference path is
  pure ``take_along_axis`` gathers — no scatter, unlike the
  ``segment_sum`` scatter-adds in typical domain matvecs.

A third family streams the **single-lane full problem**
(``structured_full_forward_step`` / ``structured_full_backward_step`` +
``smatvec_full``/``smatvec_t_full``): M-blocked phased-grid kernels whose
VMEM residency is bounded by the ``FULL_BLOCK_*`` tile sizes, with the
wide-bucket add-back as a fold-map gather and optional int8/bf16
coefficient storage dequantized in-kernel.  The ``plan`` keyword is the
static ragged wide-block plan from ``core/pdhg._wide_block_plan`` — the
XLA reference applies it in full; the Pallas path uses it to trim the
streamed wide width to the plan maximum.

A solver constructed once picks the right kernel per platform at trace
time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pdhg_matvec as _mv
from . import fused_pdhg_step as _fused
from . import structured_pdhg_step as _structured
from . import ref as _ref

_MODES = (None, "auto", "pallas", "interpret", "xla")

# lane-axis multiple for the structured kernels' full-lane blocks
STRUCT_ALIGN = 128


def _resolve_mode(backend: str | None) -> str:
    """'pallas' | 'interpret' | 'xla' from a user-facing backend name."""
    if backend not in _MODES:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {_MODES}")
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _pad_to(a: jnp.ndarray, axis: int, mult: int, value: float = 0.0):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def bmatvec(A, x, *, backend: str | None = None,
            block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """y = A @ x batched over leading axis; any [k, M, N] shape."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.bmatvec(A, x)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    xp = _pad_to(x, 1, block_n)
    y = _mv.bmatvec(Ap, xp, block_m=block_m, block_n=block_n,
                    interpret=mode == "interpret")
    return y[:, :M]


def bmatvec_t(A, y, *, backend: str | None = None,
              block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """x = A^T @ y batched over leading axis."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.bmatvec_t(A, y)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yp = _pad_to(y, 1, block_m)
    x = _mv.bmatvec_t(Ap, yp, block_m=block_m, block_n=block_n,
                      interpret=mode == "interpret")
    return x[:, :N]


def fused_forward_step(A, x, c, l, u, tau, kty, *, backend: str | None = None,
                       block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """(x_new, kx) — fused clip(x - tau(c + kty)) + forward matvec.

    Padded variables get l = u = 0 blocks (pinned to zero, matching the
    LinearProgram padding contract), so the sliced-back result equals the
    unpadded math exactly."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.fused_forward_step(A, x, c, l, u, tau[:, None], kty)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    pad_vec = lambda v: _pad_to(v, 1, block_n)
    xn, kx = _fused.fused_forward_step(
        Ap, pad_vec(x), pad_vec(c), pad_vec(l), pad_vec(u), tau, pad_vec(kty),
        block_m=block_m, block_n=block_n, interpret=mode == "interpret")
    return xn[:, :N], kx[:, :M]


def fused_backward_step(A, y, q, sigma, ineq_mask, kx_new, kx_prev, *,
                        backend: str | None = None,
                        block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """(y_new, kty) — fused proj(y + sigma(2*kx_new - kx_prev - q)) +
    adjoint matvec."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.fused_backward_step(A, y, q, sigma[:, None], ineq_mask,
                                        kx_new, kx_prev)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    pad_vec = lambda v: _pad_to(v, 1, block_m)
    yn, kty = _fused.fused_backward_step(
        Ap, pad_vec(y), pad_vec(q), sigma, pad_vec(ineq_mask),
        pad_vec(kx_new), pad_vec(kx_prev),
        block_m=block_m, block_n=block_n, interpret=mode == "interpret")
    return yn[:, :M], kty[:, :N]


# --------------------------------------------------------------------------
# structured (ELL gather/segment-reduce) family — ``s`` is a
# ``core/pdhg.StructuredOperator`` with batched [k, W, {M|N}] leaves
# --------------------------------------------------------------------------

def smatvec(s, x):
    """kx = K x through the row-side gather layout.  Pure gather-reduce —
    the XLA form is the fast path on every platform for the out-of-loop
    uses (power iteration, equilibration probes, final KKT report); only
    the inner-loop half-steps get dedicated Pallas kernels."""
    return _ref.smatvec(s, x)


def smatvec_t(s, y):
    """kty = K^T y through the column-side gather layout."""
    return _ref.smatvec_t(s, y)


def _pad_row_side(s):
    """Pad the row-side leaves' lane axes to STRUCT_ALIGN multiples for
    the full-lane Pallas blocks (padded bucket columns feed row 0 with
    val 0 — exact no-ops)."""
    return s._replace(
        row_idx=_pad_to(s.row_idx, 2, STRUCT_ALIGN),
        row_val=_pad_to(s.row_val, 2, STRUCT_ALIGN),
        wrow_idx=_pad_to(s.wrow_idx, 2, STRUCT_ALIGN),
        wrow_val=_pad_to(s.wrow_val, 2, STRUCT_ALIGN),
        wrow_ids=_pad_to(s.wrow_ids, 1, STRUCT_ALIGN))


def _pad_col_side(s):
    return s._replace(
        col_idx=_pad_to(s.col_idx, 2, STRUCT_ALIGN),
        col_val=_pad_to(s.col_val, 2, STRUCT_ALIGN),
        wcol_idx=_pad_to(s.wcol_idx, 2, STRUCT_ALIGN),
        wcol_val=_pad_to(s.wcol_val, 2, STRUCT_ALIGN),
        wcol_ids=_pad_to(s.wcol_ids, 1, STRUCT_ALIGN))


def structured_forward_step(s, x, c, l, u, tau, kty, *,
                            backend: str | None = None):
    """(x_new, kx) for a structured operator: one gather/segment-reduce
    launch for the whole k-stack (Pallas on TPU/interpret, XLA reference
    elsewhere)."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.structured_forward_step(s, x, c, l, u, tau[:, None], kty)
    M = s.row_idx.shape[-1]
    N = x.shape[1]
    pad_vec = lambda v: _pad_to(v, 1, STRUCT_ALIGN)
    xn, kx = _structured.structured_forward_step(
        _pad_row_side(s), pad_vec(x), pad_vec(c), pad_vec(l), pad_vec(u),
        tau, pad_vec(kty), interpret=mode == "interpret")
    return xn[:, :N], kx[:, :M]


def structured_backward_step(s, y, q, sigma, ineq_mask, kx_new, kx_prev, *,
                             backend: str | None = None):
    """(y_new, kty) for a structured operator."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.structured_backward_step(s, y, q, sigma[:, None],
                                             ineq_mask, kx_new, kx_prev)
    N = s.col_idx.shape[-1]
    M = y.shape[1]
    pad_vec = lambda v: _pad_to(v, 1, STRUCT_ALIGN)
    yn, kty = _structured.structured_backward_step(
        _pad_col_side(s), pad_vec(y), pad_vec(q), pad_vec(ineq_mask),
        pad_vec(kx_new), pad_vec(kx_prev), sigma,
        interpret=mode == "interpret")
    return yn[:, :M], kty[:, :N]


# --------------------------------------------------------------------------
# streaming full-problem (single-lane, M-blocked) family
# --------------------------------------------------------------------------

def smatvec_full(s, x, *, plan=()):
    """kx = K x for the single-lane full problem: fold-map wide add-back
    + ragged wide-block plan.  Like ``smatvec``, the XLA form is the fast
    path for the out-of-loop uses on every platform."""
    return _ref.smatvec_full(s, x, plan)


def smatvec_t_full(s, y, *, plan=()):
    """kty = K^T y through the column-side full layout."""
    return _ref.smatvec_t_full(s, y, plan)


def _sublane(dtype) -> int:
    """Sublane multiple for a coefficient dtype (8 f32 / 16 bf16 /
    32 int8 — second-minor tiling is 32 bytes)."""
    return 32 // jnp.dtype(dtype).itemsize


def _pad_full_side(idx, val, scale, widx, wval, wscale, fold, plan,
                   block_m, block_w, block_d):
    """Pad one gather side for the streaming kernels: narrow [1, W, S]
    to (sublane-mult, block-mult) tiles, wide [1, Ww, D] trimmed to the
    plan's max effective width then tiled, D padded PAST the bucket end
    so the fold map's zero slot lands in an all-padding (exact-zero)
    column.  Block sizes shrink to the padded extent on small problems
    so the grid never over-runs the data."""
    sub = _sublane(val.dtype)
    idx = _pad_to(idx, 1, sub)
    val = _pad_to(val, 1, sub)
    bm = min(block_m, -(-idx.shape[2] // STRUCT_ALIGN) * STRUCT_ALIGN)
    idx = _pad_to(idx, 2, bm)
    val = _pad_to(val, 2, bm)
    fold = _pad_to(fold, 1, bm)
    if plan:
        weff = min(widx.shape[1], max(wb for _, _, wb in plan))
        widx = widx[:, :weff, :]
        wval = wval[:, :weff, :]
    bw = min(block_w, -(-widx.shape[1] // sub) * sub)
    widx = _pad_to(widx, 1, bw)
    wval = _pad_to(wval, 1, bw)
    d = widx.shape[2]
    bd = min(block_d, -(-(d + 1) // STRUCT_ALIGN) * STRUCT_ALIGN)
    widx = _pad_to(jnp.pad(widx, ((0, 0), (0, 0), (0, 1))), 2, bd)
    wval = _pad_to(jnp.pad(wval, ((0, 0), (0, 0), (0, 1))), 2, bd)
    ones = jnp.ones((1, 1), jnp.float32)
    mk_scale = lambda sc: ones if sc is None else sc.reshape(1, 1)
    return (idx, val, mk_scale(scale), widx, wval, mk_scale(wscale),
            fold, bm, bw, bd)


def structured_full_forward_step(s, x, c, l, u, tau, kty, *, plan=(),
                                 backend: str | None = None,
                                 block_m: int = _structured.FULL_BLOCK_M,
                                 block_w: int = _structured.FULL_BLOCK_W,
                                 block_d: int = _structured.FULL_BLOCK_D):
    """(x_new, kx) for the single-lane full problem: one M-blocked
    streaming launch (Pallas on TPU/interpret, ragged-plan XLA reference
    elsewhere)."""
    mode = _resolve_mode(backend)
    if mode == "xla" or s.row_idx.shape[0] != 1:
        return _ref.structured_full_forward_step(s, x, c, l, u,
                                                 tau[:, None], kty, plan)
    M = s.row_idx.shape[-1]
    N = x.shape[1]
    ri, rv, rs, wri, wrv, wrs, fold, bm, bw, bd = _pad_full_side(
        s.row_idx, s.row_val, s.row_scale, s.wrow_idx, s.wrow_val,
        s.wrow_scale, s.row_fold, plan, block_m, block_w, block_d)
    pad_vec = lambda v: _pad_to(v, 1, STRUCT_ALIGN)
    xn, kx = _structured.structured_full_forward_step(
        ri, rv, rs, wri, wrv, wrs, fold,
        pad_vec(x), pad_vec(c), pad_vec(l), pad_vec(u), pad_vec(kty),
        tau[:, None], block_m=bm, block_w=bw, block_d=bd,
        interpret=mode == "interpret")
    return xn[:, :N], kx[:, :M]


def structured_full_backward_step(s, y, q, sigma, ineq_mask, kx_new,
                                  kx_prev, *, plan=(),
                                  backend: str | None = None,
                                  block_m: int = _structured.FULL_BLOCK_M,
                                  block_w: int = _structured.FULL_BLOCK_W,
                                  block_d: int = _structured.FULL_BLOCK_D):
    """(y_new, kty) for the single-lane full problem (column side)."""
    mode = _resolve_mode(backend)
    if mode == "xla" or s.col_idx.shape[0] != 1:
        return _ref.structured_full_backward_step(
            s, y, q, sigma[:, None], ineq_mask, kx_new, kx_prev, plan)
    N = s.col_idx.shape[-1]
    M = y.shape[1]
    ci, cv, cs, wci, wcv, wcs, fold, bm, bw, bd = _pad_full_side(
        s.col_idx, s.col_val, s.col_scale, s.wcol_idx, s.wcol_val,
        s.wcol_scale, s.col_fold, plan, block_m, block_w, block_d)
    pad_vec = lambda v: _pad_to(v, 1, STRUCT_ALIGN)
    yn, kty = _structured.structured_full_backward_step(
        ci, cv, cs, wci, wcv, wcs, fold,
        pad_vec(y), pad_vec(q), pad_vec(ineq_mask), pad_vec(kx_new),
        pad_vec(kx_prev), sigma[:, None], block_m=bm, block_w=bw,
        block_d=bd, interpret=mode == "interpret")
    return yn[:, :M], kty[:, :N]
