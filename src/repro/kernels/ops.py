"""Public jit'd wrappers around the Pallas kernels.

Handles (a) padding arbitrary shapes up to block multiples and slicing
results back, and (b) platform dispatch.  Every wrapper takes a
``backend`` keyword:

``None`` / ``"auto"``
    The fast path for the platform: compiled Pallas on TPU, the pure-jnp
    reference (``ref.py`` — algebraically identical, XLA-fused) everywhere
    else.  Interpret-mode Pallas is ~1000x too slow for a PDHG inner loop,
    so it is never chosen implicitly.
``"pallas"``
    Force compiled Pallas (fails off-TPU — debugging aid).
``"interpret"``
    Force the Pallas interpreter (runs anywhere; exercises the real kernel
    bodies + padding logic on CPU — what ``tests/test_kernels.py`` and the
    step-engine padding tests use).
``"xla"``
    Force the pure-jnp reference (A/B benchmarking escape hatch).

The step-engine in ``core/pdhg.py`` (``fused_dense_engine``) builds on
these wrappers, so a solver constructed once picks the right kernel per
platform at trace time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pdhg_matvec as _mv
from . import fused_pdhg_step as _fused
from . import ref as _ref

_MODES = (None, "auto", "pallas", "interpret", "xla")


def _resolve_mode(backend: str | None) -> str:
    """'pallas' | 'interpret' | 'xla' from a user-facing backend name."""
    if backend not in _MODES:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"expected one of {_MODES}")
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _pad_to(a: jnp.ndarray, axis: int, mult: int, value: float = 0.0):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def bmatvec(A, x, *, backend: str | None = None,
            block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """y = A @ x batched over leading axis; any [k, M, N] shape."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.bmatvec(A, x)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    xp = _pad_to(x, 1, block_n)
    y = _mv.bmatvec(Ap, xp, block_m=block_m, block_n=block_n,
                    interpret=mode == "interpret")
    return y[:, :M]


def bmatvec_t(A, y, *, backend: str | None = None,
              block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """x = A^T @ y batched over leading axis."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.bmatvec_t(A, y)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yp = _pad_to(y, 1, block_m)
    x = _mv.bmatvec_t(Ap, yp, block_m=block_m, block_n=block_n,
                      interpret=mode == "interpret")
    return x[:, :N]


def fused_primal_step(A, y, x, c, l, u, tau, *, backend: str | None = None,
                      block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """(x_new, x_bar) — fused clip(x - tau(c + A^T y)) + extrapolation.

    Padded variables get l = u = 0 blocks (pinned to zero, matching the
    LinearProgram padding contract), so the sliced-back result equals the
    unpadded math exactly."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.fused_primal_step(A, y, x, c, l, u, tau[:, None])
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yp = _pad_to(y, 1, block_m)
    pad_vec = lambda v: _pad_to(v, 1, block_n)
    xn, xb = _fused.fused_primal_step(
        Ap, yp, pad_vec(x), pad_vec(c), pad_vec(l), pad_vec(u), tau,
        block_m=block_m, block_n=block_n, interpret=mode == "interpret")
    return xn[:, :N], xb[:, :N]


def fused_dual_step(A, x_bar, y, q, sigma, ineq_mask, *,
                    backend: str | None = None,
                    block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """y_new — fused proj(y + sigma(A x_bar - q))."""
    mode = _resolve_mode(backend)
    if mode == "xla":
        return _ref.fused_dual_step(A, x_bar, y, q, sigma[:, None], ineq_mask)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yn = _fused.fused_dual_step(
        Ap, _pad_to(x_bar, 1, block_n), _pad_to(y, 1, block_m),
        _pad_to(q, 1, block_m), sigma, _pad_to(ineq_mask, 1, block_m),
        block_m=block_m, block_n=block_n, interpret=mode == "interpret")
    return yn[:, :M]
