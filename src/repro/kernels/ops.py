"""Public jit'd wrappers around the Pallas kernels.

Handles (a) padding arbitrary shapes up to block multiples and slicing
results back, and (b) backend dispatch: compiled Pallas on TPU, interpret
mode on CPU (this container), with the pure-jnp reference as an escape
hatch (``backend="xla"``) for A/B benchmarking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pdhg_matvec as _mv
from . import fused_pdhg_step as _fused
from . import ref as _ref


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(a: jnp.ndarray, axis: int, mult: int, value: float = 0.0):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


def bmatvec(A, x, *, backend: str | None = None,
            block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """y = A @ x batched over leading axis; any [k, M, N] shape."""
    if backend == "xla":
        return _ref.bmatvec(A, x)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    xp = _pad_to(x, 1, block_n)
    y = _mv.bmatvec(Ap, xp, block_m=block_m, block_n=block_n,
                    interpret=_interpret_default() if backend is None else backend == "interpret")
    return y[:, :M]


def bmatvec_t(A, y, *, backend: str | None = None,
              block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """x = A^T @ y batched over leading axis."""
    if backend == "xla":
        return _ref.bmatvec_t(A, y)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yp = _pad_to(y, 1, block_m)
    x = _mv.bmatvec_t(Ap, yp, block_m=block_m, block_n=block_n,
                      interpret=_interpret_default() if backend is None else backend == "interpret")
    return x[:, :N]


def fused_primal_step(A, y, x, c, l, u, tau, *, backend: str | None = None,
                      block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """(x_new, x_bar) — fused clip(x - tau(c + A^T y)) + extrapolation."""
    if backend == "xla":
        return _ref.fused_primal_step(A, y, x, c, l, u, tau[:, None])
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yp = _pad_to(y, 1, block_m)
    pad_vec = lambda v: _pad_to(v, 1, block_n)
    xn, xb = _fused.fused_primal_step(
        Ap, yp, pad_vec(x), pad_vec(c), pad_vec(l), pad_vec(u), tau,
        block_m=block_m, block_n=block_n,
        interpret=_interpret_default() if backend is None else backend == "interpret")
    return xn[:, :N], xb[:, :N]


def fused_dual_step(A, x_bar, y, q, sigma, ineq_mask, *,
                    backend: str | None = None,
                    block_m: int = _mv.BLOCK_M, block_n: int = _mv.BLOCK_N):
    """y_new — fused proj(y + sigma(A x_bar - q))."""
    if backend == "xla":
        return _ref.fused_dual_step(A, x_bar, y, q, sigma[:, None], ineq_mask)
    k, M, N = A.shape
    Ap = _pad_to(_pad_to(A, 1, block_m), 2, block_n)
    yn = _fused.fused_dual_step(
        Ap, _pad_to(x_bar, 1, block_n), _pad_to(y, 1, block_m),
        _pad_to(q, 1, block_m), sigma, _pad_to(ineq_mask, 1, block_m),
        block_m=block_m, block_n=block_n,
        interpret=_interpret_default() if backend is None else backend == "interpret")
    return yn[:, :M]
