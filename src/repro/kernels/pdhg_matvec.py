"""Pallas TPU kernels: batched constraint-matrix matvecs for the PDHG loop.

The PDHG inner loop is two matvecs per iteration over the stacked
constraint matrix K — for POP, a *batched* K of shape [k_subproblems, M, N].
These kernels tile K into MXU-aligned VMEM blocks and accumulate partial
products in VMEM, so each K element is read from HBM exactly once per
matvec (the roofline for this op — it is memory-bound at PDHG's 2 flops
per byte).

Tiling scheme (forward ``bmatvec``):

    grid = (k, M/bm, N/bn)            # N is the reduction axis
    A block  : (1, bm, bn)  VMEM
    x block  : (1, bn)      VMEM      (re-read per M row-block: bn << HBM)
    y block  : (1, bm)      VMEM      accumulated across the N axis

The transposed matvec reads the SAME layout of K (no materialised K^T in
HBM — a [k,M,N]-strided transpose would double memory traffic) and
contracts along M instead, transposing only the (bm, bn) tile in VMEM,
which the MXU handles natively via ``dot_general`` dimension numbers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned defaults: 256x256 f32 tile = 256 KiB VMEM for the K block,
# well inside the ~16 MiB/core VMEM budget with double buffering.
BLOCK_M = 256
BLOCK_N = 256


def _bmatvec_kernel(a_ref, x_ref, o_ref):
    """One (1, bm, bn) tile: o[bm] += A[bm, bn] @ x[bn]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0]                       # [bm, bn]
    x = x_ref[0]                       # [bn]
    # rank-2 dot keeps the MXU path; accumulate in f32
    o_ref[0, :] += jax.lax.dot_general(
        a, x[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0].astype(o_ref.dtype)


def _bmatvec_t_kernel(a_ref, y_ref, o_ref):
    """One (1, bm, bn) tile: o[bn] += A[bm, bn]^T @ y[bm] (in-VMEM transpose)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0]                       # [bm, bn]
    y = y_ref[0]                       # [bm]
    o_ref[0, :] += jax.lax.dot_general(
        a, y[:, None], (((0,), (0,)), ((), ())),   # contract over bm
        preferred_element_type=jnp.float32,
    )[:, 0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def bmatvec(A: jnp.ndarray, x: jnp.ndarray, *,
            block_m: int = BLOCK_M, block_n: int = BLOCK_N,
            interpret: bool = False) -> jnp.ndarray:
    """y[k, M] = A[k, M, N] @ x[k, N].  Shapes must be block-divisible
    (``ops.py`` handles padding)."""
    k, M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)
    grid = (k, M // block_m, N // block_n)
    return pl.pallas_call(
        _bmatvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, block_n), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((k, M), jnp.float32),
        interpret=interpret,
    )(A, x)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def bmatvec_t(A: jnp.ndarray, y: jnp.ndarray, *,
              block_m: int = BLOCK_M, block_n: int = BLOCK_N,
              interpret: bool = False) -> jnp.ndarray:
    """x[k, N] = A[k, M, N]^T @ y[k, M] without materialising A^T."""
    k, M, N = A.shape
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)
    # reduction axis is M now -> make it the innermost grid dim
    grid = (k, N // block_n, M // block_m)
    return pl.pallas_call(
        _bmatvec_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_m, block_n), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, block_m), lambda b, j, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda b, j, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((k, N), jnp.float32),
        interpret=interpret,
    )(A, y)
