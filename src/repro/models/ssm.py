"""Mamba2 (State Space Duality) block: chunked parallel scan for training,
O(1)-state recurrent step for decode.

Follows the minimal SSD formulation (Dao & Gu 2024): per head h with state
size N and head dim P,

    h_t = exp(a_t) * h_{t-1} + dt_t * B_t x_t^T      (a_t = -softplus-ish)
    y_t = C_t . h_t + D * x_t

Training computes y in CHUNKS: quadratic attention-like term inside each
chunk + a cross-chunk recurrence on chunk-final states via an associative
scan — this is the TPU-native layout (batched matmuls over chunks feed the
MXU; no sequential loop over 4k steps).

This is the sub-quadratic mixer that makes zamba2/xlstm eligible for the
``long_500k`` shape (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_mamba2(rng, d_model: int, d_state: int = 64, expand: int = 2,
                head_dim: int = 64, conv_width: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(rng, 6)
    s = 1.0 / np.sqrt(d_model)
    # separate projections (not one fused [z|x|B|C|dt] matrix): the d_inner
    # outputs TP-shard over the model axis while B/C/dt stay replicated —
    # a fused layout would split mid-boundary under GSPMD
    return {
        "w_z": jax.random.normal(ks[0], (d_model, d_inner), jnp.float32) * s,
        "w_x": jax.random.normal(ks[1], (d_model, d_inner), jnp.float32) * s,
        "w_B": jax.random.normal(ks[2], (d_model, d_state), jnp.float32) * s,
        "w_C": jax.random.normal(ks[3], (d_model, d_state), jnp.float32) * s,
        "w_dt": jax.random.normal(ks[4], (d_model, n_heads), jnp.float32) * s,
        "conv_w": jax.random.normal(ks[5], (conv_width, d_inner),
                                    jnp.float32) * 0.2,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),   # per-head decay
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "w_out": jax.random.normal(jax.random.fold_in(ks[5], 1),
                                   (d_inner, d_model),
                                   jnp.float32) / np.sqrt(d_inner),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
    }


def _split_proj(p, x):
    """Returns z, xc, B, C, dt — shapes [B,S,d_inner]x2, [B,S,N]x2, [B,S,H]."""
    dt_c = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(dt_c))
    xc = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(dt_c))
    Bm = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(dt_c))
    Cm = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(dt_c))
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_c))
    return z, xc, Bm, Cm, dt


def _causal_conv(p, xc, conv_state=None):
    """Depthwise causal conv along S.  With ``conv_state`` ([B, W-1, d])
    performs the one-step streaming update and returns the new state."""
    W = p["conv_w"].shape[0]
    w = p["conv_w"].astype(xc.dtype)
    if conv_state is None:
        pad = jnp.pad(xc, ((0, 0), (W - 1, 0), (0, 0)))
        out = sum(pad[:, i: i + xc.shape[1], :] * w[i] for i in range(W))
        return jax.nn.silu(out), None
    window = jnp.concatenate([conv_state, xc], axis=1)        # [B, W, d]
    out = jnp.einsum("bwd,wd->bd", window, w)[:, None, :]
    return jax.nn.silu(out), window[:, 1:, :]


def _segsum(a):
    """Stable log-space segment sums: out[..., t, s] = sum_{s<r<=t} a_r."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_train(p, x, chunk: int = 256):
    """x: [B, S, D] -> [B, S, D].  Chunk adapts to divide S."""
    import math
    Bsz, S, D = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = math.gcd(S, chunk)
    dt_model = x.dtype
    z, xc, Bm, Cm, dt = _split_proj(p, x)
    xc, _ = _causal_conv(p, xc)

    H = p["a_log"].shape[0]
    P = xc.shape[-1] // H
    N = Bm.shape[-1]
    nC = S // chunk

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [B,S,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32)) * dt             # [B,S,H] (<0)
    xh = xc.astype(jnp.float32).reshape(Bsz, nC, chunk, H, P)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nC, chunk, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nC, chunk, N)
    ac = a.reshape(Bsz, nC, chunk, H).transpose(0, 1, 3, 2)       # [B,c,H,L]
    dtc = dt.reshape(Bsz, nC, chunk, H)

    # 1) intra-chunk (quadratic in chunk, batched matmuls)
    L = jnp.exp(_segsum(ac))                                      # [B,c,H,L,L]
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcsh,bcshp->bclhp",
                        Cc, Bc, L, dtc, xh)

    # 2) chunk-final states
    a_cum = jnp.cumsum(ac, axis=-1)                               # [B,c,H,L]
    decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)               # [B,c,H,L]
    states = jnp.einsum("bcsn,bchs,bcsh,bcshp->bchpn",
                        Bc, decay_to_end, dtc, xh)                # [B,c,H,P,N]

    # 3) cross-chunk recurrence on states (associative scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                         # [B,c,H]

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    _, states_inc = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    # state ENTERING chunk c = inclusive result of chunk c-1 (shift right)
    states_in = jnp.concatenate(
        [jnp.zeros_like(states_inc[:, :1]), states_inc[:, :-1]], axis=1)

    # 4) inter-chunk contribution
    state_decay = jnp.exp(a_cum)                                  # [B,c,H,L]
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp", Cc, state_decay, states_in)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    y = y + p["d_skip"][None, None, :, None] * xh.reshape(Bsz, S, H, P)
    y = y.reshape(Bsz, S, H * P).astype(dt_model)

    # gated RMS norm (Mamba2's z-gate)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + p["norm_scale"])).astype(dt_model)
    return jnp.einsum("bsd,de->bse", y, p["w_out"].astype(dt_model))


def mamba2_init_state(p, batch: int, dtype=jnp.float32):
    d_inner = p["w_out"].shape[0]
    H = p["a_log"].shape[0]
    P = d_inner // H
    N = p["w_B"].shape[1]
    W = p["conv_w"].shape[0]
    return {
        "ssm": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, W - 1, d_inner), dtype),
    }


def mamba2_decode(p, x, state):
    """One-step recurrence.  x: [B, 1, D]."""
    dt_model = x.dtype
    z, xc, Bm, Cm, dt = _split_proj(p, x)
    xc, conv_state = _causal_conv(p, xc, state["conv"])

    H = p["a_log"].shape[0]
    P = xc.shape[-1] // H
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt)                             # [B,H]
    xh = xc[:, 0].astype(jnp.float32).reshape(-1, H, P)
    Bv = Bm[:, 0].astype(jnp.float32)                                  # [B,N]
    Cv = Cm[:, 0].astype(jnp.float32)

    h = state["ssm"] * a[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, Bv)
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + p["d_skip"][None, :, None] * xh
    y = y.reshape(x.shape[0], 1, H * P).astype(dt_model)

    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * (1.0 + p["norm_scale"])).astype(dt_model)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"].astype(dt_model))
    return out, {"ssm": h, "conv": conv_state}
