"""Base layers: norms, embeddings, RoPE, gated MLPs.

Functional style throughout: ``init_*`` returns a param dict, ``*_apply``
consumes it.  Every param dict has a parallel PartitionSpec tree produced
by ``shardings.param_specs`` (tree structure must match exactly — tests
assert this).

Dtype policy (production default): parameters are stored f32 (optimizer
master), activations/compute are bf16; the cast happens at parameter use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cast(p, dtype):
    return jax.tree.map(lambda a: a.astype(dtype), p)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}   # (1 + scale) convention


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(rng, vocab: int, d: int):
    return {"table": jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02}


def embed(p, tokens, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p, x):
    """Logits against the (possibly tied) embedding table."""
    table = p["table"].astype(x.dtype)
    return jnp.einsum("...d,vd->...v", x, table)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10_000.0):
    return theta ** (-np.arange(0, head_dim // 2, dtype=np.float32)
                     / (head_dim // 2))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))          # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(rng, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), jnp.float32) * s_in,
        "w_up": jax.random.normal(k2, (d, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d), jnp.float32) * s_out,
    }


def mlp(p, x, activation: str = "silu"):
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt))
    act = jax.nn.silu if activation == "silu" else (
        lambda a: jax.nn.gelu(a, approximate=True))
    h = act(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dt))


def init_dense(rng, d_in: int, d_out: int):
    return {"w": jax.random.normal(rng, (d_in, d_out), jnp.float32)
            / np.sqrt(d_in)}


def dense(p, x):
    return jnp.einsum("...d,de->...e", x, p["w"].astype(x.dtype))
