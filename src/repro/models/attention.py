"""Attention: GQA/MQA/MHA with RoPE, sliding windows, logit soft-capping,
causal & bidirectional modes, cross-attention, and KV-cached decoding.

Design notes for the scan-over-layers stack (``transformer.py``):

  * ``window`` is a TRACED per-layer scalar, not a Python branch.  A
    local:global pattern (gemma2/gemma3) lowers to ONE attention HLO whose
    mask depends on the scanned window value — this keeps compile time and
    HLO size O(1) in depth while preserving exact masking semantics.
  * decode keeps a ring-buffer cache of length ``cache_len`` = min(seq,
    window) for SWA layers: a 500k-context danube/mixtral decode holds a
    4k cache per layer (this is what makes ``long_500k`` sub-quadratic).
  * soft-capping (gemma2) is tanh-based and applied pre-softmax.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope

NEG_INF = -2.0 ** 30


def init_attention(rng, d_model: int, n_heads: int, n_kv: int, head_dim: int):
    kq, kk, kv, ko = jax.random.split(rng, 4)
    s = 1.0 / np.sqrt(d_model)
    so = 1.0 / np.sqrt(n_heads * head_dim)
    return {
        "wq": jax.random.normal(kq, (d_model, n_heads, head_dim), jnp.float32) * s,
        "wk": jax.random.normal(kk, (d_model, n_kv, head_dim), jnp.float32) * s,
        "wv": jax.random.normal(kv, (d_model, n_kv, head_dim), jnp.float32) * s,
        "wo": jax.random.normal(ko, (n_heads, head_dim, d_model), jnp.float32) * so,
    }


def _soft_cap(logits, cap):
    """gemma2 logit soft-capping; cap <= 0 disables (traced-friendly)."""
    capped = jnp.tanh(logits / jnp.maximum(cap, 1e-6)) * cap
    return jnp.where(cap > 0, capped, logits)


def _expand_kv(k, n_heads):
    """[B,T,Kv,hd] -> [B,T,H,hd] by repeating each KV head group-times.

    Broadcast+merge keeps the head axis sharding intact under GSPMD
    (reshaping Q's head axis into [Kv, group] instead forces an
    involuntary resharding copy — measured in the dry-run HLO)."""
    B, T, Kv, hd = k.shape
    group = n_heads // Kv
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, T, Kv, group, hd))
    return k.reshape(B, T, n_heads, hd)


def _gqa_scores(q, k, scale):
    """q: [B,S,H,hd], k: [B,T,Kv,hd] -> [B,H,S,T] with head grouping."""
    k = _expand_kv(k, q.shape[2])
    return jnp.einsum("bshk,bthk->bhst", q * scale, k)


def _gqa_out(w, v):
    """w: [B,H,S,T], v: [B,T,Kv,hd] -> [B,S,H,hd]."""
    v = _expand_kv(v, w.shape[1])
    return jnp.einsum("bhst,bthk->bshk", w, v)


def attention_train(p, x, *, window, softcap, rope_theta: float,
                    causal: bool = True, memory: Optional[jnp.ndarray] = None,
                    positions: Optional[jnp.ndarray] = None):
    """Full-sequence attention (training / prefill).

    window/softcap are traced scalars (f32; window >= seq means full).
    ``memory`` switches to cross-attention (KV from memory, no mask).
    """
    B, S, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    src = x if memory is None else memory
    k = jnp.einsum("btd,dhk->bthk", src, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", src, p["wv"].astype(dt))
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if memory is None:                     # self-attention: rotate q & k
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_scores(q, k, scale).astype(jnp.float32)   # [B,H,S,T]
    logits = _soft_cap(logits, softcap)

    if memory is None:
        T = k.shape[1]
        qp = positions[:, None, :, None]                    # [B,1,S,1]
        kp = positions[:, None, None, :]                    # [B,1,1,T]
        mask = jnp.ones((B, 1, S, T), bool)
        if causal:
            mask &= kp <= qp
        mask &= (qp - kp) < window                          # SWA band
        logits = jnp.where(mask, logits, NEG_INF)

    w = jax.nn.softmax(logits, axis=-1).astype(dt)
    o = _gqa_out(w, v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


class KVCache(NamedTuple):
    """Ring-buffer KV cache.  ``k``/``v``: [B, cache_len, Kv, hd].
    For SWA layers cache_len == window; writes wrap (slot = pos % len)."""
    k: jnp.ndarray
    v: jnp.ndarray

    @classmethod
    def zeros(cls, B, cache_len, n_kv, head_dim, dtype=jnp.bfloat16):
        z = jnp.zeros((B, cache_len, n_kv, head_dim), dtype)
        return cls(k=z, v=z)


def attention_decode(p, x, cache: KVCache, pos, *, window, softcap,
                     rope_theta: float,
                     memory: Optional[jnp.ndarray] = None,
                     cache_constraint=None):
    """One-token decode step.  x: [B, 1, D]; pos: [] int32 current position.

    Cross-attention (memory != None) reads precomputed memory directly and
    ignores the cache.
    """
    B = x.shape[0]
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))

    if memory is not None:
        k = jnp.einsum("btd,dhk->bthk", memory, p["wk"].astype(dt))
        v = jnp.einsum("btd,dhk->bthk", memory, p["wv"].astype(dt))
        scale = 1.0 / np.sqrt(q.shape[-1])
        logits = _gqa_scores(q, k, scale).astype(jnp.float32)
        logits = _soft_cap(logits, softcap)
        w = jax.nn.softmax(logits, axis=-1).astype(dt)
        return jnp.einsum("bshk,hkd->bsd", _gqa_out(w, v),
                          p["wo"].astype(dt)), cache

    pos_b = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pos_b, rope_theta)
    if cache_constraint is not None:
        q = cache_constraint(q, "q")     # replicate q heads over `model`
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    k_new = apply_rope(k_new, pos_b, rope_theta)
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))

    L = cache.k.shape[1]
    slot = jnp.mod(pos, L)
    k_all = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                         (0, slot, 0, 0))
    v_all = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                         (0, slot, 0, 0))
    if cache_constraint is not None:
        # §Perf flash-decode layout: pin the cache to its (e.g. sequence-
        # over-model) sharding so GSPMD reduces attention to per-shard
        # partial softmax + small psums instead of re-gathering the cache
        k_all = cache_constraint(k_all, "kv")
        v_all = cache_constraint(v_all, "kv")

    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = _gqa_scores(q, k_all.astype(dt), scale).astype(jnp.float32)
    if cache_constraint is not None:
        logits = cache_constraint(logits, "scores")
    logits = _soft_cap(logits, softcap)                      # [B,H,1,L]

    # ring-buffer validity: slot s holds absolute position p_s with
    # p_s = pos - ((pos - s) mod L); valid iff p_s >= 0, p_s <= pos and
    # pos - p_s < window
    slots = jnp.arange(L)
    age = jnp.mod(pos - slots, L)                            # 0..L-1
    abs_pos = pos - age
    valid = (abs_pos >= 0) & (age < window)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)

    w = jax.nn.softmax(logits, axis=-1).astype(dt)
    if cache_constraint is not None:
        w = cache_constraint(w, "scores")
    o = _gqa_out(w, v_all.astype(dt))
    if cache_constraint is not None:
        o = cache_constraint(o, "out")
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, KVCache(k=k_all, v=v_all)
