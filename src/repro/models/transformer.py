"""Composable decoder / encoder-decoder stacks over heterogeneous blocks.

The unit of composition is a **period** — a short sequence of blocks (e.g.
gemma3's [local x5, global], gemma2's [local, global], zamba2's
[mamba x6, shared-attn]) — and a **segment** scans a stack of identical
periods with ``jax.lax.scan`` + ``jax.checkpoint``:

  * compile time / HLO size stay O(period), not O(depth) — 34-56 layer
    models lower in seconds, which the 80-cell dry-run matrix depends on;
  * remat per period bounds activation memory (carries are bf16);
  * block position within the period is STATIC, so window sizes /
    mixer kinds never become traced branches (one attention HLO per block
    position, exact masking).

Weight-shared blocks (zamba2's shared attention) live OUTSIDE the scanned
params and are closed over — applied once per period with the same weights,
while their KV caches remain per-application (stacked on the period axis).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .attention import KVCache
from .layers import (cast, dense, embed, init_dense, init_embedding, init_mlp,
                     init_rmsnorm, mlp, rmsnorm, unembed)


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockCfg:
    mixer: str = "attn"          # attn | mamba2 | mlstm | slstm | shared_attn
    ffn: str = "dense"           # dense | moe | none
    window: Optional[int] = None  # None = full attention (SWA band otherwise)
    cross_attn: bool = False     # decoder block with encoder cross-attention


@dataclasses.dataclass(frozen=True)
class Segment:
    period: tuple                # tuple[BlockCfg, ...]
    n_periods: int


@dataclasses.dataclass(frozen=True)
class ModelOpts:
    """Beyond-paper performance knobs (EXPERIMENTS.md §Perf).

    sp_residual: Megatron-SP-style sequence-sharded residual stream — the
        hidden state between blocks is sharded over the `model` axis on the
        SEQUENCE dim, turning each TP all-reduce into reduce-scatter +
        all-gather around the (now 1/|model|-sized) norms.
    bf16_barrier: pins an optimization_barrier on each NORM OUTPUT (the
        tensor the TP/SP collective moves) so XLA cannot hoist the f32
        upcast above the collective (measured ~2x wire inflation without
        it: the HLO shows f32 all-gathers of bf16-semantics tensors).
    """
    sp_residual: bool = False
    bf16_barrier: bool = False
    gather_once: bool = False   # gather the SP-sharded norm output ONCE so
                                # gate/up/q/k/v einsums CSE a single AG
    cache_seq_on_model: bool = False  # flash-decode: cache seq over `model`
    mesh: object = None

    def constrain(self, x):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self.sp_residual and self.mesh is not None:
            dp = tuple(a for a in ("pod", "data")
                       if a in self.mesh.axis_names)
            if x.shape[1] % self.mesh.shape["model"] == 0:
                x = jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh, P(dp, "model", None)))
        return x

    def cache_constraint(self):
        if not (self.cache_seq_on_model and self.mesh is not None):
            return None
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        dp = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

        m = self.mesh.shape["model"]

        def constrain(t, kind):
            # kv [B,L,Kv,hd]: seq over `model`; scores [B,H,1,L]: L over
            # `model`; q/out [B,1,H,hd]: replicated over `model` (tiny) —
            # pins every attention intermediate so wo's head sharding
            # cannot back-propagate a cache re-gather
            if kind == "kv" and t.shape[1] % m == 0:
                spec = P(dp, "model", None, None)
            elif kind == "scores" and t.shape[-1] % m == 0:
                spec = P(dp, None, None, "model")
            elif kind in ("q", "out"):
                spec = P(dp, None, None, None)
            else:
                return t
            return jax.lax.with_sharding_constraint(
                t, NamedSharding(self.mesh, spec))
        return constrain

    def pin(self, h):
        """Apply to norm outputs feeding TP matmuls."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        if self.bf16_barrier:
            h = jax.lax.optimization_barrier(h)
        if (self.gather_once and self.sp_residual and self.mesh is not None
                and h.shape[1] % self.mesh.shape["model"] == 0):
            dp = tuple(a for a in ("pod", "data")
                       if a in self.mesh.axis_names)
            h = jax.lax.with_sharding_constraint(
                h, NamedSharding(self.mesh, P(dp, None, None)))
        return h


DEFAULT_OPTS = ModelOpts()


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchCfg:
    name: str
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    segments: tuple               # decoder/main stack
    enc_segments: tuple = ()      # encoder stack (enc-dec archs)
    softcap: float = 0.0
    rope_theta: float = 10_000.0
    act: str = "silu"
    tied_embeddings: bool = True
    moe: Optional[MoECfg] = None
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    frontend: Optional[str] = None   # None | "audio" | "vision"
    family: str = "dense"            # dense | moe | hybrid | ssm | audio | vlm
    # which shapes are runnable (long_500k needs sub-quadratic attention)
    supports_long: bool = False

    @property
    def n_layers(self) -> int:
        return sum(len(s.period) * s.n_periods for s in self.segments)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        return int(sum(np.prod(np.asarray(l.shape))
                       for l in jax.tree.leaves(
                           jax.eval_shape(lambda: init_params(
                               jax.random.PRNGKey(0), self)))))


# ---------------------------------------------------------------------------
# per-block init/apply
# ---------------------------------------------------------------------------

def _init_block(rng, cfg: ArchCfg, bcfg: BlockCfg):
    ks = jax.random.split(rng, 4)
    p = {"norm1": init_rmsnorm(cfg.d_model)}
    if bcfg.mixer == "attn":
        p["mixer"] = attn_mod.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim)
    elif bcfg.mixer == "mamba2":
        p["mixer"] = ssm_mod.init_mamba2(
            ks[0], cfg.d_model, cfg.ssm_state, cfg.ssm_expand, cfg.ssm_head_dim)
    elif bcfg.mixer == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(ks[0], cfg.d_model, cfg.n_heads)
    elif bcfg.mixer == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(ks[0], cfg.d_model, cfg.n_heads)
    elif bcfg.mixer == "shared_attn":
        pass                       # weights live outside the scan
    else:
        raise ValueError(bcfg.mixer)

    if bcfg.cross_attn:
        p["norm_cross"] = init_rmsnorm(cfg.d_model)
        p["cross"] = attn_mod.init_attention(
            ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim)

    if bcfg.ffn == "dense":
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["ffn"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff)
    elif bcfg.ffn == "moe":
        m = cfg.moe
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["ffn"] = moe_mod.init_moe(ks[2], cfg.d_model, m.d_ff_expert,
                                    m.n_experts, m.n_shared, m.d_ff_shared)
    return p


def _mixer_cache_init(cfg: ArchCfg, bcfg: BlockCfg, batch: int, seq: int,
                      shared_params=None, kv_dtype=jnp.bfloat16):
    """Zero cache for one block (decode).  SWA layers get window-sized
    ring buffers — the long_500k memory win."""
    if bcfg.mixer in ("attn", "shared_attn"):
        cache_len = min(seq, bcfg.window) if bcfg.window else seq
        return KVCache.zeros(batch, cache_len, cfg.n_kv, cfg.head_dim,
                             dtype=kv_dtype)
    if bcfg.mixer == "mamba2":
        proto = shared_params if shared_params is not None else None
        p = proto or ssm_mod.init_mamba2(jax.random.PRNGKey(0), cfg.d_model,
                                         cfg.ssm_state, cfg.ssm_expand,
                                         cfg.ssm_head_dim)
        return ssm_mod.mamba2_init_state(p, batch)
    if bcfg.mixer == "mlstm":
        p = xlstm_mod.init_mlstm(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads)
        return xlstm_mod.mlstm_init_state(p, batch)
    if bcfg.mixer == "slstm":
        p = xlstm_mod.init_slstm(jax.random.PRNGKey(0), cfg.d_model, cfg.n_heads)
        return xlstm_mod.slstm_init_state(p, batch)
    raise ValueError(bcfg.mixer)


def _apply_block_train(p, cfg: ArchCfg, bcfg: BlockCfg, x, shared_attn_params,
                       memory=None, causal=True, opts=DEFAULT_OPTS):
    window = float(bcfg.window) if bcfg.window else float(x.shape[1] + 1)
    h = opts.pin(rmsnorm(p["norm1"], x))
    if bcfg.mixer in ("attn", "shared_attn"):
        mp = p["mixer"] if bcfg.mixer == "attn" else shared_attn_params
        h = attn_mod.attention_train(
            mp, h, window=window, softcap=cfg.softcap,
            rope_theta=cfg.rope_theta, causal=causal)
    elif bcfg.mixer == "mamba2":
        h = ssm_mod.mamba2_train(p["mixer"], h)
    elif bcfg.mixer == "mlstm":
        h = xlstm_mod.mlstm_train(p["mixer"], h)
    elif bcfg.mixer == "slstm":
        h = xlstm_mod.slstm_train(p["mixer"], h)
    x = x + h

    if bcfg.cross_attn:
        h = opts.pin(rmsnorm(p["norm_cross"], x))
        h = attn_mod.attention_train(
            p["cross"], h, window=float(memory.shape[1] + 1),
            softcap=cfg.softcap, rope_theta=cfg.rope_theta,
            causal=False, memory=memory)
        x = x + h

    x = opts.constrain(x)
    if bcfg.ffn == "dense":
        x = x + mlp(p["ffn"], opts.pin(rmsnorm(p["norm2"], x)), cfg.act)
    elif bcfg.ffn == "moe":
        x = x + moe_mod.moe(p["ffn"], opts.pin(rmsnorm(p["norm2"], x)),
                            top_k=cfg.moe.top_k,
                            capacity_factor=cfg.moe.capacity_factor,
                            activation=cfg.act)
    return opts.constrain(x)


def _apply_block_decode(p, cfg: ArchCfg, bcfg: BlockCfg, x, cache, pos,
                        shared_attn_params, memory=None, opts=DEFAULT_OPTS):
    window = float(bcfg.window) if bcfg.window else 2.0 ** 31
    h = rmsnorm(p["norm1"], x)
    if bcfg.mixer in ("attn", "shared_attn"):
        mp = p["mixer"] if bcfg.mixer == "attn" else shared_attn_params
        h, cache = attn_mod.attention_decode(
            mp, h, cache, pos, window=window, softcap=cfg.softcap,
            rope_theta=cfg.rope_theta,
            cache_constraint=opts.cache_constraint())
    elif bcfg.mixer == "mamba2":
        h, cache = ssm_mod.mamba2_decode(p["mixer"], h, cache)
    elif bcfg.mixer == "mlstm":
        h, cache = xlstm_mod.mlstm_decode(p["mixer"], h, cache)
    elif bcfg.mixer == "slstm":
        h, cache = xlstm_mod.slstm_decode(p["mixer"], h, cache)
    x = x + h

    if bcfg.cross_attn:
        h = rmsnorm(p["norm_cross"], x)
        h, _ = attn_mod.attention_decode(
            p["cross"], h, cache=None, pos=pos, window=2.0 ** 31,
            softcap=cfg.softcap, rope_theta=cfg.rope_theta, memory=memory)
        x = x + h

    if bcfg.ffn == "dense":
        x = x + mlp(p["ffn"], rmsnorm(p["norm2"], x), cfg.act)
    elif bcfg.ffn == "moe":
        x = x + moe_mod.moe(p["ffn"], rmsnorm(p["norm2"], x),
                            top_k=cfg.moe.top_k,
                            capacity_factor=cfg.moe.capacity_factor,
                            activation=cfg.act)
    return x, cache


# ---------------------------------------------------------------------------
# segments (scan over periods)
# ---------------------------------------------------------------------------

def _init_segment(rng, cfg: ArchCfg, seg: Segment):
    """Stacked period params: leaf shapes get a leading [n_periods] axis."""
    def one_period(r):
        ks = jax.random.split(r, len(seg.period))
        return {f"b{i}": _init_block(ks[i], cfg, b)
                for i, b in enumerate(seg.period)}
    rngs = jax.random.split(rng, seg.n_periods)
    periods = [one_period(r) for r in rngs]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *periods)


def _segment_train(seg_params, cfg: ArchCfg, seg: Segment, x,
                   shared_attn_params, memory=None, causal=True,
                   remat: bool = True, unroll: bool = False,
                   opts=DEFAULT_OPTS):
    def body(carry, period_params):
        h = carry
        for i, b in enumerate(seg.period):
            h = _apply_block_train(period_params[f"b{i}"], cfg, b, h,
                                   shared_attn_params, memory, causal, opts)
        return h, None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    if unroll:
        # cost-probe mode: XLA's HloCostAnalysis counts while bodies once,
        # so roofline probes lower the stack unrolled (see launch/dryrun.py)
        for i in range(seg.n_periods):
            x, _ = body(x, jax.tree.map(lambda a: a[i], seg_params))
        return x
    x, _ = jax.lax.scan(body, x, seg_params)
    return x


def _segment_decode(seg_params, cfg: ArchCfg, seg: Segment, x, seg_cache, pos,
                    shared_attn_params, memory=None, unroll: bool = False,
                    opts=DEFAULT_OPTS):
    def body(carry, scanned):
        h = carry
        period_params, period_cache = scanned
        new_cache = {}
        for i, b in enumerate(seg.period):
            h, c = _apply_block_decode(period_params[f"b{i}"], cfg, b, h,
                                       period_cache[f"b{i}"], pos,
                                       shared_attn_params, memory, opts)
            new_cache[f"b{i}"] = c
        return h, new_cache
    if unroll:
        outs = []
        for i in range(seg.n_periods):
            x, nc = body(x, jax.tree.map(lambda a: a[i],
                                         (seg_params, seg_cache)))
            outs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, new_cache
    x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
    return x, new_cache


def _init_segment_cache(cfg: ArchCfg, seg: Segment, batch: int, seq: int,
                        kv_dtype=jnp.bfloat16):
    def one():
        return {f"b{i}": _mixer_cache_init(cfg, b, batch, seq,
                                           kv_dtype=kv_dtype)
                for i, b in enumerate(seg.period)}
    protos = [one() for _ in range(seg.n_periods)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *protos)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _has_shared_attn(cfg: ArchCfg) -> bool:
    return any(b.mixer == "shared_attn"
               for s in cfg.segments for b in s.period)


def init_params(rng, cfg: ArchCfg):
    ks = jax.random.split(rng, 8)
    p = {
        "embed": init_embedding(ks[0], cfg.vocab, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
        "segments": [
            _init_segment(jax.random.fold_in(ks[1], i), cfg, s)
            for i, s in enumerate(cfg.segments)],
    }
    if not cfg.tied_embeddings:
        p["unembed"] = init_dense(ks[2], cfg.d_model, cfg.vocab)
    if _has_shared_attn(cfg):
        p["shared_attn"] = attn_mod.init_attention(
            ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim)
    if cfg.enc_segments:
        p["enc_segments"] = [
            _init_segment(jax.random.fold_in(ks[4], i), cfg, s)
            for i, s in enumerate(cfg.enc_segments)]
        p["enc_norm"] = init_rmsnorm(cfg.d_model)
    if cfg.frontend is not None:
        # modality stub: a linear adapter over PRECOMPUTED frame/patch
        # embeddings (input_specs supplies them; the real frontend is out of
        # scope per the assignment)
        p["frontend"] = init_dense(ks[5], cfg.d_model, cfg.d_model)
    return p


def _encode(params, cfg: ArchCfg, enc_embeddings, remat=True, unroll=False,
            opts=DEFAULT_OPTS):
    x = dense(params["frontend"], enc_embeddings) if cfg.frontend else enc_embeddings
    for seg_p, seg in zip(params["enc_segments"], cfg.enc_segments):
        x = _segment_train(seg_p, cfg, seg, x, None, causal=False,
                           remat=remat, unroll=unroll, opts=opts)
    return rmsnorm(params["enc_norm"], x)


def forward_train(params, cfg: ArchCfg, tokens, enc_embeddings=None,
                  remat: bool = True, compute_dtype=jnp.bfloat16,
                  unroll: bool = False, opts=DEFAULT_OPTS):
    """Logits for next-token prediction.  tokens: [B, S] int32."""
    memory = None
    if cfg.enc_segments:
        memory = _encode(params, cfg, enc_embeddings.astype(compute_dtype),
                         remat=remat, unroll=unroll, opts=opts)
    x = embed(params["embed"], tokens, compute_dtype)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), compute_dtype)
    shared = params.get("shared_attn")
    for seg_p, seg in zip(params["segments"], cfg.segments):
        x = _segment_train(seg_p, cfg, seg, x, shared, memory=memory,
                           remat=remat, unroll=unroll, opts=opts)
    # SP residual ends here: gather the sequence back before the norm+vocab
    if opts.sp_residual and opts.mesh is not None:
        import jax as _jax
        from jax.sharding import NamedSharding as _NS, PartitionSpec as _P
        dp = tuple(a for a in ("pod", "data") if a in opts.mesh.axis_names)
        x = _jax.lax.with_sharding_constraint(
            x, _NS(opts.mesh, _P(dp, None, None)))
    x = rmsnorm(params["final_norm"], x)
    if cfg.tied_embeddings:
        return unembed(params["embed"], x)
    return dense(params["unembed"], x)


def init_cache(cfg: ArchCfg, batch: int, seq: int, kv_dtype=jnp.bfloat16):
    """Decode cache for a maximum context of ``seq``.

    ``kv_dtype`` is the KV-cache storage dtype.  It must match the serving
    compute dtype: a bf16 cache under float32 decode silently truncates the
    KV history every step, so decode drifts ~1e-3 relative from the
    teacher-forcing forward (amplified further by MoE gate renormalisation)
    even though both paths "compute in float32"."""
    return {
        "seg_caches": [_init_segment_cache(cfg, s, batch, seq,
                                           kv_dtype=kv_dtype)
                       for s in cfg.segments],
        "pos": jnp.zeros((), jnp.int32),
    }


def forward_decode(params, cfg: ArchCfg, token, cache, enc_memory=None,
                   compute_dtype=jnp.bfloat16, unroll: bool = False,
                   opts=DEFAULT_OPTS):
    """One decode step.  token: [B, 1] int32 -> (logits [B, 1, V], cache)."""
    x = embed(params["embed"], token, compute_dtype)
    x = x * jnp.asarray(np.sqrt(cfg.d_model), compute_dtype)
    pos = cache["pos"]
    shared = params.get("shared_attn")
    new_segs = []
    for seg_p, seg, seg_c in zip(params["segments"], cfg.segments,
                                 cache["seg_caches"]):
        x, nc = _segment_decode(seg_p, cfg, seg, x, seg_c, pos, shared,
                                memory=enc_memory, unroll=unroll, opts=opts)
        new_segs.append(nc)
    x = rmsnorm(params["final_norm"], x)
    logits = (unembed(params["embed"], x) if cfg.tied_embeddings
              else dense(params["unembed"], x))
    return logits, {"seg_caches": new_segs, "pos": pos + 1}


def encode(params, cfg: ArchCfg, enc_embeddings, compute_dtype=jnp.bfloat16):
    """Public encoder entry (serving: run once per request batch)."""
    return _encode(params, cfg, enc_embeddings.astype(compute_dtype),
                   remat=False)
