"""LM substrate: composable blocks (attention/MoE/Mamba2/xLSTM) assembled
into decoder-only and encoder-decoder stacks via scan-over-periods."""

from .transformer import (
    ArchCfg, BlockCfg, MoECfg, Segment,
    init_params, init_cache, forward_train, forward_decode, encode,
)

__all__ = [
    "ArchCfg", "BlockCfg", "MoECfg", "Segment",
    "init_params", "init_cache", "forward_train", "forward_decode", "encode",
]
