"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, parallelisable)
and sLSTM (scalar memory, sequential recurrence).

mLSTM training uses the stabilised parallel (quadratic) form — a
decay-masked attention-like matmul, MXU-friendly like standard attention.
Decode is the O(d^2)-per-head recurrent update, which is what qualifies
xlstm-350m for ``long_500k``.

sLSTM's gates depend on the previous hidden state, so training runs a
``lax.scan`` over time (sequential by construction — noted in DESIGN.md;
xLSTM interleaves only a few sLSTM blocks for this reason).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(rng, d_model: int, n_heads: int):
    hd = d_model // n_heads
    ks = jax.random.split(rng, 6)
    s = 1.0 / np.sqrt(d_model)
    return {
        "wq": jax.random.normal(ks[0], (d_model, n_heads, hd), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d_model, n_heads, hd), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d_model, n_heads, hd), jnp.float32) * s,
        "w_if": jax.random.normal(ks[3], (d_model, n_heads, 2), jnp.float32) * s,
        "wo_gate": jax.random.normal(ks[4], (d_model, d_model), jnp.float32) * s,
        "w_out": jax.random.normal(ks[5], (d_model, d_model), jnp.float32) * s,
    }


def mlstm_train(p, x):
    """Stabilised parallel mLSTM.  x: [B, S, D]."""
    B, S, D = x.shape
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt)).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt)).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt)).astype(jnp.float32)
    gates = jnp.einsum("bsd,dhg->bshg", x, p["w_if"].astype(dt)).astype(jnp.float32)
    log_i = -jax.nn.softplus(-gates[..., 0])            # log sigmoid(i)
    log_f = -jax.nn.softplus(-gates[..., 1])            # log sigmoid(f)

    hd = q.shape[-1]
    F = jnp.cumsum(log_f, axis=1)                       # [B,S,H]
    # D[t,s] = exp(F_t - F_s + log_i_s) for s <= t (log-space, stabilised)
    logD = (F[:, :, None, :] - F[:, None, :, :]
            + log_i[:, None, :, :])                     # [B,t,s,H]
    mask = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    logD = jnp.where(mask, logD, -jnp.inf)
    m = jnp.max(logD, axis=2, keepdims=True)            # stabiliser [B,t,1,H]
    Dmat = jnp.exp(logD - m)

    scores = jnp.einsum("bthk,bshk->btsh", q, k) / np.sqrt(hd)
    w = scores * Dmat
    num = jnp.einsum("btsh,bshk->bthk", w, v)
    den = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0, :]))
    h = num / den[..., None]                            # [B,S,H,hd]

    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["wo_gate"].astype(dt))
                       .astype(jnp.float32))
    h = (h.reshape(B, S, D) * o).astype(dt)
    return jnp.einsum("bsd,de->bse", h, p["w_out"].astype(dt))


def mlstm_init_state(p, batch: int, dtype=jnp.float32):
    D, H, hd = p["wq"].shape
    return {
        "C": jnp.zeros((batch, H, hd, hd), dtype),   # matrix memory
        "n": jnp.zeros((batch, H, hd), dtype),       # normaliser
        "m": jnp.full((batch, H), -1e30, dtype),     # stabiliser
    }


def mlstm_decode(p, x, state):
    """O(d^2) recurrent step.  x: [B, 1, D]."""
    B, _, D = x.shape
    dt = x.dtype
    xt = x[:, 0]
    q = jnp.einsum("bd,dhk->bhk", xt, p["wq"].astype(dt)).astype(jnp.float32)
    k = jnp.einsum("bd,dhk->bhk", xt, p["wk"].astype(dt)).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", xt, p["wv"].astype(dt)).astype(jnp.float32)
    gates = jnp.einsum("bd,dhg->bhg", xt, p["w_if"].astype(dt)).astype(jnp.float32)
    log_i = -jax.nn.softplus(-gates[..., 0])
    log_f = -jax.nn.softplus(-gates[..., 1])

    hd = q.shape[-1]
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    C = state["C"] * f_s[..., None, None] + i_s[..., None, None] * (
        v[..., :, None] * k[..., None, :])              # [B,H,hd,hd]
    n = state["n"] * f_s[..., None] + i_s[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q) / np.sqrt(hd)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)) / np.sqrt(hd),
                      jnp.exp(-m_new))
    h = num / den[..., None]

    o = jax.nn.sigmoid(jnp.einsum("bd,de->be", xt, p["wo_gate"].astype(dt))
                       .astype(jnp.float32))
    h = (h.reshape(B, D) * o).astype(dt)
    out = jnp.einsum("bd,de->be", h, p["w_out"].astype(dt))[:, None, :]
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(rng, d_model: int, n_heads: int):
    hd = d_model // n_heads
    ks = jax.random.split(rng, 3)
    s = 1.0 / np.sqrt(d_model)
    return {
        # input weights for [z, i, f, o]
        "w_in": jax.random.normal(ks[0], (d_model, n_heads, 4 * hd),
                                  jnp.float32) * s,
        # block-diagonal recurrent weights per head
        "r": jax.random.normal(ks[1], (n_heads, hd, 4 * hd),
                               jnp.float32) / np.sqrt(hd),
        "w_out": jax.random.normal(ks[2], (d_model, d_model),
                                   jnp.float32) * s,
    }


def slstm_init_state(p, batch: int, dtype=jnp.float32):
    D, H, four_hd = p["w_in"].shape
    hd = four_hd // 4
    return {
        "h": jnp.zeros((batch, H, hd), dtype),
        "c": jnp.zeros((batch, H, hd), dtype),
        "n": jnp.ones((batch, H, hd), dtype),
        "m": jnp.zeros((batch, H), dtype),
    }


def _slstm_cell(p, state, u):
    """u: [B, H, 4*hd] pre-activation input for one step."""
    hd = u.shape[-1] // 4
    rec = jnp.einsum("bhk,hkg->bhg", state["h"], p["r"])
    z, i, f, o = jnp.split(u + rec, 4, axis=-1)
    log_f = -jax.nn.softplus(-f)                         # sigmoid forget
    m_new = jnp.maximum(log_f.mean(-1) + state["m"], i.mean(-1))
    i_s = jnp.exp(i - m_new[..., None])
    f_s = jnp.exp(log_f + (state["m"] - m_new)[..., None])
    c = f_s * state["c"] + i_s * jnp.tanh(z)
    n = f_s * state["n"] + i_s
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_train(p, x):
    """Sequential scan over time.  x: [B, S, D]."""
    B, S, D = x.shape
    dt = x.dtype
    u = jnp.einsum("bsd,dhg->bshg", x, p["w_in"].astype(dt)).astype(jnp.float32)
    state0 = slstm_init_state(p, B)

    def step(state, u_t):
        new = _slstm_cell(p, state, u_t)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, u.transpose(1, 0, 2, 3))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(dt)
    return jnp.einsum("bsd,de->bse", h, p["w_out"].astype(dt))


def slstm_decode(p, x, state):
    dt = x.dtype
    u = jnp.einsum("bd,dhg->bhg", x[:, 0],
                   p["w_in"].astype(dt)).astype(jnp.float32)
    new = _slstm_cell(p, state, u)
    B, D = x.shape[0], x.shape[2]
    h = new["h"].reshape(B, D).astype(dt)
    return jnp.einsum("bd,de->be", h, p["w_out"].astype(dt))[:, None, :], new
