"""Mixture-of-Experts FFN: top-k routing with capacity-bounded einsum
dispatch (Mesh-TF style) + optional always-on shared experts (Qwen-MoE).

The dispatch/combine formulation keeps MoE as dense einsums — the idiom
that shards cleanly under GSPMD: expert weights are laid out [E, D, F] and
TP-sharded on F over the ``model`` axis (E is rarely divisible by the axis;
F always is for the assigned archs).  Expert-parallel all-to-all dispatch
is an alternative layout explored in the §Perf hillclimb.

POP tie-in: ``plan_expert_placement`` maps experts onto devices by solving
the paper's load-balancing MILP (experts = shards with their routing load,
devices = servers) via ``problems/load_balancing.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import init_mlp, mlp


def init_moe(rng, d: int, d_ff_expert: int, n_experts: int, n_shared: int = 0,
             d_ff_shared: int = 0):
    k_r, k_e, k_s = jax.random.split(rng, 3)
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(d_ff_expert)
    p = {
        "router": jax.random.normal(k_r, (d, n_experts), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k_e, (n_experts, d, d_ff_expert),
                                    jnp.float32) * s_in,
        "w_up": jax.random.normal(jax.random.fold_in(k_e, 1),
                                  (n_experts, d, d_ff_expert),
                                  jnp.float32) * s_in,
        "w_down": jax.random.normal(jax.random.fold_in(k_e, 2),
                                    (n_experts, d_ff_expert, d),
                                    jnp.float32) * s_out,
    }
    if n_shared > 0:
        p["shared"] = init_mlp(k_s, d, d_ff_shared)
    return p


def moe(p, x, *, top_k: int, capacity_factor: float = 1.25,
        activation: str = "silu"):
    """x: [B, S, D] -> [B, S, D].

    Capacity-bounded top-k dispatch: each expert processes at most
    C = ceil(cf * S * top_k / E) tokens per sequence; overflow tokens drop
    their lowest-priority expert (standard practice — keeps all shapes
    static and the whole layer a pair of einsums on the MXU).
    """
    B, S, D = x.shape
    E = p["router"].shape[1]
    C = int(np.ceil(capacity_factor * S * top_k / E))
    C = min(C, S)
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)         # [B,S,k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # position of each (token, choice) in its expert's queue
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)    # [B,S,k,E]
    flat = onehot.reshape(B, S * top_k, E)
    pos_in_e = jnp.cumsum(flat, axis=1) * flat - 1.0          # [B,S*k,E]
    pos_in_e = pos_in_e.reshape(B, S, top_k, E)
    keep = (pos_in_e >= 0) & (pos_in_e < C)

    # dispatch tensor [B, S, E, C]
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, -1), C, dtype=jnp.float32)
    dispatch = jnp.einsum("bske,bskec->bsec", onehot * keep, cap_oh)
    combine = jnp.einsum("bsk,bske,bskec->bsec",
                         gate_vals.astype(jnp.float32), onehot * keep, cap_oh)

    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(dt), x)  # [B,E,C,D]
    act = jax.nn.silu if activation == "silu" else (
        lambda a: jax.nn.gelu(a, approximate=True))
    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(dt))
    ye = jnp.einsum("becf,efd->becd", act(g) * u, p["w_down"].astype(dt))
    y = jnp.einsum("bsec,becd->bsd", combine.astype(dt), ye)

    if "shared" in p:
        y = y + mlp(p["shared"], x, activation)
    return y


# ---------------------------------------------------------------------------
# POP-based expert placement (the registered ``moe_placement`` domain)
# ---------------------------------------------------------------------------

def expert_gate_load(p, x, *, top_k: int) -> np.ndarray:
    """Per-expert routing load from the router's gate statistics — the
    demand vector for POP expert placement (``repro.domains.
    moe_placement``): run the same top-k routing as :func:`moe` and sum
    each expert's normalised gate mass over every (batch, position,
    choice).  ``x``: [B, S, D]."""
    dt = x.dtype
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)          # [B,S,k]
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)
    E = p["router"].shape[1]
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)    # [B,S,k,E]
    return np.asarray(jnp.einsum("bsk,bske->e", gate_vals, onehot),
                      np.float64)


def plan_expert_placement(expert_load: np.ndarray, n_devices: int,
                          current: np.ndarray | None = None, k: int = 4,
                          seed: int = 0, backend: str = "auto"):
    """Place experts on devices to maximise the gate load served under
    per-device compute and memory caps, migrating as little expert-weight
    memory as possible — the registered ``moe_placement`` domain (the
    paper's technique, fourth scenario).  Returns device id per expert."""
    from ..core.config import ExecConfig, SolveConfig
    from ..domains.moe_placement import (MoEPlacementInstance, SPEC,
                                         place_experts)

    expert_load = np.asarray(expert_load, np.float64)
    E = expert_load.shape[0]
    if current is None:
        current = np.arange(E) % n_devices
    inst = MoEPlacementInstance(
        load=expert_load, mem=np.ones(E),
        current=np.asarray(current, np.int64),
        cap=np.full(n_devices, np.ceil(2.0 * E / n_devices)),
        compute=np.full(n_devices, expert_load.sum() / n_devices))
    placement, _, _ = place_experts(
        inst,
        solve_cfg=SolveConfig(k=k, strategy="stratified", seed=seed,
                              min_per_sub=SPEC.default_solve.min_per_sub),
        exec_cfg=ExecConfig(backend=backend,
                            solver_kw=SPEC.default_exec.solver_kw))
    return placement
