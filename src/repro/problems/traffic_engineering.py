"""WAN traffic engineering (paper §3.2): max-total-flow on a path formulation.

    maximize   sum_j f_j              f_j = sum_p f_j^p
    s.t.       f_j <= d_j                         ∀ demands j
               sum_{j, p: e in p} f_j^p <= c_e    ∀ edges e
               f_j^p >= 0

POP split (paper's recipe): each sub-problem keeps the WHOLE network but a
1/k fraction of every link capacity; *commodities* (demands) are
partitioned.  The network is never partitioned because traffic can flow
between any node pair.

The constraint operator is structured: the edge-capacity rows are a
segment-sum over each path's edge list (the path-edge incidence matrix for
the paper's scale — 5x10^5 demands x 4 paths — would have ~2x10^6 columns;
dense is out of the question, which is the paper's point).

Also includes the Kentucky-Data-Link-like topology generator (754 nodes /
1790 edges, geometric), k-shortest-path precomputation, and the CSPF
heuristic baseline.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pdhg import OperatorLP, structured_from_coo
from ..core.plan import SubLayout
from ..core.pop import POPProblem


# ---------------------------------------------------------------------------
# topology + demands
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Topology:
    n_nodes: int
    edges: np.ndarray        # [E, 2] directed node pairs
    capacity: np.ndarray     # [E]
    adj: list                # adjacency: node -> list of (nbr, edge_id, length)


def make_topology(n_nodes: int = 754, target_edges: int = 1790,
                  seed: int = 0) -> Topology:
    """KDL-like geometric network: nodes scattered in the plane, each
    connected to nearest neighbours until the undirected edge budget is hit.
    Returned edge set is DIRECTED (both orientations), capacities in Gbps
    drawn from a WAN-ish mix."""
    rng = np.random.default_rng(seed)
    xy = rng.uniform(0, 1, (n_nodes, 2))
    xy[:, 0] *= 2.0                                  # east-west elongation, KDL-ish
    # connect k nearest neighbours, dedupe
    d2 = ((xy[:, None, :] - xy[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    und = set()
    k_nn = 2
    while len(und) < target_edges:
        for u in range(n_nodes):
            for v in np.argsort(d2[u])[:k_nn]:
                und.add((min(u, int(v)), max(u, int(v))))
        k_nn += 1
    und = sorted(und)[:target_edges]
    # directed
    edges = np.array([(u, v) for u, v in und] + [(v, u) for u, v in und])
    caps_und = rng.choice([10.0, 40.0, 100.0], len(und), p=[0.5, 0.3, 0.2])
    capacity = np.concatenate([caps_und, caps_und])
    lengths = np.sqrt(((xy[edges[:, 0]] - xy[edges[:, 1]]) ** 2).sum(-1))
    adj = [[] for _ in range(n_nodes)]
    for e, (u, v) in enumerate(edges):
        adj[u].append((int(v), e, float(lengths[e])))
    return Topology(n_nodes=n_nodes, edges=edges, capacity=capacity, adj=adj)


def _dijkstra_tree(topo: Topology, src: int, weight_jitter: np.ndarray):
    """Shortest-path tree from src; returns (prev_edge[node] or -1)."""
    n = topo.n_nodes
    dist = np.full(n, np.inf)
    prev_edge = np.full(n, -1, np.int64)
    dist[src] = 0.0
    pq = [(0.0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u] + 1e-12:
            continue
        for v, e, w in topo.adj[u]:
            nd = d + w * weight_jitter[e]
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                prev_edge[v] = e
                heapq.heappush(pq, (nd, v))
    return prev_edge


def k_shortest_paths(topo: Topology, pairs: np.ndarray, n_paths: int = 4,
                     max_len: int = 48, seed: int = 0) -> np.ndarray:
    """Approximate k-shortest paths via weight-perturbed Dijkstra trees
    (one tree per (source, draw): efficient for many demands sharing
    sources).  Returns path_edges [n_demands, n_paths, max_len] int32,
    -1 padded; duplicate paths are kept (harmless: they split flow)."""
    rng = np.random.default_rng(seed)
    E = topo.edges.shape[0]
    srcs = np.unique(pairs[:, 0])
    out = np.full((pairs.shape[0], n_paths, max_len), -1, np.int64)
    for draw in range(n_paths):
        jitter = (np.ones(E) if draw == 0
                  else rng.uniform(1.0, 1.0 + 0.6 * draw, E))
        trees = {int(s): _dijkstra_tree(topo, int(s), jitter) for s in srcs}
        for j, (s, t) in enumerate(pairs):
            prev = trees[int(s)]
            path = []
            node = int(t)
            while node != int(s) and prev[node] >= 0 and len(path) < max_len:
                e = prev[node]
                path.append(e)
                node = int(topo.edges[e, 0])
            if node == int(s):
                out[j, draw, : len(path)] = path[::-1]
    return out


def make_demands(topo: Topology, n_demands: int, seed: int = 0):
    """Gravity-ish random demands between distinct node pairs."""
    rng = np.random.default_rng(seed)
    pairs = rng.integers(0, topo.n_nodes, (n_demands, 2))
    same = pairs[:, 0] == pairs[:, 1]
    pairs[same, 1] = (pairs[same, 1] + 1) % topo.n_nodes
    size = rng.lognormal(-2.0, 1.0, n_demands)
    return pairs, size


# ---------------------------------------------------------------------------
# structured constraint operator
# ---------------------------------------------------------------------------

def _k_mv(data, x):
    """Rows: [demand caps (n), edge caps (E)].  x = f [n*P] flattened."""
    path_edges, edge_proto = data        # [n, P, L] int32; [E+1] proto
    n, P, L = path_edges.shape
    E = edge_proto.shape[0] - 1
    f = x.reshape(n, P)
    dem = f.sum(axis=1)
    # each unit of f[j,p] loads every edge on its path
    contrib = jnp.broadcast_to(f[:, :, None], (n, P, L)).reshape(-1)
    seg = jnp.where(path_edges.reshape(-1) >= 0,
                    path_edges.reshape(-1), E)
    edge_load = jax.ops.segment_sum(contrib, seg, num_segments=E + 1)[:E]
    return jnp.concatenate([dem, edge_load])


def _kt_mv(data, y):
    path_edges, edge_proto = data
    n, P, L = path_edges.shape
    E = edge_proto.shape[0] - 1
    y_dem = y[:n]
    y_edge = jnp.concatenate([y[n: n + E], jnp.zeros(1, y.dtype)])
    pe = jnp.where(path_edges >= 0, path_edges, E)
    g = y_dem[:, None] + y_edge[pe].sum(axis=2)           # [n, P]
    return g.reshape(-1)


class TrafficProblem(POPProblem):
    """Max-total-flow TE, POP-partitioned over COMMODITIES (capacity/k)."""

    K_mv = staticmethod(_k_mv)
    KT_mv = staticmethod(_kt_mv)

    def __init__(self, topo: Topology, pairs: np.ndarray, demand: np.ndarray,
                 path_edges: np.ndarray, coef_dtype: str = "float32"):
        self.topo = topo
        self.pairs = pairs
        self.demand = demand
        self.path_edges = path_edges                       # [n, P, L]
        self.n_entities = pairs.shape[0]
        # ELL coefficient storage ("float32"/"bfloat16"/"int8" — see
        # core/pdhg.quantize_structured); TE coefficients are all 1.0, so
        # even int8 is exact here and just shrinks the streamed payload
        self.coef_dtype = coef_dtype

    # --- partitioning hooks ---------------------------------------------------
    def entity_attrs(self):
        plen = (self.path_edges >= 0).sum(axis=2).mean(axis=1)
        return np.stack([self.demand, plen], axis=1)

    def entity_scores(self):
        return self.demand

    def source_groups(self):
        """Group key for the paper's Fig. 6 skewed split (same-source)."""
        return self.pairs[:, 0]

    def sub_layout(self, n_slots: int) -> SubLayout:
        """Warm-start remap layout (``core/plan.py``): x = f [n_slots * P]
        (slot ``s`` owns its P per-path flows — each demand's path set is a
        property of the demand, so the flows travel with it); rows =
        [demand caps (n_slots), edge caps (E)] with the edge-capacity block
        lane-global."""
        P = self.path_edges.shape[1]
        E = self.topo.edges.shape[0]
        return SubLayout(
            x_slot=np.arange(n_slots)[:, None] * P + np.arange(P)[None, :],
            y_slot=np.arange(n_slots)[:, None],
            x_global=np.empty(0, np.int64),
            y_global=n_slots + np.arange(E))

    # --- LP construction --------------------------------------------------------
    def build_sub(self, idx_row: np.ndarray, frac: float,
                  scale: Optional[np.ndarray] = None) -> OperatorLP:
        n_local = idx_row.shape[0]
        valid = idx_row >= 0
        g = np.maximum(idx_row, 0)
        pe = np.where(valid[:, None, None], self.path_edges[g], -1)
        dem = np.where(valid, self.demand[g], 0.0)
        if scale is not None:
            dem = dem * scale                              # replicated entities
        P = pe.shape[1]
        n_var = n_local * P
        E = self.topo.edges.shape[0]

        c = -np.ones(n_var)                                # max total flow
        # kill flow variables with no real path (or padded demand slots)
        has_path = (pe >= 0).any(axis=2).reshape(-1)
        u = np.where(has_path, np.inf, 0.0)
        u = np.minimum(u, np.repeat(dem, P) + 1e-9)        # f_j^p <= d_j too
        l = np.zeros(n_var)
        q = np.concatenate([dem, self.topo.capacity * frac])
        data = (jnp.asarray(pe, jnp.int32), jnp.zeros(E + 1, jnp.float32))

        # ELL index metadata: demand rows sum each commodity's P flows,
        # edge rows sum every (commodity, path) crossing the edge — the
        # per-commodity path segment-sums as explicit gathers, unlocking
        # engine="fused_structured".  Edge-row width is the lane's worst
        # path congestion (data-dependent; stack_ops pads across lanes).
        fcol = np.broadcast_to(
            (np.arange(n_local)[:, None] * P + np.arange(P)[None, :])[:, :, None],
            pe.shape)
        on_edge = pe >= 0
        rows = np.concatenate([np.repeat(np.arange(n_local), P),
                               n_local + pe[on_edge]])
        cols = np.concatenate([np.arange(n_local * P), fcol[on_edge]])
        vals = np.ones(rows.shape[0])
        structured = structured_from_coo(rows, cols, vals,
                                         n_local + E, n_var,
                                         coef_dtype=self.coef_dtype)
        return OperatorLP(
            c=jnp.asarray(c, jnp.float32), q=jnp.asarray(q, jnp.float32),
            l=jnp.asarray(l, jnp.float32), u=jnp.asarray(u, jnp.float32),
            ineq_mask=jnp.ones(q.shape[0], bool), data=data,
            structured=structured)

    # --- solution handling --------------------------------------------------------
    def extract(self, op: OperatorLP, x: np.ndarray, idx_row: np.ndarray):
        P = self.path_edges.shape[1]
        return x[: idx_row.shape[0] * P].reshape(-1, P)

    def evaluate(self, f: np.ndarray) -> dict:
        """f: [n, P] per-path flows in GLOBAL entity order."""
        flow = f.sum(axis=1)
        # feasibility: recompute edge loads
        E = self.topo.edges.shape[0]
        load = np.zeros(E + 1)
        pe = np.where(self.path_edges >= 0, self.path_edges, E)
        np.add.at(load, pe.reshape(-1),
                  np.broadcast_to(f[:, :, None], pe.shape).reshape(-1))
        util = load[:E] / self.topo.capacity
        return {
            "total_flow": float(flow.sum()),
            "demand_satisfaction": float(flow.sum() / self.demand.sum()),
            "max_edge_util": float(util.max()),
            "overflow": float(np.maximum(load[:E] - self.topo.capacity, 0).sum()),
        }


# ---------------------------------------------------------------------------
# CSPF heuristic baseline (constrained shortest path first, over k paths)
# ---------------------------------------------------------------------------

def cspf_heuristic(prob: TrafficProblem, seed: int = 0) -> np.ndarray:
    """Greedy CSPF: demands in descending size; each routed on whichever of
    its precomputed paths has the largest residual bottleneck; allocation =
    min(demand, bottleneck).  Returns f [n, P]."""
    topo = prob.topo
    residual = topo.capacity.astype(np.float64).copy()
    n, P, L = prob.path_edges.shape
    f = np.zeros((n, P))
    order = np.argsort(-prob.demand)
    for j in order:
        best_p, best_bn = -1, 0.0
        for p in range(P):
            es = prob.path_edges[j, p]
            es = es[es >= 0]
            if es.size == 0:
                continue
            bn = residual[es].min()
            if bn > best_bn:
                best_bn, best_p = bn, p
        if best_p < 0:
            continue
        amt = min(prob.demand[j], best_bn)
        if amt <= 0:
            continue
        es = prob.path_edges[j, best_p]
        es = es[es >= 0]
        residual[es] -= amt
        f[j, best_p] = amt
    return f
