"""Query load balancing (paper §3.3): E-Store-style shard placement MILP.

    minimize   sum_ij (1 - t_ij) r'_ij m_i          (data movement)
    s.t.       L - eps <= sum_i r_ij l_i <= L + eps   ∀ servers j
               sum_j r_ij = 1                         ∀ shards i
               sum_i r'_ij m_i <= C_j                 ∀ servers j
               r_ij <= r'_ij <= r_ij + 1,  r' binary

Solved TPU-natively by LP relaxation (PDHG) + rounding + greedy repair
(``core/rounding.py`` recipe, see DESIGN.md §2 — branch-and-bound does not
map to TPUs).  In the relaxation r' = r at the optimum (movement costs are
non-negative), so we solve in r only.

POP split is DOMAIN-AWARE here (the paper's point about careful splits):
sub-problems get disjoint *server groups*, and every shard follows its
CURRENT server into that server's sub-problem — otherwise the split itself
would force movement, destroying the objective.  Shard-subset load totals
are then equalised by the partitioner ("ensuring that each shard subset
has the same total load", §3.3): servers are dealt into groups round-robin
by their current load so group totals concentrate.

This module therefore overrides the generic orchestration with its own
``pop_solve`` (same map/reduce machinery, domain split rule).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import backends as backends_mod
from ..core import pdhg
from ..core import plan as plan_mod
from ..core.pdhg import OperatorLP, structured_from_coo


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardWorkload:
    load: np.ndarray       # [n] query load per shard
    mem: np.ndarray        # [n] memory per shard
    placement: np.ndarray  # [n] current server of each shard
    cap: np.ndarray        # [S] server memory capacity
    eps_frac: float        # tolerance as a fraction of mean server load
    # stable external shard ids (None = positional): what warm-start
    # remapping matches on when the shard population churns between ticks
    ids: Optional[np.ndarray] = None

    @property
    def n_shards(self):
        return self.load.shape[0]

    def shard_ids(self) -> np.ndarray:
        return (np.arange(self.n_shards) if self.ids is None
                else np.asarray(self.ids))

    @property
    def n_servers(self):
        return self.cap.shape[0]

    @property
    def target(self):
        return self.load.sum() / self.n_servers


def make_shard_workload(n_shards: int, n_servers: int, *, skew: float = 1.2,
                        eps_frac: float = 0.1, hot_frac: float = 0.0,
                        seed: int = 0) -> ShardWorkload:
    """Zipf-ish shard loads (optionally with 'Taylor Swift' hot shards),
    uniform-ish memory, and a load-skewed initial placement (the state a
    balancer is called to fix)."""
    rng = np.random.default_rng(seed)
    load = rng.zipf(skew + 1.0, n_shards).astype(np.float64)
    load = np.minimum(load, 50.0) + rng.uniform(0, 1, n_shards)
    if hot_frac > 0:
        n_hot = max(1, int(hot_frac * n_shards))
        hot = rng.choice(n_shards, n_hot, replace=False)
        load[hot] *= n_shards / 20.0               # single-shard hot spots
    mem = rng.uniform(0.5, 2.0, n_shards)
    # skewed initial placement: early servers got the recent (hot) shards
    p = np.exp(-np.linspace(0, 2.0, n_servers))
    placement = rng.choice(n_servers, n_shards, p=p / p.sum())
    cap = np.full(n_servers, 2.0 * mem.sum() / n_servers)
    return ShardWorkload(load=load, mem=mem, placement=placement, cap=cap,
                         eps_frac=eps_frac)


# ---------------------------------------------------------------------------
# structured operator: rows = [load<=, -load<=, mem<=, assign ==]
# ---------------------------------------------------------------------------

def _k_mv(data, x):
    l, m, _cost = data                   # [n], [n], [n, S]
    n = l.shape[0]
    S = _cost.shape[1]
    X = x.reshape(n, S)
    load = X.T @ l                       # [S]
    mem = X.T @ m                        # [S]
    one = X.sum(axis=1)                  # [n]
    return jnp.concatenate([load, -load, mem, one])


def _kt_mv(data, y):
    l, m, _cost = data
    n = l.shape[0]
    S = _cost.shape[1]
    y_lo = y[:S]
    y_neg = y[S: 2 * S]
    y_mem = y[2 * S: 3 * S]
    y_one = y[3 * S: 3 * S + n]
    g = (l[:, None] * (y_lo - y_neg)[None, :]
         + m[:, None] * y_mem[None, :]
         + y_one[:, None])
    return g.reshape(-1)


# engine="auto" hint consumed by pdhg.select_engine: the distribution
# matrix X is a DENSE [n, S] block — the per-server rows are matmuls
# (X.T @ l), not segment-sums — so the gather-ELL fused_structured engine
# does ~2x the flops of these vmapped matvecs and measures ~2x slower at
# every size.  The index metadata is still available on demand
# (_relax_op(structured=True) — what the conformance matrix forces); auto
# just resolves to the measured winner.
_k_mv.preferred_engine = "matvec"
_kt_mv.preferred_engine = "matvec"


@dataclasses.dataclass
class LBResult:
    placement: np.ndarray
    movement: float
    max_load_dev: float     # max_j |load_j - L| / L
    feasible: bool
    solve_time_s: float
    extra: dict


class LoadBalanceProblem:
    """E-Store MILP with POP over server groups (domain-aware split)."""

    def __init__(self, wl: ShardWorkload):
        self.wl = wl
        self.n_entities = wl.n_shards

    # ------------------------------------------------------------------ LP --
    def _relax_op(self, shards: np.ndarray, servers: np.ndarray,
                  n_pad: int, s_pad: int,
                  L_target: Optional[float] = None,
                  eps_eff: Optional[float] = None,
                  structured: bool = False,
                  coef_dtype: str = "float32") -> OperatorLP:
        """LP relaxation over (shard subset x server subset), padded.

        ``structured=True`` additionally attaches the ELL index metadata —
        only wanted when a caller will FORCE ``engine="fused_structured"``
        (the conformance matrix does); the auto path never reads it here
        (``_k_mv.preferred_engine``), so the online re-balance hot path
        skips the O(nnz log nnz) packing + device upload by default."""
        wl = self.wl
        n_r, s_r = shards.shape[0], servers.shape[0]
        l = np.zeros(n_pad); l[:n_r] = wl.load[shards]
        m = np.zeros(n_pad); m[:n_r] = wl.mem[shards]
        # movement cost matrix (1 - t_ij) * m_i
        cost = np.zeros((n_pad, s_pad))
        cost[:n_r, :s_r] = wl.mem[shards][:, None]
        cur = wl.placement[shards]
        loc = {int(s): j for j, s in enumerate(servers)}
        cur_local = np.array([loc.get(int(s), -1) for s in cur])
        for i in np.flatnonzero(cur_local >= 0):
            cost[i, cur_local[i]] = 0.0

        L_sub = (wl.load[shards].sum() / max(s_r, 1)
                 if L_target is None else L_target)
        eps = wl.eps_frac * wl.target if eps_eff is None else eps_eff
        cap_pad = np.zeros(s_pad); cap_pad[:s_r] = wl.cap[servers]
        real_s = np.arange(s_pad) < s_r
        q = np.concatenate([
            np.where(real_s, L_sub + eps, 0.0),       # load <= L+eps
            np.where(real_s, -(L_sub - eps), 0.0),    # -load <= -(L-eps)
            cap_pad,                                  # mem <= cap
            np.where(np.arange(n_pad) < n_r, 1.0, 0.0),  # assign == 1
        ])
        ineq = np.concatenate([np.ones(3 * s_pad, bool), np.zeros(n_pad, bool)])
        u = np.zeros((n_pad, s_pad))
        u[:n_r, :s_r] = 1.0

        structured_op = None
        if structured:
            # ELL index metadata (engine="fused_structured"): X[i, j] feeds
            # the three per-server rows of j (weights l_i / -l_i / m_i) and
            # shard i's assign row; load-row width is the lane's shard count
            # (the server-group split keeps lanes small — the POP effect).
            ii, jj = np.meshgrid(np.arange(n_pad), np.arange(s_pad),
                                 indexing="ij")
            ii, jj = ii.ravel(), jj.ravel()
            xcol = ii * s_pad + jj
            rows = np.concatenate([jj, s_pad + jj, 2 * s_pad + jj,
                                   3 * s_pad + ii])
            cols = np.concatenate([xcol] * 4)
            vals = np.concatenate([l[ii], -l[ii], m[ii],
                                   np.ones(ii.shape[0])])
            structured_op = structured_from_coo(rows, cols, vals,
                                                3 * s_pad + n_pad,
                                                n_pad * s_pad,
                                                coef_dtype=coef_dtype)
        return OperatorLP(
            c=jnp.asarray(cost.reshape(-1), jnp.float32),
            q=jnp.asarray(q, jnp.float32),
            l=jnp.zeros(n_pad * s_pad, jnp.float32),
            u=jnp.asarray(u.reshape(-1), jnp.float32),
            ineq_mask=jnp.asarray(ineq),
            data=(jnp.asarray(l, jnp.float32), jnp.asarray(m, jnp.float32),
                  jnp.asarray(cost, jnp.float32)),
            structured=structured_op,
        )

    # ------------------------------------------------------------- rounding --
    def _round_repair(self, r: np.ndarray, shards: np.ndarray,
                      servers: np.ndarray,
                      L_target: Optional[float] = None,
                      eps_eff: Optional[float] = None) -> np.ndarray:
        """argmax-round the relaxation then greedily repair load bounds and
        memory caps.  Returns the GLOBAL placement for ``shards``."""
        wl = self.wl
        n_r, s_r = shards.shape[0], servers.shape[0]
        rr = r[:n_r, :s_r]
        pick = rr.argmax(axis=1)
        # keep current server on near-ties (cheap anti-movement bias)
        loc = {int(s): j for j, s in enumerate(servers)}
        cur_local = np.array([loc.get(int(s), -1) for s in wl.placement[shards]])
        for i in range(n_r):
            ci = cur_local[i]
            if ci >= 0 and rr[i, ci] >= rr[i, pick[i]] - 1e-3:
                pick[i] = ci

        load = np.zeros(s_r)
        mem_u = np.zeros(s_r)
        np.add.at(load, pick, wl.load[shards])
        np.add.at(mem_u, pick, wl.mem[shards])
        L_sub = (wl.load[shards].sum() / max(s_r, 1)
                 if L_target is None else L_target)
        eps = wl.eps_frac * wl.target if eps_eff is None else eps_eff
        sl = wl.load[shards]
        sm = wl.mem[shards]

        def load_pass():
            # repeatedly move (or swap) shards to shrink the worst
            # (over, under) pair's deviation; stop when inside the window or
            # no improving move exists.  O(moves * n_sub) — sub-problems are
            # small post-POP, which keeps this cheap (the POP effect again).
            for _ in range(4 * n_r):
                over = int(np.argmax(load))
                under = int(np.argmin(load))
                if load[over] <= L_sub + eps and load[under] >= L_sub - eps:
                    break
                cur_dev = max(load[over] - L_sub, L_sub - load[under])
                members = np.flatnonzero(pick == over)
                if members.size == 0:
                    break
                # direct move over -> under
                fits = mem_u[under] + sm[members] <= wl.cap[servers[under]]
                new_dev = np.maximum(np.abs(load[over] - sl[members] - L_sub),
                                     np.abs(load[under] + sl[members] - L_sub))
                new_dev = np.where(fits, new_dev, np.inf)
                best = int(np.argmin(new_dev + 1e-6 * sm[members]))
                if new_dev[best] < cur_dev - 1e-12:
                    i = members[best]
                    load[over] -= sl[i]; mem_u[over] -= sm[i]
                    pick[i] = under
                    load[under] += sl[i]; mem_u[under] += sm[i]
                    continue
                # swap fallback (handles memory-saturated receivers): trade
                # a hot shard from `over` for a cold shard from `under`
                mu = np.flatnonzero(pick == under)
                if mu.size == 0:
                    break
                d = sl[members][:, None] - sl[mu][None, :]      # load traded
                mem_ok = ((mem_u[under] + sm[members][:, None] - sm[mu][None, :]
                           <= wl.cap[servers[under]]) &
                          (mem_u[over] - sm[members][:, None] + sm[mu][None, :]
                           <= wl.cap[servers[over]]))
                sw_dev = np.maximum(np.abs(load[over] - d - L_sub),
                                    np.abs(load[under] + d - L_sub))
                sw_dev = np.where(mem_ok, sw_dev, np.inf)
                io, iu = np.unravel_index(int(np.argmin(sw_dev)), sw_dev.shape)
                if sw_dev[io, iu] >= cur_dev - 1e-12:
                    break
                i, o = members[io], mu[iu]
                load[over] += sl[o] - sl[i]; mem_u[over] += sm[o] - sm[i]
                load[under] += sl[i] - sl[o]; mem_u[under] += sm[i] - sm[o]
                pick[i], pick[o] = under, over

        def mem_pass():
            # shed from servers over their memory cap; prefer destinations
            # that are load-underloaded so the next load_pass has less to fix
            for _ in range(2 * n_r):
                over_m = int(np.argmax(mem_u - wl.cap[servers]))
                if mem_u[over_m] <= wl.cap[servers[over_m]]:
                    break
                members = np.flatnonzero(pick == over_m)
                if members.size == 0:
                    break
                headroom = wl.cap[servers] - mem_u
                dest = int(np.argmax(np.minimum(headroom, sm[members].max())
                                     - 0.05 * (load - L_sub)))
                fits = sm[members] <= headroom[dest]
                if not fits.any():
                    break
                # move the shard whose LOAD best fills dest's deficit and
                # whose memory fits (memory relief is the loop guarantee)
                deficit = max(L_sub - load[dest], 0.0)
                score = np.where(fits, -np.abs(sl[members] - deficit), -np.inf)
                i = members[int(np.argmax(score))]
                load[over_m] -= sl[i]; mem_u[over_m] -= sm[i]
                pick[i] = dest
                load[dest] += sl[i]; mem_u[dest] += sm[i]

        for _ in range(3):
            load_pass()
            mem_pass()
        load_pass()
        return servers[pick]

    # ------------------------------------------------------------ evaluate --
    def evaluate(self, placement: np.ndarray) -> dict:
        wl = self.wl
        moved = placement != wl.placement
        movement = float(wl.mem[moved].sum())
        load = np.zeros(wl.n_servers)
        np.add.at(load, placement, wl.load)
        mem_u = np.zeros(wl.n_servers)
        np.add.at(mem_u, placement, wl.mem)
        L = wl.target
        eps = wl.eps_frac * L
        return {
            "movement": movement,
            "n_moved": int(moved.sum()),
            "max_load_dev": float(np.abs(load - L).max() / L),
            "load_feasible": bool((np.abs(load - L) <= eps * 1.05).all()),
            "mem_feasible": bool((mem_u <= wl.cap * 1.001).all()),
        }

    # ---------------------------------------------------------------- full --
    def solve_full(self, solver_kw: Optional[dict] = None,
                   warm: Optional["LBResult"] = None,
                   backend: str = "auto", engine: str = "auto") -> LBResult:
        """Unpartitioned §3.3 baseline, routed through the same
        backend/engine substrate as the POP path (k=1 stack — so the
        full-problem baseline benefits from the fused step engine and the
        jit-cached map solver too)."""
        solver_kw = dict(solver_kw or {})
        wl = self.wl
        shards = np.arange(wl.n_shards)
        servers = np.arange(wl.n_servers)
        eps_eff = 0.95 * wl.eps_frac * wl.target
        op = self._relax_op(shards, servers, wl.n_shards, wl.n_servers,
                            L_target=wl.target, eps_eff=eps_eff)
        t0 = time.perf_counter()
        state = warm.extra.get("full_state") if warm is not None else None
        warm_b = None
        if state is not None and state["x"].shape == op.c.shape:
            warm_b = (state["x"], state["y"])
        res, backend_name, engine_name = backends_mod.solve_one_ex(
            op, _k_mv, _kt_mv, solver_kw, backend=backend, engine=engine,
            warm=warm_b)
        r = np.asarray(res.x).reshape(wl.n_shards, wl.n_servers)
        placement = self._round_repair(r, shards, servers,
                                       L_target=wl.target, eps_eff=eps_eff)
        dt = time.perf_counter() - t0
        ev = self.evaluate(placement)
        ev["iterations"] = int(res.iterations)
        ev["full_state"] = dict(x=np.asarray(res.x), y=np.asarray(res.y))
        # observability: what actually ran ("auto" resolved) + plan cache
        ev["backend"] = backend_name
        ev["engine"] = engine_name
        ev["plan_cache"] = "full"
        ev["k"] = 1
        return LBResult(placement=placement, movement=ev["movement"],
                        max_load_dev=ev["max_load_dev"],
                        feasible=ev["load_feasible"] and ev["mem_feasible"],
                        solve_time_s=dt, extra=ev)

    # ----------------------------------------------------------------- POP --
    def pop_solve(self, k: int, seed: int = 0,
                  solver_kw: Optional[dict] = None,
                  backend: str = "auto", engine: str = "auto",
                  warm: Optional["LBResult"] = None,
                  warm_start: bool = True) -> LBResult:
        """Domain-aware POP: server groups (round-robin by load), shards
        follow their current server; batched PDHG map step through the
        ``core/backends.py`` registry; per-sub round+repair reduce.

        ``warm`` re-solves an updated workload from a previous POP
        ``LBResult`` (online path).  While the shard population is stable
        the previous server grouping and shard subsets are reused so the
        stacked sub-LPs keep their shapes, and every lane starts from its
        previous PDHG iterates.  Across churn (shards arrived/departed —
        matched via ``ShardWorkload.ids`` — or a k change) the grouping is
        recomputed and the old iterates are REMAPPED: each surviving
        shard's distribution row follows it to its new (lane, row),
        restricted to the server columns its old and new lanes share;
        per-server dual rows move with their server, per-shard assign rows
        with their shard; lanes that matched nothing start cold
        (``extra["warm_fraction"]`` reports the matched share).
        ``warm_start=False`` reuses only the grouping (the cold control in
        ``benchmarks/bench_online_resolve.py``)."""
        solver_kw = dict(solver_kw or {})
        wl = self.wl
        ids = wl.shard_ids()
        state = warm.extra.get("pop_state") if warm is not None else None
        reuse = (state is not None and state["k"] == k
                 and state["n_shards"] == wl.n_shards
                 and np.array_equal(
                     state.get("ids", np.arange(state["n_shards"])), ids))
        grouping_kept = False
        if reuse:
            groups = state["groups"]
            shard_sets = state["shard_sets"]
            s_pad = state["s_pad"]
        else:
            if (state is not None and len(state["groups"]) == k
                    and np.array_equal(
                        np.sort(np.concatenate(state["groups"])),
                        np.arange(wl.n_servers))):
                # shard churn over the same server fleet: KEEP the previous
                # server grouping (shards follow their current server, so a
                # stable grouping keeps most surviving shards in their old
                # lane — the analogue of core/plan.py's repair_plan, and
                # what makes the remapped warm start land in an unchanged
                # lane context)
                groups = state["groups"]
                s_pad = state["s_pad"]
                grouping_kept = True
            else:
                # deal servers into k groups by descending current load
                # (stratified)
                cur_load = np.zeros(wl.n_servers)
                np.add.at(cur_load, wl.placement, wl.load)
                order = np.argsort(-cur_load)
                groups = [order[i::k] for i in range(k)]
                s_pad = max(len(g) for g in groups)
            shard_sets = [list(np.flatnonzero(np.isin(wl.placement, g)))
                          for g in groups]

            # §3.3 pre-pass: equalise shard-subset TOTAL loads across groups
            # (these cross-group shards must move anyway — load has to leave
            # overloaded server groups no matter how the sub-LPs come out).
            totals = np.array([wl.load[s].sum() for s in shard_sets])
            targets = np.array([wl.target * len(g) for g in groups])
            tol = 0.005 * wl.target * max(min(len(g) for g in groups), 1)
            for _ in range(wl.n_shards):
                dev = totals - targets
                hi, lo = int(np.argmax(dev)), int(np.argmin(dev))
                if (dev[hi] <= tol and -dev[lo] <= tol) or not shard_sets[hi]:
                    break
                cands = shard_sets[hi]
                loads = wl.load[cands]
                # any move that shrinks the (hi, lo) pair's worst deviation
                cur = max(dev[hi], -dev[lo])
                new_pair = np.maximum(np.abs(dev[hi] - loads),
                                      np.abs(dev[lo] + loads))
                pick = int(np.argmin(new_pair))
                if new_pair[pick] >= cur - 1e-12:
                    break                  # no improving transfer exists
                shard = cands.pop(pick)
                shard_sets[lo].append(shard)
                totals[hi] -= wl.load[shard]
                totals[lo] += wl.load[shard]

            shard_sets = [np.asarray(s, np.int64) for s in shard_sets]
        n_pad = max(len(s) for s in shard_sets)

        t0 = time.perf_counter()
        L = wl.target
        eps = wl.eps_frac * L
        # tighten each sub's window by its residual total-load deviation so
        # sub-feasible implies globally-feasible
        sub_eps = []
        for s, g in zip(shard_sets, groups):
            dev = abs(wl.load[s].sum() / max(len(g), 1) - L)
            sub_eps.append(float(np.clip(0.95 * eps - dev, 0.25 * eps, eps)))
        ops = [self._relax_op(s, g, n_pad, s_pad, L_target=L, eps_eff=e)
               for s, g, e in zip(shard_sets, groups, sub_eps)]
        batched = pdhg.stack_ops(ops)
        warm_xy = None
        warm_fraction = None
        if warm_start and state is not None:
            if reuse and state["x"].shape == batched.c.shape:
                warm_xy = (state["x"], state["y"])
                warm_fraction = 1.0
            else:
                warm_xy, warm_fraction = _remap_lb_state(
                    state, ids, groups, shard_sets, n_pad, s_pad)
        backend_name, engine_run, _ = backends_mod.resolve_exec(
            batched, _k_mv, _kt_mv, backend, engine)
        res = backends_mod.solve_map(batched, _k_mv, _kt_mv, solver_kw,
                                     backend=backend_name, engine=engine_run,
                                     warm=warm_xy)
        jax.block_until_ready(res.x)
        placement = wl.placement.copy()
        for i, (s, g) in enumerate(zip(shard_sets, groups)):
            r = np.asarray(res.x[i]).reshape(n_pad, s_pad)
            placement[s] = self._round_repair(r, s, g, L_target=L,
                                              eps_eff=sub_eps[i])
        dt = time.perf_counter() - t0
        ev = self.evaluate(placement)
        ev["iterations"] = int(np.asarray(res.iterations).sum())
        ev["warm_fraction"] = warm_fraction
        # observability: what actually ran + how the previous grouping was
        # reused ("hit" = verbatim, "repair" = server grouping kept across
        # shard churn, "miss" = fresh grouping)
        ev["backend"] = backend_name
        ev["engine"] = pdhg.engine_name(engine_run)
        ev["plan_cache"] = ("hit" if reuse
                            else "repair" if grouping_kept else "miss")
        ev["k"] = k
        ev["pop_state"] = dict(
            k=k, n_shards=wl.n_shards, ids=ids, groups=groups,
            shard_sets=shard_sets, s_pad=s_pad, n_pad=n_pad,
            x=np.asarray(res.x), y=np.asarray(res.y))
        return LBResult(placement=placement, movement=ev["movement"],
                        max_load_dev=ev["max_load_dev"],
                        feasible=ev["load_feasible"] and ev["mem_feasible"],
                        solve_time_s=dt, extra=ev)


# ---------------------------------------------------------------------------
# churn-aware warm-start remap (domain-specific analogue of core/plan.py's
# remap_warm: the LB split is over SERVER GROUPS, so both axes of the
# distribution matrix have identity that must be followed across plans)
# ---------------------------------------------------------------------------

def _remap_lb_state(state: dict, ids: np.ndarray, groups, shard_sets,
                    n_pad: int, s_pad: int):
    """Scatter a previous pop_state's iterates onto a new grouping.

    x[i] is a [n_pad, s_pad] distribution of lane i's shards over lane i's
    servers: a surviving shard's row follows it to its new (lane, row) and
    each entry follows its server's column — copied only for servers the
    shard's old and new lanes share (the shard followed its current server,
    so in the common case that is most of the row).  y rows:
    [load<= (s_pad), -load<= (s_pad), mem<= (s_pad), assign== (n_pad)] —
    the three per-server blocks move with their server, assign rows with
    their shard.  ARRIVED shards have no previous row: their distribution
    starts at zero with the population-mean assign dual (a dual-only warm
    start; seeding their primal — e.g. one-hot on the current server — was
    measured WORSE at low churn, where the injected mass forces large dual
    corrections in an otherwise converged lane).  Lanes that matched no
    shard start cold via the mask.  Returns (WarmStart, warm_fraction).
    """
    k_o = state["k"]
    s_pad_o = state["s_pad"]
    x_o = np.asarray(state["x"], np.float32)
    n_pad_o = x_o.shape[1] // s_pad_o
    x_o = x_o.reshape(k_o, n_pad_o, s_pad_o)
    y_o = np.asarray(state["y"], np.float32)
    old_ids = state.get("ids", np.arange(state["n_shards"]))

    shard_pos = {}
    for o, ss in enumerate(state["shard_sets"]):
        for r, g in enumerate(np.asarray(ss)):
            shard_pos[old_ids[g]] = (o, r)
    srv_pos = {}
    for o, gg in enumerate(state["groups"]):
        for j, srv in enumerate(np.asarray(gg)):
            srv_pos[int(srv)] = (o, j)

    # population-mean assign dual: the dual-only prior for arrived shards
    assign_duals = [y_o[o, 3 * s_pad_o + r]
                    for o, ss in enumerate(state["shard_sets"])
                    for r in range(len(np.asarray(ss)))]
    avg_assign = float(np.mean(assign_duals)) if assign_duals else 0.0

    k = len(groups)
    x_w = np.zeros((k, n_pad, s_pad), np.float32)
    y_w = np.zeros((k, 3 * s_pad + n_pad), np.float32)
    mask = np.zeros(k, bool)
    matched = 0
    live = 0
    for i, (ss, gg) in enumerate(zip(shard_sets, groups)):
        gg = np.asarray(gg)
        for j, srv in enumerate(gg):
            hit = srv_pos.get(int(srv))
            if hit is not None:
                o, j_old = hit
                for blk in range(3):
                    y_w[i, blk * s_pad + j] = y_o[o, blk * s_pad_o + j_old]
        for r, g in enumerate(np.asarray(ss)):
            live += 1
            hit = shard_pos.get(ids[g])
            if hit is None:
                y_w[i, 3 * s_pad + r] = avg_assign   # arrived: dual-only
                continue
            o, r_old = hit
            matched += 1
            mask[i] = True
            y_w[i, 3 * s_pad + r] = y_o[o, 3 * s_pad_o + r_old]
            for j, srv in enumerate(gg):
                sh = srv_pos.get(int(srv))
                if sh is not None and sh[0] == o:
                    x_w[i, r, j] = x_o[o, r_old, sh[1]]
    warm_fraction = matched / max(live, 1)
    ws = plan_mod.WarmStart(
        x_w.reshape(k, -1), y_w, mask,
        dict(warm_fraction=warm_fraction, matched=matched,
             fresh=live - matched, lanes_cold=int((~mask).sum()),
             identity=False))
    return ws, warm_fraction


# ---------------------------------------------------------------------------
# shared placement entry point
# ---------------------------------------------------------------------------

def balance_placement(load: np.ndarray, n_targets: int,
                      current: Optional[np.ndarray] = None, *,
                      cap: Optional[np.ndarray] = None,
                      eps_frac: float = 0.2, pop_k: int = 4, seed: int = 0,
                      backend: str = "auto", engine: str = "auto",
                      solver_kw: Optional[dict] = None,
                      warm: Optional[LBResult] = None,
                      shard_ids: Optional[np.ndarray] = None) -> LBResult:
    """Place ``load``-weighted shards onto ``n_targets`` via the §3.3 MILP.

    The one entry point for every "shards onto servers" reuse of the paper
    (MoE expert placement in ``models/moe.py``, request balancing in
    ``serve/engine.py``): default sticky placement, uniform memory, the
    shared k_eff heuristic, and the POP-vs-full branch live here once.
    ``backend`` names a map-step backend, ``engine`` a PDHG step engine
    (``core/backends.py`` / ``core/pdhg.py``).  ``warm`` chains repeated
    balancing calls: pass the previous ``LBResult`` to warm-start the
    re-solve when loads drift (the serving tick path); with ``shard_ids``
    (stable external ids) the warm start survives shard arrivals and
    departures too — surviving shards are matched by id and their iterates
    remapped onto the new grouping.
    """
    load = np.asarray(load, np.float64)
    n = load.shape[0]
    if current is None:
        current = np.arange(n) % n_targets
    if cap is None:
        cap = np.full(n_targets, float(n))
    wl = ShardWorkload(load=load, mem=np.ones(n),
                       placement=np.asarray(current, np.int64),
                       cap=cap, eps_frac=eps_frac, ids=shard_ids)
    prob = LoadBalanceProblem(wl)
    k_eff = max(1, min(pop_k, n_targets // 2))
    if k_eff > 1:
        return prob.pop_solve(k_eff, seed=seed, solver_kw=solver_kw,
                              backend=backend, engine=engine, warm=warm)
    return prob.solve_full(solver_kw=solver_kw, warm=warm)


# ---------------------------------------------------------------------------
# E-Store greedy baseline
# ---------------------------------------------------------------------------

def estore_greedy(wl: ShardWorkload) -> np.ndarray:
    """E-Store's single-tier greedy: repeatedly move the hottest shard from
    the most-loaded server to the least-loaded one until within tolerance."""
    placement = wl.placement.copy()
    load = np.zeros(wl.n_servers)
    np.add.at(load, placement, wl.load)
    mem_u = np.zeros(wl.n_servers)
    np.add.at(mem_u, placement, wl.mem)
    L = wl.target
    eps = wl.eps_frac * L
    by_server = [list(np.flatnonzero(placement == j)) for j in range(wl.n_servers)]
    for j in range(wl.n_servers):
        by_server[j].sort(key=lambda i: wl.load[i])
    for _ in range(10 * wl.n_shards):
        over = int(np.argmax(load))
        if load[over] <= L + eps:
            break
        if not by_server[over]:
            break
        i = by_server[over].pop()              # hottest shard there
        under = int(np.argmin(load + 1e12 * (mem_u + wl.mem[i] > wl.cap)))
        if load[under] + wl.load[i] > load[over] - 1e-12:
            break                              # no improving move left
        placement[i] = under
        load[over] -= wl.load[i]; load[under] += wl.load[i]
        mem_u[over] -= wl.mem[i]; mem_u[under] += wl.mem[i]
        by_server[under].append(i)
        by_server[under].sort(key=lambda q: wl.load[q])
    return placement
