"""The paper's three problem domains (§3), with full optimization
formulations, POP-able operator forms, and the heuristic baselines the
paper compares against (Gandiva-like packing, CSPF, E-Store greedy)."""

from .cluster_scheduling import GavelProblem, gandiva_heuristic, make_cluster_workload
from .traffic_engineering import (
    TrafficProblem, cspf_heuristic, make_topology, make_demands, k_shortest_paths,
)
from .load_balancing import LoadBalanceProblem, estore_greedy, make_shard_workload

__all__ = [
    "GavelProblem", "gandiva_heuristic", "make_cluster_workload",
    "TrafficProblem", "cspf_heuristic", "make_topology", "make_demands",
    "k_shortest_paths",
    "LoadBalanceProblem", "estore_greedy", "make_shard_workload",
]
