"""Gavel-style heterogeneous cluster scheduling (paper §3.1).

Max-min fair allocation of heterogeneous accelerators to DL jobs, with
optional *space sharing* (two jobs concurrently on one accelerator — the
paper's 10^6-job-combination configuration).

LP (epigraph form, per DESIGN.md §2 — PDHG solves (X, t) jointly):

    maximize t
    s.t.     t <= scale_m * sum_{c∋m, j} T[c, j, slot_m] X[c, j]   ∀ jobs m
             sum_{c∋m, j} X[c, j] <= 1                             ∀ jobs m
             sum_c z_c X[c, j] <= num_workers_j * frac             ∀ types j
             0 <= X <= 1

where c ranges over job *combos* — singletons, plus unordered pairs when
space sharing is on.  scale_m = 1 / (w_m * max_j T_mj) normalises each
job's throughput to [0, 1] so the max-min is over *fair-share-relative*
rates, matching Gavel's heterogeneity-aware LP shape.

The constraint operator is STRUCTURED (segment-sum over combo membership;
no dense K is ever built): the full 10^6-combo problem has ~3x10^6
variables, far past dense range, and this is exactly the regime the paper
targets.  POP partitions *jobs* (combos are then intra-subset pairs, giving
the k^2 variable reduction of paper Fig. 2) and splits worker counts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pdhg import OperatorLP, structured_from_coo
from ..core.plan import SubLayout
from ..core.pop import POPProblem


# ---------------------------------------------------------------------------
# workload generation (Gavel-like: 3 accelerator generations)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterWorkload:
    T: np.ndarray            # [n_jobs, n_types] raw throughputs
    w: np.ndarray            # [n_jobs] priorities
    z: np.ndarray            # [n_jobs] workers requested
    num_workers: np.ndarray  # [n_types]
    interference: np.ndarray  # [n_jobs] space-sharing throughput retention in (0,1]
    job_type: np.ndarray     # [n_jobs] int label (for clustered partitions)


def make_cluster_workload(n_jobs: int, num_workers=(256, 256, 256),
                          seed: int = 0) -> ClusterWorkload:
    """Synthetic Gavel-like workload: job archetypes with distinct
    speedup profiles across 3 accelerator generations (V100/P100/K80-ish)."""
    rng = np.random.default_rng(seed)
    archetypes = np.array([
        # relative throughput on [v100, p100, k80]
        [1.00, 0.45, 0.25],   # attention-heavy
        [1.00, 0.60, 0.35],   # conv-heavy
        [1.00, 0.80, 0.60],   # small model / input-bound
        [1.00, 0.35, 0.10],   # tensor-core-dependent
    ])
    jt = rng.integers(0, len(archetypes), n_jobs)
    base = rng.lognormal(0.0, 0.5, n_jobs)[:, None]
    T = archetypes[jt] * base * rng.uniform(0.9, 1.1, (n_jobs, 3))
    w = rng.choice([1.0, 2.0, 4.0], n_jobs, p=[0.7, 0.2, 0.1])
    z = np.ones(n_jobs)
    interference = rng.uniform(0.55, 0.95, n_jobs)
    return ClusterWorkload(T=T, w=w, z=z,
                           num_workers=np.asarray(num_workers, np.float64),
                           interference=interference, job_type=jt)


# ---------------------------------------------------------------------------
# structured constraint operator
# ---------------------------------------------------------------------------

def _k_mv(data, x):
    """K x for the epigraph LP.  Layout of x: [X_flat (C*R), t].

    Row blocks:  [epigraph (n), time (n), workers (R)]

    ``seg`` is a [n_jobs+1] prototype array carrying the (static) job count
    in its SHAPE — jit-safe where a plain int leaf would become a tracer.
    """
    S, member, z, seg = data             # S: [C, R, 2] scaled T; member: [C, 2]
    n_jobs = seg.shape[0] - 1
    C, R, _ = S.shape
    X = x[: C * R].reshape(C, R)
    t = x[C * R]

    # per-(combo, slot) scaled throughput contribution
    contrib = jnp.einsum("crs,cr->cs", S, X)              # [C, 2]
    thpt = jax.ops.segment_sum(contrib.reshape(-1), member.reshape(-1),
                               num_segments=n_jobs + 1)[:n_jobs]
    # time: each combo occurrence consumes the member's time budget
    time_c = X.sum(axis=1)                                # [C]
    occ = jnp.broadcast_to(time_c[:, None], member.shape).reshape(-1)
    time = jax.ops.segment_sum(occ, member.reshape(-1),
                               num_segments=n_jobs + 1)[:n_jobs]
    workers = (z[:, None] * X).sum(axis=0)                # [R]
    return jnp.concatenate([t - thpt, time, workers])


def _kt_mv(data, y):
    """K^T y.  y layout: [y_ep (n), y_time (n), y_work (R)]."""
    S, member, z, seg = data
    n_jobs = seg.shape[0] - 1
    C, R, _ = S.shape
    y_ep = y[:n_jobs]
    y_time = y[n_jobs: 2 * n_jobs]
    y_work = y[2 * n_jobs: 2 * n_jobs + R]

    y_ep_pad = jnp.concatenate([y_ep, jnp.zeros(1, y.dtype)])
    y_time_pad = jnp.concatenate([y_time, jnp.zeros(1, y.dtype)])
    ep_m = y_ep_pad[member]                               # [C, 2]
    tm_m = y_time_pad[member]                             # [C, 2]

    gX = (-jnp.einsum("crs,cs->cr", S, ep_m)
          + tm_m.sum(axis=1)[:, None]
          + z[:, None] * y_work[None, :])
    gt = y_ep.sum()
    return jnp.concatenate([gX.reshape(-1), gt[None]])


# ---------------------------------------------------------------------------
# POP problem
# ---------------------------------------------------------------------------

class GavelProblem(POPProblem):
    """Max-min fair scheduling, POP-partitioned over JOBS."""

    K_mv = staticmethod(_k_mv)
    KT_mv = staticmethod(_kt_mv)

    def __init__(self, wl: ClusterWorkload, space_sharing: bool = False,
                 leftover_bonus: float = 0.05, coef_dtype: str = "float32"):
        self.wl = wl
        self.space_sharing = space_sharing
        self.n_entities = wl.T.shape[0]
        self.n_types = wl.T.shape[1]
        # ELL coefficient storage for the structured metadata
        # (core/pdhg.quantize_structured: "float32"/"bfloat16"/"int8")
        self.coef_dtype = coef_dtype
        self.scale = 1.0 / (wl.w * wl.T.max(axis=1))
        # secondary water-filling term: after the min is maximised, spend
        # leftover capacity on mean throughput (objective stays linear)
        self.leftover_bonus = leftover_bonus

    # --- partitioning hooks -------------------------------------------------
    def entity_attrs(self):
        return np.concatenate([
            self.wl.T * self.scale[:, None],
            self.wl.w[:, None], self.wl.z[:, None],
        ], axis=1)

    def entity_scores(self):
        return self.wl.w * self.wl.z

    def sub_layout(self, n_slots: int) -> SubLayout:
        """Warm-start remap layout (``core/plan.py``).

        x = [X_flat (C*R), t] with singleton combos FIRST (``_combos``), so
        slot ``s`` owns X[s, :] — the job's own allocation row.  Pair-combo
        variables (space sharing) have no single owner and restart cold on
        a remap.  Rows: [epigraph (n), time (n), workers (R)] — the first
        two move with their job, the worker-cap rows are lane-global.
        """
        R = self.n_types
        C = n_slots
        if self.space_sharing:
            C += n_slots * (n_slots - 1) // 2
        x_slot = np.arange(n_slots)[:, None] * R + np.arange(R)[None, :]
        y_slot = np.stack([np.arange(n_slots), n_slots + np.arange(n_slots)],
                          axis=1)
        return SubLayout(x_slot=x_slot, y_slot=y_slot,
                         x_global=np.array([C * R]),
                         y_global=2 * n_slots + np.arange(R))

    # --- combo construction -------------------------------------------------
    def _combos(self, ids: np.ndarray):
        """Singleton + (if space sharing) within-subset pair combos.
        ids may contain -1 padding (kept as dead combos)."""
        n = ids.shape[0]
        singles = np.stack([ids, np.full(n, -1)], axis=1)
        if not self.space_sharing:
            return singles
        iu, ju = np.triu_indices(n, k=1)
        pairs = np.stack([ids[iu], ids[ju]], axis=1)
        # a pair is dead if either member is padding
        dead = (pairs < 0).any(axis=1)
        pairs[dead] = -1
        return np.concatenate([singles, pairs], axis=0)

    def _structured(self, S: np.ndarray, member: np.ndarray, z: np.ndarray,
                    n_local: int):
        """ELL index metadata (``core/pdhg.StructuredOperator``) for the
        singleton-combo operator — what lets ``engine="fused_structured"``
        run the per-job segment-sums as batched gather/segment-reduce
        kernels.  Pair combos (space sharing) make the worker rows ~C wide
        (C ~ n^2/2): genuinely dense-in-X rows where ELL padding loses to
        the matvec engine, so space-sharing builds skip the metadata."""
        C, R, _ = S.shape
        n = n_local
        mem = np.broadcast_to(member[:, None, :], (C, R, 2))
        xcol = np.broadcast_to(
            (np.arange(C)[:, None] * R + np.arange(R)[None, :])[:, :, None],
            (C, R, 2))
        valid = mem < n                               # dump slot = n
        # epigraph rows: +1 on t, -S[c, r, s] on each member's X entries
        rows = [np.arange(n), mem[valid], n + mem[valid]]
        cols = [np.full(n, C * R), xcol[valid], xcol[valid]]
        vals = [np.ones(n), -S[valid], np.ones(int(valid.sum()))]
        # worker rows: z_c on X[c, r]
        live = np.broadcast_to((z != 0)[:, None], (C, R))
        rows.append((2 * n + np.broadcast_to(np.arange(R)[None, :],
                                             (C, R)))[live])
        cols.append((np.arange(C)[:, None] * R
                     + np.arange(R)[None, :])[live])
        vals.append(np.broadcast_to(z[:, None], (C, R))[live])
        return structured_from_coo(
            np.concatenate(rows), np.concatenate(cols), np.concatenate(vals),
            2 * n + R, C * R + 1, coef_dtype=self.coef_dtype)

    def _build(self, combos_global: np.ndarray, local_of, n_local: int,
               frac: float, scale_vec: Optional[np.ndarray]) -> OperatorLP:
        wl = self.wl
        C = combos_global.shape[0]
        R = self.n_types
        S = np.zeros((C, R, 2))
        member = np.full((C, 2), n_local, np.int64)       # dump slot
        z = np.zeros(C)
        valid0 = combos_global[:, 0] >= 0
        g0 = np.maximum(combos_global[:, 0], 0)
        g1 = np.maximum(combos_global[:, 1], 0)
        is_pair = combos_global[:, 1] >= 0

        # slot 0
        S[valid0, :, 0] = (wl.T[g0] * self.scale[g0, None])[valid0]
        member[valid0, 0] = local_of(combos_global[valid0, 0])
        # slot 1 (pairs): both jobs retain interference-scaled throughput
        inter = np.sqrt(wl.interference[g0] * wl.interference[g1])
        S[is_pair, :, 0] *= inter[is_pair, None]
        S[is_pair, :, 1] = (wl.T[g1] * self.scale[g1, None] *
                            inter[:, None])[is_pair]
        member[is_pair, 1] = local_of(combos_global[is_pair, 1])
        z[valid0] = wl.z[g0][valid0]                      # pairs share workers

        n_var = C * R + 1
        c = np.zeros(n_var); c[-1] = -1.0                 # max t
        # secondary: -bonus/n * sum_m rho_m  (keeps max-min primary)
        c[: C * R] = -(self.leftover_bonus / max(n_local, 1)) * S.sum(axis=2).reshape(-1)
        l = np.zeros(n_var)
        u = np.zeros(n_var)
        u[: C * R] = np.repeat(valid0.astype(np.float64), R)
        u[-1] = 10.0
        # replication (§4.3) scales each replica's time-budget share — time
        # budget is the per-job "demand" here (padded slots get 0: their
        # combos are dead, so a zero budget stays trivially feasible)
        time_rhs = (np.ones(n_local) if scale_vec is None
                    else np.asarray(scale_vec, np.float64))
        q = np.concatenate([
            np.zeros(n_local),                            # epigraph rows
            time_rhs,                                     # time rows
            wl.num_workers * frac,                        # worker rows
        ])
        ineq = np.ones(q.shape[0], bool)
        data = (jnp.asarray(S, jnp.float32), jnp.asarray(member, jnp.int32),
                jnp.asarray(z, jnp.float32), jnp.zeros(n_local + 1, jnp.float32))
        structured = (None if self.space_sharing
                      else self._structured(S, member, z, n_local))
        return OperatorLP(
            c=jnp.asarray(c, jnp.float32), q=jnp.asarray(q, jnp.float32),
            l=jnp.asarray(l, jnp.float32), u=jnp.asarray(u, jnp.float32),
            ineq_mask=jnp.asarray(ineq), data=data, structured=structured)

    def build_sub(self, idx_row: np.ndarray, frac: float,
                  scale: Optional[np.ndarray] = None) -> OperatorLP:
        n_local = idx_row.shape[0]
        lut = np.full(self.n_entities + 1, n_local, np.int64)
        valid = idx_row >= 0
        lut[idx_row[valid]] = np.flatnonzero(valid)
        local_of = lambda g: lut[g]
        combos = self._combos(idx_row)
        return self._build(combos, local_of, n_local, frac, scale)

    # --- solution handling ----------------------------------------------------
    def extract(self, op: OperatorLP, x: np.ndarray, idx_row: np.ndarray):
        """Per-job normalised effective throughput rho_m (the quantity the
        paper's Fig. 3 reports the mean of)."""
        S, member, z, seg = op.data
        n_local = seg.shape[0] - 1
        C, R, _ = np.asarray(S).shape
        X = x[: C * R].reshape(C, R)
        contrib = np.einsum("crs,cr->cs", np.asarray(S), X)
        thpt = np.zeros(n_local + 1)
        np.add.at(thpt, np.asarray(member).reshape(-1), contrib.reshape(-1))
        return thpt[: idx_row.shape[0]]

    def evaluate(self, rho: np.ndarray) -> dict:
        return {
            "mean_norm_throughput": float(rho.mean()),
            "min_norm_throughput": float(rho.min()),
            "p10_norm_throughput": float(np.percentile(rho, 10)),
        }


# ---------------------------------------------------------------------------
# heuristic baseline (Gandiva-like introspective packing)
# ---------------------------------------------------------------------------

def gandiva_heuristic(wl: ClusterWorkload, space_sharing: bool = True,
                      seed: int = 0) -> np.ndarray:
    """Greedy affinity + opportunistic pair-packing, Gandiva-style.

    Each job is placed on its best-throughput type (subject to capacity,
    filling types in affinity order); when a type is oversubscribed, jobs
    time-share it equally; with space sharing, the heuristic packs pairs of
    jobs with compatible interference to reclaim time.  Returns per-job
    normalised effective throughput (same metric as GavelProblem.extract).
    """
    rng = np.random.default_rng(seed)
    n, R = wl.T.shape
    scale = 1.0 / (wl.w * wl.T.max(axis=1))
    order = rng.permutation(n)
    assign = np.zeros(n, np.int64)
    count = np.zeros(R)
    for m in order:
        prefs = np.argsort(-wl.T[m])
        # place on best type whose load (jobs per worker) is lowest relative
        load = count[prefs] / wl.num_workers[prefs]
        pick = prefs[int(np.argmin(load + np.arange(R) * 0.05))]
        assign[m] = pick
        count[pick] += wl.z[m]

    rho = np.zeros(n)
    for j in range(R):
        members = np.flatnonzero(assign == j)
        if members.size == 0:
            continue
        cap = wl.num_workers[j]
        if space_sharing and members.size > cap:
            # pack pairs (best interference first) until fits
            members_sorted = members[np.argsort(-wl.interference[members])]
            n_pairs = min(int(members.size - cap), members.size // 2)
            paired = members_sorted[: 2 * n_pairs]
            alone = members_sorted[2 * n_pairs:]
            eff_units = n_pairs + alone.size
            share = min(1.0, cap / max(eff_units, 1))
            inter = wl.interference[paired]
            rho[paired] = wl.T[paired, j] * scale[paired] * share * inter
            rho[alone] = wl.T[alone, j] * scale[alone] * share
        else:
            share = min(1.0, cap / members.size)
            rho[members] = wl.T[members, j] * scale[members] * share
    return rho
