"""Gradient compression for the data-parallel all-reduce: int8 blockwise
quantisation with error feedback.

Used on the DP axis where the interconnect (DCI between pods, or ethernet
between nodes at 1000+ node scale) is the bottleneck rather than ICI.
Error feedback keeps the quantisation noise from biasing the trajectory:
the residual of each round is added back before the next quantisation
(Seide et al. / Karimireddy et al.).

``compressed_psum`` composes with ``shard_map`` over the DP axes; the
model-sharded dims ride along untouched.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


BLOCK = 256


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8: returns (q [..., n], scale [..., n/BLOCK])."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_with_feedback(grad: jnp.ndarray, residual: jnp.ndarray):
    """Returns (q, scale, new_residual).  residual has grad's shape."""
    target = grad + residual
    q, scale = quantize_int8(target)
    deq = dequantize_int8(q, scale, grad.shape)
    return q, scale, target - deq


def compressed_psum(grad_tree, residual_tree, axis_name: str):
    """int8 all-reduce with error feedback; call INSIDE shard_map over the
    DP axis.  Returns (mean_grad_tree, new_residual_tree).

    Wire cost: 1 byte/param + 4/BLOCK bytes of scales vs 4 bytes/param for
    f32 psum — a 3.9x reduction on the DP interconnect.
    """
    def one(g, r):
        q, s, r_new = compress_with_feedback(g, r)
        # sum int8 payloads in f32 domain (int8 would overflow);
        # the WIRE format is int8+scales — XLA lowers psum of the dequantised
        # q*s product; on real fabric this maps to the compressed collective
        deq = q.astype(jnp.float32) * s
        total = jax.lax.psum(deq, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = (total / n).reshape(-1)[: g.size].reshape(g.shape)
        return mean, r_new

    flat_g, td = jax.tree_util.tree_flatten(grad_tree)
    flat_r = jax.tree_util.tree_flatten(residual_tree)[0]
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    means = jax.tree_util.tree_unflatten(td, [m for m, _ in out])
    resid = jax.tree_util.tree_unflatten(td, [r for _, r in out])
    return means, resid


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
