"""Training substrate: optimizer, sharded train step, gradient compression."""
from .optimizer import AdamWConfig, AdamWState, init_state, apply_updates
from .train_step import TrainConfig, make_train_step, jit_train_step, cross_entropy
