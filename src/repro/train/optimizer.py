"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule.  Pure-pytree implementation (no optax dependency);
optimizer state shards exactly like the parameters (m/v mirror specs)."""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object        # pytree like params
    v: object        # pytree like params


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1.0 + jnp.cos(np.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def _wd_mask(path) -> bool:
    """Decay matrices only — skip norms/scales/biases/1-d leaves."""
    names = [str(e.key) for e in path
             if isinstance(e, jax.tree_util.DictKey)]
    no_decay = {"scale", "bias", "a_log", "dt_bias", "d_skip", "norm_scale"}
    return not (names and names[-1] in no_decay)


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay > 0 and _wd_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unflatten = jax.tree_util.tree_unflatten
    return (
        unflatten(treedef, new_p),
        AdamWState(step=step, m=unflatten(treedef, new_m),
                   v=unflatten(treedef, new_v)),
        {"lr": lr, "grad_norm": gnorm},
    )
