"""Sharded training step: microbatched grad accumulation, bf16 compute /
f32 master params, remat-per-period, GSPMD-sharded end to end.

Overlap note (production behaviour this code is written to elicit): with
grad accumulation as a ``lax.scan``, XLA schedules each microbatch's DP
all-reduce (from the batch-sharded loss) asynchronously against the next
microbatch's compute — collective/compute overlap falls out of the
dataflow; no manual double-buffering needed.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import transformer as tf
from ..launch import shardings as sh
from . import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_microbatches: int = 1
    compute_dtype: str = "bfloat16"
    remat: bool = True
    unroll_segments: bool = False    # cost-probe mode (see launch/dryrun.py)
    sp_residual: bool = False        # §Perf: sequence-parallel residual
    bf16_barrier: bool = False       # §Perf: pin TP collectives to bf16
    gather_once: bool = False        # §Perf: single shared AG per norm
    adamw: opt_mod.AdamWConfig = opt_mod.AdamWConfig()


def cross_entropy(logits, labels):
    """Mean CE over all positions.  Works with vocab-sharded logits (the
    logsumexp reduce becomes a psum under GSPMD)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: tf.ArchCfg, tcfg: TrainConfig, mesh: Optional[Mesh]):
    dtype = jnp.bfloat16 if tcfg.compute_dtype == "bfloat16" else jnp.float32
    opts = tf.ModelOpts(sp_residual=tcfg.sp_residual,
                        bf16_barrier=tcfg.bf16_barrier,
                        gather_once=tcfg.gather_once, mesh=mesh)

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        if mesh is not None:
            tokens = jax.lax.with_sharding_constraint(
                tokens, NamedSharding(mesh, sh.batch_spec(mesh)))
        logits = tf.forward_train(
            params, cfg, tokens,
            enc_embeddings=batch.get("enc_embeddings"),
            remat=tcfg.remat, compute_dtype=dtype,
            unroll=tcfg.unroll_segments, opts=opts)
        return cross_entropy(logits, labels)

    return loss_fn


def make_train_step(cfg: tf.ArchCfg, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leaves are GLOBAL arrays shaped [B_global, ...]; with
    n_microbatches > 1 they are reshaped to [n_micro, B/n_micro, ...] and
    scanned (grad accumulation)."""
    loss_fn = make_loss_fn(cfg, tcfg, mesh)
    n_micro = tcfg.n_microbatches

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro

        params, opt_state, metrics = opt_mod.apply_updates(
            tcfg.adamw, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def jit_train_step(cfg: tf.ArchCfg, tcfg: TrainConfig, mesh: Mesh,
                   params_shape, batch_shape):
    """jit with explicit in/out shardings + donation (params/opt buffers
    are donated — at 27-140B params this is what keeps peak memory at 1x)."""
    p_specs = sh.param_specs(params_shape, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    o_shard = opt_mod.AdamWState(
        step=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs),
        v=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs))
    b_shard = jax.tree.map(
        lambda a: NamedSharding(
            mesh, sh.batch_spec(mesh) if a.ndim == 2
            else P(sh.dp_axes(mesh), *([None] * (a.ndim - 1)))),
        batch_shape)
    metrics_shard = {"lr": NamedSharding(mesh, P()),
                     "grad_norm": NamedSharding(mesh, P()),
                     "loss": NamedSharding(mesh, P())}
    step = make_train_step(cfg, tcfg, mesh)
    return jax.jit(
        step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, metrics_shard),
        donate_argnums=(0, 1),
    )
