"""Cluster scheduler service: POP-accelerated Gavel for the training fleet.

This is where the paper's technique becomes a first-class feature of the
framework: the scheduler periodically recomputes the fleet-wide max-min
fair allocation of accelerator types to training jobs (the LM archs in
``repro.configs``) by solving the Gavel LP through POP — so a 10k-job fleet
reallocates in seconds instead of the ~30 minutes the paper quotes for the
full formulation.

Flow per scheduling round:
    observe() -> jobs + measured throughputs     (from job heartbeats)
    allocate() -> POP-k Gavel solve              (core/pop + problems/*)
    to_assignments() -> per-job (resource type, time fraction) leases
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core import pop
from ..problems.cluster_scheduling import ClusterWorkload, GavelProblem


@dataclasses.dataclass
class JobSpec:
    job_id: str
    arch: str                   # one of repro.configs.ARCH_IDS
    priority: float = 1.0
    n_workers: int = 1
    # measured tokens/sec per accelerator type (filled by heartbeats)
    throughputs: Optional[np.ndarray] = None


@dataclasses.dataclass
class SchedulerConfig:
    resource_types: tuple = ("tpu_v5e", "tpu_v4", "gpu_h100")
    num_workers: tuple = (256, 256, 256)
    pop_k: int = 8
    space_sharing: bool = False
    round_seconds: float = 300.0
    # map-step execution backend (core/backends.py registry); "auto" picks
    # shard_map on a multi-device mesh, (chunked_)vmap on one device
    map_backend: str = "auto"
    # equilibrate: probe-based operator scaling — measured -29% iterations
    # on Gavel-type LPs (EXPERIMENTS.md §Perf cell 3)
    solver_kw: dict = dataclasses.field(default_factory=lambda: dict(
        max_iters=20_000, tol_primal=1e-4, tol_gap=1e-4, equilibrate=True))


class GavelScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.jobs: Dict[str, JobSpec] = {}
        self.last_alloc: Optional[np.ndarray] = None
        self.last_round_time: float = 0.0
        # warm-start state: POPResult / SolveResult of the previous round.
        # Successive rounds see EMA-drifted throughputs — the textbook
        # online re-solve — AND job churn (submits/removes).  Each job gets
        # a stable numeric id at submit; pop_solve(warm=, entity_ids=)
        # matches surviving jobs across rounds and remaps their iterates
        # onto the new round's plan, so the warm start survives churn
        # instead of falling back to cold whenever the job set changes.
        self._warm = None
        self._eids: Dict[str, int] = {}
        self._next_eid: int = 0
        self._warm_full_eids: tuple = ()   # k=1 path: jobs the warm is FOR
        self.last_warm_fraction: Optional[float] = None

    # ------------------------------------------------------------- job API --
    def submit(self, job: JobSpec):
        if job.throughputs is None:
            # cold-start prior: arch-family default speedup profile
            job.throughputs = np.array([1.0, 0.6, 0.8]) * (
                0.5 + abs(hash(job.arch)) % 1000 / 1000.0)
        if job.job_id not in self._eids:
            self._eids[job.job_id] = self._next_eid
            self._next_eid += 1
        self.jobs[job.job_id] = job

    def remove(self, job_id: str):
        self.jobs.pop(job_id, None)
        self._eids.pop(job_id, None)

    def report_throughput(self, job_id: str, measured: np.ndarray):
        """Heartbeat path: refine T with live measurements (EMA)."""
        j = self.jobs[job_id]
        j.throughputs = 0.7 * j.throughputs + 0.3 * measured

    # ---------------------------------------------------------- scheduling --
    def _workload(self) -> ClusterWorkload:
        jobs = list(self.jobs.values())
        T = np.stack([j.throughputs for j in jobs])
        return ClusterWorkload(
            T=T,
            w=np.array([j.priority for j in jobs]),
            z=np.array([float(j.n_workers) for j in jobs]),
            num_workers=np.asarray(self.cfg.num_workers, np.float64),
            interference=np.full(len(jobs), 0.8),
            job_type=np.zeros(len(jobs), np.int64),
        )

    def allocate(self) -> Dict[str, np.ndarray]:
        """One scheduling round: POP-k Gavel solve -> {job: X_row}.  Warm
        state chains through job churn: surviving jobs are matched by their
        stable id and continue from their previous iterates (new arrivals
        start from population priors, see ``core/plan.py``); only a POP <->
        full-problem mode flip drops the warm state.  ``warm_fraction``
        (matched share, via :meth:`fairness_report`) is logged per round."""
        if not self.jobs:
            return {}
        t0 = time.perf_counter()
        wl = self._workload()
        prob = GavelProblem(wl, space_sharing=self.cfg.space_sharing)
        eids = np.array([self._eids[j] for j in self.jobs], np.int64)
        k = max(1, min(self.cfg.pop_k, len(self.jobs) // 8))
        if k > 1:
            warm = self._warm if isinstance(self._warm, pop.POPResult) else None
            res = pop.pop_solve(prob, k, strategy="stratified",
                                backend=self.cfg.map_backend,
                                solver_kw=self.cfg.solver_kw,
                                warm=warm, entity_ids=eids)
            rho = res.alloc
            self._warm = res
            self.last_warm_fraction = (res.warm_stats["warm_fraction"]
                                       if res.warm_stats else None)
        else:
            # full-problem path (tiny fleets): the flat LP has no per-entity
            # remap, so warm only while the job IDENTITY sequence is
            # unchanged (a same-size swap would silently misalign rows) —
            # below the POP threshold a cold solve is cheap anyway
            full_warm = self._warm if not isinstance(self._warm,
                                                     pop.POPResult) else None
            if full_warm is not None and tuple(eids) != self._warm_full_eids:
                full_warm = None
            rho, res, _, _ = pop.solve_full(prob, solver_kw=self.cfg.solver_kw,
                                            warm=full_warm)
            self._warm = res
            self._warm_full_eids = tuple(eids)
            self.last_warm_fraction = None if full_warm is None else 1.0
        self.last_round_time = time.perf_counter() - t0
        self.last_alloc = rho
        return {j.job_id: rho[i] for i, j in enumerate(self.jobs.values())}

    def fairness_report(self) -> dict:
        if self.last_alloc is None:
            return {}
        rho = np.atleast_1d(self.last_alloc)
        return {
            "min_norm_throughput": float(rho.min()),
            "mean_norm_throughput": float(rho.mean()),
            "round_time_s": self.last_round_time,
            "n_jobs": len(self.jobs),
            "warm_fraction": self.last_warm_fraction,
        }
