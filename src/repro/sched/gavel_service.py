"""Cluster scheduler service: POP-accelerated Gavel for the training fleet.

This is where the paper's technique becomes a first-class feature of the
framework: the scheduler periodically recomputes the fleet-wide max-min
fair allocation of accelerator types to training jobs (the LM archs in
``repro.configs``) by solving the Gavel LP through POP — so a 10k-job fleet
reallocates in seconds instead of the ~30 minutes the paper quotes for the
full formulation.

Flow per scheduling round:
    observe() -> jobs + measured throughputs     (from job heartbeats)
    allocate() -> POP-k Gavel solve              (core/pop + problems/*)
    to_assignments() -> per-job (resource type, time fraction) leases
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from ..core import pop
from ..problems.cluster_scheduling import ClusterWorkload, GavelProblem


@dataclasses.dataclass
class JobSpec:
    job_id: str
    arch: str                   # one of repro.configs.ARCH_IDS
    priority: float = 1.0
    n_workers: int = 1
    # measured tokens/sec per accelerator type (filled by heartbeats)
    throughputs: Optional[np.ndarray] = None


@dataclasses.dataclass
class SchedulerConfig:
    resource_types: tuple = ("tpu_v5e", "tpu_v4", "gpu_h100")
    num_workers: tuple = (256, 256, 256)
    pop_k: int = 8
    space_sharing: bool = False
    round_seconds: float = 300.0
    # map-step execution backend (core/backends.py registry); "auto" picks
    # shard_map on a multi-device mesh, (chunked_)vmap on one device
    map_backend: str = "auto"
    # equilibrate: probe-based operator scaling — measured -29% iterations
    # on Gavel-type LPs (EXPERIMENTS.md §Perf cell 3)
    solver_kw: dict = dataclasses.field(default_factory=lambda: dict(
        max_iters=20_000, tol_primal=1e-4, tol_gap=1e-4, equilibrate=True))


class GavelScheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.jobs: Dict[str, JobSpec] = {}
        self.last_alloc: Optional[np.ndarray] = None
        self.last_round_time: float = 0.0
        # warm-start state: POPResult / SolveResult of the previous round +
        # the job-id tuple it was computed for.  Successive rounds see the
        # SAME jobs with EMA-drifted throughputs — the textbook online
        # re-solve, so each round continues from the previous iterates.
        self._warm = None
        self._warm_jobs: tuple = ()

    # ------------------------------------------------------------- job API --
    def submit(self, job: JobSpec):
        if job.throughputs is None:
            # cold-start prior: arch-family default speedup profile
            job.throughputs = np.array([1.0, 0.6, 0.8]) * (
                0.5 + abs(hash(job.arch)) % 1000 / 1000.0)
        self.jobs[job.job_id] = job

    def remove(self, job_id: str):
        self.jobs.pop(job_id, None)

    def report_throughput(self, job_id: str, measured: np.ndarray):
        """Heartbeat path: refine T with live measurements (EMA)."""
        j = self.jobs[job_id]
        j.throughputs = 0.7 * j.throughputs + 0.3 * measured

    # ---------------------------------------------------------- scheduling --
    def _workload(self) -> ClusterWorkload:
        jobs = list(self.jobs.values())
        T = np.stack([j.throughputs for j in jobs])
        return ClusterWorkload(
            T=T,
            w=np.array([j.priority for j in jobs]),
            z=np.array([float(j.n_workers) for j in jobs]),
            num_workers=np.asarray(self.cfg.num_workers, np.float64),
            interference=np.full(len(jobs), 0.8),
            job_type=np.zeros(len(jobs), np.int64),
        )

    def allocate(self) -> Dict[str, np.ndarray]:
        """One scheduling round: POP-k Gavel solve -> {job: X_row},
        warm-started from the previous round while the job set is stable
        (any submit/remove invalidates the warm state — shapes change)."""
        if not self.jobs:
            return {}
        t0 = time.perf_counter()
        wl = self._workload()
        prob = GavelProblem(wl, space_sharing=self.cfg.space_sharing)
        k = max(1, min(self.cfg.pop_k, len(self.jobs) // 8))
        job_key = (k, tuple(self.jobs))
        warm = self._warm if job_key == self._warm_jobs else None
        if k > 1:
            res = pop.pop_solve(prob, k, strategy="stratified",
                                backend=self.cfg.map_backend,
                                solver_kw=self.cfg.solver_kw,
                                warm=warm if isinstance(warm, pop.POPResult)
                                else None)
            rho = res.alloc
            self._warm = res
        else:
            full_warm = warm if not isinstance(warm, pop.POPResult) else None
            rho, res, _, _ = pop.solve_full(prob, solver_kw=self.cfg.solver_kw,
                                            warm=full_warm)
            self._warm = res
        self._warm_jobs = job_key
        self.last_round_time = time.perf_counter() - t0
        self.last_alloc = rho
        return {j.job_id: rho[i] for i, j in enumerate(self.jobs.values())}

    def fairness_report(self) -> dict:
        if self.last_alloc is None:
            return {}
        rho = np.atleast_1d(self.last_alloc)
        return {
            "min_norm_throughput": float(rho.min()),
            "mean_norm_throughput": float(rho.mean()),
            "round_time_s": self.last_round_time,
            "n_jobs": len(self.jobs),
        }
