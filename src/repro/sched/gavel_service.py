"""Cluster scheduler service: POP-accelerated Gavel for the training fleet.

DEPRECATED surface: :class:`GavelScheduler` is now a thin forwarder onto
the one public API — a :class:`repro.service.PopService` session over the
registered ``gavel`` domain (``repro.domains.gavel``).  It keeps the
job-book-keeping conveniences (submit/remove/heartbeats -> stable entity
ids) and produces bit-identical allocations to the pre-session scheduler,
but new code should drive the session directly:

    service = PopService()
    session = service.session("fleet", GavelInstance(wl, job_ids=eids))
    alloc = session.step(GavelInstance(wl, job_ids=eids))   # per round

Flow per scheduling round (unchanged):
    observe() -> jobs + measured throughputs     (from job heartbeats)
    allocate() -> POP-k Gavel solve              (one session.step)
    to_assignments() -> per-job (resource type, time fraction) leases
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, Optional

import numpy as np

from ..core.config import ExecConfig, SolveConfig
from ..domains.gavel import GavelInstance
from ..problems.cluster_scheduling import ClusterWorkload
from ..service import PopService


@dataclasses.dataclass
class JobSpec:
    job_id: str
    arch: str                   # one of repro.configs.ARCH_IDS
    priority: float = 1.0
    n_workers: int = 1
    # measured tokens/sec per accelerator type (filled by heartbeats)
    throughputs: Optional[np.ndarray] = None


@dataclasses.dataclass
class SchedulerConfig:
    resource_types: tuple = ("tpu_v5e", "tpu_v4", "gpu_h100")
    num_workers: tuple = (256, 256, 256)
    pop_k: int = 8
    space_sharing: bool = False
    round_seconds: float = 300.0
    # map-step execution backend (core/backends.py registry); "auto" picks
    # shard_map on a multi-device mesh, (chunked_)vmap on one device
    map_backend: str = "auto"
    # equilibrate: probe-based operator scaling — measured -29% iterations
    # on Gavel-type LPs (EXPERIMENTS.md §Perf cell 3)
    solver_kw: dict = dataclasses.field(default_factory=lambda: dict(
        max_iters=20_000, tol_primal=1e-4, tol_gap=1e-4, equilibrate=True))


class GavelScheduler:
    """DEPRECATED: drive ``PopService.session(...,
    GavelInstance(...))`` directly; this class forwards onto exactly that
    session (same solves, bit-identical allocations) and only adds the
    job-dict plumbing."""

    def __init__(self, cfg: SchedulerConfig):
        warnings.warn(
            "GavelScheduler is deprecated: use repro.service.PopService"
            ".session(tenant, repro.domains.GavelInstance(...)) — this "
            "class forwards onto that session (results are identical)",
            DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.jobs: Dict[str, JobSpec] = {}
        self.last_alloc: Optional[np.ndarray] = None
        self.last_round_time: float = 0.0
        # the one public API: a per-fleet session.  Warm-start state (plan
        # reuse, churn repair, id-matched warm remaps) lives INSIDE it —
        # successive rounds see EMA-drifted throughputs and job churn, and
        # the session chains warm state through both.
        self._session = PopService().session(
            "gavel-fleet", domain="gavel",
            solve=SolveConfig(k=cfg.pop_k, strategy="stratified",
                              min_per_sub=8),
            exec=ExecConfig(backend=cfg.map_backend,
                            solver_kw=dict(cfg.solver_kw)))
        self._eids: Dict[str, int] = {}
        self._next_eid: int = 0
        self.last_warm_fraction: Optional[float] = None

    # ------------------------------------------------------------- job API --
    def submit(self, job: JobSpec):
        if job.throughputs is None:
            # cold-start prior: arch-family default speedup profile
            job.throughputs = np.array([1.0, 0.6, 0.8]) * (
                0.5 + abs(hash(job.arch)) % 1000 / 1000.0)
        if job.job_id not in self._eids:
            self._eids[job.job_id] = self._next_eid
            self._next_eid += 1
        self.jobs[job.job_id] = job

    def remove(self, job_id: str):
        self.jobs.pop(job_id, None)
        self._eids.pop(job_id, None)

    def report_throughput(self, job_id: str, measured: np.ndarray):
        """Heartbeat path: refine T with live measurements (EMA)."""
        j = self.jobs[job_id]
        j.throughputs = 0.7 * j.throughputs + 0.3 * measured

    # ---------------------------------------------------------- scheduling --
    def _workload(self) -> ClusterWorkload:
        jobs = list(self.jobs.values())
        T = np.stack([j.throughputs for j in jobs])
        return ClusterWorkload(
            T=T,
            w=np.array([j.priority for j in jobs]),
            z=np.array([float(j.n_workers) for j in jobs]),
            num_workers=np.asarray(self.cfg.num_workers, np.float64),
            interference=np.full(len(jobs), 0.8),
            job_type=np.zeros(len(jobs), np.int64),
        )

    def allocate(self) -> Dict[str, np.ndarray]:
        """One scheduling round = one ``session.step``: the session reuses
        or repairs its plan, matches surviving jobs by their stable id and
        continues from their previous iterates (new arrivals start from
        population priors — ``core/plan.py``); only a POP <-> full-problem
        mode flip drops the warm state.  ``warm_fraction`` (matched share,
        via :meth:`fairness_report`) is logged per round."""
        if not self.jobs:
            return {}
        t0 = time.perf_counter()
        eids = np.array([self._eids[j] for j in self.jobs], np.int64)
        inst = GavelInstance(self._workload(),
                             space_sharing=self.cfg.space_sharing,
                             job_ids=eids)
        result = self._session.step(inst)
        rho = result.alloc
        self.last_warm_fraction = result.warm_fraction
        self.last_round_time = time.perf_counter() - t0
        self.last_alloc = rho
        return {j.job_id: rho[i] for i, j in enumerate(self.jobs.values())}

    def fairness_report(self) -> dict:
        if self.last_alloc is None:
            return {}
        rho = np.atleast_1d(self.last_alloc)
        return {
            "min_norm_throughput": float(rho.min()),
            "mean_norm_throughput": float(rho.mean()),
            "round_time_s": self.last_round_time,
            "n_jobs": len(self.jobs),
            "warm_fraction": self.last_warm_fraction,
        }
