"""Fault tolerance & elasticity runtime: heartbeats, straggler detection,
elastic remesh planning, and POP-sub-problem re-dispatch.

At 1000+ nodes the failure model is: pods die (heartbeat timeout), pods
straggle (step-time outliers), and capacity changes (preemption /
backfill).  The runtime's job is to (a) notice fast, (b) shrink or grow
the data-parallel axis without a cold restart, and (c) re-dispatch work.

POP tie-in (why this lives in ``sched/``): POP sub-problems are idempotent
and stateless — the natural unit of re-execution.  When a worker dies
mid-map-step, its sub-problems are re-dealt to survivors (``redispatch``);
when the mesh shrinks, ``plan_remesh`` picks the largest valid (data,
model) grid and the checkpointer's sharding-aware restore re-lands state.

This module is deliberately execution-agnostic (pure planning + state
machines) so it unit-tests on CPU and drives either a real multi-host
runtime or the simulated one in ``examples/fault_tolerance_demo.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# heartbeat failure detector
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HeartbeatMonitor:
    """Phi-accrual-lite: a worker is DEAD after ``timeout_s`` silence,
    SUSPECT after ``suspect_s``."""
    timeout_s: float = 30.0
    suspect_s: float = 10.0
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, worker: int, now: Optional[float] = None):
        self.last_seen[worker] = time.monotonic() if now is None else now

    def status(self, now: Optional[float] = None) -> Dict[int, str]:
        now = time.monotonic() if now is None else now
        out = {}
        for w, t in self.last_seen.items():
            dt = now - t
            out[w] = ("dead" if dt > self.timeout_s
                      else "suspect" if dt > self.suspect_s else "alive")
        return out

    def alive(self, now: Optional[float] = None) -> List[int]:
        return [w for w, s in self.status(now).items() if s != "dead"]


# ---------------------------------------------------------------------------
# straggler detection (step-time outliers)
# ---------------------------------------------------------------------------

class StragglerDetector:
    """Rolling median/MAD over per-worker step durations; a worker whose
    recent steps exceed median + k*MAD is a straggler.  Mitigation at the
    POP layer: its queued sub-problems are re-dealt (cheap, idempotent);
    at the training layer: it is flagged for remesh on next checkpoint."""

    def __init__(self, window: int = 32, k: float = 4.0):
        self.window = window
        self.k = k
        self.hist: Dict[int, List[float]] = {}

    def record(self, worker: int, duration_s: float):
        h = self.hist.setdefault(worker, [])
        h.append(duration_s)
        if len(h) > self.window:
            h.pop(0)

    def stragglers(self) -> List[int]:
        if not self.hist:
            return []
        all_steps = np.concatenate([np.asarray(h) for h in self.hist.values()])
        med = np.median(all_steps)
        mad = np.median(np.abs(all_steps - med)) + 1e-9
        out = []
        for w, h in self.hist.items():
            recent = np.median(np.asarray(h[-8:]))
            if recent > med + self.k * mad:
                out.append(w)
        return out


# ---------------------------------------------------------------------------
# elastic remesh planning
# ---------------------------------------------------------------------------

def plan_remesh(n_alive: int, model_parallel: int,
                multi_pod_threshold: int = 512) -> dict:
    """Largest usable (pod, data, model) grid for the surviving chips.

    ``model`` is fixed (weights are laid out for it); the data axis absorbs
    the loss.  Returns the plan + how many chips idle (spares pool)."""
    if n_alive < model_parallel:
        return {"ok": False, "reason": "fewer chips than model-parallel group"}
    data = n_alive // model_parallel
    used = data * model_parallel
    shape = ((2, data // 2, model_parallel)
             if used >= multi_pod_threshold and data % 2 == 0
             else (data, model_parallel))
    return {
        "ok": True,
        "mesh_shape": shape,
        "axis_names": (("pod", "data", "model") if len(shape) == 3
                       else ("data", "model")),
        "chips_used": used,
        "spares": n_alive - used,
        # global batch is kept constant by scaling microbatches:
        "microbatch_scale": None,
    }


def scale_microbatches(global_batch: int, n_micro_old: int, data_old: int,
                       data_new: int) -> int:
    """Keep the global batch (and therefore the optimizer trajectory) fixed
    across a resize by growing grad-accumulation steps."""
    per_dev_micro = global_batch // (n_micro_old * data_old)
    n_new = int(np.ceil(global_batch / (per_dev_micro * data_new)))
    while global_batch % (n_new * data_new):
        n_new += 1
    return n_new


# ---------------------------------------------------------------------------
# POP sub-problem re-dispatch
# ---------------------------------------------------------------------------

def redispatch(assignment: Dict[int, List[int]], dead: List[int],
               alive: List[int]) -> Dict[int, List[int]]:
    """Re-deal sub-problems owned by dead workers to the least-loaded
    survivors.  Sub-problems are idempotent (pure LP solves) so this is
    safe even if a 'dead' worker later returns a stale answer."""
    assignment = {w: list(s) for w, s in assignment.items()}
    orphaned = []
    for w in dead:
        orphaned.extend(assignment.pop(w, []))
    for w in alive:
        assignment.setdefault(w, [])
    for sub in orphaned:
        target = min(alive, key=lambda w: len(assignment[w]))
        assignment[target].append(sub)
    return assignment


# ---------------------------------------------------------------------------
# deadline-based speculative re-execution (map-step stragglers)
# ---------------------------------------------------------------------------

def speculative_backups(pending: Dict[int, float], now: float,
                        deadline_s: float) -> List[int]:
    """Sub-problems past their deadline get a backup copy elsewhere (first
    answer wins) — classic MapReduce speculation, valid here because POP
    sub-problem solves are deterministic and side-effect-free."""
    return [sub for sub, started in pending.items()
            if now - started > deadline_s]
