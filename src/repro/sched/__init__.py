"""Cluster scheduling (POP-Gavel) + fault tolerance/elasticity runtime."""
from .gavel_service import GavelScheduler, SchedulerConfig, JobSpec
from .elastic import (HeartbeatMonitor, StragglerDetector, plan_remesh,
                      scale_microbatches, redispatch, speculative_backups)
