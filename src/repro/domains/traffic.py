"""WAN traffic engineering as a registered domain (paper §3.2).

The LP/entity model lives in ``problems/traffic_engineering.py``
(:class:`TrafficProblem` — commodities are entities, per-path flows the
variables; every sub-problem keeps the whole network at 1/k capacity).
The domain instance IS the problem object: it already bundles topology,
demands and precomputed paths, and rebuilding it per tick is how demand
drift enters.
"""

from __future__ import annotations

from ..core.config import ExecConfig, SolveConfig
from ..problems.traffic_engineering import TrafficProblem
from .base import DomainSpec
from .registry import register

SPEC = register(DomainSpec(
    name="traffic",
    instance_types=(TrafficProblem,),
    describe="max-total-flow WAN TE (commodities onto k-shortest paths)",
    problem=lambda inst: inst,
    # the SLO tuner's quality scalar (repro.tuning)
    quality=lambda m: m["total_flow"],
    default_solve=SolveConfig(k=8, strategy="stratified"),
    default_exec=ExecConfig(solver_kw=dict(
        max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)),
))
