"""The declarative domain contract: :class:`DomainSpec` + the generic
adapter that turns a spec into a POP-able problem.

The paper's pitch is that POP is a *technique*, not three bespoke solvers.
This module is where that becomes an interface: a domain describes itself
as data — an entity model, an LP builder, operator matvecs, a warm-start
layout, reduce/rounding hooks — and registers the description
(``repro.domains.register``).  ``core/`` then drives every domain through
the same ``plan -> build -> solve -> reduce`` pipeline with ZERO
domain-specific branches; :class:`~repro.service.PopService` sessions look
domains up by name (or infer them from the instance type) and call the
hooks.

Two ways to fill a spec:

* **declarative hooks** (the registry-only path, how the MoE expert
  placement domain onboards): provide ``n_entities`` / ``entity_attrs`` /
  ``build_sub`` / ``K_mv`` / ``KT_mv`` / ``extract`` (+ optional
  ``entity_scores``, ``sub_layout``, ``round``, ``evaluate``) and the
  generic :class:`SpecProblem` adapter is synthesised for you.
* **a ``problem`` factory** (how the pre-existing paper domains are
  ported): map the instance to an existing
  :class:`~repro.core.pop.POPProblem`; the remaining hooks default to the
  problem's own methods.

Domains whose split is not an entity partition at all (load balancing
splits SERVER GROUPS and shards follow their server) provide a
``step_override`` instead: the session calls it with the instance, the
configs and its carried warm state, and the domain runs its own pipeline —
still behind the one public ``session.step`` door.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import numpy as np

from ..core.config import ExecConfig, SolveConfig
from ..core.pop import POPProblem


@dataclasses.dataclass
class StepOutcome:
    """What a ``step_override`` returns — the fields the session needs to
    assemble an :class:`~repro.service.Allocation` plus the warm state it
    should carry into the next step."""

    alloc: np.ndarray
    metrics: dict
    warm_state: Any
    backend: Optional[str] = None
    engine: Optional[str] = None
    plan_cache: str = "miss"
    warm_fraction: Optional[float] = None
    solve_time_s: float = 0.0
    build_time_s: float = 0.0
    iterations: int = 0
    k: int = 1
    raw: Any = None


@dataclasses.dataclass(frozen=True)
class DomainSpec:
    """A POP domain as data.  See the module docstring for the two fill
    styles; every callable takes the *domain instance* first."""

    name: str
    # instance types session()/spec_for() infer the domain from
    instance_types: Tuple[type, ...] = ()
    describe: str = ""

    # --- path A: adapt an existing POPProblem ------------------------------
    problem: Optional[Callable[[Any], POPProblem]] = None

    # --- path B: declarative hooks (SpecProblem is synthesised) ------------
    n_entities: Optional[Callable[[Any], int]] = None
    entity_attrs: Optional[Callable[[Any], np.ndarray]] = None
    entity_scores: Optional[Callable[[Any], np.ndarray]] = None
    build_sub: Optional[Callable] = None      # (inst, idx_row, frac, scale)
    K_mv: Optional[Callable] = None
    KT_mv: Optional[Callable] = None
    sub_layout: Optional[Callable] = None     # (inst, n_slots) -> SubLayout
    extract: Optional[Callable] = None        # (inst, op, x, idx_row)

    # --- shared hooks -------------------------------------------------------
    entity_ids: Optional[Callable[[Any], Optional[np.ndarray]]] = None
    round: Optional[Callable] = None          # (inst, alloc) -> allocation
    evaluate: Optional[Callable] = None       # (inst, alloc) -> metrics
    # the domain quality SCALAR (metrics dict -> float, higher = better):
    # what the SLO auto-tuner (repro.tuning) measures quality loss on.
    # Defaults to metrics["objective"] when absent
    quality: Optional[Callable[[dict], float]] = None
    # solver-free fallback allocation, (inst) -> alloc: the last rung of
    # the serving degradation ladder (docs/ROBUSTNESS.md) — what a session
    # returns when the solve diverges/misses its deadline and there is no
    # previous allocation to repeat
    greedy: Optional[Callable] = None
    default_solve: SolveConfig = SolveConfig()
    default_exec: ExecConfig = ExecConfig()

    # --- full custom online step (domain-aware splits, e.g. LB) ------------
    step_override: Optional[Callable] = None  # (inst, solve, exec, warm)

    def __post_init__(self):
        if self.step_override is not None:
            return
        if self.problem is None:
            needed = ("n_entities", "entity_attrs", "build_sub", "K_mv",
                      "KT_mv", "extract")
            missing = [f for f in needed if getattr(self, f) is None]
            if missing:
                raise ValueError(
                    f"domain {self.name!r}: provide a problem= factory, a "
                    f"step_override=, or the declarative hooks (missing: "
                    f"{missing})")

    def make_problem(self, instance: Any) -> POPProblem:
        """The POP-able problem for ``instance`` (builds the generic
        adapter when the spec is declarative)."""
        if self.problem is not None:
            return self.problem(instance)
        return SpecProblem(self, instance)

    def ids_of(self, instance: Any) -> Optional[np.ndarray]:
        return None if self.entity_ids is None else self.entity_ids(instance)

    def metrics_of(self, instance: Any, problem: Optional[POPProblem],
                   alloc: np.ndarray) -> dict:
        if self.evaluate is not None:
            return self.evaluate(instance, alloc)
        if problem is not None:
            return problem.evaluate(alloc)
        return {}

    def quality_of(self, metrics: Optional[dict]) -> Optional[float]:
        """The scalar the tuner tracks, from a step's metrics dict (None
        when the domain has no usable quality signal)."""
        if not isinstance(metrics, dict):
            return None
        if self.quality is not None:
            try:
                return float(self.quality(metrics))
            except (KeyError, TypeError, ValueError):
                return None
        obj = metrics.get("objective")
        return None if obj is None else float(obj)


class SpecProblem(POPProblem):
    """Generic :class:`~repro.core.pop.POPProblem` synthesised from a
    declarative :class:`DomainSpec` — what lets a new scenario onboard
    through the registry alone, without subclassing anything.

    The operator matvecs are taken from the SPEC (one function object per
    domain, not per instance), so every instance of a domain shares the
    jitted solver caches in ``core/backends.py``."""

    def __init__(self, spec: DomainSpec, instance: Any):
        self.spec = spec
        self.instance = instance
        self.n_entities = int(spec.n_entities(instance))
        # instance attributes shadow the POPProblem staticmethods; same
        # spec => same function identity => shared jit caches
        self.K_mv = spec.K_mv
        self.KT_mv = spec.KT_mv

    def entity_attrs(self) -> np.ndarray:
        return self.spec.entity_attrs(self.instance)

    def entity_scores(self) -> np.ndarray:
        if self.spec.entity_scores is not None:
            return self.spec.entity_scores(self.instance)
        return super().entity_scores()

    def build_sub(self, idx_row, frac, scale=None):
        return self.spec.build_sub(self.instance, idx_row, frac, scale)

    def sub_layout(self, n_slots: int):
        if self.spec.sub_layout is None:
            return None
        return self.spec.sub_layout(self.instance, n_slots)

    def extract(self, op, x, idx_row):
        return self.spec.extract(self.instance, op, x, idx_row)

    def evaluate(self, alloc) -> dict:
        return self.spec.metrics_of(self.instance, None, alloc)
