"""Query/request load balancing as a registered domain (paper §3.3).

The split here is NOT an entity partition: sub-problems get disjoint
*server groups* and every shard follows its current server (otherwise the
split itself would force movement).  The domain therefore registers a
``step_override`` instead of the declarative build hooks — the session
still owns warm-state chaining and observability, but the pipeline inside
is :func:`repro.problems.load_balancing.balance_placement` (which also
carries the §3.3 rounding + greedy repair and the POP-vs-full ``k_eff``
rule).

The instance is a :class:`BalanceInstance`: anything that places
``load``-weighted shards onto ``n_targets`` — decode request groups onto
replicas (``serve.engine``), shards onto database servers, experts onto
devices when you want the sticky/server-group behaviour.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.config import ExecConfig, SolveConfig
from ..problems.load_balancing import LBResult, balance_placement
from .base import DomainSpec, StepOutcome
from .registry import register


@dataclasses.dataclass
class BalanceInstance:
    """One balancing tick's input."""

    load: np.ndarray                        # [n] per-shard load
    n_targets: int                          # servers/replicas
    current: Optional[np.ndarray] = None    # [n] current placement (sticky)
    cap: Optional[np.ndarray] = None        # [n_targets] memory capacity
    eps_frac: float = 0.2                   # load-window tolerance
    # stable external shard/session ids (None = positional): what lets the
    # warm state survive shard arrivals/departures between ticks
    ids: Optional[np.ndarray] = None

    @property
    def n_shards(self) -> int:
        return np.asarray(self.load).shape[0]


def _step(inst: BalanceInstance, solve_cfg: SolveConfig,
          exec_cfg: ExecConfig, warm) -> StepOutcome:
    prev: Optional[LBResult] = warm if isinstance(warm, LBResult) else None
    res = balance_placement(
        inst.load, inst.n_targets, inst.current, cap=inst.cap,
        eps_frac=inst.eps_frac, pop_k=solve_cfg.k, seed=solve_cfg.seed,
        backend=exec_cfg.backend, engine=exec_cfg.engine,
        solver_kw=exec_cfg.solver_dict() or None,
        warm=prev, shard_ids=inst.ids)
    return StepOutcome(
        alloc=res.placement,
        metrics={k: v for k, v in res.extra.items()
                 if k not in ("pop_state", "full_state")},
        warm_state=res,
        backend=res.extra.get("backend"),
        engine=res.extra.get("engine"),
        plan_cache=res.extra.get("plan_cache", "miss"),
        warm_fraction=res.extra.get("warm_fraction"),
        solve_time_s=res.solve_time_s,
        iterations=int(res.extra.get("iterations", 0)),
        # the k that ACTUALLY ran (balance_placement's k_eff rule, or the
        # k=1 full fallback) — reported, not re-derived
        k=int(res.extra.get("k", 1)), raw=res)


SPEC = register(DomainSpec(
    name="load_balance",
    instance_types=(BalanceInstance,),
    describe="E-Store shard placement MILP (shards onto server groups)",
    step_override=_step,
    default_solve=SolveConfig(k=4),
    default_exec=ExecConfig(),
))
