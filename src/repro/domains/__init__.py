"""Declarative POP domain registry — one public onboarding path for every
scenario.

A domain is a :class:`DomainSpec` (``base.py``): an entity model, an LP
builder in operator form, warm-start layout, reduce/rounding hooks — data,
not a subclass.  Register it (``register``) and
``repro.service.PopService`` sessions drive it through the generic
``plan -> build -> solve -> reduce`` pipeline with zero domain branches in
``core/``.

Importing this package registers the built-in paper domains plus the MoE
placement scenario:

====================  =====================================================
``gavel``             max-min fair cluster scheduling (§3.1)
``traffic``           WAN traffic engineering (§3.2)
``load_balance``      E-Store shard/query load balancing (§3.3)
``moe_placement``     MoE expert placement (the §3.3 MILP re-targeted at
                      an expert fleet; onboarded through the registry
                      alone — the template for new scenarios)
====================  =====================================================
"""

from .base import DomainSpec, SpecProblem, StepOutcome
from .registry import get, names, register, spec_for

# built-in domains self-register on import
from . import gavel           # noqa: F401  (registers "gavel")
from . import traffic         # noqa: F401  (registers "traffic")
from . import load_balance    # noqa: F401  (registers "load_balance")
from . import moe_placement   # noqa: F401  (registers "moe_placement")

from .gavel import GavelInstance
from .load_balance import BalanceInstance
from .moe_placement import (MoEPlacementInstance, greedy_placement,
                            make_placement_instance, place_experts)

__all__ = [
    "DomainSpec", "SpecProblem", "StepOutcome",
    "register", "get", "names", "spec_for",
    "GavelInstance", "BalanceInstance", "MoEPlacementInstance",
    "make_placement_instance", "place_experts", "greedy_placement",
]
