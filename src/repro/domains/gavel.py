"""Gavel cluster scheduling as a registered domain (paper §3.1).

The LP/entity model lives in ``problems/cluster_scheduling.py``
(:class:`GavelProblem` — jobs are entities, combos the variables); this
module is the declarative registration that lets the scheduler enter
through ``PopService.session(...).step(...)`` like every other scenario.

A step's instance is a :class:`GavelInstance`: the measured workload
(throughputs, priorities, worker counts) plus the stable job ids that let
warm starts survive job churn between scheduling rounds.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.config import ExecConfig, SolveConfig
from ..problems.cluster_scheduling import ClusterWorkload, GavelProblem
from .base import DomainSpec
from .registry import register


@dataclasses.dataclass
class GavelInstance:
    """One scheduling round's input: the fleet as measured right now."""

    wl: ClusterWorkload
    space_sharing: bool = False
    # stable external job ids (None = positional): what warm-start
    # remapping matches on when jobs are submitted/removed between rounds
    job_ids: Optional[np.ndarray] = None

    @property
    def n_jobs(self) -> int:
        return self.wl.T.shape[0]


def _problem(inst: GavelInstance) -> GavelProblem:
    return GavelProblem(inst.wl, space_sharing=inst.space_sharing)


def _evaluate(inst: GavelInstance, rho: np.ndarray) -> dict:
    rho = np.atleast_1d(rho)
    return {
        "mean_norm_throughput": float(rho.mean()),
        "min_norm_throughput": float(rho.min()),
        "p10_norm_throughput": float(np.percentile(rho, 10)),
    }


SPEC = register(DomainSpec(
    name="gavel",
    instance_types=(GavelInstance,),
    describe="max-min fair cluster scheduling (jobs onto accelerator types)",
    problem=_problem,
    entity_ids=lambda inst: inst.job_ids,
    evaluate=_evaluate,
    # the SLO tuner's quality scalar (repro.tuning): the paper's headline
    # objective for this domain
    quality=lambda m: m["mean_norm_throughput"],
    # the scheduler's historical operating point: stratified splits, POP
    # only once the fleet has >= 8 jobs per sub-problem
    default_solve=SolveConfig(k=8, strategy="stratified", min_per_sub=8),
    default_exec=ExecConfig(solver_kw=dict(
        max_iters=20_000, tol_primal=1e-4, tol_gap=1e-4, equilibrate=True)),
))
