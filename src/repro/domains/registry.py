"""The domain registry: name -> :class:`~repro.domains.base.DomainSpec`.

One flat dict plus lookup helpers.  Built-in paper domains self-register
at ``repro.domains`` import time; external code registers its own spec the
same way:

    from repro.domains import DomainSpec, register

    register(DomainSpec(name="my_domain", instance_types=(MyInstance,),
                        n_entities=..., entity_attrs=..., build_sub=...,
                        K_mv=..., KT_mv=..., extract=...))

after which ``PopService.session(tenant, MyInstance(...))`` just works —
the service infers the domain from the instance type (:func:`spec_for`),
or takes an explicit ``domain="my_domain"``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from .base import DomainSpec

_REGISTRY: Dict[str, DomainSpec] = {}


def register(spec: DomainSpec, *, replace: bool = False) -> DomainSpec:
    """Add ``spec`` under ``spec.name``.  Re-registering an existing name
    is an error unless ``replace=True`` (guards against two modules
    silently fighting over a name)."""
    if not replace and spec.name in _REGISTRY:
        raise ValueError(f"domain {spec.name!r} is already registered "
                         "(pass replace=True to override)")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> DomainSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown domain {name!r}; registered: "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> tuple:
    return tuple(sorted(_REGISTRY))


def spec_for(instance: Any) -> Optional[DomainSpec]:
    """Infer the domain of ``instance`` from registered ``instance_types``
    (most-derived match wins; None when no registered type matches)."""
    best: Optional[DomainSpec] = None
    best_depth = -1
    for spec in _REGISTRY.values():
        for t in spec.instance_types:
            if isinstance(instance, t):
                depth = len(type(instance).__mro__) - len(t.__mro__)
                # prefer the registration closest to the concrete type
                if best is None or depth < best_depth:
                    best, best_depth = spec, depth
    return best
