"""MoE expert placement: the fourth scenario, onboarded through the
domain registry ALONE — no :class:`~repro.core.pop.POPProblem` subclass,
no bespoke pipeline; just the declarative hooks below driving the generic
``plan -> build -> solve -> reduce`` stages.

Experts are entities, devices are resources.  The serving fleet is
OVERLOADED (routed gate load exceeds aggregate device compute — the hot
phase an MoE placer actually gets called in), so the objective is the
paper's extensive kind: place experts onto devices to maximise the gate
load actually SERVED under per-device compute and memory caps, with a
small migration penalty keeping placements sticky (expert weights are
large; migrations stall serving):

    maximize   sum_{e,d} (load_e - lam * m_e * [d != cur_e]) x_{e,d}
    s.t.       sum_e load_e x_{e,d} <= C_d      ∀ devices d  (compute)
               sum_e m_e    x_{e,d} <= M_d     ∀ devices d  (memory)
               sum_d x_{e,d} <= 1              ∀ experts e  (served once)
               0 <= x <= 1    (+ rounding & greedy repair)

POP split (the paper's recipe, same shape as traffic §3.2): EXPERTS are
partitioned into k load-stratified subsets; every sub-problem keeps ALL
devices with a 1/k slice of the compute and memory caps, so sub-feasible
solutions sum to a globally feasible one.  The demand vector comes from
the router's gate statistics (:func:`repro.models.moe.expert_gate_load`).

The constraint operator is the same dense [n, D] block as load balancing
(§3.3), so the domain reuses those matvecs verbatim — same function
identity, same jitted solver caches.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.config import ExecConfig, SolveConfig
from ..core.pdhg import OperatorLP
from ..core.plan import SubLayout
from ..problems.load_balancing import _k_mv, _kt_mv
from .base import DomainSpec
from .registry import register

import jax.numpy as jnp


@dataclasses.dataclass
class MoEPlacementInstance:
    """One placement tick: the expert fleet as routed right now."""

    load: np.ndarray                      # [E] routing load (gate stats)
    mem: np.ndarray                       # [E] expert weight memory
    current: np.ndarray                   # [E] current device of each expert
    cap: np.ndarray                       # [D] device memory capacity
    compute: np.ndarray                   # [D] device compute capacity (load units)
    move_penalty: float = 0.05            # lam: served-load cost per moved mem unit
    # stable expert ids (None = positional): lets warm starts survive
    # experts being added/retired between ticks
    ids: Optional[np.ndarray] = None

    @property
    def n_experts(self) -> int:
        return self.load.shape[0]

    @property
    def n_devices(self) -> int:
        return self.cap.shape[0]


def make_placement_instance(n_experts: int, n_devices: int, *,
                            skew: float = 1.2, overload: float = 1.25,
                            seed: int = 0) -> MoEPlacementInstance:
    """Synthetic instance: Zipf-ish gate loads (a few hot experts — the
    usual router pathology), near-uniform expert memory, a load-oblivious
    current placement, and aggregate compute ``1/overload`` of the routed
    load (the overloaded phase a placer is called in)."""
    rng = np.random.default_rng(seed)
    load = np.minimum(rng.zipf(skew + 1.0, n_experts), 50.0).astype(np.float64)
    load += rng.uniform(0, 1, n_experts)
    mem = rng.uniform(0.8, 1.2, n_experts)
    current = rng.permutation(n_experts) % n_devices
    cap = np.full(n_devices, 2.0 * mem.sum() / n_devices)
    compute = np.full(n_devices, load.sum() / overload / n_devices)
    return MoEPlacementInstance(load=load, mem=mem, current=current,
                                cap=cap, compute=compute)


# ---------------------------------------------------------------------------
# declarative hooks
# ---------------------------------------------------------------------------

def _entity_attrs(inst: MoEPlacementInstance) -> np.ndarray:
    return np.stack([inst.load, inst.mem], axis=1)


def _build_sub(inst: MoEPlacementInstance, idx_row: np.ndarray, frac: float,
               scale: Optional[np.ndarray] = None) -> OperatorLP:
    """Sub-LP over expert subset ``idx_row`` (-1 padded): all D devices at
    a 1/k slice of the compute/memory caps — sub caps sum exactly to the
    full-problem caps, so sub-feasible implies globally feasible."""
    D = inst.n_devices
    n_pad = idx_row.shape[0]
    valid = idx_row >= 0
    g = np.maximum(idx_row, 0)
    load = np.where(valid, inst.load[g], 0.0)
    if scale is not None:                      # §4.3 replication scales demand
        load = load * np.asarray(scale, np.float64)
    mem = np.where(valid, inst.mem[g], 0.0)

    # value of serving expert e on device d: its load, minus the sticky
    # migration penalty off its current device (minimize -> c = -value)
    value = np.broadcast_to(load[:, None], (n_pad, D)).copy()
    penalty = inst.move_penalty * mem
    value -= penalty[:, None]
    value[np.flatnonzero(valid), inst.current[g[valid]]] += penalty[valid]
    value[~valid] = 0.0

    q = np.concatenate([
        inst.compute * frac,                   # load served <= compute/k
        np.zeros(D),                           # (-load <= 0: inactive row
                                               #  of the shared operator)
        inst.cap * frac,                       # mem <= cap/k
        np.where(valid, 1.0, 0.0),             # served at most once
    ])
    ineq = np.ones(q.shape[0], bool)           # ALL rows are <=
    u = np.zeros((n_pad, D))
    u[valid] = 1.0
    return OperatorLP(
        c=jnp.asarray(-value.reshape(-1), jnp.float32),
        q=jnp.asarray(q, jnp.float32),
        l=jnp.zeros(n_pad * D, jnp.float32),
        u=jnp.asarray(u.reshape(-1), jnp.float32),
        ineq_mask=jnp.asarray(ineq),
        data=(jnp.asarray(load, jnp.float32), jnp.asarray(mem, jnp.float32),
              jnp.asarray(-value, jnp.float32)),
    )


def _sub_layout(inst: MoEPlacementInstance, n_slots: int) -> SubLayout:
    """Warm-start remap layout: slot ``s`` owns its distribution row
    x[s, :] and its served-once dual row; the 3D per-device rows are
    lane-global."""
    D = inst.n_devices
    return SubLayout(
        x_slot=np.arange(n_slots)[:, None] * D + np.arange(D)[None, :],
        y_slot=(3 * D + np.arange(n_slots))[:, None],
        x_global=np.empty(0, np.int64),
        y_global=np.arange(3 * D))


def _extract(inst: MoEPlacementInstance, op: OperatorLP, x: np.ndarray,
             idx_row: np.ndarray) -> np.ndarray:
    D = inst.n_devices
    return x[: idx_row.shape[0] * D].reshape(-1, D)


def _round(inst: MoEPlacementInstance, r: np.ndarray) -> np.ndarray:
    """Round the coalesced [E, D] distribution to a placement: argmax with
    a sticky tie bias (experts the LP left unserved stay where they are —
    their load is queued, not their weights), then greedily repair memory
    caps and shift load from saturated to starved devices while it
    increases the served total."""
    E, D = inst.n_experts, inst.n_devices
    r = np.asarray(r)[:E]
    pick = r.argmax(axis=1)
    best = r[np.arange(E), pick]
    cur = r[np.arange(E), inst.current]
    keep = (cur >= best - 1e-3) | (best < 1e-6)
    pick = np.where(keep, inst.current, pick)

    load = np.zeros(D)
    mem_u = np.zeros(D)
    np.add.at(load, pick, inst.load)
    np.add.at(mem_u, pick, inst.mem)

    # memory pass: shed from over-cap devices to the emptiest that fits
    for _ in range(2 * E):
        over = int(np.argmax(mem_u - inst.cap))
        if mem_u[over] <= inst.cap[over]:
            break
        members = np.flatnonzero(pick == over)
        if members.size == 0:
            break
        dest = int(np.argmin(mem_u / inst.cap))
        fits = inst.mem[members] <= inst.cap[dest] - mem_u[dest]
        if dest == over or not fits.any():
            break
        e = members[np.flatnonzero(fits)[0]]
        pick[e] = dest
        load[over] -= inst.load[e]; load[dest] += inst.load[e]
        mem_u[over] -= inst.mem[e]; mem_u[dest] += inst.mem[e]

    # served pass: move load from saturated devices into starved compute
    # while the move strictly increases the served total
    for _ in range(4 * E):
        surplus = load - inst.compute
        over = int(np.argmax(surplus))
        under = int(np.argmin(surplus))
        if surplus[over] <= 0 or surplus[under] >= 0:
            break
        members = np.flatnonzero(pick == over)
        if members.size == 0:
            break
        deficit = -surplus[under]
        gain = (np.minimum(inst.load[members], deficit)
                - np.maximum(inst.load[members] - surplus[over], 0.0))
        fits = mem_u[under] + inst.mem[members] <= inst.cap[under]
        gain = np.where(fits, gain, -np.inf)
        best_i = int(np.argmax(gain))
        if gain[best_i] <= 1e-9:
            break
        e = members[best_i]
        pick[e] = under
        load[over] -= inst.load[e]; load[under] += inst.load[e]
        mem_u[over] -= inst.mem[e]; mem_u[under] += inst.mem[e]
    return pick


def _evaluate(inst: MoEPlacementInstance, placement: np.ndarray) -> dict:
    placement = np.asarray(placement, np.int64)
    moved = placement != inst.current
    load = np.zeros(inst.n_devices)
    mem_u = np.zeros(inst.n_devices)
    np.add.at(load, placement, inst.load)
    np.add.at(mem_u, placement, inst.mem)
    served = float(np.minimum(load, inst.compute).sum())
    movement = float(inst.mem[moved].sum())
    return {
        "served": served,
        "served_fraction": served / float(inst.load.sum()),
        "movement": movement,
        "n_moved": int(moved.sum()),
        "compute_util": served / float(inst.compute.sum()),
        "mem_feasible": bool((mem_u <= inst.cap * 1.001).all()),
        # the bench/acceptance objective: served gate load net of the
        # sticky migration penalty (maximise)
        "objective": served - inst.move_penalty * movement,
    }


SPEC = register(DomainSpec(
    name="moe_placement",
    instance_types=(MoEPlacementInstance,),
    describe="MoE expert placement (experts onto devices: maximise served "
             "gate load under compute + memory caps)",
    n_entities=lambda inst: inst.n_experts,
    entity_attrs=_entity_attrs,
    entity_scores=lambda inst: inst.load,
    build_sub=_build_sub,
    K_mv=_k_mv,                  # the §3.3 dense-block operator, verbatim —
    KT_mv=_kt_mv,                # same function identity = shared jit caches
    sub_layout=_sub_layout,
    extract=_extract,
    entity_ids=lambda inst: inst.ids,
    round=_round,
    evaluate=_evaluate,
    # the SLO tuner's quality scalar (repro.tuning): served gate load —
    # strictly positive, unlike the movement-penalized objective, so
    # relative quality ratios stay meaningful
    quality=lambda m: m["served"],
    # degradation-ladder fallback (defined below, resolved at call time)
    greedy=lambda inst: greedy_placement(inst),
    default_solve=SolveConfig(k=4, strategy="stratified", min_per_sub=8),
    default_exec=ExecConfig(solver_kw=dict(
        max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)),
))


# ---------------------------------------------------------------------------
# conveniences: one-shot placement + greedy baseline
# ---------------------------------------------------------------------------

def place_experts(inst: MoEPlacementInstance, *,
                  solve_cfg: Optional[SolveConfig] = None,
                  exec_cfg: Optional[ExecConfig] = None,
                  warm=None):
    """One-shot POP placement through a throwaway service session (the
    one-door path: the session owns the k dispatch, rounding and
    observability; used by ``models.moe.plan_expert_placement`` and the
    bench).  ``warm`` seeds the session from a previous call's result.
    Returns ``(placement, POPResult-or-FullResult, metrics)``."""
    from ..service import PopService     # lazy: service imports domains

    session = PopService().session(
        "domains.place_experts", inst,
        solve=solve_cfg or SPEC.default_solve,
        exec=exec_cfg or SPEC.default_exec)
    if warm is not None:
        session.seed(warm)
    out = session.step(inst)
    return out.alloc, out.raw, out.metrics


def greedy_placement(inst: MoEPlacementInstance) -> np.ndarray:
    """Movement-oblivious greedy baseline: experts by load descending,
    each onto the least-loaded device with memory headroom."""
    order = np.argsort(-inst.load)
    pick = np.zeros(inst.n_experts, np.int64)
    load = np.zeros(inst.n_devices)
    mem_u = np.zeros(inst.n_devices)
    for e in order:
        ok = mem_u + inst.mem[e] <= inst.cap
        cand = np.where(ok, load, np.inf)
        d = int(np.argmin(cand))
        pick[e] = d
        load[d] += inst.load[e]
        mem_u[d] += inst.mem[e]
    return pick
