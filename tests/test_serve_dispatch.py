"""Fleet-scale serving core: the cross-tenant micro-batching dispatcher,
the host-memory paging tier, and the shared-state concurrency fixes.

The acceptance claims pinned here:

- concurrent dispatcher steps are BIT-IDENTICAL per tenant to the
  synchronous single-tenant path (lane independence in ``solve_stacked``
  + the replica-lane padding precedent make sharing invisible);
- no stats are lost under concurrent steps (the service lock sweep);
- a mid-traffic ``checkpoint()`` restores cleanly;
- 1k create/end session cycles hold memory flat (no leaked sessions,
  LRU slots or paged blobs);
- evicted tenants restore transparently warm on ``session()`` re-entry;
- the deadline ladder's rate caches are bounded LRUs.
"""

import gc
import threading
import time
import weakref

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExecConfig, SolveConfig
from repro.core import backends as backends_mod
from repro.core import pdhg
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import (DispatchConfig, MicroBatchDispatcher, PopService,
                           _BoundedLRU)

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)
SOLVE = SolveConfig(k=3)
EXEC = ExecConfig(solver_kw=KW)


def _traffic(n=24, seed=0, scale=1.0):
    topo = make_topology(20, 40, seed=seed)
    pairs, dem = make_demands(topo, n, seed=seed)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
    return TrafficProblem(topo, pairs, dem * scale, pe)


def _sync_reference(seeds, scales):
    """Per-tenant allocations from isolated synchronous services."""
    ref = {}
    for seed in seeds:
        svc = PopService()
        sess = svc.session(f"t{seed}", _traffic(seed=seed),
                           solve=SOLVE, exec=EXEC)
        ref[seed] = [np.asarray(sess.step(_traffic(seed=seed,
                                                   scale=sc)).alloc)
                     for sc in scales]
    return ref


# ---------------------------------------------------------------------------
# the tentpole claim: coalesced concurrent solves are bit-identical
# ---------------------------------------------------------------------------

class TestDispatchBitIdentity:
    def test_concurrent_steps_match_sync_bit_for_bit(self):
        seeds, scales = range(4), [1.0, 1.03, 1.07]
        ref = _sync_reference(seeds, scales)
        svc = PopService(dispatch=True)
        sessions = {s: svc.session(f"t{s}", _traffic(seed=s),
                                   solve=SOLVE, exec=EXEC) for s in seeds}
        try:
            for rnd, sc in enumerate(scales):
                futs = {s: sessions[s].step_async(
                            _traffic(seed=s, scale=sc)) for s in seeds}
                for s, f in futs.items():
                    a = f.result(timeout=300)
                    assert a.status == "ok"
                    assert np.array_equal(np.asarray(a.alloc), ref[s][rnd]), \
                        f"tenant {s} round {rnd} diverged from sync path"
            d = svc.dispatcher.stats()
            # warm chains stayed per-tenant: round 2+ are plan hits
            assert all(sessions[s].last.plan_cache == "hit" for s in seeds)
            assert d["requests"] == len(list(seeds)) * len(scales)
        finally:
            svc.close()

    def test_held_dispatcher_coalesces_deterministically(self):
        # queue 4 compatible tenants while the dispatcher gate is held:
        # release must produce ONE coalesced launch serving all 4
        seeds = range(4)
        svc = PopService(dispatch=True)
        sessions = {s: svc.session(f"t{s}", _traffic(seed=s),
                                   solve=SOLVE, exec=EXEC) for s in seeds}
        try:
            for s in seeds:                      # warm + compile, solo
                sessions[s].step(_traffic(seed=s))
            before = svc.dispatcher.stats()
            with svc.dispatcher.hold():
                futs = [sessions[s].step_async(_traffic(seed=s, scale=1.05))
                        for s in seeds]
                time.sleep(0.5)                  # let all 4 enqueue
            for f in futs:
                assert f.result(timeout=300).status == "ok"
            after = svc.dispatcher.stats()
            assert after["coalesced_requests"] - before["coalesced_requests"] == 4
            assert after["launches"] - before["launches"] == 1
            assert after["max_group"] >= 4
            assert after["batching_ratio"] > 1.0
        finally:
            svc.close()

    def test_no_stats_lost_under_concurrency(self):
        seeds, rounds = range(6), 3
        svc = PopService(dispatch=True)
        sessions = {s: svc.session(f"t{s}", _traffic(seed=s),
                                   solve=SOLVE, exec=EXEC) for s in seeds}
        try:
            futs = []
            for rnd in range(rounds):
                futs += [sessions[s].step_async(
                    _traffic(seed=s, scale=1.0 + 0.02 * rnd)) for s in seeds]
            allocs = [f.result(timeout=300) for f in futs]
            st = svc.stats()
            assert st["steps"] == len(list(seeds)) * rounds == len(allocs)
            assert (st["plan_hits"] + st["plan_repairs"] + st["plan_misses"]
                    + st["full_solves"] + st["fallback_steps"]) == st["steps"]
            per_sess = sum(sessions[s].stats["steps"] for s in seeds)
            assert per_sess == st["steps"]
        finally:
            svc.close()

    def test_checkpoint_mid_traffic_restores_cleanly(self):
        seeds = range(4)
        svc = PopService(dispatch=True)
        sessions = {s: svc.session(f"t{s}", _traffic(seed=s),
                                   solve=SOLVE, exec=EXEC) for s in seeds}
        try:
            for s in seeds:
                sessions[s].step(_traffic(seed=s))
            stop = threading.Event()
            blobs = []

            def snapshotter():
                while not stop.is_set():
                    blobs.append(svc.checkpoint())

            t = threading.Thread(target=snapshotter)
            t.start()
            try:
                futs = [sessions[s].step_async(_traffic(seed=s, scale=1.05))
                        for s in seeds] + \
                       [sessions[s].step_async(_traffic(seed=s, scale=1.1))
                        for s in seeds]
                for f in futs:
                    assert f.result(timeout=300).status == "ok"
            finally:
                stop.set()
                t.join(timeout=60)
            assert blobs
            # every snapshot taken mid-traffic restores without errors
            restored = PopService()
            rep = restored.restore(blobs[-1])
            assert not rep["errors"]
            assert sorted(rep["restored"]) == [f"t{s}" for s in seeds]
            a = restored.session("t0", domain="traffic").step(
                _traffic(seed=0, scale=1.06))
            assert a.plan_cache == "hit" and a.status == "ok"
        finally:
            svc.close()


# ---------------------------------------------------------------------------
# the paging tier
# ---------------------------------------------------------------------------

class TestPaging:
    def test_eviction_and_transparent_warm_reentry(self):
        svc = PopService(max_resident=2)
        for s in range(5):
            svc.session(f"t{s}", _traffic(seed=s), solve=SOLVE,
                        exec=EXEC).step(_traffic(seed=s))
        st = svc.stats()
        assert st["resident_sessions"] <= 2
        assert st["paged_tenants"] == 3 and st["paged_bytes"] > 0
        assert st["n_sessions"] == 5
        # re-entry by name restores the evicted tenant's warm state: the
        # next step is a verbatim plan hit with a fully-warm start
        a = svc.session("t0", domain="traffic").step(
            _traffic(seed=0, scale=1.02))
        assert a.plan_cache == "hit" and a.warm_fraction == 1.0
        st = svc.stats()
        assert st["paged_in"] >= 1 and st["session_reentries"] >= 1
        assert st["page_restore_failures"] == 0

    def test_stale_handle_step_reattaches_warm(self):
        svc = PopService(max_resident=1)
        handles = {}
        for s in range(3):
            handles[s] = svc.session(f"t{s}", _traffic(seed=s),
                                     solve=SOLVE, exec=EXEC)
            handles[s].step(_traffic(seed=s))
        # t0 and t1 are paged out and their handle objects stripped; a
        # step on the old handle must reload the blob, not start cold
        a = handles[0].step(_traffic(seed=0, scale=1.03))
        assert a.plan_cache == "hit" and a.warm_fraction == 1.0
        assert svc.stats()["n_sessions"] == 3

    def test_end_session_clears_both_tiers_memory_flat(self):
        svc = PopService(max_resident=2)
        # a couple of REAL stepped sessions so blobs exist, then churn
        for s in range(4):
            svc.session(f"warm{s}", _traffic(seed=s), solve=SOLVE,
                        exec=EXEC).step(_traffic(seed=s))
        refs = []
        for i in range(1000):
            sess = svc.session(f"churn{i}", domain="traffic",
                               solve=SOLVE, exec=EXEC)
            refs.append(weakref.ref(sess))
            del sess
            svc.end_session(f"churn{i}")
        for s in range(4):
            svc.end_session(f"warm{s}")
        gc.collect()
        assert not svc._sessions and not svc._lru
        assert len(svc._pager) == 0 and svc._pager.nbytes() == 0
        assert svc.stats()["n_sessions"] == 0
        alive = sum(r() is not None for r in refs)
        assert alive == 0, f"{alive} ended sessions still referenced"

    def test_corrupt_blob_degrades_to_cold_session(self):
        svc = PopService(max_resident=1)
        for s in range(2):
            svc.session(f"t{s}", _traffic(seed=s), solve=SOLVE,
                        exec=EXEC).step(_traffic(seed=s))
        assert "t0" in svc._pager
        blob = svc._pager.peek_packed("t0")
        svc._pager._blobs["t0"] = blob[:-8] + b"\x00" * 8    # corrupt it
        sess = svc.session("t0", domain="traffic", solve=SOLVE, exec=EXEC)
        a = sess.step(_traffic(seed=0, scale=1.01))
        assert a.status == "ok" and a.plan_cache == "miss"   # cold restart
        assert svc.stats()["page_restore_failures"] >= 1


# ---------------------------------------------------------------------------
# bounded rate caches
# ---------------------------------------------------------------------------

class TestBoundedRateCaches:
    def test_bounded_lru_unit(self):
        lru = _BoundedLRU(3)
        for i in range(5):
            lru[i] = i * 10
        assert len(lru) == 3 and lru.evictions == 2
        assert list(lru) == [2, 3, 4]
        assert lru.get(2) == 20                  # refreshes recency
        lru[5] = 50
        assert list(lru) == [4, 2, 5] and lru.evictions == 3
        assert lru.get(3) is None

    def test_service_rate_caches_bounded_and_reported(self):
        svc = PopService(rate_cache_size=2)
        for s in range(4):
            sess = svc.session(f"t{s}", _traffic(n=20 + s, seed=s),
                               solve=SOLVE, exec=EXEC)
            sess.step(_traffic(n=20 + s, seed=s))
        assert len(svc._rates) <= 2 and len(svc._overheads) <= 2
        st = svc.stats()
        assert st["rate_evictions"] >= 4
        assert st["rate_keys"] <= 4


# ---------------------------------------------------------------------------
# the coalescing substrate (unit level)
# ---------------------------------------------------------------------------

class TestCoalesceSubstrate:
    def test_concat_split_roundtrip(self):
        import jax

        from repro.core import pop as pop_mod
        # same layout (the coalesce-key precondition), different content
        probs = [_traffic(n=24, seed=s) for s in range(3)]
        stacks = [pop_mod.build(p, pop_mod.plan(p, 3, strategy="stratified"))
                  for p in probs]
        merged = pdhg.concat_stacks(stacks)
        assert backends_mod.batch_size(merged) == sum(
            backends_mod.batch_size(s) for s in stacks)
        sizes = [backends_mod.batch_size(s) for s in stacks]
        parts = backends_mod.split_result(merged, sizes)
        for part, stack in zip(parts, stacks):
            # the NON-structured payload round-trips bit-for-bit; the
            # structured half is padded to the group-max ELL widths, so
            # only its dense realisation is comparable
            flat_a = jax.tree.leaves(part._replace(structured=None))
            flat_b = jax.tree.leaves(stack._replace(structured=None))
            assert all(np.array_equal(x, y)
                       for x, y in zip(flat_a, flat_b))

    def test_concat_pads_mismatched_ell_widths(self):
        import jax
        # seeds 0 and 2: identical bare layouts (the coalesce-key match),
        # different max-row ELL widths (topology sparsity) — the case
        # concat_stacks must pad to the group maximum
        ops = []
        for seed in (0, 2):
            p = _traffic(n=24, seed=seed)
            ops.append(jax.tree.map(lambda a: jnp.asarray(a)[None],
                                    p.build_full()))
        a_s, b_s = ops[0].structured, ops[1].structured
        assert a_s is not None and b_s is not None
        assert any(x is not None and y is not None and x.shape != y.shape
                   for x, y in zip(a_s, b_s)), "fixture lost its mismatch"
        merged = pdhg.concat_stacks(ops)
        assert backends_mod.batch_size(merged) == 2
        for v, x, y in zip(merged.structured, a_s, b_s):
            if v is None:
                continue
            for d in range(1, v.ndim):
                assert v.shape[d] == max(x.shape[d], y.shape[d])

    def test_coalesce_key_none_for_streaming_engine(self):
        prob = _traffic()
        import jax
        op = jax.tree.map(lambda a: jnp.asarray(a)[None], prob.build_full())
        kw = (("max_iters", 100),)
        base = backends_mod.coalesce_key(
            op, prob.K_mv, prob.KT_mv, "vmap",
            pdhg.matvec_engine(prob.K_mv, prob.KT_mv), dict(kw), {})
        assert base is not None
        streaming = pdhg.StepEngine("fused_structured_full",
                                    pdhg.dense_K_mv, pdhg.dense_KT_mv,
                                    pdhg.dense_K_mv, pdhg.dense_KT_mv)
        assert backends_mod.coalesce_key(
            op, prob.K_mv, prob.KT_mv, "vmap", streaming,
            dict(kw), {}) is None    # single-lane streaming: never share

    def test_coalesce_key_equal_for_compatible_tenants(self):
        import jax
        keys = []
        for seed in range(2):
            p = _traffic(seed=seed)
            op = jax.tree.map(lambda a: jnp.asarray(a)[None], p.build_full())
            keys.append(backends_mod.coalesce_key(
                op, p.K_mv, p.KT_mv, "vmap",
                pdhg.matvec_engine(p.K_mv, p.KT_mv),
                dict(max_iters=100), {}))
        assert keys[0] is not None and keys[0] == keys[1]

    def test_pow2_padding(self):
        assert backends_mod.next_pow2(1) == 1
        assert backends_mod.next_pow2(3) == 4
        assert backends_mod.next_pow2(4) == 4
        assert backends_mod.next_pow2(9) == 16
