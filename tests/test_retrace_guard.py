"""Runtime sanitizers: steady-state ``PopSession.step()`` must run the
map-step backends with ZERO retraces and ZERO host syncs.  The guards
themselves are unit-tested first (they must actually trip), then armed
over a 10-tick warm session including one churn repair — the acceptance
claim of the popcheck PR."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.runtime import (HostSyncError, RetraceError,
                                    host_sync_tripwire, retrace_guard,
                                    steady_state_guard)
from repro.core import ExecConfig, SolveConfig
from repro.domains import GavelInstance
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.service import PopService

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


# ---------------------------------------------------------------------------
# the guards must trip (a sanitizer that can't fire proves nothing)
# ---------------------------------------------------------------------------

class TestGuardsTrip:
    def test_retrace_guard_counts_fresh_compiles(self):
        with pytest.raises(RetraceError, match="compilation"):
            with retrace_guard(max_retraces=0):
                jax.jit(lambda x: x * 2.0)(jnp.arange(4.0))  # fresh compile

    def test_retrace_guard_budget_and_stats(self):
        with retrace_guard(max_retraces=1) as stats:
            jax.jit(lambda x: x * 3.0)(jnp.arange(4.0))
        assert stats.compiles == 1 and stats.compiled_names

    def test_retrace_guard_silent_on_cached_execution(self):
        fn = jax.jit(lambda x: x + 1.0)
        x = jnp.arange(8.0)
        fn(x).block_until_ready()                    # compile outside
        with retrace_guard(max_retraces=0) as stats:
            fn(x)                                    # cache hit
        assert stats.compiles == 0

    def test_tripwire_rejects_numpy_readback(self):
        x = jnp.arange(4.0)
        with pytest.raises(HostSyncError, match="np.asarray"):
            with host_sync_tripwire():
                np.asarray(x)

    def test_tripwire_rejects_block_and_get(self):
        x = jnp.arange(4.0)
        with pytest.raises(HostSyncError, match="block_until_ready"):
            with host_sync_tripwire():
                jax.block_until_ready(x)
        with pytest.raises(HostSyncError, match="device_get"):
            with host_sync_tripwire():
                jax.device_get(x)

    def test_tripwire_allows_pure_host_numpy(self):
        with host_sync_tripwire():
            out = np.asarray([1.0, 2.0]) + np.array(3.0)
        assert out.shape == (2,)

    def test_tripwire_restores_patches(self):
        orig_asarray = np.asarray
        with host_sync_tripwire():
            assert np.asarray is not orig_asarray
        assert np.asarray is orig_asarray
        np.asarray(jnp.arange(2.0))                  # fine again


# ---------------------------------------------------------------------------
# the acceptance claim: warm session ticks are retrace- and sync-free
# ---------------------------------------------------------------------------

class TestSteadyStateSession:
    def test_ten_warm_ticks_zero_retraces_zero_host_syncs(self):
        svc = PopService()
        sess = svc.session("fleet", domain="gavel",
                           solve=SolveConfig(k=2, strategy="stratified"),
                           exec=ExecConfig(solver_kw=KW))
        ids = np.arange(32)

        # warm-up covers every step TYPE once, outside the guard: the
        # cold first solve, a plan hit, and one churn repair (the masked
        # warm-start blend in backends._resolve_warm compiles its tiny
        # where/broadcast primitives the first time a partially-cold
        # lane mask appears — a one-time cost per shape, paid here)
        sess.step(GavelInstance(make_cluster_workload(32, seed=0),
                                job_ids=ids))
        sess.step(GavelInstance(make_cluster_workload(32, seed=1),
                                job_ids=ids))
        ids = np.concatenate([ids[4:], 100 + np.arange(4)])
        warm = sess.step(GavelInstance(make_cluster_workload(32, seed=2),
                                       job_ids=ids))
        assert warm.plan_cache == "repair"

        with steady_state_guard(max_retraces=0) as stats:
            for tick in range(3, 11):
                if tick == 7:
                    # a SECOND churn, inside the guard: 4 more jobs
                    # leave, 4 arrive — the plan repairs in place and,
                    # shapes being stable, compiles nothing
                    ids = np.concatenate([ids[4:], 200 + np.arange(4)])
                wl = make_cluster_workload(32, seed=tick)
                a = sess.step(GavelInstance(wl, job_ids=ids))
                if tick == 7:
                    assert a.plan_cache == "repair"
                else:
                    assert a.plan_cache == "hit"
                assert a.k == 2

        assert stats.compiles == 0, stats.compiled_names
        # the guard really covered the hot path: every tick went through
        # a wrapped MAP_BACKENDS entry at least once
        assert stats.hot_backend_calls >= 8


class TestSteadyStateDispatcher:
    """The serving dispatcher's steady state: a coalesced multi-tenant
    step must compile NOTHING and perform zero host syncs inside the map
    backends.  Power-of-two lane padding is what makes this assertable —
    every coalesced launch of the 4-tenant group lands on the same padded
    lane count, so the warm-up sweeps compile every shape the steady
    rounds will see."""

    def test_coalesced_multi_tenant_step_is_clean(self):
        import time

        from repro.problems.traffic_engineering import (TrafficProblem,
                                                        k_shortest_paths,
                                                        make_demands,
                                                        make_topology)

        def traffic(seed, scale=1.0):
            topo = make_topology(20, 40, seed=seed)
            pairs, dem = make_demands(topo, 24, seed=seed)
            pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10,
                                  seed=seed)
            return TrafficProblem(topo, pairs, dem * scale, pe)

        svc = PopService(dispatch=True)
        seeds = range(4)
        sessions = {s: svc.session(f"t{s}", traffic(s),
                                   solve=SolveConfig(k=2),
                                   exec=ExecConfig(solver_kw=KW))
                    for s in seeds}
        try:
            def sweep(scale):
                # the hold gate makes the group deterministic: all four
                # tenants' tickets queue, then dispatch as ONE launch
                with svc.dispatcher.hold():
                    futs = [sessions[s].step_async(traffic(s, scale))
                            for s in seeds]
                    time.sleep(0.5)
                return [f.result(timeout=300) for f in futs]

            # warm-up outside the guard: the cold coalesced launch, then
            # the warm-started (plan hit) coalesced launch — between them
            # every solver variant the steady rounds exercise
            sweep(1.0)
            sweep(1.02)

            with steady_state_guard(max_retraces=0) as stats:
                for rnd in range(3):
                    allocs = sweep(1.04 + 0.02 * rnd)
                    assert all(a.status == "ok" for a in allocs)
                    assert all(a.plan_cache == "hit" for a in allocs)

            assert stats.compiles == 0, stats.compiled_names
            assert stats.hot_backend_calls >= 3
            d = svc.dispatcher.stats()
            assert d["coalesced_launches"] >= 5
            assert d["batching_ratio"] > 1.0
        finally:
            svc.close()
