"""Runtime sanitizers: steady-state ``PopSession.step()`` must run the
map-step backends with ZERO retraces and ZERO host syncs.  The guards
themselves are unit-tested first (they must actually trip), then armed
over a 10-tick warm session including one churn repair — the acceptance
claim of the popcheck PR."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.runtime import (HostSyncError, RetraceError,
                                    host_sync_tripwire, retrace_guard,
                                    steady_state_guard)
from repro.core import ExecConfig, SolveConfig
from repro.domains import GavelInstance
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.service import PopService

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


# ---------------------------------------------------------------------------
# the guards must trip (a sanitizer that can't fire proves nothing)
# ---------------------------------------------------------------------------

class TestGuardsTrip:
    def test_retrace_guard_counts_fresh_compiles(self):
        with pytest.raises(RetraceError, match="compilation"):
            with retrace_guard(max_retraces=0):
                jax.jit(lambda x: x * 2.0)(jnp.arange(4.0))  # fresh compile

    def test_retrace_guard_budget_and_stats(self):
        with retrace_guard(max_retraces=1) as stats:
            jax.jit(lambda x: x * 3.0)(jnp.arange(4.0))
        assert stats.compiles == 1 and stats.compiled_names

    def test_retrace_guard_silent_on_cached_execution(self):
        fn = jax.jit(lambda x: x + 1.0)
        x = jnp.arange(8.0)
        fn(x).block_until_ready()                    # compile outside
        with retrace_guard(max_retraces=0) as stats:
            fn(x)                                    # cache hit
        assert stats.compiles == 0

    def test_tripwire_rejects_numpy_readback(self):
        x = jnp.arange(4.0)
        with pytest.raises(HostSyncError, match="np.asarray"):
            with host_sync_tripwire():
                np.asarray(x)

    def test_tripwire_rejects_block_and_get(self):
        x = jnp.arange(4.0)
        with pytest.raises(HostSyncError, match="block_until_ready"):
            with host_sync_tripwire():
                jax.block_until_ready(x)
        with pytest.raises(HostSyncError, match="device_get"):
            with host_sync_tripwire():
                jax.device_get(x)

    def test_tripwire_allows_pure_host_numpy(self):
        with host_sync_tripwire():
            out = np.asarray([1.0, 2.0]) + np.array(3.0)
        assert out.shape == (2,)

    def test_tripwire_restores_patches(self):
        orig_asarray = np.asarray
        with host_sync_tripwire():
            assert np.asarray is not orig_asarray
        assert np.asarray is orig_asarray
        np.asarray(jnp.arange(2.0))                  # fine again


# ---------------------------------------------------------------------------
# the acceptance claim: warm session ticks are retrace- and sync-free
# ---------------------------------------------------------------------------

class TestSteadyStateSession:
    def test_ten_warm_ticks_zero_retraces_zero_host_syncs(self):
        svc = PopService()
        sess = svc.session("fleet", domain="gavel",
                           solve=SolveConfig(k=2, strategy="stratified"),
                           exec=ExecConfig(solver_kw=KW))
        ids = np.arange(32)

        # warm-up covers every step TYPE once, outside the guard: the
        # cold first solve, a plan hit, and one churn repair (the masked
        # warm-start blend in backends._resolve_warm compiles its tiny
        # where/broadcast primitives the first time a partially-cold
        # lane mask appears — a one-time cost per shape, paid here)
        sess.step(GavelInstance(make_cluster_workload(32, seed=0),
                                job_ids=ids))
        sess.step(GavelInstance(make_cluster_workload(32, seed=1),
                                job_ids=ids))
        ids = np.concatenate([ids[4:], 100 + np.arange(4)])
        warm = sess.step(GavelInstance(make_cluster_workload(32, seed=2),
                                       job_ids=ids))
        assert warm.plan_cache == "repair"

        with steady_state_guard(max_retraces=0) as stats:
            for tick in range(3, 11):
                if tick == 7:
                    # a SECOND churn, inside the guard: 4 more jobs
                    # leave, 4 arrive — the plan repairs in place and,
                    # shapes being stable, compiles nothing
                    ids = np.concatenate([ids[4:], 200 + np.arange(4)])
                wl = make_cluster_workload(32, seed=tick)
                a = sess.step(GavelInstance(wl, job_ids=ids))
                if tick == 7:
                    assert a.plan_cache == "repair"
                else:
                    assert a.plan_cache == "hit"
                assert a.k == 2

        assert stats.compiles == 0, stats.compiled_names
        # the guard really covered the hot path: every tick went through
        # a wrapped MAP_BACKENDS entry at least once
        assert stats.hot_backend_calls >= 8
