"""Per-kernel allclose sweeps: Pallas (interpret mode on CPU) vs ref.py
pure-jnp oracles, across shapes and dtypes, plus hypothesis property tests.

``ops`` dispatch defaults to the XLA reference off-TPU (the fast path), so
these tests force the Pallas kernel bodies explicitly: interpret mode on
CPU, compiled on TPU."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

# force the real kernels: compiled Pallas on TPU, interpreter elsewhere
PALLAS = "pallas" if jax.default_backend() == "tpu" else "interpret"


SHAPES = [
    (1, 128, 128),     # single sub-problem, exactly one block
    (2, 256, 256),     # block-aligned
    (3, 300, 180),     # ragged (exercise padding)
    (4, 64, 512),      # wide
    (2, 512, 64),      # tall
    (8, 129, 257),     # off-by-one over block edges
]

DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape_kmn, dtype, seed=0):
    rng = np.random.default_rng(seed)
    k, M, N = shape_kmn
    A = jnp.asarray(rng.normal(size=(k, M, N)), dtype)
    x = jnp.asarray(rng.normal(size=(k, N)), dtype)
    y = jnp.asarray(rng.normal(size=(k, M)), dtype)
    return A, x, y


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bmatvec_matches_ref(shape, dtype):
    A, x, _ = _mk(shape, dtype)
    got = ops.bmatvec(A, x, backend=PALLAS)
    want = ref.bmatvec(A, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_bmatvec_t_matches_ref(shape, dtype):
    A, _, y = _mk(shape, dtype)
    got = ops.bmatvec_t(A, y, backend=PALLAS)
    want = ref.bmatvec_t(A, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32), **_tol(dtype))


def _primal_operands(shape, seed=1):
    rng = np.random.default_rng(seed)
    k, M, N = shape
    x = jnp.asarray(rng.normal(size=(k, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, N)), jnp.float32)
    l = jnp.asarray(rng.normal(size=(k, N)) - 2.0, jnp.float32)
    u = l + jnp.asarray(rng.uniform(0.5, 3.0, (k, N)), jnp.float32)
    tau = jnp.asarray(rng.uniform(0.01, 0.2, k), jnp.float32)
    kty = jnp.asarray(rng.normal(size=(k, N)), jnp.float32)
    return x, c, l, u, tau, kty


def _dual_operands(shape, seed=2):
    rng = np.random.default_rng(seed)
    k, M, N = shape
    y = jnp.asarray(rng.normal(size=(k, M)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(k, M)), jnp.float32)
    sigma = jnp.asarray(rng.uniform(0.01, 0.2, k), jnp.float32)
    mask = jnp.asarray(rng.random((k, M)) < 0.6)
    kxn = jnp.asarray(rng.normal(size=(k, M)), jnp.float32)
    kxp = jnp.asarray(rng.normal(size=(k, M)), jnp.float32)
    return y, q, sigma, mask, kxn, kxp


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_forward_step_matches_ref(shape):
    A, _, _ = _mk(shape, jnp.float32, seed=1)
    x, c, l, u, tau, kty = _primal_operands(shape)
    xn, kx = ops.fused_forward_step(A, x, c, l, u, tau, kty, backend=PALLAS)
    rn, rkx = ref.fused_forward_step(A, x, c, l, u, tau[:, None], kty)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(rn), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kx), np.asarray(rkx), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_backward_step_matches_ref(shape):
    A, _, _ = _mk(shape, jnp.float32, seed=2)
    y, q, sigma, mask, kxn, kxp = _dual_operands(shape)
    yn, kty = ops.fused_backward_step(A, y, q, sigma, mask, kxn, kxp,
                                      backend=PALLAS)
    rn, rkty = ref.fused_backward_step(A, y, q, sigma[:, None], mask, kxn, kxp)
    np.testing.assert_allclose(np.asarray(yn), np.asarray(rn), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kty), np.asarray(rkty), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# structured (two-bucket ELL gather/segment-reduce) kernels
# ---------------------------------------------------------------------------

# (k, M, N, density) — skewed shapes: one full row + one full column force
# the wide buckets, ragged sizes exercise the lane-axis padding
STRUCT_SHAPES = [
    (1, 64, 96, 0.3),
    (3, 45, 67, 0.25),
    (4, 130, 250, 0.05),
    (2, 256, 129, 0.1),
]


def _mk_structured(k, M, N, density, seed=0):
    from repro.core import pdhg
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(k, M, N)) * (rng.random((k, M, N)) < density)
    G[:, M // 2, :] = rng.normal(size=(k, N))     # a wide row
    G[:, :, N // 3] = rng.normal(size=(k, M))     # a wide column
    rows, cols = np.meshgrid(np.arange(M), np.arange(N), indexing="ij")
    structs = [pdhg.structured_from_coo(rows.ravel(), cols.ravel(),
                                        G[i].ravel(), M, N)
               for i in range(k)]
    s = jax.tree.map(lambda *xs: jnp.stack(xs), *structs)
    return s, G.astype(np.float32)


@pytest.mark.parametrize("shape", STRUCT_SHAPES)
def test_smatvec_matches_dense(shape):
    """Both gather layouts of the StructuredOperator encode the same K."""
    k, M, N, density = shape
    s, G = _mk_structured(k, M, N, density)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(k, N)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(k, M)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.smatvec(s, x)),
                               np.einsum("kmn,kn->km", G, np.asarray(x)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(ops.smatvec_t(s, y)),
                               np.einsum("kmn,km->kn", G, np.asarray(y)),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", STRUCT_SHAPES)
def test_structured_forward_step_matches_ref(shape):
    k, M, N, density = shape
    s, _ = _mk_structured(k, M, N, density)
    x, c, l, u, tau, kty = _primal_operands((k, M, N))
    xn, kx = ops.structured_forward_step(s, x, c, l, u, tau, kty,
                                         backend=PALLAS)
    rn, rkx = ref.structured_forward_step(s, x, c, l, u, tau[:, None], kty)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(rn), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kx), np.asarray(rkx), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", STRUCT_SHAPES)
def test_structured_backward_step_matches_ref(shape):
    k, M, N, density = shape
    s, _ = _mk_structured(k, M, N, density)
    y, q, sigma, mask, kxn, kxp = _dual_operands((k, M, N))
    yn, kty = ops.structured_backward_step(s, y, q, sigma, mask, kxn, kxp,
                                           backend=PALLAS)
    rn, rkty = ref.structured_backward_step(s, y, q, sigma[:, None], mask,
                                            kxn, kxp)
    np.testing.assert_allclose(np.asarray(yn), np.asarray(rn), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(kty), np.asarray(rkty), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 4),
    m=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_bmatvec_arbitrary_shapes(k, m, n, seed):
    """Padding logic must be exact for ANY shape (property: pad+slice == ref)."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(k, m, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.bmatvec(A, x, backend=PALLAS, block_m=128, block_n=128)),
        np.asarray(ref.bmatvec(A, x)), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fused_forward_respects_box(seed):
    """Property: the fused forward step's x_new ALWAYS lies inside [l, u]."""
    rng = np.random.default_rng(seed)
    k, M, N = 2, 160, 96
    A = jnp.asarray(rng.normal(size=(k, M, N)), jnp.float32)
    kty = jnp.asarray(rng.normal(size=(k, N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(k, N)) * 10, jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, N)), jnp.float32)
    l = jnp.asarray(rng.normal(size=(k, N)) - 1, jnp.float32)
    u = l + jnp.asarray(rng.uniform(0.0, 2.0, (k, N)), jnp.float32)
    tau = jnp.asarray(rng.uniform(0.001, 1.0, k), jnp.float32)
    xn, _ = ops.fused_forward_step(A, x, c, l, u, tau, kty, backend=PALLAS)
    assert bool(jnp.all(xn >= l - 1e-6) & jnp.all(xn <= u + 1e-6))


def test_block_size_sweep():
    """Results are block-size independent (tiling must not change math)."""
    rng = np.random.default_rng(3)
    A = jnp.asarray(rng.normal(size=(2, 384, 320)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 320)), jnp.float32)
    base = np.asarray(ref.bmatvec(A, x))
    for bm, bn in [(128, 128), (256, 128), (128, 256), (384, 320)]:
        got = np.asarray(ops.bmatvec(A, x, backend=PALLAS, block_m=bm, block_n=bn))
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)
