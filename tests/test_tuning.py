"""The SLO auto-tuner (repro.tuning + the service integration).

Pins the planner against the committed fixture profile (cluster
scheduling's flat curve picks a LARGE k, traffic's steep curve a SMALL k
at the same 2% SLO — the paper's point that no static default serves
both), the artifact seal (version/digest/platform gates), replication
escalation before quality surrender, the online refiner's retune flow
(warm state survives a mid-session k change via plan repair), and the
``slo_violations``/``retunes`` counters in ``service.stats()``."""

import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import ExecConfig, SolveConfig
from repro.domains import GavelInstance
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import PopService
from repro.tuning import (DomainCurves, OnlineTuner, ProfileError, SLOTarget,
                          check_profile, latency_at, launch_defaults,
                          load_profile, plan_for_slo, profile_digest,
                          quality_loss_at, save_profile)

FIXTURE = Path(__file__).resolve().parent / "fixtures" / "tuning" / \
    "profile_fixture.json"

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


def _traffic(n=24, seed=0, scale=1.0):
    topo = make_topology(20, 40, seed=seed)
    pairs, dem = make_demands(topo, n, seed=seed)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
    return TrafficProblem(topo, pairs, dem * scale, pe)


@pytest.fixture(scope="module")
def profile():
    return check_profile(load_profile(FIXTURE))


# ---------------------------------------------------------------------------
# the SLO contract
# ---------------------------------------------------------------------------

class TestSLOTarget:
    def test_frozen_hashable_validated(self):
        a = SLOTarget(max_quality_loss=0.02, step_deadline_s=1.5)
        b = SLOTarget(max_quality_loss=0.02, step_deadline_s=1.5)
        assert a == b and hash(a) == hash(b)
        with pytest.raises(dataclasses.FrozenInstanceError):
            a.max_quality_loss = 0.5

    @pytest.mark.parametrize("kw", [
        dict(max_quality_loss=-0.1),
        dict(max_quality_loss=1.0),
        dict(step_deadline_s=0.0),
        dict(step_deadline_s=-2.0),
    ])
    def test_rejects_out_of_range(self, kw):
        with pytest.raises(ValueError):
            SLOTarget(**kw)


# ---------------------------------------------------------------------------
# the artifact seal
# ---------------------------------------------------------------------------

class TestProfileSeal:
    def test_fixture_is_sealed(self, profile):
        assert profile.digest == profile_digest(profile)
        assert {"gavel", "traffic"} <= set(profile.domains)

    def test_digest_rejects_tampering(self, tmp_path):
        obj = json.loads(FIXTURE.read_text())
        obj["domains"]["traffic"]["n_exponent"] = 9.9   # hand-edit
        p = tmp_path / "edited.json"
        p.write_text(json.dumps(obj))
        with pytest.raises(ProfileError, match="digest mismatch"):
            check_profile(load_profile(p))

    def test_version_gate(self, tmp_path, profile):
        stale = dataclasses.replace(profile, version=0)
        p = save_profile(stale, tmp_path / "stale.json")  # reseals digest
        with pytest.raises(ProfileError, match="version"):
            check_profile(load_profile(p))

    def test_platform_gate(self, profile):
        with pytest.raises(ProfileError, match="measured on"):
            check_profile(profile, platform="tpu9000")
        assert check_profile(profile, platform="cpu") is profile

    def test_load_does_not_validate(self, tmp_path):
        obj = json.loads(FIXTURE.read_text())
        obj["digest"] = "sha256:bogus"
        p = tmp_path / "bogus.json"
        p.write_text(json.dumps(obj))
        prof = load_profile(p)               # parse-only door
        with pytest.raises(ProfileError):
            check_profile(prof)              # popcheck: disable=profile-staleness

    def test_unreadable_raises_profile_error(self, tmp_path):
        with pytest.raises(ProfileError):
            load_profile(tmp_path / "nope.json")   # popcheck: disable=profile-staleness


# ---------------------------------------------------------------------------
# the offline planner: measured curves -> cheapest config meeting the SLO
# ---------------------------------------------------------------------------

class TestPlanner:
    def test_gavel_flat_curve_picks_large_k(self, profile):
        # ISSUE acceptance: cluster scheduling at a 2% SLO -> k >= 16
        plan = plan_for_slo(profile, "gavel", 512, SLOTarget(0.02))
        assert plan.solve.k >= 16
        assert plan.predicted_quality_loss <= 0.02
        assert plan.source == "curves"

    def test_traffic_steep_curve_picks_small_k(self, profile):
        # same SLO, opposite answer: traffic loses 20% already at k=16
        plan = plan_for_slo(profile, "traffic", 400, SLOTarget(0.02))
        assert plan.solve.k <= 4
        assert plan.predicted_quality_loss <= 0.02

    def test_deadline_escalates_replication_before_quality(self, profile):
        # a deadline no small-k config can meet: the planner reaches for
        # a replication row at large k (granular-POP) instead of just
        # surrendering quality
        slo = SLOTarget(max_quality_loss=0.05, step_deadline_s=20.0)
        plan = plan_for_slo(profile, "traffic", 400, slo)
        assert plan.source in ("replicated", "deadline-limited")
        if plan.source == "replicated":
            assert plan.solve.replicate_threshold is not None
            assert plan.predicted_quality_loss <= 0.05

    def test_latency_scales_with_n(self, profile):
        curves = profile.domains["gavel"]
        t_probe = latency_at(curves, 8, curves.probe_n)
        t_big = latency_at(curves, 8, curves.probe_n * 4)
        assert t_big > t_probe * 2       # superlinear exponent (1.4)

    def test_quality_loss_interpolates(self, profile):
        curves = profile.domains["traffic"]
        # between measured k=4 (4.9% loss) and k=16 (20% loss)
        loss8 = quality_loss_at(curves, 8)
        assert 0.049 < loss8 < 0.20

    def test_base_solve_fields_survive_planning(self, profile):
        base = SolveConfig(k=8, strategy="stratified", seed=7)
        plan = plan_for_slo(profile, "gavel", 512, SLOTarget(0.02),
                            base_solve=base)
        assert plan.solve.strategy == "stratified"
        assert plan.solve.seed == 7

    def test_unknown_domain_keeps_base(self, profile):
        base = SolveConfig(k=8)
        plan = plan_for_slo(profile, "warehouse", 100, SLOTarget(0.02),
                            base_solve=base)
        assert plan.solve == base
        assert plan.source == "no-curves"

    def test_launch_defaults_from_cost_line(self, profile):
        d = launch_defaults(profile)
        assert d is not None
        assert 0.5 <= d["max_wait_ms"] <= 20.0
        assert d["max_lanes"] >= 8
        # pow2 lane cap (jit cache friendliness)
        assert d["max_lanes"] & (d["max_lanes"] - 1) == 0


# ---------------------------------------------------------------------------
# the online refiner
# ---------------------------------------------------------------------------

class TestOnlineTuner:
    def _tuner(self, profile, slo, base=None, domain="gavel"):
        return OnlineTuner(profile, domain, slo,
                           base or SolveConfig(k=8), ExecConfig())

    def test_latency_violation_doubles_k_after_patience(self, profile):
        t = self._tuner(None, SLOTarget(0.5, step_deadline_s=0.01))
        t.plan_initial(256)
        ev1 = t.observe(8, 0.5, 1.0)
        assert ev1.violation == "latency" and ev1.new_solve is None
        ev2 = t.observe(8, 0.5, 1.0)     # patience=2 reached
        assert ev2.new_solve is not None and ev2.new_solve.k == 16

    def test_cooldown_holds_after_move(self, profile):
        t = self._tuner(None, SLOTarget(0.5, step_deadline_s=0.01))
        t.plan_initial(256)
        t.observe(8, 0.5, 1.0)
        assert t.observe(8, 0.5, 1.0).new_solve.k == 16
        # cooldown: violations keep being recorded but no immediate
        # second move at the new operating point
        for _ in range(2):
            assert t.observe(16, 0.5, 1.0).new_solve is None
        assert t.observe(16, 0.5, 1.0).new_solve is not None

    def test_quality_violation_escalates_replication_first(self, profile):
        t = self._tuner(profile, SLOTarget(max_quality_loss=0.02),
                        base=SolveConfig(k=16), domain="gavel")
        t.plan_initial(512)
        t.solve_cfg = SolveConfig(k=16)          # pin the operating point
        t.observe(8, 0.1, 1.00)                  # reference at smaller k
        t.observe(16, 0.1, 0.90)                 # 10% loss vs k=8
        ev = t.observe(16, 0.1, 0.90)
        assert ev.violation == "quality"
        assert ev.new_solve is not None
        # profile has replication rows at k=16 meeting 2%: escalate there
        assert ev.new_solve.k == 16
        assert ev.new_solve.replicate_threshold is not None

    def test_quality_violation_without_rows_halves_k(self):
        t = self._tuner(None, SLOTarget(max_quality_loss=0.02))
        t.plan_initial(256)
        t.observe(4, 0.1, 1.00)
        t.observe(8, 0.1, 0.80)
        ev = t.observe(8, 0.1, 0.80)
        assert ev.new_solve is not None and ev.new_solve.k == 4
        assert ev.new_solve.replicate_threshold is None

    def test_min_per_sub_clamped_move_is_skipped(self):
        # gavel's min_per_sub=8 voids k=8 -> 16 at n=96 (k_for caps both
        # at 12): the tuner must not churn configs for an unchanged split
        base = SolveConfig(k=12, min_per_sub=8)
        t = self._tuner(None, SLOTarget(0.5, step_deadline_s=0.01),
                        base=base)
        t.plan_initial(96)
        t.observe(12, 0.5, 1.0)
        ev = t.observe(12, 0.5, 1.0)
        assert ev.violation == "latency" and ev.new_solve is None


# ---------------------------------------------------------------------------
# service integration: session(slo=...), counters, retune-under-churn
# ---------------------------------------------------------------------------

class TestServiceIntegration:
    def test_profile_plans_session_and_counts_nothing_when_met(self, profile):
        svc = PopService(exec=ExecConfig(solver_kw=KW), profile=profile)
        wl = make_cluster_workload(96, seed=0)
        sess = svc.session("t", GavelInstance(wl), slo=SLOTarget(0.02))
        # gavel's flat curve -> large k (clamped by n/min_per_sub)
        assert sess.solve_cfg.k >= 16
        a = sess.step(GavelInstance(wl))
        assert a.status == "ok"
        st = svc.stats()
        assert st["slo_violations"] == 0
        assert st["retunes"] == 0

    def test_str_profile_path_is_loaded_and_checked(self):
        svc = PopService(exec=ExecConfig(solver_kw=KW), profile=str(FIXTURE))
        assert svc.profile is not None
        assert "gavel" in svc.profile.domains

    def test_tampered_profile_rejected_at_service_door(self, tmp_path):
        obj = json.loads(FIXTURE.read_text())
        obj["version"] = 99
        p = tmp_path / "bad.json"
        p.write_text(json.dumps(obj))
        with pytest.raises(ProfileError):
            PopService(profile=str(p))

    def test_slo_requires_slotarget_type(self):
        svc = PopService(exec=ExecConfig(solver_kw=KW))
        with pytest.raises(TypeError, match="SLOTarget"):
            svc.session("t", _traffic(), slo=0.02)

    def test_reentry_pins_slo(self, profile):
        svc = PopService(exec=ExecConfig(solver_kw=KW), profile=profile)
        prob = _traffic()
        svc.session("t", prob, slo=SLOTarget(0.02))
        svc.session("t", prob, slo=SLOTarget(0.02))        # same: fine
        with pytest.raises(ValueError, match="SLO"):
            svc.session("t", prob, slo=SLOTarget(0.10))

    def test_retune_under_churn_keeps_warm_state(self):
        # an impossible deadline forces a latency retune mid-session;
        # the k change must ride the repair path (warm_fraction > 0),
        # never a cold start — then survive entity churn on top
        svc = PopService(exec=ExecConfig(solver_kw=KW))
        wl = make_cluster_workload(96, seed=0)
        ids = np.arange(96)
        slo = SLOTarget(max_quality_loss=0.5, step_deadline_s=1e-4)
        sess = svc.session("t", domain="gavel", slo=slo)
        ks = []
        for _ in range(4):
            a = sess.step(GavelInstance(wl, job_ids=ids))
            ks.append(a.k)
            if a.plan_cache != "miss":
                assert a.warm_fraction is not None
                assert a.warm_fraction > 0.0
        assert ks[-1] > ks[0]            # the deadline forced k upward
        # churn 10 jobs at the retuned k: repair, not rebuild
        wl2 = make_cluster_workload(96, seed=1)
        ids2 = ids.copy()
        ids2[:10] = np.arange(1000, 1010)
        a = sess.step(GavelInstance(wl2, job_ids=ids2))
        assert a.plan_cache in ("repair", "hit")
        assert a.warm_fraction is not None and a.warm_fraction > 0.0
        st = svc.stats()
        assert st["slo_violations"] > 0
        assert st["retunes"] >= 1
        assert sess.stats["retunes"] >= 1

    def test_untuned_sessions_never_touch_counters(self):
        svc = PopService(exec=ExecConfig(solver_kw=KW))
        sess = svc.session("t", _traffic())
        sess.step(_traffic())
        st = svc.stats()
        assert st["slo_violations"] == 0 and st["retunes"] == 0
        assert sess.slo is None
