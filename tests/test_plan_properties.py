"""Property tests for the PopPlan churn invariants (``core/plan.py``).

Three invariants hold for ANY churn pattern (departures, arrivals,
position shuffles, any k):

  1. ``repair_plan``: surviving entities keep their exact (lane, slot).
  2. ``remap_warm``: the per-entity iterate blocks of survivors move
     INTACT — the remap acts as a permutation on survivor blocks (each
     survivor's block lands, bit-identical, at its new (lane, slot); no
     block is duplicated onto another survivor, none is lost).
  3. ``WarmStart.mask`` covers exactly the lanes with no matched entity.

Hypothesis drives randomised churn through ``tests/_hypothesis_compat``
(skip-safe: without hypothesis installed the ``@given`` tests skip
cleanly); the same checker also runs under a fixed-seed parametrisation so
the invariants stay exercised on hypothesis-less installs.
"""

import numpy as np
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core import plan as plan_mod
from repro.core import pop
from repro.core.plan import SubLayout


class _ToyProblem(pop.POPProblem):
    """Minimal POP-able problem: 2 owned variables + 1 owned constraint row
    per slot, 1 lane-global variable, 2 lane-global rows."""

    def __init__(self, ids, scores):
        self.n_entities = len(ids)
        self._scores = np.asarray(scores, np.float64)

    def entity_attrs(self):
        return self._scores[:, None]

    def entity_scores(self):
        return self._scores

    def sub_layout(self, n_slots):
        return SubLayout(
            x_slot=np.arange(2 * n_slots).reshape(n_slots, 2),
            y_slot=np.arange(n_slots)[:, None],
            x_global=np.array([2 * n_slots]),
            y_global=n_slots + np.arange(2))


def _shapes_for(p):
    return {"x": (p.k, 2 * p.n_per + 1), "y": (p.k, p.n_per + 2)}


def _sentinel_iterates(p, ids):
    """Unique per-entity block values: x block = (1000+id, 2000+id),
    y row = 3000+id; lane-globals = 9e5 + lane."""
    (_, n_var), (_, n_con) = _shapes_for(p)["x"], _shapes_for(p)["y"]
    x = np.zeros((p.k, n_var), np.float32)
    y = np.zeros((p.k, n_con), np.float32)
    lay = p.layout
    for lane in range(p.k):
        x[lane, lay.x_global] = 900_000 + lane
        y[lane, lay.y_global] = 910_000 + lane
        for slot in range(p.n_per):
            e = int(p.entity_of_slot[lane, slot])
            if e >= 0:
                eid = ids[e]
                x[lane, lay.x_slot[slot]] = [1000 + eid, 2000 + eid]
                y[lane, lay.y_slot[slot]] = 3000 + eid
    return x, y


def _positions(p, ids):
    out = {}
    for lane in range(p.k):
        for slot in range(p.n_per):
            e = int(p.entity_of_slot[lane, slot])
            if e >= 0:
                out[int(ids[e])] = (lane, slot)
    return out


def _check_churn_invariants(seed, n_old, k, survive_frac, n_arrive,
                            restratify):
    rng = np.random.default_rng(seed)
    k = max(1, min(k, n_old))
    old_ids = np.arange(n_old) * 7 + 3                    # arbitrary stable ids
    prob_old = _ToyProblem(old_ids, rng.uniform(0.5, 2.0, n_old))
    old_plan = pop.plan(prob_old, k, strategy="stratified",
                        entity_ids=old_ids)
    old_plan.shapes = _shapes_for(old_plan)
    x_old, y_old = _sentinel_iterates(old_plan, old_ids)
    pos_old = _positions(old_plan, old_ids)

    survive = rng.random(n_old) < survive_frac
    if not survive.any() and n_arrive == 0:
        n_arrive = 1                                      # keep the new set non-empty
    new_ids = np.concatenate([old_ids[survive],
                              100_000 + np.arange(n_arrive)])
    perm = rng.permutation(new_ids.shape[0])              # positions churn too
    new_ids = new_ids[perm]
    prob_new = _ToyProblem(new_ids, rng.uniform(0.5, 2.0, new_ids.shape[0]))

    if restratify:
        # fresh plans need k <= n (pop.plan precondition); repair_plan has
        # no such limit — departure-heavy churn just leaves lanes empty
        k_new = min(k, new_ids.shape[0])
        new_plan = pop.plan(prob_new, k_new, strategy="stratified",
                            seed=seed + 1, entity_ids=new_ids)
    else:
        new_plan = plan_mod.repair_plan(old_plan, prob_new,
                                        entity_ids=new_ids)
    new_plan.shapes = _shapes_for(new_plan)
    pos_new = _positions(new_plan, new_ids)

    survivors = set(old_ids[survive].tolist()) & set(new_ids.tolist())

    # ---- invariant 1: repair keeps survivor (lane, slot) ------------------
    if not restratify:
        for eid in survivors:
            assert pos_new[eid] == pos_old[eid], (
                f"survivor {eid} moved {pos_old[eid]} -> {pos_new[eid]}")

    ws = plan_mod.remap_warm(old_plan, new_plan, (x_old, y_old))

    # ---- invariant 2: remap is a permutation on survivor blocks -----------
    # every live entity occupies a DISTINCT (lane, slot) in the new plan
    # (injectivity), and each survivor's sentinel block arrived intact at
    # its position (the per-entity asserts) — together: a bijection from
    # survivor blocks onto their new positions, nothing duplicated or lost
    all_pos = list(pos_new.values())
    assert len(set(all_pos)) == len(all_pos)
    lay = new_plan.layout
    for eid in survivors:
        lane, slot = pos_new[eid]
        np.testing.assert_array_equal(ws.x[lane, lay.x_slot[slot]],
                                      [1000 + eid, 2000 + eid])
        np.testing.assert_array_equal(ws.y[lane, lay.y_slot[slot]],
                                      [3000 + eid])
    assert ws.stats["matched"] == len(survivors)
    assert ws.stats["fresh"] == (new_ids.shape[0] - len(survivors))

    # ---- invariant 3: mask covers exactly the unmatched lanes -------------
    for lane in range(new_plan.k):
        lane_entities = [int(new_ids[e])
                         for e in new_plan.entity_of_slot[lane] if e >= 0]
        has_survivor = any(eid in survivors for eid in lane_entities)
        assert bool(ws.mask[lane]) == has_survivor, (
            f"lane {lane}: mask {bool(ws.mask[lane])} but "
            f"has_survivor={has_survivor}")
    assert ws.stats["lanes_cold"] == int((~np.asarray(ws.mask)).sum())


# ---------------------------------------------------------------------------
# hypothesis-driven randomised churn — defined only when hypothesis is
# installed (collection stays clean without it, and the fixed-seed
# parametrisation below keeps the same checker exercised regardless)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_old=st.integers(2, 24),
           k=st.integers(1, 4),
           survive_pct=st.integers(0, 100),
           n_arrive=st.integers(0, 8))
    def test_repair_remap_invariants_random_churn(seed, n_old, k,
                                                  survive_pct, n_arrive):
        _check_churn_invariants(seed, n_old, k, survive_pct / 100.0,
                                n_arrive, restratify=False)

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_old=st.integers(2, 24),
           k=st.integers(1, 4),
           survive_pct=st.integers(0, 100),
           n_arrive=st.integers(0, 8))
    def test_remap_invariants_across_restratification(seed, n_old, k,
                                                      survive_pct, n_arrive):
        """remap_warm is plan-agnostic: survivor blocks move intact even
        onto a freshly re-stratified plan (every (lane, slot) reshuffles)."""
        _check_churn_invariants(seed, n_old, k, survive_pct / 100.0,
                                n_arrive, restratify=True)


# ---------------------------------------------------------------------------
# fixed-seed fallback: the same checker always runs, hypothesis or not
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n_old,k,survive_frac,n_arrive,restratify", [
    (0, 12, 3, 0.7, 3, False),
    (1, 12, 3, 0.7, 3, True),
    (2, 8, 4, 0.0, 5, False),      # everyone departs: all lanes cold
    (3, 20, 2, 1.0, 0, False),     # identity churn: everyone matched
    (4, 5, 4, 0.4, 0, True),       # departures only, k near n
    (5, 16, 1, 0.5, 8, False),     # single lane
])
def test_churn_invariants_fixed_seeds(seed, n_old, k, survive_frac,
                                      n_arrive, restratify):
    _check_churn_invariants(seed, n_old, k, survive_frac, n_arrive,
                            restratify)


def test_hypothesis_shim_mode():
    """Document which mode this run took (real hypothesis vs skip shim)."""
    assert HAVE_HYPOTHESIS in (True, False)
