"""PDHG solver correctness vs scipy.optimize.linprog (HiGHS) oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st
from scipy.optimize import linprog

from repro.core import LinearProgram, pdhg


def _random_lp(seed, n=50, mi=30, me=0):
    """Random bounded-feasible LP: box [0,1], Gx <= h with slack-positive h."""
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    G = rng.normal(size=(mi, n))
    h = G @ rng.uniform(0.2, 0.8, n) + rng.uniform(0.1, 1.0, mi)  # strictly feasible
    A = rng.normal(size=(me, n)) if me else None
    b = (A @ rng.uniform(0.2, 0.8, n)) if me else None
    return c, G, h, A, b


@pytest.mark.parametrize("seed", range(6))
def test_matches_scipy_inequality(seed):
    c, G, h, _, _ = _random_lp(seed)
    ref = linprog(c, A_ub=G, b_ub=h, bounds=(0, 1), method="highs")
    lp = LinearProgram.build(c=c, G=G, h=h, l=np.zeros_like(c), u=np.ones_like(c))
    res = pdhg.solve_dense(lp, max_iters=60_000, tol_primal=1e-6, tol_gap=1e-6)
    assert abs(float(res.primal_obj) - ref.fun) < 1e-3 * (1 + abs(ref.fun))
    # and the solution is feasible in the ORIGINAL problem
    v = lp.violations(res.x)
    assert float(v["ineq_max"]) < 1e-3
    assert float(v["box_max"]) < 1e-5


@pytest.mark.parametrize("seed", range(3))
def test_matches_scipy_with_equalities(seed):
    c, G, h, A, b = _random_lp(seed + 100, n=40, mi=20, me=5)
    ref = linprog(c, A_ub=G, b_ub=h, A_eq=A, b_eq=b, bounds=(0, 1), method="highs")
    lp = LinearProgram.build(c=c, G=G, h=h, A=A, b=b,
                             l=np.zeros_like(c), u=np.ones_like(c))
    res = pdhg.solve_dense(lp, max_iters=60_000, tol_primal=1e-6, tol_gap=1e-6)
    assert abs(float(res.primal_obj) - ref.fun) < 2e-3 * (1 + abs(ref.fun))
    v = lp.violations(res.x)
    assert float(v["eq_max"]) < 2e-3


def test_padding_invariance():
    """128-padding must not change the solution (pinned vars, BIG rows)."""
    c, G, h, _, _ = _random_lp(7, n=33, mi=17)
    lp_small = LinearProgram.build(c=c, G=G, h=h, l=np.zeros_like(c),
                                   u=np.ones_like(c), pad_to=64)
    lp_big = LinearProgram.build(c=c, G=G, h=h, l=np.zeros_like(c),
                                 u=np.ones_like(c), pad_to=512)
    r1 = pdhg.solve_dense(lp_small, max_iters=40_000)
    r2 = pdhg.solve_dense(lp_big, max_iters=40_000)
    assert abs(float(r1.primal_obj) - float(r2.primal_obj)) < 1e-3 * (
        1 + abs(float(r1.primal_obj)))


def test_batched_matches_individual():
    """vmap-batched solve (POP's map step) == per-problem solves."""
    lps = []
    for seed in range(4):
        c, G, h, _, _ = _random_lp(seed + 50, n=30, mi=20)
        lps.append(LinearProgram.build(c=c, G=G, h=h, l=np.zeros_like(c),
                                       u=np.ones_like(c)))
    import jax
    ops = jax.tree.map(lambda *xs: jnp.stack(xs), *[pdhg.dense_ops(lp) for lp in lps])
    batched = pdhg.solve_batched(ops, max_iters=40_000)
    for i, lp in enumerate(lps):
        single = pdhg.solve_dense(lp, max_iters=40_000)
        assert abs(float(batched.primal_obj[i]) - float(single.primal_obj)) < 2e-3 * (
            1 + abs(float(single.primal_obj)))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_feasibility_and_bound(seed):
    """Property: PDHG never returns an infeasible x, and its objective is
    within tolerance of (i.e. not meaningfully BELOW) the LP optimum."""
    c, G, h, _, _ = _random_lp(seed % 10_000, n=24, mi=12)
    ref = linprog(c, A_ub=G, b_ub=h, bounds=(0, 1), method="highs")
    lp = LinearProgram.build(c=c, G=G, h=h, l=np.zeros_like(c), u=np.ones_like(c))
    res = pdhg.solve_dense(lp, max_iters=60_000)
    v = lp.violations(res.x)
    # PDHG at rel-tol 1e-4 leaves small absolute violations on unlucky
    # random instances; the property is "never meaningfully infeasible"
    assert float(v["ineq_max"]) < 1e-2
    assert float(res.primal_obj) >= ref.fun - 1e-2 * (1 + abs(ref.fun))


def test_operator_form_matches_dense():
    """A structured K_mv/KT_mv must agree with the dense path (this is the
    contract the domain problems rely on)."""
    rng = np.random.default_rng(11)
    n, mi = 40, 24
    c, G, h, _, _ = _random_lp(11, n=n, mi=mi)
    lp = LinearProgram.build(c=c, G=G, h=h, l=np.zeros_like(c), u=np.ones_like(c))
    op = pdhg.dense_ops(lp)

    # "structured" version: split K into two halves stitched by custom mv
    K, q, mask = lp.stacked()
    half = K.shape[0] // 2
    data = (K[:half], K[half:])
    K_mv = lambda d, x: jnp.concatenate([d[0] @ x, d[1] @ x])
    KT_mv = lambda d, y: d[0].T @ y[:half] + d[1].T @ y[half:]
    op2 = pdhg.OperatorLP(c=op.c, q=q, l=op.l, u=op.u, ineq_mask=mask, data=data)

    r1 = pdhg.solve(op, max_iters=30_000)
    r2 = pdhg.solve(op2, K_mv, KT_mv, max_iters=30_000)
    assert abs(float(r1.primal_obj) - float(r2.primal_obj)) < 2e-3 * (
        1 + abs(float(r1.primal_obj)))
