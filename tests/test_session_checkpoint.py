"""Session checkpoint/restore: byte-format integrity, service round trips,
degrade-to-cold on damage, and a cross-process restore (the rolling
restart docs/ROBUSTNESS.md promises)."""

import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _subproc import repro_env
from repro.checkpoint import (CheckpointError, config_digest, pack_state,
                              unpack_state)
from repro.core import ExecConfig, SolveConfig
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import PopService

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


def _traffic(n=24, seed=0, scale=1.0):
    topo = make_topology(20, 40, seed=seed)
    pairs, dem = make_demands(topo, n, seed=seed)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
    return TrafficProblem(topo, pairs, dem * scale, pe)


def _service(k=4):
    return PopService(solve=SolveConfig(k=k), exec=ExecConfig(solver_kw=KW))


# ---------------------------------------------------------------------------
# the byte format
# ---------------------------------------------------------------------------

class TestByteFormat:
    def test_round_trip(self):
        meta = {"tenants": {"a": {"mode": "pop", "steps": 3}}}
        arrays = {"t0/x": np.arange(12.0).reshape(3, 4),
                  "t0/idx": np.arange(6).reshape(2, 3)}
        blob = pack_state(meta, arrays)
        m2, a2 = unpack_state(blob)
        assert m2 == meta
        for k in arrays:
            np.testing.assert_array_equal(a2[k], arrays[k])

    def test_not_bytes(self):
        with pytest.raises(CheckpointError, match="must be bytes"):
            unpack_state("not bytes")

    def test_bad_magic(self):
        blob = pack_state({}, {})
        with pytest.raises(CheckpointError, match="magic"):
            unpack_state(b"NOTMAGIC" + blob[8:])

    def test_truncated_header(self):
        with pytest.raises(CheckpointError, match="truncated"):
            unpack_state(pack_state({}, {})[:10])

    def test_truncated_payload(self):
        blob = pack_state({}, {"t0/x": np.zeros(8)})
        with pytest.raises(CheckpointError, match="truncated"):
            unpack_state(blob[:-20])

    def test_flipped_payload_byte(self):
        blob = pack_state({}, {"t0/x": np.zeros(8)})
        bad = bytearray(blob)
        bad[-5] ^= 0xFF
        with pytest.raises(CheckpointError, match="hash mismatch"):
            unpack_state(bytes(bad))

    def test_version_pinned(self):
        blob = pack_state({}, {})
        meta_start = 8 + 8
        raw = blob[meta_start:].split(b"}", 1)
        tampered = blob.replace(b'"version": 1', b'"version": 9')
        assert raw is not None   # keep the slice honest
        with pytest.raises(CheckpointError, match="version"):
            unpack_state(tampered)

    def test_config_digest_tracks_configs(self):
        a = config_digest(SolveConfig(k=4), ExecConfig(solver_kw=KW))
        b = config_digest(SolveConfig(k=4), ExecConfig(solver_kw=KW))
        c = config_digest(SolveConfig(k=8), ExecConfig(solver_kw=KW))
        assert a == b != c


# ---------------------------------------------------------------------------
# service round trips
# ---------------------------------------------------------------------------

class TestServiceRoundTrip:
    def test_pop_path_restores_warm(self):
        svc = _service()
        inst = _traffic()
        sess = svc.session("a", inst)
        sess.step(inst)
        sess.step(_traffic(scale=1.1))
        blob = svc.checkpoint()

        fresh = _service()
        report = fresh.restore(blob)
        assert report == {"restored": ["a"], "cold": [], "errors": {}}
        assert fresh.stats()["checkpoint_restores"] == 1
        restored = fresh.session("a", domain="traffic")
        assert restored.steps == sess.steps

        nxt = _traffic(scale=1.2)
        a_fresh = restored.step(nxt)
        a_cont = sess.step(nxt)
        assert a_fresh.warm_fraction and a_fresh.warm_fraction > 0
        assert a_fresh.plan_cache == "hit"
        np.testing.assert_allclose(a_fresh.alloc, a_cont.alloc)

    def test_full_path_restores_warm(self):
        svc = PopService(solve=SolveConfig(k=1),
                         exec=ExecConfig(solver_kw=KW))
        inst = _traffic()
        sess = svc.session("a", inst)
        sess.step(inst)
        blob = svc.checkpoint()

        fresh = PopService(solve=SolveConfig(k=1),
                           exec=ExecConfig(solver_kw=KW))
        report = fresh.restore(blob)
        assert report["restored"] == ["a"]
        alloc = fresh.session("a", domain="traffic").step(
            _traffic(scale=1.05))
        assert alloc.warm_fraction == 1.0
        assert alloc.plan_cache == "full"

    def test_cold_session_round_trips(self):
        svc = _service()
        svc.session("idle", domain="traffic")
        report = _service_restore(svc)
        assert report["cold"] == ["idle"] and not report["errors"]

    def test_multi_tenant(self):
        svc = _service()
        for t in ("a", "b"):
            inst = _traffic(seed=0 if t == "a" else 1)
            svc.session(t, inst).step(inst)
        fresh = _service()
        report = fresh.restore(svc.checkpoint())
        assert sorted(report["restored"]) == ["a", "b"]

    def test_stale_digest_degrades_to_cold(self):
        svc = _service()
        inst = _traffic()
        svc.session("a", inst).step(inst)
        meta, arrays = unpack_state(svc.checkpoint())
        meta["tenants"]["a"]["digest"] = "0" * 16
        fresh = _service()
        report = fresh.restore(pack_state(meta, arrays))
        assert report["cold"] == ["a"]
        assert "digest mismatch" in report["errors"]["a"]
        assert fresh.stats()["checkpoint_failures"] == 1

    def test_missing_array_degrades_to_cold(self):
        svc = _service()
        inst = _traffic()
        svc.session("a", inst).step(inst)
        meta, arrays = unpack_state(svc.checkpoint())
        arrays = {k: v for k, v in arrays.items() if not k.endswith("/x")}
        fresh = _service()
        report = fresh.restore(pack_state(meta, arrays))
        assert report["cold"] == ["a"]
        assert "missing array" in report["errors"]["a"]

    def test_strict_restore_raises(self):
        fresh = _service()
        with pytest.raises(CheckpointError):
            fresh.restore(b"garbage-bytes-here", strict=True)

    def test_garbage_blob_never_crashes(self):
        fresh = _service()
        report = fresh.restore(b"\x00" * 64)
        assert report["restored"] == [] and report["errors"]
        assert fresh.stats()["checkpoint_failures"] == 1


def _service_restore(svc):
    fresh = _service()
    return fresh.restore(svc.checkpoint())


# ---------------------------------------------------------------------------
# cross-process restore: the actual rolling-restart scenario
# ---------------------------------------------------------------------------

CHILD = textwrap.dedent("""
    import sys
    import numpy as np
    from repro.core import ExecConfig, SolveConfig
    from repro.problems.traffic_engineering import (TrafficProblem,
        k_shortest_paths, make_demands, make_topology)
    from repro.service import PopService

    KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)
    topo = make_topology(20, 40, seed=0)
    pairs, dem = make_demands(topo, 24, seed=0)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=0)
    nxt = TrafficProblem(topo, pairs, dem * 1.2, pe)

    svc = PopService(solve=SolveConfig(k=4), exec=ExecConfig(solver_kw=KW))
    report = svc.restore(open(sys.argv[1], "rb").read(), strict=True)
    assert report["restored"] == ["a"], report
    alloc = svc.session("a", domain="traffic").step(nxt)
    assert alloc.warm_fraction is not None and alloc.warm_fraction > 0, \\
        alloc.warm_fraction
    assert alloc.plan_cache == "hit", alloc.plan_cache
    np.save(sys.argv[2], np.asarray(alloc.alloc, dtype=np.float64))
""")


class TestCrossProcessRestore:
    def test_restore_in_fresh_process_matches_uninterrupted(self, tmp_path):
        svc = _service()
        inst = _traffic()
        sess = svc.session("a", inst)
        sess.step(inst)
        sess.step(_traffic(scale=1.1))
        blob_path = tmp_path / "session.ckpt"
        blob_path.write_bytes(svc.checkpoint())

        out_path = tmp_path / "alloc.npy"
        proc = subprocess.run(
            [sys.executable, "-c", CHILD, str(blob_path), str(out_path)],
            env=repro_env(), capture_output=True, text=True, timeout=300)
        assert proc.returncode == 0, proc.stderr

        # the uninterrupted session, same next instance
        cont = sess.step(_traffic(scale=1.2))
        child_alloc = np.load(out_path)
        np.testing.assert_allclose(child_alloc, cont.alloc, rtol=1e-6)
