"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs
(full configs are exercised only via the dry-run's ShapeDtypeStructs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import (forward_decode, forward_train, init_cache,
                          init_params, encode)
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainConfig, make_train_step


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.enc_segments:
        out["enc_embeddings"] = jnp.asarray(
            rng.normal(0, 1, (B, 32, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    logits = forward_train(params, cfg, b["tokens"],
                           enc_embeddings=b.get("enc_embeddings"))
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = opt_mod.init_state(params)
    tcfg = TrainConfig(n_microbatches=2,
                       adamw=opt_mod.AdamWConfig(warmup_steps=1, total_steps=4))
    step = jax.jit(make_train_step(cfg, tcfg, mesh=None))
    params2, opt2, metrics = step(params, opt, _batch(cfg, B=4))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    deltas = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                          params, params2)
    assert max(jax.tree.leaves(deltas)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b = _batch(cfg)
    cache = init_cache(cfg, 2, 96)
    memory = (encode(params, cfg, b["enc_embeddings"])
              if cfg.enc_segments else None)
    logits, cache2 = forward_decode(params, cfg, b["tokens"][:, :1], cache,
                                    enc_memory=memory)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full-size configs must carry the EXACT assigned hyperparams."""
    spec = {
        "h2o_danube3_4b": dict(L=24, d=3840, H=32, kv=8, ff=10240, V=32000),
        "gemma3_4b": dict(L=34, d=2560, H=8, kv=4, ff=10240, V=262144),
        "gemma2_27b": dict(L=46, d=4608, H=32, kv=16, ff=36864, V=256000),
        "llama3_8b": dict(L=32, d=4096, H=32, kv=8, ff=14336, V=128256),
        "mixtral_8x22b": dict(L=56, d=6144, H=48, kv=8, ff=16384, V=32768),
        "qwen2_moe_a2_7b": dict(L=24, d=2048, H=16, kv=16, ff=1408, V=151936),
        "zamba2_2_7b": dict(L=63, d=2560, H=32, kv=32, ff=10240, V=32000),
        "seamless_m4t_medium": dict(L=12, d=1024, H=16, kv=16, ff=4096,
                                    V=256206),
        "chameleon_34b": dict(L=48, d=8192, H=64, kv=8, ff=22016, V=65536),
        "xlstm_350m": dict(L=24, d=1024, H=4, kv=4, ff=0, V=50304),
    }[arch]
    cfg = get_config(arch)
    assert cfg.d_model == spec["d"]
    assert cfg.n_heads == spec["H"]
    assert cfg.n_kv == spec["kv"]
    assert cfg.vocab == spec["V"]
    if cfg.moe is not None:
        assert cfg.moe.d_ff_expert == spec["ff"]
    elif spec["ff"]:
        assert cfg.d_ff == spec["ff"]
    # zamba2: 54 mamba + 9 shared-attn applications = 63 block applications;
    # the assignment's "54L" counts the mamba layers
    if arch == "zamba2_2_7b":
        mamba_layers = sum(
            sum(1 for b in s.period if b.mixer == "mamba2") * s.n_periods
            for s in cfg.segments)
        assert mamba_layers == 54
    elif arch == "seamless_m4t_medium":
        assert cfg.n_layers == 12                  # + 12 encoder layers
        enc_layers = sum(len(s.period) * s.n_periods for s in cfg.enc_segments)
        assert enc_layers == 12
    else:
        assert cfg.n_layers == spec["L"]


@pytest.mark.parametrize("arch,expected_b", [
    ("h2o_danube3_4b", 3.8e9), ("llama3_8b", 8.0e9),
    ("gemma2_27b", 27e9), ("mixtral_8x22b", 140e9),
    ("chameleon_34b", 34e9),
])
def test_param_counts_in_range(arch, expected_b):
    """Full configs land within 20% of the published parameter count."""
    cfg = get_config(arch)
    import repro.launch.specs as sp
    flat = jax.tree.leaves(sp.params_shape(cfg))
    n = sum(int(np.prod(l.shape)) for l in flat)
    assert 0.8 * expected_b < n < 1.25 * expected_b, f"{arch}: {n:.3g}"
