"""PopPlan pipeline + churn-aware warm starts (ISSUE 3).

Covers the staged plan/build/solve/reduce pipeline, plan reuse and repair,
and remap_warm across identity churn (must be bit-for-bit the PR-2 warm
path), entity arrivals/departures, k changes, and re-stratification — plus
the acceptance bar: a 20%-churn warm re-solve takes no more iterations
than the cold control on all three paper domains.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import pop
from repro.core.plan import PopPlan, WarmStart, remap_warm, repair_plan
from repro.problems.cluster_scheduling import (GavelProblem,
                                               make_cluster_workload)
from repro.problems.load_balancing import (LoadBalanceProblem, ShardWorkload,
                                           make_shard_workload)
from repro.problems.traffic_engineering import (TrafficProblem, k_shortest_paths,
                                                make_demands, make_topology)

KW = dict(max_iters=8_000, tol_primal=1e-4, tol_gap=1e-4)


def _gavel(n=48, seed=0):
    wl = make_cluster_workload(n, num_workers=(24, 24, 24), seed=seed)
    return GavelProblem(wl, space_sharing=False)


def _churn_gavel(wl, frac, seed):
    """Replace ``frac`` of the jobs and jitter survivors' throughputs."""
    rng = np.random.default_rng(seed)
    n = wl.T.shape[0]
    n_out = int(frac * n)
    keep = np.arange(n)[n_out:]
    fresh = make_cluster_workload(n_out, num_workers=(24, 24, 24),
                                  seed=seed + 50)
    cat = lambda a, b: np.concatenate([a[keep], b])
    wl2 = dataclasses.replace(
        wl, T=cat(wl.T, fresh.T) * rng.uniform(0.98, 1.02, (n, 3)),
        w=cat(wl.w, fresh.w), z=cat(wl.z, fresh.z),
        interference=cat(wl.interference, fresh.interference),
        job_type=cat(wl.job_type, fresh.job_type))
    ids2 = np.concatenate([keep, 1_000 + np.arange(n_out)])
    return wl2, ids2


# ---------------------------------------------------------------------------
# pipeline staging
# ---------------------------------------------------------------------------

def test_pipeline_stages_match_pop_solve():
    """plan -> build -> solve -> reduce == pop_solve (same partition)."""
    prob = _gavel()
    p = pop.make_plan(prob, 4, strategy="stratified")
    ops = pop.build(prob, p)
    res = pop.solve(prob, p, ops, solver_kw=KW)
    alloc = pop.reduce(prob, p, ops, res)
    one_call = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW)
    np.testing.assert_allclose(alloc, one_call.alloc, rtol=1e-6)
    assert p.shapes is not None and p.shapes["x"][0] == 4
    # pop_solve(plan=) runs the given plan verbatim
    pinned = pop.pop_solve(prob, 4, plan=p, solver_kw=KW)
    np.testing.assert_array_equal(pinned.idx, p.idx)


def test_plan_reuse_on_stable_instance():
    """warm with an unchanged instance reuses the plan object itself."""
    prob = _gavel()
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW)
    rng = np.random.default_rng(0)
    wl2 = dataclasses.replace(prob.wl,
                              T=prob.wl.T * rng.uniform(0.98, 1.02,
                                                        prob.wl.T.shape))
    nxt = pop.pop_solve(GavelProblem(wl2), 4, warm=prev, solver_kw=KW)
    assert nxt.plan is prev.plan
    assert nxt.warm_stats["identity"] and nxt.warm_stats["warm_fraction"] == 1.0


# ---------------------------------------------------------------------------
# remap_warm: identity churn must be the PR-2 path bit-for-bit
# ---------------------------------------------------------------------------

def test_identity_remap_is_bit_for_bit():
    prob = _gavel()
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW)
    ops = pop.build(prob, prev.plan)
    ws = remap_warm(prev.plan, prev.plan, prev, ops=ops)
    assert ws.stats["identity"]
    np.testing.assert_array_equal(np.asarray(ws.x), prev.x)
    np.testing.assert_array_equal(np.asarray(ws.y), prev.y)
    assert bool(np.all(ws.mask))


def test_identity_churn_solve_matches_direct_warm():
    """pop_solve(warm=) on a stable instance == handing the raw (x, y) to
    the solve stage — the remap layer adds nothing on the identity path."""
    prob = _gavel()
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW)
    rng = np.random.default_rng(1)
    wl2 = dataclasses.replace(prob.wl,
                              T=prob.wl.T * rng.uniform(0.98, 1.02,
                                                        prob.wl.T.shape))
    prob2 = GavelProblem(wl2)
    via_pop = pop.pop_solve(prob2, 4, warm=prev, solver_kw=KW)
    ops = pop.build(prob2, prev.plan)
    direct = pop.solve(prob2, prev.plan, ops, solver_kw=KW,
                       warm=(prev.x, prev.y))
    np.testing.assert_array_equal(via_pop.iterations,
                                  np.asarray(direct.iterations))
    np.testing.assert_allclose(via_pop.x, np.asarray(direct.x), atol=1e-7)


# ---------------------------------------------------------------------------
# churn: arrivals, departures, k changes, re-stratification
# ---------------------------------------------------------------------------

def test_warm_across_arrival_and_departure():
    prob = _gavel()
    ids = np.arange(prob.n_entities)
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW,
                         entity_ids=ids)
    wl2, ids2 = _churn_gavel(prob.wl, 0.25, seed=3)
    res = pop.pop_solve(GavelProblem(wl2), 4, warm=prev, solver_kw=KW,
                        entity_ids=ids2)
    assert bool(res.converged.all())
    st = res.warm_stats
    assert not st["identity"]
    assert st["fresh"] == int(0.25 * prob.n_entities)
    assert st["dropped"] == int(0.25 * prob.n_entities)
    assert 0.7 < st["warm_fraction"] < 0.8
    # repaired plan: every surviving job kept its (lane, slot)
    old_pos = {int(e): (l, s) for l in range(4)
               for s, e in enumerate(prev.plan.entity_of_slot[l]) if e >= 0}
    new_plan = res.plan
    new_ids = new_plan.external_ids()
    kept = 0
    for l in range(4):
        for s, e in enumerate(new_plan.entity_of_slot[l]):
            if e >= 0 and new_ids[e] in old_pos:
                assert old_pos[new_ids[e]] == (l, s)
                kept += 1
    assert kept == st["matched"]


def test_warm_across_k_change_converges():
    prob = _gavel(n=64)
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW)
    res = pop.pop_solve(prob, 8, warm=prev, solver_kw=KW)
    assert res.idx.shape[0] == 8
    assert bool(res.converged.all())
    assert res.warm_stats["warm_fraction"] == 1.0
    # and back down
    res2 = pop.pop_solve(prob, 2, warm=res, solver_kw=KW)
    assert res2.idx.shape[0] == 2
    assert bool(res2.converged.all())


def test_warm_with_replan_restratifies():
    prob = _gavel()
    prev = pop.pop_solve(prob, 4, strategy="random", seed=0, solver_kw=KW)
    res = pop.pop_solve(prob, 4, strategy="random", seed=9, warm=prev,
                        replan=True, solver_kw=KW)
    assert not np.array_equal(res.idx, prev.idx)       # genuinely re-planned
    assert bool(res.converged.all())                   # warm still total


def test_warm_with_mismatched_id_spaces_degrades_to_cold():
    """warm built WITH entity_ids + re-solve WITHOUT them (or vice versa)
    must not pair entities by numeric coincidence — it starts cold."""
    prob = _gavel()
    ids = 100 + np.arange(prob.n_entities)
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW,
                         entity_ids=ids)
    res = pop.pop_solve(prob, 4, warm=prev, solver_kw=KW)   # no entity_ids
    assert bool(res.converged.all())
    assert res.warm_stats["warm_fraction"] == 0.0
    assert "id spaces differ" in res.warm_stats["reason"]


def test_warm_without_layout_degrades_to_cold():
    """Problems without sub_layout must not raise across churn."""
    prob = _gavel()
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW)
    prev.plan = dataclasses.replace(prev.plan, layout=None)
    res = pop.pop_solve(prob, 8, warm=prev, solver_kw=KW)   # k change + no layout
    assert bool(res.converged.all())
    assert res.warm_stats["warm_fraction"] == 0.0


# ---------------------------------------------------------------------------
# acceptance: 20% churn warm <= cold on all three domains
# ---------------------------------------------------------------------------

def test_churn20_warm_le_cold_cluster():
    prob = _gavel(n=64, seed=0)
    ids = np.arange(64)
    prev = pop.pop_solve(prob, 4, strategy="stratified", solver_kw=KW,
                         entity_ids=ids)
    wl2, ids2 = _churn_gavel(prob.wl, 0.2, seed=11)
    prob2 = GavelProblem(wl2)
    warm = pop.pop_solve(prob2, 4, warm=prev, solver_kw=KW, entity_ids=ids2)
    cold = pop.pop_solve(prob2, 4, plan=warm.plan, solver_kw=KW)  # same plan
    assert bool(warm.converged.all())
    assert warm.iterations.sum() <= cold.iterations.sum()


def test_churn20_warm_le_cold_traffic():
    topo = make_topology(n_nodes=40, target_edges=90, seed=0)
    pairs, size = make_demands(topo, 200, seed=0)
    paths = k_shortest_paths(topo, pairs, n_paths=3, max_len=20, seed=0)
    sel = np.arange(128)
    prob = TrafficProblem(topo, pairs[sel], size[sel], paths[sel])
    prev = pop.pop_solve(prob, 4, strategy="random", solver_kw=KW,
                         entity_ids=sel)
    rng = np.random.default_rng(2)
    keep = sel[26:]
    newcomers = 128 + np.arange(26)
    sel2 = np.concatenate([keep, newcomers])
    prob2 = TrafficProblem(topo, pairs[sel2],
                           size[sel2] * rng.uniform(0.97, 1.03, 128),
                           paths[sel2])
    warm = pop.pop_solve(prob2, 4, warm=prev, solver_kw=KW, entity_ids=sel2)
    cold = pop.pop_solve(prob2, 4, plan=warm.plan, solver_kw=KW)
    assert bool(warm.converged.all())
    assert warm.iterations.sum() <= cold.iterations.sum()


def test_churn20_warm_le_cold_load_balancing():
    wl = make_shard_workload(128, 16, seed=0)
    wl = dataclasses.replace(wl, ids=np.arange(128))
    kw = dict(max_iters=12_000, tol_primal=1e-4, tol_gap=1e-4)
    prev = LoadBalanceProblem(wl).pop_solve(4, solver_kw=kw)
    rng = np.random.default_rng(4)
    pool = make_shard_workload(256, 16, seed=9)
    keep = np.sort(rng.choice(128, 102, replace=False))
    new = rng.choice(256, 26, replace=False)
    wl2 = ShardWorkload(
        load=np.concatenate([wl.load[keep], pool.load[new]])
             * rng.uniform(0.97, 1.03, 128),
        mem=np.concatenate([wl.mem[keep], pool.mem[new]]),
        placement=np.concatenate([prev.placement[keep],
                                  rng.integers(0, 16, 26)]),
        cap=wl.cap, eps_frac=wl.eps_frac,
        ids=np.concatenate([keep, 1_000 + new]))
    prob2 = LoadBalanceProblem(wl2)
    # cold control shares the grouping (warm minus the warm start)
    cold = prob2.pop_solve(4, solver_kw=kw, warm=prev, warm_start=False)
    warm = prob2.pop_solve(4, solver_kw=kw, warm=prev)
    assert warm.extra["warm_fraction"] == pytest.approx(102 / 128)
    assert warm.extra["iterations"] <= cold.extra["iterations"]


# ---------------------------------------------------------------------------
# repair_plan invariants + warm_mask semantics
# ---------------------------------------------------------------------------

def test_repair_plan_departure_only_shrinks_slots():
    prob = _gavel(n=40)
    ids = np.arange(40)
    p = pop.make_plan(prob, 4, strategy="stratified", entity_ids=ids)
    wl2 = dataclasses.replace(prob.wl, T=prob.wl.T[:24], w=prob.wl.w[:24],
                              z=prob.wl.z[:24],
                              interference=prob.wl.interference[:24],
                              job_type=prob.wl.job_type[:24])
    p2 = repair_plan(p, GavelProblem(wl2), entity_ids=ids[:24])
    assert p2.k == 4
    assert p2.n_per <= p.n_per
    live = p2.entity_of_slot[p2.entity_of_slot >= 0]
    assert sorted(live.tolist()) == list(range(24))


def test_warm_mask_lane_starts_cold():
    """A masked-out lane must solve exactly like a cold lane."""
    prob = _gavel(n=32)
    p = pop.make_plan(prob, 2, strategy="stratified")
    ops = pop.build(prob, p)
    cold = pop.solve(prob, p, ops, solver_kw=KW)
    # garbage warm iterates, all lanes masked out -> identical to cold
    rng = np.random.default_rng(0)
    junk_x = rng.uniform(0, 1, np.asarray(ops.c).shape).astype(np.float32)
    junk_y = rng.uniform(0, 1, np.asarray(ops.q).shape).astype(np.float32)
    masked = pop.solve(prob, p, ops, solver_kw=KW,
                       warm=WarmStart(junk_x, junk_y,
                                      np.zeros(2, bool), {}))
    np.testing.assert_array_equal(np.asarray(cold.x), np.asarray(masked.x))
    np.testing.assert_array_equal(np.asarray(cold.iterations),
                                  np.asarray(masked.iterations))


def test_solve_stacked_warm_mask_matches_backend_blend():
    """pdhg.solve_stacked(warm_mask=) is the same per-lane cold blend that
    backends._resolve_warm applies to a WarmStart — pin the two
    implementations to each other (and to the cold solve)."""
    from repro.core import pdhg
    prob = _gavel(n=16)
    p = pop.make_plan(prob, 2, strategy="stratified")
    ops = pop.build(prob, p)
    kw = dict(max_iters=400, tol_primal=1e-4, tol_gap=1e-4)
    rng = np.random.default_rng(3)
    junk_x = rng.uniform(0, 1, np.asarray(ops.c).shape).astype(np.float32)
    junk_y = rng.uniform(0, 1, np.asarray(ops.q).shape).astype(np.float32)
    mask = np.array([False, False])
    cold = pdhg.solve_stacked(ops, engine="matvec", K_mv=prob.K_mv,
                              KT_mv=prob.KT_mv, **kw)
    via_solver = pdhg.solve_stacked(ops, engine="matvec", K_mv=prob.K_mv,
                                    KT_mv=prob.KT_mv, warm_x=junk_x,
                                    warm_y=junk_y, warm_mask=mask, **kw)
    # pin BOTH paths to the matvec engine: engine="auto" now resolves to
    # fused_structured for Gavel (index metadata is attached), and the bit
    # equality this test asserts is about warm-mask blending, not engines
    # (engine equivalence is tests/test_engine_conformance.py's job)
    via_backend = pop.solve(prob, p, ops, solver_kw=kw, engine="matvec",
                            warm=WarmStart(junk_x, junk_y, mask, {}))
    np.testing.assert_array_equal(np.asarray(cold.x),
                                  np.asarray(via_solver.x))
    np.testing.assert_array_equal(np.asarray(via_solver.x),
                                  np.asarray(via_backend.x))


def test_solve_full_engine_plumbing():
    """solve_full accepts engine=/backend= and matches the default path."""
    prob = _gavel(n=24)
    a1, r1, _, _ = pop.solve_full(prob, solver_kw=KW)
    a2, r2, _, _ = pop.solve_full(prob, solver_kw=KW, engine="matvec",
                                  backend="vmap")
    np.testing.assert_allclose(a1, a2, atol=1e-6)
    assert int(r1.iterations) == int(r2.iterations)
