"""Chaos suite for the fault-tolerant serving layer (docs/ROBUSTNESS.md).

Every injector in ``repro.analysis.faults`` must land the session on its
intended degradation-ladder rung: a finite allocation, the right
``Allocation.status``/``faults``, the right service counters — and zero
unhandled exceptions.  Run via ``make test-faults``.
"""

import numpy as np
import pytest

from repro.analysis import faults as fj
from repro.core import ExecConfig, SolveConfig
from repro.core import pop as pop_mod
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.service import PopService

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


def _traffic(n=24, seed=0, scale=1.0):
    topo = make_topology(20, 40, seed=seed)
    pairs, dem = make_demands(topo, n, seed=seed)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
    return TrafficProblem(topo, pairs, dem * scale, pe)


def _service(k=4):
    return PopService(solve=SolveConfig(k=k), exec=ExecConfig(solver_kw=KW))


def _warmed(svc, tenant="t", steps=2):
    inst = _traffic()
    sess = svc.session(tenant, inst)
    sess.step(inst)
    for i in range(1, steps):
        sess.step(_traffic(scale=1.0 + 0.1 * i))
    return sess


# ---------------------------------------------------------------------------
# divergence quarantine
# ---------------------------------------------------------------------------

class TestDivergenceQuarantine:
    def test_poisoned_lane_recovers(self):
        svc = _service()
        sess = _warmed(svc)
        fj.poison_warm(sess, lanes=[1])
        alloc = sess.step(_traffic(scale=1.3))
        assert alloc.status == "recovered"
        assert any(f.startswith("divergence:") for f in alloc.faults)
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()
        s = svc.stats()
        assert s["recovered_steps"] == 1
        assert s["quarantined_lanes"] >= 1
        assert s["faults"] >= 1

    def test_healthy_lanes_keep_iterates(self):
        svc = _service()
        sess = _warmed(svc)
        fj.poison_warm(sess, lanes=[0])
        alloc = sess.step(_traffic(scale=1.3))
        # the retry kept the plan and the surviving lanes' iterates
        ws = alloc.raw.warm_stats
        assert ws is not None and ws["quarantined_lanes"] == 1
        assert 0.0 < ws["warm_fraction"] < 1.0

    def test_next_step_is_clean(self):
        svc = _service()
        sess = _warmed(svc)
        fj.poison_warm(sess, lanes=[1])
        sess.step(_traffic(scale=1.3))
        after = sess.step(_traffic(scale=1.35))
        assert after.status == "ok" and after.faults == ()

    def test_all_lanes_poisoned_still_finite(self):
        svc = _service()
        sess = _warmed(svc)
        fj.poison_warm(sess, lanes=list(range(4)))
        alloc = sess.step(_traffic(scale=1.3))
        assert alloc.status == "recovered"
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()


class TestWarmStateDamage:
    def test_dropped_plan_flags_mismatch(self):
        svc = _service()
        sess = _warmed(svc)
        fj.drop_warm_plan(sess)
        alloc = sess.step(_traffic(scale=1.3))
        assert alloc.status == "recovered"
        assert "warm-state-mismatch" in alloc.faults
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()

    def test_mismatched_shapes_flag_mismatch(self):
        svc = _service()
        sess = _warmed(svc)
        fj.mismatch_warm(sess)
        alloc = sess.step(_traffic(scale=1.3))
        assert alloc.status == "recovered"
        assert "warm-state-mismatch" in alloc.faults

    def test_injectors_demand_warm_state(self):
        svc = _service()
        sess = svc.session("cold", domain="traffic")
        with pytest.raises(ValueError, match="warm state"):
            fj.poison_warm(sess)
        with pytest.raises(ValueError, match="warm state"):
            fj.drop_warm_plan(sess)


# ---------------------------------------------------------------------------
# deadline ladder
# ---------------------------------------------------------------------------

class TestDeadlineLadder:
    def test_unmeasured_rate_runs_full(self):
        svc = _service()
        inst = _traffic()
        sess = svc.session("t", inst)
        alloc = sess.step(inst, deadline_s=0.001)   # no rate model yet
        assert alloc.status == "ok" and alloc.faults == ()

    def test_inflated_rate_falls_back_within_deadline(self):
        svc = _service()
        sess = _warmed(svc)
        fj.inflate_rates(svc, factor=1e6)
        deadline = 0.5
        import time
        t0 = time.perf_counter()
        alloc = sess.step(_traffic(scale=1.3), deadline_s=deadline)
        wall = time.perf_counter() - t0
        assert alloc.status == "fallback"
        assert "deadline" in alloc.faults
        assert alloc.metrics["fallback_source"] == "previous-allocation"
        assert wall < 2 * deadline
        assert svc.stats()["fallback_steps"] == 1

    def test_tight_budget_degrades(self):
        svc = _service()
        sess = _warmed(svc)
        key = next(k for k in svc._rates if k[0] == "pop")
        svc._rates[key] = 2e-5
        svc._overheads[key] = 0.0
        alloc = sess.step(_traffic(scale=1.3), deadline_s=0.002)
        assert alloc.status == "degraded"
        assert any(f.startswith("deadline:") for f in alloc.faults)
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()
        assert svc.stats()["degraded_steps"] == 1

    def test_loose_deadline_is_clean(self):
        svc = _service()
        sess = _warmed(svc)
        alloc = sess.step(_traffic(scale=1.3), deadline_s=100.0)
        assert alloc.status == "ok" and alloc.faults == ()

    def test_fallback_without_history_uses_greedy(self):
        # rates are SERVICE-level: a fresh tenant with the same
        # (domain, config, shape) inherits the measurement, so its very
        # first deadline-bound step can land on the last rung — which must
        # come from the domain's greedy hook when there is no history
        from repro.domains import make_placement_instance
        svc = PopService(solve=SolveConfig(k=4),
                         exec=ExecConfig(solver_kw=KW))
        inst = make_placement_instance(32, 8, seed=0)
        warm = svc.session("a", inst)
        warm.step(inst)
        fj.inflate_rates(svc, factor=1e6)
        fresh = svc.session("b", domain="moe_placement")
        alloc = fresh.step(inst, deadline_s=0.5)
        assert alloc.status == "fallback"
        assert alloc.metrics["fallback_source"] == "greedy"
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()

    def test_no_history_no_greedy_raises(self):
        svc = _service()
        _warmed(svc, tenant="a")
        fj.inflate_rates(svc, factor=1e6)
        fresh = svc.session("b", domain="traffic")
        with pytest.raises(RuntimeError, match="no previous allocation"):
            fresh.step(_traffic(scale=1.3), deadline_s=0.5)


# ---------------------------------------------------------------------------
# input validation at the solve boundary
# ---------------------------------------------------------------------------

class TestNonFiniteRejection:
    def test_solve_instance_rejects_nan_demand(self):
        inst = _traffic()
        bad = TrafficProblem(inst.topo, inst.pairs,
                             np.where(np.arange(len(inst.demand)) == 3,
                                      np.nan, inst.demand),
                             inst.path_edges)
        with pytest.raises(ValueError, match="non-finite instance data"):
            pop_mod.solve_instance(bad, SolveConfig(k=4),
                                   ExecConfig(solver_kw=KW))

    def test_solve_full_ex_rejects_nan_demand(self):
        inst = _traffic()
        bad = TrafficProblem(inst.topo, inst.pairs,
                             np.where(np.arange(len(inst.demand)) == 3,
                                      np.inf, inst.demand),
                             inst.path_edges)
        with pytest.raises(ValueError, match="non-finite instance data"):
            pop_mod.solve_full_ex(bad, exec_cfg=ExecConfig(solver_kw=KW))

    def test_error_names_the_field(self):
        inst = _traffic()
        bad = TrafficProblem(inst.topo, inst.pairs,
                             np.full_like(inst.demand, np.nan),
                             inst.path_edges)
        with pytest.raises(ValueError, match="field"):
            pop_mod.solve_instance(bad, SolveConfig(k=4),
                                   ExecConfig(solver_kw=KW))


# ---------------------------------------------------------------------------
# seed() validation (warm-state type vs mode)
# ---------------------------------------------------------------------------

class TestSeedValidation:
    def test_unknown_mode_rejected(self):
        svc = _service()
        sess = svc.session("t", domain="traffic")
        with pytest.raises(ValueError, match="unknown mode"):
            sess.seed(object(), mode="warm")

    def test_pop_mode_needs_popresult(self):
        svc = _service()
        sess = _warmed(svc)
        full = pop_mod.solve_full_ex(_traffic(),
                                     exec_cfg=ExecConfig(solver_kw=KW))
        with pytest.raises(TypeError, match="needs a POPResult"):
            sess.seed(full, mode="pop")

    def test_full_mode_needs_solveresult(self):
        svc = _service()
        sess = _warmed(svc)
        res = sess._warm      # a POPResult
        with pytest.raises(TypeError, match="FullResult or SolveResult"):
            sess.seed(res, mode="full")

    def test_pop_mode_needs_iterates(self):
        svc = _service()
        sess = _warmed(svc)
        import dataclasses
        hollow = dataclasses.replace(sess._warm, x=None, y=None)
        with pytest.raises(ValueError, match="no solver"):
            sess.seed(hollow, mode="pop")


# ---------------------------------------------------------------------------
# the whole table, one sweep: no fault class crashes or emits non-finite data
# ---------------------------------------------------------------------------

class TestChaosSweep:
    @pytest.mark.parametrize("name", ["poison-warm", "drop-warm-plan",
                                      "mismatch-warm", "inflate-rates"])
    def test_session_faults_never_crash(self, name):
        svc = _service()
        sess = _warmed(svc)
        if name == "inflate-rates":
            fj.FAULTS[name](svc, 1e6)
            alloc = sess.step(_traffic(scale=1.3), deadline_s=0.5)
            assert alloc.status == "fallback"
        else:
            fj.FAULTS[name](sess)
            alloc = sess.step(_traffic(scale=1.3))
            assert alloc.status == "recovered"
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()
        assert alloc.faults
        s = svc.stats()
        assert s["faults"] >= 1
        assert s["recovered_steps"] + s["fallback_steps"] == 1

    @pytest.mark.parametrize("name", ["truncate-checkpoint",
                                      "corrupt-checkpoint"])
    def test_checkpoint_faults_degrade_to_cold(self, name):
        svc = _service()
        _warmed(svc)
        blob = svc.checkpoint()
        damaged = fj.FAULTS[name](blob)
        fresh = _service()
        report = fresh.restore(damaged)
        assert report["restored"] == []
        assert report["errors"]
        assert fresh.stats()["checkpoint_failures"] == 1
        # the service still serves — cold
        sess = fresh.session("t", domain="traffic")
        alloc = sess.step(_traffic())
        assert alloc.status == "ok"
        assert np.isfinite(np.asarray(alloc.alloc, float)).all()
