"""Back-compat shims: the legacy doors (``pop_solve``, ``GavelScheduler``,
``balance_requests``) must (a) warn DeprecationWarning and (b) produce
BIT-IDENTICAL allocations to the new single door
(``PopService.session(...).step(...)``) on all three paper domains — they
are thin forwarders, not parallel implementations."""

import warnings

import numpy as np
import pytest

from repro.core import ExecConfig, SolveConfig, pop
from repro.domains import BalanceInstance, GavelInstance
from repro.problems.cluster_scheduling import make_cluster_workload
from repro.problems.traffic_engineering import (TrafficProblem,
                                                k_shortest_paths,
                                                make_demands, make_topology)
from repro.serve.engine import balance_requests
from repro.service import PopService

KW = dict(max_iters=300, tol_primal=1e-5, tol_gap=1e-5)


def _traffic(n=24, seed=0):
    topo = make_topology(20, 40, seed=seed)
    pairs, dem = make_demands(topo, n, seed=seed)
    pe = k_shortest_paths(topo, pairs, n_paths=2, max_len=10, seed=seed)
    return TrafficProblem(topo, pairs, dem, pe)


# ---------------------------------------------------------------------------
# traffic: pop_solve(...) vs session.step(...)
# ---------------------------------------------------------------------------

def test_traffic_pop_solve_shim_bitident():
    prob = _traffic()
    with pytest.warns(DeprecationWarning, match="pop_solve"):
        old = pop.pop_solve(prob, 3, strategy="stratified", solver_kw=KW)
    sess = PopService().session(
        "t", prob, solve=SolveConfig(k=3, strategy="stratified"),
        exec=ExecConfig(solver_kw=KW))
    new = sess.step(prob)
    assert np.array_equal(old.alloc, new.alloc)
    # warm tick: hand-carried warm= vs session-internal chaining
    prob2 = TrafficProblem(prob.topo, prob.pairs, prob.demand * 1.03,
                           prob.path_edges)
    with pytest.warns(DeprecationWarning):
        old2 = pop.pop_solve(prob2, 3, strategy="stratified", solver_kw=KW,
                             warm=old)
    new2 = sess.step(prob2)
    assert np.array_equal(old2.alloc, new2.alloc)
    assert new2.plan_cache == "hit"
    assert old2.plan_source == "reused"


# ---------------------------------------------------------------------------
# gavel: GavelScheduler rounds vs hand-driven session steps
# ---------------------------------------------------------------------------

def test_gavel_scheduler_shim_bitident():
    from repro.sched.gavel_service import (GavelScheduler, JobSpec,
                                           SchedulerConfig)
    rng = np.random.default_rng(0)
    cfg = SchedulerConfig(pop_k=2, solver_kw=dict(KW))
    with pytest.warns(DeprecationWarning, match="GavelScheduler"):
        sched = GavelScheduler(cfg)
    for i in range(32):
        sched.submit(JobSpec(
            job_id=f"j{i}", arch="llama3_8b",
            priority=float(rng.choice([1.0, 2.0])),
            throughputs=np.abs(rng.normal([1.0, 0.6, 0.8], 0.2)) + 0.05))

    sess = PopService().session(
        "fleet", domain="gavel",
        solve=SolveConfig(k=2, strategy="stratified", min_per_sub=8),
        exec=ExecConfig(backend=cfg.map_backend, solver_kw=dict(KW)))

    # round 1 (cold), round 2 (drift, warm), round 3 (churn, repaired plan)
    for round_no in range(3):
        if round_no == 1:
            sched.report_throughput("j0", np.array([0.2, 0.1, 0.15]))
        if round_no == 2:
            sched.remove("j1")
            sched.submit(JobSpec(job_id="j99", arch="llama3_8b",
                                 throughputs=np.array([1.0, 0.5, 0.7])))
        alloc = sched.allocate()
        eids = np.array([sched._eids[j] for j in sched.jobs], np.int64)
        mine = sess.step(GavelInstance(sched._workload(), job_ids=eids))
        assert np.array_equal(np.stack([np.atleast_1d(v)
                                        for v in alloc.values()]).ravel(),
                              np.asarray(mine.alloc).ravel()), round_no
    assert sched.last_warm_fraction == mine.warm_fraction
    assert mine.plan_cache == "repair"          # round 3 churned the fleet


# ---------------------------------------------------------------------------
# load balancing: balance_requests ticks vs session steps
# ---------------------------------------------------------------------------

def test_balance_requests_shim_bitident():
    rng = np.random.default_rng(1)
    n, rep = 40, 6
    load = rng.uniform(1.0, 8.0, n)
    current = rng.integers(0, rep, n)
    gids = np.arange(n)

    sess = PopService().session(
        "bal", domain="load_balance", solve=SolveConfig(k=2),
        exec=ExecConfig(solver_kw=dict(max_iters=6_000)))

    with pytest.warns(DeprecationWarning, match="balance_requests"):
        old = balance_requests(load, rep, current, pop_k=2, eps_frac=0.25,
                               group_ids=gids)
    new = sess.step(BalanceInstance(load=load, n_targets=rep,
                                    current=current, eps_frac=0.25,
                                    ids=gids))
    assert np.array_equal(old.placement, new.alloc)
    assert new.backend is not None and new.backend != "auto"

    # churn tick: 5 groups finish, 5 arrive — warm survives via ids
    keep = np.arange(5, n)
    load2 = np.concatenate([load[keep] * 1.05, rng.uniform(1.0, 8.0, 5)])
    cur2 = np.concatenate([old.placement[keep], rng.integers(0, rep, 5)])
    gids2 = np.concatenate([gids[keep], n + np.arange(5)])
    with pytest.warns(DeprecationWarning):
        old2 = balance_requests(load2, rep, cur2, pop_k=2, eps_frac=0.25,
                                warm=old, group_ids=gids2)
    new2 = sess.step(BalanceInstance(load=load2, n_targets=rep,
                                     current=cur2, eps_frac=0.25, ids=gids2))
    assert np.array_equal(old2.placement, new2.alloc)
    assert old2.warm_fraction == new2.warm_fraction
    assert new2.warm_fraction is not None and new2.warm_fraction > 0.5
    assert new2.plan_cache == "repair"          # server grouping kept
