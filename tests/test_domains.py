"""Domain registry + the registry-only MoE placement domain.

The acceptance stakes: all four domains solve through the one session
door with zero domain branches in core/, and MoE placement — onboarded
through the registry alone — lands within 1.5% of its unpartitioned
solve_full objective at k>=4 while beating the greedy baseline."""

import dataclasses

import numpy as np
import pytest

from repro.core import ExecConfig, SolveConfig, pop
from repro.domains import (DomainSpec, GavelInstance, SpecProblem,
                           greedy_placement, make_placement_instance,
                           place_experts, register, registry)
from repro.domains.moe_placement import SPEC as MOE_SPEC, _evaluate
from repro.problems.cluster_scheduling import (GavelProblem,
                                               make_cluster_workload)
from repro.service import PopService

KW = dict(max_iters=250, tol_primal=1e-4, tol_gap=1e-4)


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtins_registered(self):
        assert registry.names() == ("gavel", "load_balance", "moe_placement",
                                    "traffic")
        for name in registry.names():
            assert registry.get(name).name == name

    def test_unknown_and_duplicate(self):
        with pytest.raises(KeyError, match="unknown domain"):
            registry.get("warp_drive")
        with pytest.raises(ValueError, match="already registered"):
            register(registry.get("gavel"))
        # replace=True is the sanctioned override (restore right after)
        register(registry.get("gavel"), replace=True)

    def test_spec_for_infers_from_type(self):
        inst = make_placement_instance(16, 4)
        assert registry.spec_for(inst).name == "moe_placement"
        assert registry.spec_for(object()) is None

    def test_declarative_spec_requires_hooks(self):
        with pytest.raises(ValueError, match="missing"):
            DomainSpec(name="hollow")
        # a problem factory alone is a complete spec
        DomainSpec(name="ok", problem=lambda inst: inst)


# ---------------------------------------------------------------------------
# registry-driven == classic pipeline (zero domain branches in core/)
# ---------------------------------------------------------------------------

def test_gavel_registry_matches_classic_pipeline():
    wl = make_cluster_workload(24, seed=0)
    prob = GavelProblem(wl)
    classic = pop.solve_instance(prob,
                                 SolveConfig(k=3, strategy="stratified"),
                                 ExecConfig(solver_kw=KW))
    sess = PopService().session(
        "g", GavelInstance(wl),
        solve=SolveConfig(k=3, strategy="stratified"),
        exec=ExecConfig(solver_kw=KW))
    via_registry = sess.step(GavelInstance(wl))
    assert np.array_equal(classic.alloc, np.asarray(via_registry.alloc))


def test_spec_problem_adapter_shares_matvec_identity():
    """SpecProblem must expose the SPEC's matvecs (one function object per
    domain) so every instance shares the jitted solver caches."""
    a = SpecProblem(MOE_SPEC, make_placement_instance(16, 4, seed=0))
    b = SpecProblem(MOE_SPEC, make_placement_instance(24, 4, seed=1))
    assert a.K_mv is b.K_mv and a.KT_mv is b.KT_mv
    assert a.n_entities == 16 and b.n_entities == 24
    assert a.entity_attrs().shape == (16, 2)
    assert a.entity_scores().shape == (16,)


# ---------------------------------------------------------------------------
# MoE placement: the acceptance row
# ---------------------------------------------------------------------------

class TestMoEPlacement:
    def test_pop_within_1p5pct_of_full_at_k4(self):
        inst = make_placement_instance(128, 8, seed=0)
        _, _, ev_full = place_experts(inst, solve_cfg=SolveConfig(k=1))
        for k in (4, 8):
            _, res, ev = place_experts(
                inst, solve_cfg=SolveConfig(k=k, strategy="stratified"))
            assert ev["objective"] >= 0.985 * ev_full["objective"], (k, ev)
            assert ev["mem_feasible"]
        assert res.engine == "matvec"       # the domain's preferred engine

    def test_pop_beats_greedy(self):
        inst = make_placement_instance(128, 8, seed=1)
        _, _, ev = place_experts(inst, solve_cfg=SolveConfig(k=4))
        ev_g = _evaluate(inst, greedy_placement(inst))
        assert ev["objective"] > ev_g["objective"]
        # greedy rebalances by moving nearly everything; POP serves the
        # same load while keeping most experts where they are
        assert ev["n_moved"] < 0.5 * ev_g["n_moved"]

    def test_session_warm_chain_with_expert_churn(self):
        svc = PopService()
        inst = make_placement_instance(64, 8, seed=2)
        inst.ids = np.arange(64)
        sess = svc.session("moe", inst, exec=ExecConfig(solver_kw=KW))
        a1 = sess.step(inst)
        assert a1.plan_cache == "miss" and a1.k == 4
        # drift only
        inst2 = dataclasses.replace(inst, load=inst.load * 1.03)
        a2 = sess.step(inst2)
        assert a2.plan_cache == "hit" and a2.warm_fraction == 1.0
        # 6 experts retired, 6 new ones: stable ids keep survivors warm
        keep = np.arange(6, 64)
        rng = np.random.default_rng(3)
        inst3 = dataclasses.replace(
            inst,
            load=np.concatenate([inst2.load[keep],
                                 rng.uniform(1, 4, 6)]),
            mem=np.concatenate([inst.mem[keep], rng.uniform(0.8, 1.2, 6)]),
            current=np.concatenate([a2.alloc[keep],
                                    rng.integers(0, 8, 6)]),
            ids=np.concatenate([inst.ids[keep], 100 + np.arange(6)]))
        a3 = sess.step(inst3)
        assert a3.plan_cache == "repair"
        assert 0.7 < a3.warm_fraction < 1.0

    def test_rounding_respects_memory(self):
        inst = make_placement_instance(48, 6, seed=4)
        inst.cap = np.full(6, 1.3 * inst.mem.sum() / 6)   # tight caps
        placement, _, ev = place_experts(
            inst, solve_cfg=SolveConfig(k=4),
            exec_cfg=ExecConfig(solver_kw=KW))
        assert ev["mem_feasible"]
        assert placement.shape == (48,)
        assert placement.min() >= 0 and placement.max() < 6

    def test_gate_load_feeds_demand_vector(self):
        import jax
        from repro.models.moe import expert_gate_load, init_moe
        rng = jax.random.PRNGKey(0)
        p = init_moe(rng, d=16, d_ff_expert=32, n_experts=8)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
        load = expert_gate_load(p, x, top_k=2)
        assert load.shape == (8,)
        assert load.min() >= 0
        # gate mass is normalised per (token, choice-set): sums to B*S
        np.testing.assert_allclose(load.sum(), 2 * 12, rtol=1e-4)
