"""Domain-problem tests: operator correctness (adjointness), POP quality
vs full solve, heuristic comparisons, feasibility of coalesced solutions."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import pop, skewed_partition
from repro.problems.cluster_scheduling import (
    GavelProblem, gandiva_heuristic, make_cluster_workload)
from repro.problems.traffic_engineering import (
    TrafficProblem, cspf_heuristic, make_topology, make_demands,
    k_shortest_paths)
from repro.problems.load_balancing import (
    LoadBalanceProblem, estore_greedy, make_shard_workload)

SOLVER_KW = dict(max_iters=20_000, tol_primal=1e-4, tol_gap=1e-4)


# ---------------------------------------------------------------------------
# fixtures (module-scoped: building paths etc. is the slow part)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gavel():
    wl = make_cluster_workload(48, num_workers=(10, 10, 10), seed=3)
    return GavelProblem(wl, space_sharing=False)


@pytest.fixture(scope="module")
def gavel_ss():
    wl = make_cluster_workload(32, num_workers=(8, 8, 8), seed=4)
    return GavelProblem(wl, space_sharing=True)


@pytest.fixture(scope="module")
def traffic():
    # enough demands that links congest — the regime the paper targets
    # (under light load greedy CSPF is trivially near-optimal)
    topo = make_topology(n_nodes=60, target_edges=140, seed=0)
    pairs, dem = make_demands(topo, 1500, seed=1)
    pe = k_shortest_paths(topo, pairs, n_paths=3, max_len=24, seed=2)
    return TrafficProblem(topo, pairs, dem, pe)


# ---------------------------------------------------------------------------
# operator adjointness: <K x, y> == <x, K^T y>  (catches any index bug)
# ---------------------------------------------------------------------------

def _adjoint_check(problem, op, n_var, n_con, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n_var), jnp.float32)
    y = jnp.asarray(rng.normal(size=n_con), jnp.float32)
    lhs = float(jnp.dot(problem.K_mv(op.data, x), y))
    rhs = float(jnp.dot(x, problem.KT_mv(op.data, y)))
    assert abs(lhs - rhs) < 1e-2 * (1 + abs(lhs)), (lhs, rhs)


def test_gavel_operator_adjoint(gavel):
    op = gavel.build_full()
    _adjoint_check(gavel, op, op.c.shape[0], op.q.shape[0])


def test_gavel_ss_operator_adjoint(gavel_ss):
    op = gavel_ss.build_full()
    _adjoint_check(gavel_ss, op, op.c.shape[0], op.q.shape[0])


def test_traffic_operator_adjoint(traffic):
    op = traffic.build_full()
    _adjoint_check(traffic, op, op.c.shape[0], op.q.shape[0])


def test_lb_operator_adjoint():
    wl = make_shard_workload(64, 8, seed=0)
    prob = LoadBalanceProblem(wl)
    op = prob._relax_op(np.arange(64), np.arange(8), 64, 8)
    from repro.problems.load_balancing import _k_mv, _kt_mv
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=op.c.shape[0]), jnp.float32)
    y = jnp.asarray(rng.normal(size=op.q.shape[0]), jnp.float32)
    lhs = float(jnp.dot(_k_mv(op.data, x), y))
    rhs = float(jnp.dot(x, _kt_mv(op.data, y)))
    assert abs(lhs - rhs) < 1e-2 * (1 + abs(lhs))


# ---------------------------------------------------------------------------
# cluster scheduling
# ---------------------------------------------------------------------------

def test_gavel_pop_close_to_full(gavel):
    full, res, _, _ = pop.solve_full(gavel, solver_kw=SOLVER_KW)
    ev_full = gavel.evaluate(full)
    r = pop.pop_solve(gavel, 4, strategy="stratified", solver_kw=SOLVER_KW)
    ev_pop = gavel.evaluate(r.alloc)
    # paper: quasi-optimal (sub-problems here are small, allow 12%)
    assert ev_pop["mean_norm_throughput"] > 0.88 * ev_full["mean_norm_throughput"]
    assert ev_pop["min_norm_throughput"] > 0.80 * ev_full["min_norm_throughput"]


def test_gavel_beats_gandiva_on_fairness(gavel):
    full, _, _, _ = pop.solve_full(gavel, solver_kw=SOLVER_KW)
    rho_h = gandiva_heuristic(gavel.wl, space_sharing=False)
    assert (gavel.evaluate(full)["min_norm_throughput"]
            > 2.0 * gavel.evaluate(rho_h)["min_norm_throughput"])


def test_gavel_space_sharing_improves_throughput(gavel_ss):
    """Space sharing strictly enlarges the feasible set -> mean cannot drop."""
    wl = gavel_ss.wl
    base = GavelProblem(wl, space_sharing=False)
    f_base, _, _, _ = pop.solve_full(base, solver_kw=SOLVER_KW)
    f_ss, _, _, _ = pop.solve_full(gavel_ss, solver_kw=SOLVER_KW)
    assert (gavel_ss.evaluate(f_ss)["mean_norm_throughput"]
            >= 0.98 * base.evaluate(f_base)["mean_norm_throughput"])


def test_gavel_allocation_feasible(gavel):
    """Coalesced POP allocation satisfies the ORIGINAL worker constraints."""
    r = pop.pop_solve(gavel, 4, strategy="stratified", solver_kw=SOLVER_KW)
    # rho <= 1 per job (time feasibility implies this after scaling)
    assert (r.alloc <= 1.0 + 1e-3).all()


# ---------------------------------------------------------------------------
# traffic engineering
# ---------------------------------------------------------------------------

def test_traffic_pop_close_to_full_and_feasible(traffic):
    full, res, _, _ = pop.solve_full(traffic, solver_kw=SOLVER_KW)
    ev_full = traffic.evaluate(full)
    r = pop.pop_solve(traffic, 4, strategy="random", seed=0, solver_kw=SOLVER_KW)
    ev = traffic.evaluate(r.alloc)
    assert ev["total_flow"] > 0.85 * ev_full["total_flow"]
    assert ev["max_edge_util"] < 1.01      # concatenation stays feasible
    assert ev_full["max_edge_util"] < 1.01


def test_traffic_random_beats_skewed(traffic):
    """Paper Fig. 6: same-source (skewed) splits lose flow vs random."""
    k = 8
    r_rand = pop.pop_solve(traffic, k, strategy="random", solver_kw=SOLVER_KW)
    idx = skewed_partition(traffic.source_groups(), k)
    r_skew = pop.pop_solve(traffic, k, partition_idx=idx, solver_kw=SOLVER_KW)
    f_rand = traffic.evaluate(r_rand.alloc)["total_flow"]
    f_skew = traffic.evaluate(r_skew.alloc)["total_flow"]
    assert f_rand > f_skew


def test_traffic_pop_beats_cspf(traffic):
    r = pop.pop_solve(traffic, 4, strategy="random", solver_kw=SOLVER_KW)
    f_pop = traffic.evaluate(r.alloc)["total_flow"]
    f_cspf = traffic.evaluate(cspf_heuristic(traffic))["total_flow"]
    assert f_pop > 0.97 * f_cspf           # typically strictly better


def test_cspf_feasible(traffic):
    ev = traffic.evaluate(cspf_heuristic(traffic))
    assert ev["max_edge_util"] <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# load balancing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 2, 4])
def test_lb_full_and_pop_feasible(seed):
    wl = make_shard_workload(256, 16, seed=seed)
    prob = LoadBalanceProblem(wl)
    full = prob.solve_full(solver_kw=SOLVER_KW)
    assert full.feasible
    r = prob.pop_solve(4, solver_kw=SOLVER_KW)
    assert r.max_load_dev < 2.0 * wl.eps_frac   # near-window even when tight
    # POP movement within 2x of full (paper: near-optimal)
    assert r.movement < 2.0 * full.movement + 1e-9


def test_lb_beats_greedy_on_balance():
    wl = make_shard_workload(256, 16, seed=0)
    prob = LoadBalanceProblem(wl)
    full = prob.solve_full(solver_kw=SOLVER_KW)
    ev_g = prob.evaluate(estore_greedy(wl))
    assert full.max_load_dev < ev_g["max_load_dev"]


def test_lb_placement_valid():
    wl = make_shard_workload(128, 8, seed=1)
    prob = LoadBalanceProblem(wl)
    r = prob.pop_solve(2, solver_kw=SOLVER_KW)
    assert r.placement.shape == (128,)
    assert ((r.placement >= 0) & (r.placement < 8)).all()
