"""Cross-engine conformance matrix: the acceptance gate for the step-engine
substrate.

Every cell of (engine x map backend x paper domain) must produce the same
trajectory to 1e-5 on a FIXED iteration budget (tolerances 0 so no lane
terminates early — this compares trajectories, not "two different converged
points").  The three engines run the SAME mathematical operator through
three executions:

  * ``matvec``           — the domain's own K_mv/KT_mv callables, vmapped
  * ``fused_structured`` — the ELL index metadata the domain attaches
                           (``StructuredOperator``), via the batched
                           gather/segment-reduce kernels
  * ``fused``            — the densified K (``structured_to_dense``)
                           through the blocked matmul kernels

so a pass pins the index metadata against the domain callables AND against
an explicit dense materialisation, across every execution backend
(ragged/padded k included) and for warm-started runs.

Also home to the in-loop-KKT regression gate: ``kkt="inloop"`` (free
convergence checks from carried products) must match ``kkt="standalone"``
(fresh operator passes per check) BIT-level on the CPU/XLA path — proof
the carried products never drift through restarts, lane freezing, or warm
starts.
"""

import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _subproc import repro_env
from repro.core import backends as backends_mod
from repro.core import pdhg, pop
from repro.problems.cluster_scheduling import GavelProblem, make_cluster_workload
from repro.problems.load_balancing import (LoadBalanceProblem,
                                           make_shard_workload,
                                           _k_mv as lb_k_mv,
                                           _kt_mv as lb_kt_mv)
from repro.problems.traffic_engineering import (TrafficProblem, k_shortest_paths,
                                                make_demands, make_topology)

# fixed-budget settings: tol 0 => every lane runs max_iters exactly
FIXED_KW = dict(max_iters=120, check_every=40, tol_primal=0.0, tol_gap=0.0)

ENGINES = ("matvec", "fused", "fused_structured")
BACKENDS = sorted(backends_mod.MAP_BACKENDS)
DOMAINS = ("cluster", "traffic", "balance")


def _cluster_case():
    # 16 jobs over k=3 lanes: ragged slot padding (6/5/5)
    wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    p = pop.plan(prob, 3, strategy="stratified")
    return pop.build(prob, p), prob.K_mv, prob.KT_mv


def _traffic_case():
    topo = make_topology(24, 48, seed=1)
    pairs, dem = make_demands(topo, 14, seed=1)
    pe = k_shortest_paths(topo, pairs, n_paths=3, max_len=12, seed=1)
    prob = TrafficProblem(topo, pairs, dem, pe)
    p = pop.plan(prob, 3, strategy="stratified")
    return pop.build(prob, p), prob.K_mv, prob.KT_mv


def _balance_case():
    # the LB domain split: server groups, shards follow their server —
    # ragged shard counts per lane, padded to n_pad
    wl = make_shard_workload(18, 6, seed=2)
    prob = LoadBalanceProblem(wl)
    groups = [np.arange(6)[i::3] for i in range(3)]
    shard_sets = [np.flatnonzero(np.isin(wl.placement, g)) for g in groups]
    n_pad = max(len(s) for s in shard_sets)
    ops = pdhg.stack_ops([prob._relax_op(s, g, n_pad, 2, structured=True)
                          for s, g in zip(shard_sets, groups)])
    return ops, lb_k_mv, lb_kt_mv


_CASES = {"cluster": _cluster_case, "traffic": _traffic_case,
          "balance": _balance_case}


@pytest.fixture(scope="module")
def cells():
    """domain -> (structured ops, densified ops, K_mv, KT_mv, reference)."""
    out = {}
    for name, build in _CASES.items():
        ops, k_mv, kt_mv = build()
        assert ops.structured is not None, name
        dense = ops._replace(data=(pdhg.structured_to_dense(ops.structured),),
                             structured=None)
        ref = backends_mod.solve_map(ops, k_mv, kt_mv, FIXED_KW,
                                     backend="vmap", engine="matvec")
        out[name] = (ops, dense, k_mv, kt_mv, ref)
    return out


def _engine_inputs(cells, domain, engine):
    ops, dense, k_mv, kt_mv, ref = cells[domain]
    if engine == "fused":
        return dense, pdhg.dense_K_mv, pdhg.dense_KT_mv, ref
    return ops, k_mv, kt_mv, ref


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("domain", DOMAINS)
def test_conformance_matrix(domain, engine, backend, cells):
    """ISSUE acceptance: every engine x backend x domain cell agrees with
    the matvec/vmap reference to 1e-5 at a fixed budget.  chunked_vmap
    runs chunk=2 so k=3 exercises the ragged-k padding path."""
    ops, k_mv, kt_mv, ref = _engine_inputs(cells, domain, engine)
    opts = {"chunk": 2} if backend == "chunked_vmap" else {}
    r = backends_mod.solve_map(ops, k_mv, kt_mv, FIXED_KW,
                               backend=backend, engine=engine, **opts)
    np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r.y), np.asarray(ref.y),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(r.iterations),
                                  np.asarray(ref.iterations))


@pytest.mark.parametrize("domain", DOMAINS)
def test_conformance_warm_started(domain, cells):
    """Warm-started runs stay in conformance: every engine seeded with the
    same previous iterates produces the same (fixed-budget) trajectory."""
    ops, _, k_mv, kt_mv, _ = cells[domain]
    seed = backends_mod.solve_map(ops, k_mv, kt_mv,
                                  dict(FIXED_KW, max_iters=80),
                                  backend="vmap", engine="matvec")
    warm = (seed.x, seed.y)
    results = {}
    for engine in ENGINES:
        e_ops, e_km, e_ktm, _ = _engine_inputs(cells, domain, engine)
        results[engine] = backends_mod.solve_map(
            e_ops, e_km, e_ktm, FIXED_KW, backend="vmap", engine=engine,
            warm=warm)
    for engine in ("fused", "fused_structured"):
        np.testing.assert_allclose(np.asarray(results[engine].x),
                                   np.asarray(results["matvec"].x),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(results[engine].y),
                                   np.asarray(results["matvec"].y),
                                   rtol=1e-5, atol=1e-5)


def test_auto_picks_structured_when_metadata_present(cells):
    ops, _, _, _, _ = cells["cluster"]
    assert pdhg.select_engine(ops, GavelProblem.K_mv,
                              GavelProblem.KT_mv) == "fused_structured"
    bare = ops._replace(structured=None)
    assert pdhg.select_engine(bare, GavelProblem.K_mv,
                              GavelProblem.KT_mv) == "matvec"
    with pytest.raises(ValueError, match="fused_structured"):
        pdhg.resolve_engine("fused_structured", bare)


def test_conformance_multi_device_subprocess():
    """Ragged k on a real multi-device mesh: k=3 on a forced 4-device host
    pads to 4 lanes in shard_map/pmap; the structured engine must ride the
    padded batch unchanged (index arrays replicate like any other leaf)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        assert jax.device_count() == 4, jax.device_count()
        from repro.core import backends as backends_mod, pop
        from repro.problems.cluster_scheduling import (GavelProblem,
                                                       make_cluster_workload)
        wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
        prob = GavelProblem(wl, space_sharing=False)
        p = pop.plan(prob, 3, strategy="stratified")
        ops = pop.build(prob, p)
        kw = dict(max_iters=120, check_every=40, tol_primal=0.0, tol_gap=0.0)
        ref = backends_mod.solve_map(ops, prob.K_mv, prob.KT_mv, kw,
                                     backend="vmap", engine="matvec")
        for backend in ("shard_map", "pmap"):
            for engine in ("matvec", "fused_structured"):
                r = backends_mod.solve_map(ops, prob.K_mv, prob.KT_mv, kw,
                                           backend=backend, engine=engine)
                np.testing.assert_allclose(np.asarray(r.x), np.asarray(ref.x),
                                           rtol=1e-5, atol=1e-5)
        print("multi-device conformance ok")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600, env=repro_env())
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "multi-device conformance ok" in r.stdout


# ---------------------------------------------------------------------------
# in-loop KKT regression gate (ISSUE satellite): fused-KKT == standalone-KKT
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_inloop_kkt_matches_standalone_bitwise(engine, cells):
    """The in-loop KKT path (convergence checks from carried products, zero
    extra operator passes) must report the same residuals, iteration counts
    and restart points as the standalone reference (fresh K/K^T passes per
    check) — bit-level on the CPU/XLA path.  Real tolerances + small
    check_every so early termination, lane freezing and adaptive restarts
    are all exercised."""
    ops, k_mv, kt_mv, _ = _engine_inputs(cells, "cluster", engine)
    kw = dict(max_iters=2_000, check_every=20, tol_primal=1e-4, tol_gap=1e-4)
    r_in = pdhg.solve_stacked(ops, engine=engine, K_mv=k_mv, KT_mv=kt_mv,
                              kkt="inloop", **kw)
    r_ref = pdhg.solve_stacked(ops, engine=engine, K_mv=k_mv, KT_mv=kt_mv,
                               kkt="standalone", **kw)
    assert bool(np.asarray(r_in.converged).all())
    exact = jax.default_backend() != "tpu"
    cmp = (np.testing.assert_array_equal if exact
           else lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6,
                                                        atol=1e-6))
    cmp(np.asarray(r_in.x), np.asarray(r_ref.x))
    cmp(np.asarray(r_in.y), np.asarray(r_ref.y))
    cmp(np.asarray(r_in.primal_res), np.asarray(r_ref.primal_res))
    cmp(np.asarray(r_in.gap), np.asarray(r_ref.gap))
    np.testing.assert_array_equal(np.asarray(r_in.iterations),
                                  np.asarray(r_ref.iterations))
    np.testing.assert_array_equal(np.asarray(r_in.n_restarts),
                                  np.asarray(r_ref.n_restarts))


def test_inloop_kkt_warm_masked_bitwise(cells):
    """The carried-product bookkeeping survives masked warm starts (the
    churn path): in-loop == standalone bit-level there too."""
    ops, k_mv, kt_mv, ref = cells["cluster"][0], cells["cluster"][2], \
        cells["cluster"][3], cells["cluster"][4]
    rng = np.random.default_rng(0)
    wx = jnp.asarray(rng.uniform(0, 1, np.asarray(ops.c).shape), jnp.float32)
    wy = jnp.asarray(rng.uniform(0, 1, np.asarray(ops.q).shape), jnp.float32)
    mask = jnp.asarray([True, False, True])
    kw = dict(max_iters=1_000, check_every=20, tol_primal=1e-4, tol_gap=1e-4)
    r_in = pdhg.solve_stacked(ops, engine="fused_structured", warm_x=wx,
                              warm_y=wy, warm_mask=mask, kkt="inloop", **kw)
    r_ref = pdhg.solve_stacked(ops, engine="fused_structured", warm_x=wx,
                               warm_y=wy, warm_mask=mask, kkt="standalone",
                               **kw)
    if jax.default_backend() != "tpu":
        np.testing.assert_array_equal(np.asarray(r_in.x), np.asarray(r_ref.x))
        np.testing.assert_array_equal(np.asarray(r_in.primal_res),
                                      np.asarray(r_ref.primal_res))
    np.testing.assert_array_equal(np.asarray(r_in.iterations),
                                  np.asarray(r_ref.iterations))
    np.testing.assert_array_equal(np.asarray(r_in.n_restarts),
                                  np.asarray(r_ref.n_restarts))


def test_unknown_kkt_mode_rejected():
    ops, k_mv, kt_mv = _cluster_case()
    with pytest.raises(ValueError, match="kkt mode"):
        pdhg.solve_stacked(ops, engine="matvec", kkt="telepathy")


# ---------------------------------------------------------------------------
# observability: results must report the backend/engine that ACTUALLY ran
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("engine", ("matvec", "fused_structured"))
def test_reported_execution_matches_forced_cell(backend, engine):
    """Every forced (engine x backend) cell must come back on the
    POPResult verbatim — the resolution layer may not silently substitute."""
    wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    from repro.core.config import ExecConfig, SolveConfig
    opts = {"chunk": 2} if backend == "chunked_vmap" else {}
    res = pop.solve_instance(
        prob, SolveConfig(k=3, strategy="stratified"),
        ExecConfig(backend=backend, engine=engine,
                   solver_kw=FIXED_KW, backend_opts=opts))
    assert res.backend == backend
    assert res.engine == engine
    assert res.plan_source == "fresh"


def test_reported_execution_resolves_auto():
    """backend="auto"/engine="auto" must be REPORTED as the concrete
    resolution, never echoed back as "auto" — the observability gap this
    PR closes."""
    wl = make_cluster_workload(16, num_workers=(6, 6, 6), seed=3)
    prob = GavelProblem(wl, space_sharing=False)
    from repro.core.config import SolveConfig
    res = pop.solve_instance(prob, SolveConfig(k=3, strategy="stratified"))
    assert res.backend in backends_mod.MAP_BACKENDS
    assert res.engine in ("matvec", "fused", "fused_structured")
    # Gavel singleton combos carry StructuredOperator metadata -> auto
    # must pick the structured-fused engine (pinned by
    # test_auto_picks_structured_when_metadata_present at solve_map level)
    assert res.engine == "fused_structured"
    from repro.core.config import ExecConfig as _EC
    full = pop.solve_full_ex(prob, exec_cfg=_EC(solver_kw=dict(FIXED_KW)))
    assert full.backend in backends_mod.MAP_BACKENDS
    assert full.engine == "fused_structured"
